#!/usr/bin/env bash
# PR gate: tier-1 tests + a quick-mode Fig. 15 smoke so the edge-list/CSR
# crossover benchmark and the adaptive dispatcher run on every change.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== edgelist-vs-CSR smoke (quick mode) =="
python - <<'PY'
from benchmarks.bench_edgelist_vs_csr import run
run(quick=True)
PY

echo "== query sweeps: pushdown + chunk pipeline + GSQL parity (quick mode) =="
# writes the BENCH_queries.json snapshot: the pushdown sweep (chunks
# skipped, bytes decoded), the latency-scaled sequential-vs-pipelined sweep
# (wall times, speedup floor, overlap efficiency), and the GSQL-vs-builder
# parity sweep (both front ends bit-identical, parse+compile <= 5% of a
# cold execution).  All assert their results stay bit-identical to their
# baselines.
python - <<'PY'
from benchmarks.bench_queries import run
run(quick=True)
PY

echo "== epoch refresh: incremental advance vs full rebuild (quick mode) =="
# writes the BENCH_refresh.json snapshot: incremental epoch advance vs a
# full topology rebuild on a <=5% append, asserting the >=5x floor and
# bit-identical post-sync query results against a cold-started engine.
python - <<'PY'
from benchmarks.bench_refresh import run
run(quick=True)
PY

echo "== shared-scan serving: batched vs unbatched throughput (quick mode) =="
# writes the BENCH_serving.json snapshot: query_batch bit-parity vs solo
# runs, the shared-pass chunk-counter contract (same-parameter riders cost
# one solo run's chunks), and the closed-loop throughput floor — batched
# serving >= 2x unbatched at 16 concurrent clients on the same worker pool.
python - <<'PY'
from benchmarks.bench_serving import run
run(quick=True)
PY

echo "== point-lookup tier: fast path vs full engine (quick mode) =="
# writes the BENCH_lookup.json snapshot: bit-parity of the plan-cached
# lookup path against the full engine on green/yellow templates, then the
# warm-cache closed-loop p50 sweep asserting the >=10x speedup floor for
# green (point + single-hop) lookups.
python - <<'PY'
from benchmarks.bench_lookup import run
run(quick=True)
PY

echo "== chaos: success rate + p99 under seeded faults (quick mode) =="
# writes the BENCH_chaos.json snapshot: the seeded fault-rate sweep
# (transient + torn + spike on lake-table reads at 0/5/10%), asserting the
# 100% success floor, bit-parity of results against the fault-free run,
# and bounded p99 inflation.  The chaos test suite itself (fixed seeds)
# runs with the tier-1 tests below.
python - <<'PY'
from benchmarks.bench_chaos import run
run(quick=True)
PY

echo "== streaming ingestion: freshness SLO + oracle parity (quick mode) =="
# writes the BENCH_freshness.json snapshot: p50/p99 commit->queryable and
# ingest->queryable latency for a paced CDC stream under concurrent query
# load (bounded-p99 floor), row-for-row parity of the micro-batched lake
# against a batch-committed oracle (zero dropped/duplicated events), and
# the typed-backpressure-under-stall / heal-and-drain-exactly-once arc.
python - <<'PY'
from benchmarks.bench_freshness import run
run(quick=True)
PY

echo "== shard fabric: scatter-gather throughput + bit-parity (quick mode) =="
# writes the BENCH_shard.json snapshot: the 1/2/4-shard BI-suite sweep with
# cold caches under modeled lake latency, asserting every sharded result is
# bit-identical to the single engine (vset, accumulators, frames in global
# edge-id order) and the 4-shard fabric clears the >=1.5x suite-throughput
# floor.  The shard test suite itself runs with the tier-1 tests below.
python - <<'PY'
from benchmarks.bench_shard import run
run(quick=True)
PY

echo "== tier-1 tests (slow SPMD dry-runs deselected) =="
# test_archs_smoke / test_train_substrate and one misc test fail in this
# container for environment reasons (installed jax predates APIs the model
# stack uses: optimization_barrier differentiation, jax.sharding.AxisType).
# They are excluded here so the gate is green iff the graph engine is green;
# drop the exclusions once the jax toolchain is updated.
python -m pytest -x -q -m "not slow" \
    --ignore=tests/test_archs_smoke.py \
    --ignore=tests/test_train_substrate.py \
    --deselect tests/test_misc_coverage.py::test_make_elastic_mesh_single_device

echo "OK"
