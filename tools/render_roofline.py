"""Render the dry-run roofline table (markdown) from benchmarks/results/dryrun.

    PYTHONPATH=src python tools/render_roofline.py [--mesh pod]
"""

from __future__ import annotations

import argparse
import glob
import json
import os

DRYRUN = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                      "benchmarks", "results", "dryrun")


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def load(mesh: str | None = None) -> list[dict]:
    recs = []
    for p in sorted(glob.glob(os.path.join(DRYRUN, "*.json"))):
        with open(p) as f:
            r = json.load(f)
        if mesh and r.get("mesh") != mesh:
            continue
        recs.append(r)
    return recs


def render(mesh: str | None) -> str:
    rows = [
        "| arch | cell | mesh | compute | memory | collective | dominant | "
        "MODEL/HLO flops | roofline frac | GB/dev | fits |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in load(mesh):
        if r["status"] == "skipped":
            rows.append(
                f"| {r['arch']} | {r['cell']} | {r['mesh']} | — | — | — | "
                f"skipped | — | — | — | — |")
            continue
        if r["status"] != "ok":
            rows.append(
                f"| {r['arch']} | {r['cell']} | {r['mesh']} | ERROR | | | "
                f"{r.get('error','')[:60]} | | | | |")
            continue
        t = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['cell']} | {r['mesh']} "
            f"| {fmt_s(t['compute_s'])} | {fmt_s(t['memory_s'])} "
            f"| {fmt_s(t['collective_s'])} | **{t['dominant']}** "
            f"| {t['useful_flop_fraction']:.2f} "
            f"| {t['roofline_fraction']:.3f} "
            f"| {r['per_device_bytes']/1e9:.2f} "
            f"| {'Y' if r['fits_hbm'] else 'N'} |")
    return "\n".join(rows)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None)
    args = ap.parse_args()
    print(render(args.mesh))
