"""The consolidated typed-error surface of the repro package.

Every error the engine raises on purpose derives from :class:`ReproError`,
so callers embedding the engine can catch one base instead of hunting
per-module exception types::

    try:
        session.query("bi1", tag="Music")
    except repro.ReproError as e:
        ...   # any engine-originated failure: GSQL, timeout, serving, catalog

The concrete types keep their historical stdlib bases (``TimeoutError``,
``RuntimeError``) so pre-consolidation ``except`` clauses continue to match,
and the old defining modules (``repro.gsql.errors``, ``repro.core.plan``,
``repro.serving.server``, ``repro.core.catalog``) re-export them for one
release — import from here going forward.

This module is imported by the lowest layers of the package, so it must
stay dependency-free: stdlib only, nothing from ``repro.*``.
"""

from __future__ import annotations

from typing import Optional


class ReproError(Exception):
    """Base of every intentional error the repro engine raises."""


# ---------------------------------------------------------------------------
# GSQL front end (formerly repro/gsql/errors.py)
# ---------------------------------------------------------------------------

class GSQLError(ReproError):
    """Base of every GSQL front-end error, carrying a 1-based (line, col)
    source position when one is known.  Every failure a query text can
    produce is raised *before* any lake read."""

    def __init__(self, message: str, line: Optional[int] = None,
                 col: Optional[int] = None):
        self.line = line
        self.col = col
        if line is not None:
            message = f"{message} (line {line}, col {col})"
        super().__init__(message)


class GSQLSyntaxError(GSQLError):
    """Malformed query text (lexer/parser)."""


class GSQLCompileError(GSQLError):
    """Well-formed text that fails schema validation or parameter binding."""


# ---------------------------------------------------------------------------
# execution (formerly repro/core/plan.py)
# ---------------------------------------------------------------------------

class QueryTimeoutError(ReproError, TimeoutError):
    """``ExecOptions.timeout_s`` exceeded.

    Raised at *stage boundaries* — before each E/U/V/ACCUM stage read of a
    staged ``edge_scan``, before the reads of the legacy path and
    ``vertex_map``, and between hops/statements in the executor — so a
    timed-out query stops before issuing its next batch of lake reads
    rather than mid-decode.  The serving layer reports it as a typed
    per-request error without killing the worker.
    """


# ---------------------------------------------------------------------------
# serving (formerly repro/serving/server.py)
# ---------------------------------------------------------------------------

class ServerOverloadedError(ReproError, RuntimeError):
    """The bounded request queue is full — the server sheds the request
    instead of blocking the submitting client (backpressure surfaces at the
    edge, where the caller can retry, rather than as hidden queueing)."""


class TenantQuotaExceededError(ServerOverloadedError):
    """The submitting tenant already holds ``tenant_quota`` requests in
    flight — per-tenant admission control, so one hot tenant sheds onto
    itself instead of filling the shared queue."""


# ---------------------------------------------------------------------------
# streaming ingestion (repro/ingest, DESIGN.md §12)
# ---------------------------------------------------------------------------

class IngestBackpressureError(ReproError, RuntimeError):
    """The bounded ingest queue is full — the pipeline sheds the change
    event back to the producer instead of buffering without bound.  The
    ingestion analog of :class:`ServerOverloadedError`: backpressure
    surfaces typed at the edge (where the source can pause its tail or
    retry with backoff) rather than as silent memory growth while the
    committer is stalled."""


class DanglingEdgeError(ReproError, ValueError):
    """An edge upsert references an endpoint vertex the graph does not have
    (neither committed to the lake nor pending in the same micro-batch).
    Raised typed at admission — the producer edge — instead of silently
    accepting the row and relying on dangling-edge compaction to hide it
    from every query forever.  Carries the offending table/column/key."""

    def __init__(self, message: str, table: Optional[str] = None,
                 column: Optional[str] = None, key=None):
        self.table = table
        self.column = column
        self.key = key
        super().__init__(message)


# ---------------------------------------------------------------------------
# catalog (formerly repro/core/catalog.py)
# ---------------------------------------------------------------------------

class MissingTableError(ReproError, RuntimeError):
    """A schema-mapped table does not exist in the lake — a configuration
    error, never silently treated as 'no snapshots yet'."""


# ---------------------------------------------------------------------------
# lake I/O (DESIGN.md §11): the fault taxonomy the retry layer classifies
# ---------------------------------------------------------------------------

class LakeError(ReproError):
    """Base of every typed lake-I/O failure.

    Carries the object ``key`` involved and an ``attempt_trace`` (one line
    per failed attempt when the retry layer re-raises), so a surfaced error
    says *which* object failed and *what was tried* — callers never have to
    pattern-match stdlib exception text to find out.
    """

    def __init__(self, message: str, key: Optional[str] = None,
                 attempts: Optional[list] = None):
        self.key = key
        self.attempt_trace = list(attempts or [])
        if key is not None:
            message = f"{message} [key={key}]"
        if self.attempt_trace:
            message = (f"{message} (after {len(self.attempt_trace)} attempts: "
                       + " | ".join(self.attempt_trace) + ")")
        super().__init__(message)


class TransientLakeError(LakeError, ConnectionError):
    """A retryable store fault: throttled GET, connection reset, torn
    (short) read of an immutable object.  The retry policy's *only*
    retryable class — everything else fails fast."""


class MissingObjectError(LakeError, FileNotFoundError):
    """The requested key does not exist in the store (fatal — retrying
    cannot make an object appear).  Keeps ``FileNotFoundError`` as a base so
    pre-consolidation ``except`` clauses continue to match; raw
    ``FileNotFoundError``/``OSError`` never escape ``ObjectStore`` anymore."""


class LakeCorruptionError(LakeError, ValueError):
    """The object exists and was read in full, but its contents are not
    what the format promises (bad magic, undecodable footer/chunk).  Fatal:
    the bytes are durably wrong, a retry re-reads the same corruption."""


__all__ = [
    "ReproError",
    "GSQLError",
    "GSQLSyntaxError",
    "GSQLCompileError",
    "QueryTimeoutError",
    "ServerOverloadedError",
    "TenantQuotaExceededError",
    "IngestBackpressureError",
    "DanglingEdgeError",
    "MissingTableError",
    "LakeError",
    "TransientLakeError",
    "MissingObjectError",
    "LakeCorruptionError",
]
