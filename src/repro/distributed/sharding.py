"""Sharding rules per architecture family (DESIGN.md §5).

Production mesh: ``(data=16, model=16)`` per pod, with an outer ``pod`` axis
(pure data parallelism) for multi-pod.  Rules:

- **LM params** — FSDP over ``data`` + Megatron TP over ``model``: matmul
  weights shard (in_dim -> data, out_dim -> model) or transposed for the
  row-parallel projections; MoE expert stacks shard experts over ``model``
  (EP) and d_model over ``data``; vocab shards over ``model``.  Non-divisible
  head counts rely on GSPMD uneven-sharding padding (verified; DESIGN.md §5).
- **LM batch** — (B, S) over (pod, data).
- **KV caches** — batch over (pod, data), kv-heads over model (GQA); the MLA
  latent cache is head-less so it shards batch-only.
- **GNN** — nodes/edges/triplets shard over *all* mesh axes (file-based
  sharding, paper §4.1); small MLP params replicate.
- **RecSys** — embedding tables row-shard over ``model``; dense params
  replicate; batch shards over (pod, data).

Optimizer state inherits parameter specs (same tree structure).
"""

from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    """Data-parallel axes: ('pod', 'data') on multi-pod, ('data',) otherwise."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def all_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)


def named(mesh: Mesh, *spec) -> NamedSharding:
    return NamedSharding(mesh, P(*spec))


# ---------------------------------------------------------------------------
# LM parameter rules
# ---------------------------------------------------------------------------

_LM_RULES: list[tuple[str, tuple]] = [
    # (path-substring, spec for the param's own dims — layer axis prepended
    #  automatically for stacked layer params)
    ("embed", ("model", "data")),
    ("lm_head", ("data", "model")),
    ("ln_", (None,)),
    ("norm_ckv", (None,)),
    # attention
    ("attn/wq", ("data", "model")),
    ("attn/wk", ("data", "model")),
    ("attn/wv", ("data", "model")),
    ("attn/wo", ("model", "data")),
    ("attn/bq", ("model",)),
    ("attn/bk", ("model",)),
    ("attn/bv", ("model",)),
    ("attn/w_dkv", ("data", None)),
    ("attn/w_krope", ("data", None)),
    ("attn/w_uk", (None, "model")),
    ("attn/w_uv", (None, "model")),
    # dense FFN
    ("ffn/w_gate", ("data", "model")),
    ("ffn/w_up", ("data", "model")),
    ("ffn/w_down", ("model", "data")),
    # MoE
    ("moe/router", ("data", None)),
    ("moe/w_gate", ("model", "data", None)),
    ("moe/w_up", ("model", "data", None)),
    ("moe/w_down", ("model", None, "data")),
    ("moe/shared/w_gate", ("data", "model")),
    ("moe/shared/w_up", ("data", "model")),
    ("moe/shared/w_down", ("model", "data")),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def lm_param_spec(path, leaf) -> P:
    s = _path_str(path)
    in_layer_stack = s.startswith("layers/") or "/layers/" in s
    for pattern, spec in _LM_RULES:
        if pattern in s:
            spec = tuple(spec)
            if in_layer_stack:
                spec = (None,) + spec      # leading stacked-layer axis
            spec = spec[: leaf.ndim] if len(spec) > leaf.ndim else spec
            spec = spec + (None,) * (leaf.ndim - len(spec))
            return P(*spec)
    return P()  # replicate by default (norms, scalars)


def lm_param_shardings(mesh: Mesh, params) -> dict:
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: named(mesh, *lm_param_spec(path, leaf)), params
    )


def lm_state_shardings(mesh: Mesh, state) -> dict:
    p_sh = lm_param_shardings(mesh, state["params"])
    return {
        "params": p_sh,
        "opt": {
            "m": lm_param_shardings(mesh, state["opt"]["m"]),
            "v": lm_param_shardings(mesh, state["opt"]["v"]),
        },
        "step": named(mesh),
    }


def lm_batch_shardings(mesh: Mesh, batch) -> dict:
    dp = dp_axes(mesh)
    return jax.tree.map(
        lambda leaf: named(mesh, dp, *([None] * (leaf.ndim - 1))), batch
    )


def lm_cache_shardings(mesh: Mesh, caches, mla: bool) -> object:
    dp = dp_axes(mesh)
    if mla:
        # (L, B, S, C): batch over dp only
        return jax.tree.map(lambda _: named(mesh, None, dp, None, None), caches)

    # (L, B, S, Hk, Dh): batch over dp; kv heads over model when divisible,
    # else the head dim (flash-decoding-style Dh split) — input shardings
    # require divisibility, unlike internal constraints
    def spec(leaf):
        if leaf.ndim == 4:   # MLA int8 scale (L, B, S, 1) rides batch-only
            return named(mesh, None, dp, None, None)
        n_kv, d_head = leaf.shape[3], leaf.shape[4]
        m = mesh.shape["model"]
        if n_kv % m == 0:
            return named(mesh, None, dp, None, "model", None)
        if d_head % m == 0:
            return named(mesh, None, dp, None, None, "model")
        return named(mesh, None, dp, None, None, None)

    return jax.tree.map(spec, caches)


# ---------------------------------------------------------------------------
# GNN rules
# ---------------------------------------------------------------------------

def gnn_param_shardings(mesh: Mesh, params):
    return jax.tree.map(lambda _: named(mesh), params)  # replicate


def gnn_batch_shardings(mesh: Mesh, batch):
    ax = all_axes(mesh)
    world = int(np.prod([mesh.shape[a] for a in ax]))

    def spec(leaf):
        # node/edge/triplet arrays shard over every axis (file-based
        # sharding); small per-graph arrays (graph_mask, molecule targets)
        # replicate — input shardings require divisibility
        if leaf.ndim == 0 or leaf.shape[0] % world != 0:
            return named(mesh)
        return named(mesh, ax, *([None] * (leaf.ndim - 1)))

    return jax.tree.map(spec, batch)


def gnn_state_shardings(mesh: Mesh, state):
    return {
        "params": gnn_param_shardings(mesh, state["params"]),
        "opt": {
            "m": gnn_param_shardings(mesh, state["opt"]["m"]),
            "v": gnn_param_shardings(mesh, state["opt"]["v"]),
        },
        "step": named(mesh),
    }


# ---------------------------------------------------------------------------
# RecSys rules
# ---------------------------------------------------------------------------

def recsys_param_spec(path, leaf) -> P:
    s = _path_str(path)
    if s.startswith("embed") or s.startswith("linear"):
        return P("model", *([None] * (leaf.ndim - 1)))
    return P()


def recsys_param_shardings(mesh: Mesh, params):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: named(mesh, *recsys_param_spec(path, leaf)), params
    )


def recsys_batch_shardings(mesh: Mesh, batch):
    dp = dp_axes(mesh)
    return jax.tree.map(
        lambda leaf: named(mesh, dp, *([None] * (leaf.ndim - 1)))
        if leaf.ndim else named(mesh),
        batch,
    )


def recsys_state_shardings(mesh: Mesh, state):
    p_sh = recsys_param_shardings(mesh, state["params"])
    return {
        "params": p_sh,
        "opt": {
            "m": recsys_param_shardings(mesh, state["opt"]["m"]),
            "v": recsys_param_shardings(mesh, state["opt"]["v"]),
        },
        "step": named(mesh),
    }
