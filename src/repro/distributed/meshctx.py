"""Ambient mesh context for activation sharding constraints.

Model code calls ``constrain(x, "dp", None, "model", ...)`` with *role* names;
when a mesh context is active the roles resolve to actual mesh axes and a
``with_sharding_constraint`` is emitted; with no context it is a no-op (smoke
tests, single-device runs).  Roles:

- ``"dp"``    -> the data-parallel axes (("pod","data") on multi-pod meshes),
- ``"model"`` -> the tensor/expert-parallel axis,
- ``None``    -> unsharded dimension.

Without these constraints GSPMD replicates attention/FFN activations across
the idle model axis (measured 16x FLOP inflation on the 16x16 mesh — see
EXPERIMENTS.md §Perf), so they are part of the baseline parallelization, not
an optimization.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()


def current_mesh() -> Optional[Mesh]:
    return getattr(_STATE, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    prev = getattr(_STATE, "mesh", None)
    _STATE.mesh = mesh
    try:
        yield
    finally:
        _STATE.mesh = prev


def _resolve(mesh: Mesh, role):
    if role is None:
        return None
    if role == "dp":
        axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        return axes if axes else None
    if role == "fsdp":
        return "data" if "data" in mesh.axis_names else None
    if role == "model":
        return "model" if "model" in mesh.axis_names else None
    if role == "all":
        return tuple(mesh.axis_names)
    return role


def constrain(x: jax.Array, *roles):
    """Apply a sharding constraint by role names; no-op without a mesh."""
    mesh = current_mesh()
    if mesh is None:
        return x
    if len(roles) != x.ndim:
        raise ValueError(f"{len(roles)} roles for rank-{x.ndim} array")
    spec = P(*[_resolve(mesh, r) for r in roles])
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
