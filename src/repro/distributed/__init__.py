"""Distributed runtime: sharding rules, collectives, compression, fault
tolerance, and the elastic mesh helpers."""
