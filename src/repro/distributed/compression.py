"""Gradient compression with error feedback (DESIGN.md §6).

Int8 uniform quantization per-leaf with max-abs scaling, plus an error-
feedback residual so compression noise is unbiased across steps (1-bit
Adam / EF-SGD family).  Intended for the cross-pod gradient reduction where
links are scarce: quantize -> all-reduce int8 payload -> dequantize.  In
single-process runs the quantize/dequantize pair is applied to the gradient
tree (the all-reduce is implicit in data-parallel pjit), which preserves the
numerics the multi-pod deployment would see.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


class ErrorFeedbackCompressor:
    """Stateful gradient-tree compressor with error feedback.

    Usage: ``grads, self.residual = compressor(grads, residual)`` — the
    returned grads are the dequantized (what every pod would see after the
    compressed all-reduce); the residual carries the quantization error into
    the next step.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = enabled

    def init_residual(self, grads: Any) -> Any:
        return jax.tree.map(lambda g: jnp.zeros_like(g, dtype=jnp.float32), grads)

    def __call__(self, grads: Any, residual: Optional[Any] = None):
        if not self.enabled:
            return grads, residual

        def _one(g, r):
            g32 = g.astype(jnp.float32) + (0.0 if r is None else r)
            q, scale = quantize_int8(g32)
            deq = dequantize_int8(q, scale)
            return deq.astype(g.dtype), g32 - deq

        if residual is None:
            residual = self.init_residual(grads)
        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_r = treedef.flatten_up_to(residual)
        pairs = [_one(g, r) for g, r in zip(flat_g, flat_r)]
        new_g = treedef.unflatten([p[0] for p in pairs])
        new_r = treedef.unflatten([p[1] for p in pairs])
        return new_g, new_r


def compression_ratio(grads: Any) -> float:
    """Bytes saved by int8 vs the native dtype (for logging)."""
    native = sum(g.size * g.dtype.itemsize for g in jax.tree.leaves(grads))
    compressed = sum(g.size * 1 + 4 for g in jax.tree.leaves(grads))
    return native / max(compressed, 1)
