"""Fault tolerance: heartbeats, straggler detection, preemption handling
(DESIGN.md §6).

On a real cluster these hooks bind to the coordination service; here they are
fully functional in-process implementations driven by the training loop:

- ``HeartbeatRegistry`` — workers (threads/hosts) tick; a monitor flags
  workers whose last tick is older than the timeout (failure detection).
- ``StragglerDetector``  — per-step duration statistics; steps slower than
  ``threshold x median`` are flagged; the data pipeline responds by issuing
  backup fetches (see ``lakehouse.io_pool.fetch_with_backup``).
- ``PreemptionGuard``    — converts SIGTERM/SIGINT into a "save and exit
  cleanly at the next step boundary" flag (how TPU preemptions are handled).
"""

from __future__ import annotations

import signal
import statistics
import threading
import time
from typing import Optional


class HeartbeatRegistry:
    def __init__(self, timeout_s: float = 30.0):
        self.timeout_s = timeout_s
        self._last: dict[str, float] = {}
        self._lock = threading.Lock()

    def tick(self, worker: str) -> None:
        with self._lock:
            self._last[worker] = time.monotonic()

    def dead_workers(self) -> list[str]:
        now = time.monotonic()
        with self._lock:
            return [w for w, t in self._last.items() if now - t > self.timeout_s]

    def healthy(self) -> bool:
        return not self.dead_workers()


class StragglerDetector:
    def __init__(self, threshold: float = 2.0, window: int = 50):
        self.threshold = threshold
        self.window = window
        self._durations: list[float] = []
        self.flagged_steps: list[int] = []

    def record(self, step: int, duration_s: float) -> bool:
        """Record a step duration; returns True if it's a straggler step."""
        self._durations.append(duration_s)
        if len(self._durations) > self.window:
            self._durations.pop(0)
        if len(self._durations) < 5:
            return False
        med = statistics.median(self._durations)
        if duration_s > self.threshold * med:
            self.flagged_steps.append(step)
            return True
        return False

    @property
    def median_s(self) -> float:
        return statistics.median(self._durations) if self._durations else 0.0


class PreemptionGuard:
    """Turns termination signals into a clean save-and-exit request."""

    def __init__(self, install: bool = True):
        self.requested = threading.Event()
        self._installed = []
        if install:
            try:
                for sig in (signal.SIGTERM, signal.SIGINT):
                    prev = signal.signal(sig, self._handler)
                    self._installed.append((sig, prev))
            except ValueError:
                pass  # not on the main thread (tests)

    def _handler(self, _sig, _frame) -> None:
        self.requested.set()

    def request(self) -> None:  # programmatic preemption (tests, scheduler)
        self.requested.set()

    def should_stop(self) -> bool:
        return self.requested.is_set()

    def uninstall(self) -> None:
        for sig, prev in self._installed:
            signal.signal(sig, prev)
        self._installed.clear()
