"""The LogicalQuery IR — the declarative form every query takes before
compilation (DESIGN.md §8).

Pure data: this module imports nothing from ``repro.core`` (or the parser /
compiler), so both front ends can build it — the GSQL parser from text, and
``repro.core.query.Query.to_ir()`` from fluent-builder chains — without
import cycles.  Structural equality ignores source positions (``pos`` fields
compare as equal), which is what makes the round-trip property testable:

    builder -> IR -> render() -> parse() -> IR   must compare equal.

A query is a sequence of SELECT statements sharing one accumulator space
(BI5-style multi-stage queries: an early statement computes ``@deg``, a
later one filters its seed on it).  Each statement is a seed + linear hop
path, a WHERE conjunction whose conjuncts each bind to one alias, ACCUM
updates, and optional POST-ACCUM blocks (a post-hop aggregation seeded from
an already-matched alias — BI2's second aggregation, declaratively).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Union

_NOPOS = (0, 0)


def _pos_field():
    # source position for error messages; excluded from structural equality
    return dataclasses.field(default=_NOPOS, compare=False, repr=False)


# ---------------------------------------------------------------------------
# values
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Param:
    """A ``$name`` placeholder, bound at compile time."""

    name: str
    pos: tuple = _pos_field()


@dataclasses.dataclass(frozen=True)
class ColRef:
    """``alias.column`` or ``alias.@accum`` reference."""

    alias: str
    column: str
    is_accum: bool = False
    pos: tuple = _pos_field()

    def render(self) -> str:
        return f"{self.alias}.{'@' if self.is_accum else ''}{self.column}"


Value = Union[int, float, str, bool, Param]


def render_value(v) -> str:
    if isinstance(v, Param):
        return f"${v.name}"
    if isinstance(v, ColRef):
        return v.render()
    if isinstance(v, bool):
        return "true" if v else "false"
    if isinstance(v, str):
        # single quotes inside fall back to double-quote delimiters; the
        # grammar has no escape sequences (DESIGN.md §8)
        return f'"{v}"' if "'" in v else f"'{v}'"
    if isinstance(v, float):
        return repr(v)
    return str(v)


# ---------------------------------------------------------------------------
# conditions
# ---------------------------------------------------------------------------

CMP_OPS = ("==", "!=", ">", ">=", "<", "<=")


@dataclasses.dataclass(frozen=True)
class Cmp:
    """``ref op value`` comparison."""

    ref: ColRef
    op: str                       # one of CMP_OPS
    value: Value
    pos: tuple = _pos_field()

    def render(self) -> str:
        return f"{self.ref.render()} {self.op} {render_value(self.value)}"

    def refs(self):
        if isinstance(self.value, ColRef):
            return (self.ref, self.value)
        return (self.ref,)


@dataclasses.dataclass(frozen=True)
class InSet:
    """``ref IN (v1, v2, ...)`` membership."""

    ref: ColRef
    values: tuple
    pos: tuple = _pos_field()

    def render(self) -> str:
        return (f"{self.ref.render()} IN "
                f"({', '.join(render_value(v) for v in self.values)})")

    def refs(self):
        return (self.ref,)


@dataclasses.dataclass(frozen=True)
class OrCond:
    """Disjunction of simple conditions (all over one alias)."""

    items: tuple          # tuple[Cmp | InSet, ...]
    pos: tuple = _pos_field()

    def render(self) -> str:
        return "(" + " OR ".join(c.render() for c in self.items) + ")"

    def refs(self):
        return tuple(r for c in self.items for r in c.refs())


Cond = Union[Cmp, InSet, OrCond]


# ---------------------------------------------------------------------------
# accumulators
# ---------------------------------------------------------------------------

ACCUM_OPS = {"sum": "+=", "max": "MAX=", "min": "MIN=", "or": "OR="}


@dataclasses.dataclass(frozen=True)
class AccumStmt:
    """``alias.@name op= value`` — value is a literal, ``$param`` or a
    same-hop ``alias.column`` reference."""

    target: ColRef                # is_accum=True
    op: str                       # "sum" | "max" | "min" | "or"
    value: Union[Value, ColRef]
    pos: tuple = _pos_field()

    def render(self) -> str:
        return f"{self.target.render()} {ACCUM_OPS[self.op]} {render_value(self.value)}"


# ---------------------------------------------------------------------------
# pattern
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class VertexPat:
    """``Type:alias`` vertex pattern element."""

    vtype: str
    alias: str
    pos: tuple = _pos_field()

    def render(self) -> str:
        return f"{self.vtype}:{self.alias}"


@dataclasses.dataclass(frozen=True)
class HopPat:
    """One ``-(Edge:alias)-`` link.  ``direction`` is the engine's frontier
    orientation: ``out`` (``-(E)->``, frontier on the edge's src side),
    ``in`` (``<-(E)-``), or ``auto`` (plain ``-(E)-``, resolved from the
    schema at compile time; ambiguous for self-type edges)."""

    edge_type: str
    alias: Optional[str] = None
    direction: str = "auto"       # "out" | "in" | "auto"
    pos: tuple = _pos_field()

    def render(self) -> str:
        inner = self.edge_type if self.alias is None else f"{self.edge_type}:{self.alias}"
        if self.direction == "in":
            return f"<-({inner})-"
        if self.direction == "out":
            return f"-({inner})->"
        return f"-({inner})-"


@dataclasses.dataclass(frozen=True)
class PostAccumIR:
    """``POST-ACCUM src_alias -(Edge)- Type:t [WHERE ...] ACCUM ...`` — one
    extra aggregation hop seeded from an alias the main path already
    matched."""

    source_alias: str
    hop: HopPat
    target: VertexPat
    where: tuple = ()             # tuple[Cond, ...]
    accums: tuple = ()            # tuple[AccumStmt, ...]
    pos: tuple = _pos_field()

    def render(self) -> str:
        s = f"POST-ACCUM {self.source_alias} {self.hop.render()} {self.target.render()}"
        if self.where:
            s += " WHERE " + " AND ".join(c.render() for c in self.where)
        s += " ACCUM " + ", ".join(a.render() for a in self.accums)
        return s


@dataclasses.dataclass(frozen=True)
class StatementIR:
    """One SELECT statement: projection + seed/hop path + clauses."""

    select_alias: str
    vertices: tuple               # tuple[VertexPat, ...]  (len == hops + 1)
    hops: tuple = ()              # tuple[HopPat, ...]
    where: tuple = ()             # tuple[Cond, ...]  (top-level conjunction)
    accums: tuple = ()            # tuple[AccumStmt, ...]
    post: tuple = ()              # tuple[PostAccumIR, ...]
    pos: tuple = _pos_field()

    def render(self) -> str:
        path = [self.vertices[0].render()]
        for hop, v in zip(self.hops, self.vertices[1:]):
            path.append(hop.render())
            path.append(v.render())
        s = f"SELECT {self.select_alias} FROM " + " ".join(path)
        if self.where:
            s += "\nWHERE " + " AND ".join(c.render() for c in self.where)
        if self.accums:
            s += "\nACCUM " + ", ".join(a.render() for a in self.accums)
        for p in self.post:
            s += "\n" + p.render()
        return s


@dataclasses.dataclass(frozen=True)
class LogicalQuery:
    """A full query: one or more statements over a shared accumulator space."""

    statements: tuple             # tuple[StatementIR, ...]

    def render(self) -> str:
        """Canonical GSQL text of this IR (parses back to an equal IR)."""
        return ";\n\n".join(st.render() for st in self.statements)

    def param_names(self) -> set:
        """Every ``$name`` the query mentions (install-time contract)."""
        names: set = set()

        def walk_value(v):
            if isinstance(v, Param):
                names.add(v.name)

        for st in self.statements:
            conds = list(st.where)
            accums = list(st.accums)
            for p in st.post:
                conds += list(p.where)
                accums += list(p.accums)
            for c in conds:
                for item in (c.items if isinstance(c, OrCond) else (c,)):
                    if isinstance(item, Cmp):
                        walk_value(item.value)
                    else:
                        for v in item.values:
                            walk_value(v)
            for a in accums:
                walk_value(a.value)
        return names
