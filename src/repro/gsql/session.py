"""GraphSession — the unified query facade (DESIGN.md §8).

One object owns everything a caller used to wire by hand: engine lifetime,
epoch acquisition per query, per-session :class:`~repro.core.query.ExecOptions`
defaults (pushdown / pipeline / timeout instead of scattered ``run()``
kwargs), the parse-time validation catalog, and the registry of *installed*
queries — named, pre-validated GSQL texts the serving layer executes with
bound parameters (the paper's "install once, serve many" flow)::

    session = repro.connect(store, ldbc_graph_schema())
    session.install("bi1", \"\"\"
        SELECT p FROM Tag:t -(HasTag:e1)- Comment:c -(HasCreator:e2)- Person:p
        WHERE t.name == $tag AND e2.creationDate > $date
          AND p.gender == "Female"
        ACCUM p.@cnt += 1
    \"\"\")
    res = session.query("bi1", tag="Music", date=20100101)
    print(session.explain("bi1", tag="Music", date=20100101))

``query()`` accepts either an installed name or literal GSQL text.  Every
execution pins one epoch for the whole (possibly multi-statement) query and
resets the accumulators the query writes before running, so repeated calls
are deterministic (the raw builder path mutates accumulators cumulatively).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.lookup import (
    LookupPlan,
    RouteDecision,
    execute_lookup,
    neighbor_ids,
    point_get,
)
from repro.core.query import (
    CompiledQuery,
    ExecOptions,
    QueryResult,
    execute_compiled,
    execute_compiled_batch,
)
from repro.gsql import ir
from repro.gsql.compiler import (
    Catalog,
    compile_lookup,
    compile_query,
    explain_compiled,
    validate_query,
)
from repro.gsql.parser import parse


@dataclasses.dataclass
class InstalledQuery:
    """A named, parse-time-validated GSQL query.

    ``route`` is the install-time traffic-light verdict (DESIGN.md §10):
    green/yellow templates carry a ``lookup_plan`` and serve through the
    plan-cached fast path of :mod:`repro.core.lookup`; red templates run the
    full engine."""

    name: str
    text: str
    query_ir: ir.LogicalQuery
    param_names: frozenset
    route: Optional[RouteDecision] = None
    lookup_plan: Optional[LookupPlan] = None


class GraphSession:
    """The single public execution entry over one engine."""

    def __init__(self, engine, options: Optional[ExecOptions] = None,
                 own_engine: bool = False):
        self.engine = engine
        self.options = options or ExecOptions()
        self._own_engine = own_engine
        self._installed: dict[str, InstalledQuery] = {}
        self._catalog: Optional[Catalog] = None
        self._ingest = None

    # -- lifecycle --------------------------------------------------------------

    @classmethod
    def for_engine(cls, engine, options: Optional[ExecOptions] = None
                   ) -> "GraphSession":
        """The engine's cached session (created on first use) — what the BI
        wrappers and the server use so every caller shares one installed-query
        registry and one options default."""
        session = getattr(engine, "_gsql_session", None)
        if session is None:
            session = cls(engine, options)
            engine._gsql_session = session
        return session

    def close(self) -> None:
        if self._ingest is not None:
            self._ingest.close()
            self._ingest = None
        if self._own_engine:
            self.engine.close()

    def __enter__(self) -> "GraphSession":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- ingestion --------------------------------------------------------------

    def ingest(self, config=None):
        """The session's streaming-ingestion pipeline (DESIGN.md §12),
        started on first call and closed with the session::

            pipe = session.ingest()
            pipe.upsert("comments", {...row...})
            pipe.delete("persons", 4621)
            pipe.drain()          # force commit + epoch publish

        One pipeline per session/engine (the committer is the single writer
        per table); pass an :class:`~repro.ingest.IngestConfig` on the
        *first* call to tune cadence/queue depth."""
        if self._ingest is None:
            from repro.ingest import IngestPipeline
            self._ingest = IngestPipeline(self.engine, config).start()
        elif config is not None:
            raise ValueError("ingest() already started for this session — "
                             "config only applies on the first call")
        return self._ingest

    # -- catalog ----------------------------------------------------------------

    def catalog(self) -> Catalog:
        """The validation catalog (schema + lake-table column sets), built
        lazily and cached — table schemas are immutable in this lake."""
        if self._catalog is None:
            self._catalog = Catalog.from_engine(self.engine)
        return self._catalog

    # -- install ----------------------------------------------------------------

    def install(self, name: str, text: str) -> InstalledQuery:
        """Parse + schema-validate a query and register it under ``name``.

        Validation covers everything except parameter values (those bind per
        ``query()`` call), so a bad installed query fails here — at install
        time — never while serving.  Install also *classifies* the template
        (green/yellow/red, DESIGN.md §10) and compiles the fast-path
        :class:`~repro.core.lookup.LookupPlan` for the green/yellow tiers.

        Idempotent on identical text: re-installing the same name with the
        same query returns the existing registration (armed plan caches stay
        warm).  Different text replaces the registration and invalidates the
        current epoch's armed plan — the new plan object never matches the
        cached entry's identity, and we also drop the stale entry eagerly."""
        existing = self._installed.get(name)
        if existing is not None and existing.text == text:
            return existing
        query_ir = parse(text)
        param_names = frozenset(validate_query(query_ir, self.catalog()))
        route, plan = compile_lookup(query_ir, self.catalog(), name)
        iq = InstalledQuery(name=name, text=text, query_ir=query_ir,
                           param_names=param_names, route=route,
                           lookup_plan=plan)
        self._installed[name] = iq
        if existing is not None:
            self._drop_armed(name)
        return iq

    def _drop_armed(self, name: str) -> None:
        """Evict ``name``'s armed plan from the current epoch (re-install)."""
        mgr = getattr(self.engine, "epochs", None)
        epoch = mgr.current() if mgr is not None else None
        if epoch is not None and getattr(epoch, "lookup_plans", None) is not None:
            with epoch.lookup_lock:
                epoch.lookup_plans.pop(name, None)

    def installed_queries(self) -> dict[str, InstalledQuery]:
        return dict(self._installed)

    def installed(self, name: str) -> Optional[InstalledQuery]:
        """The registration for ``name``, or ``None`` (no copy — the serving
        layer consults this per request to route lookups)."""
        return self._installed.get(name)

    def is_installed(self, name: str) -> bool:
        return name in self._installed

    # -- execution --------------------------------------------------------------

    def _exec_engine(self):
        """The execution target: the shard fabric's scatter-gather executor
        when one is attached (DESIGN.md §13) — same engine surface, fanned
        out — else the engine itself.  Resolved per call so attaching a
        fabric mid-session takes effect immediately."""
        fabric = getattr(self.engine, "_shard_fabric", None)
        return fabric.executor if fabric is not None else self.engine

    def _resolve_ir(self, text_or_name: str) -> ir.LogicalQuery:
        iq = self._installed.get(text_or_name)
        if iq is not None:
            return iq.query_ir
        return parse(text_or_name)

    def _compile(self, text_or_name: str, params: dict) -> CompiledQuery:
        return compile_query(self._resolve_ir(text_or_name), self.catalog(),
                             params)

    def query(self, text_or_name: str, options: Optional[ExecOptions] = None,
              epoch=None, **params) -> QueryResult:
        """Execute an installed query (by name) or literal GSQL text.

        The session acquires one snapshot-pinned epoch for the whole query
        (pass ``epoch`` to time-travel onto an explicitly acquired one) and
        runs it against a *private* accumulator store sized to that epoch:
        results are a pure function of (text, params, epoch), concurrent
        server workers can never observe each other's partial accumulator
        state, and the arrays a result carries are never mutated by later
        queries.  ``options`` overrides the session defaults for this call
        only."""
        compiled = self._compile(text_or_name, params)
        res = execute_compiled(self._exec_engine(), compiled,
                               options=options or self.options, epoch=epoch,
                               private_accums=True)
        iq = self._installed.get(text_or_name)
        if iq is not None and iq.route is not None:
            res.tier = iq.route.tier    # route stays "full" — this IS the engine
        return res

    # -- the point-lookup tier (DESIGN.md §10) ----------------------------------

    def route_of(self, name: str) -> RouteDecision:
        """The install-time traffic-light verdict for an installed name."""
        return self._installed[name].route

    def lookup(self, name: str, options: Optional[ExecOptions] = None,
               epoch=None, **params) -> QueryResult:
        """Execute an installed template through the serving fast path.

        Green/yellow templates bypass the compiler and the staged scan
        entirely — IDM probe + CSR slice (+ single-chunk column fetch for
        yellow) against one pinned epoch — and return a
        :class:`~repro.core.query.QueryResult` bit-identical to ``query()``
        on the same epoch, stamped ``route="lookup"``.  Red templates fall
        through to the full engine (``route="full"``), so callers can use
        ``lookup()`` unconditionally."""
        iq = self._installed.get(name)
        if iq is None:
            raise KeyError(f"no installed query named {name!r}")
        if iq.lookup_plan is None:
            return self.query(name, options=options, epoch=epoch, **params)
        res = execute_lookup(self.engine, iq.lookup_plan, params, epoch=epoch)
        fabric = getattr(self.engine, "_shard_fabric", None)
        if fabric is not None:
            # in-process fabric: the coordinator serves the point read, the
            # route stats attribute it to the shard that owns the seed
            fabric.note_lookup()
        return res

    def get_vertex(self, vertex_type: str, vertex_id, columns=(),
                   epoch=None) -> Optional[dict]:
        """Point-read one vertex by primary key: IDM probe + (optionally)
        single-chunk column reads.  ``None`` when the id is unknown to the
        pinned epoch."""
        out = point_get(self.engine, vertex_type, vertex_id,
                        columns=columns, epoch=epoch)
        fabric = getattr(self.engine, "_shard_fabric", None)
        if fabric is not None:
            fabric.note_lookup(vertex_type, out.get("dense_id")
                               if out is not None else None)
        return out

    def neighbors(self, edge_type: str, vertex_id, direction: str = "out",
                  ids: str = "raw", epoch=None):
        """One vertex's neighbors over ``edge_type`` — a CSR adjacency slice
        against the pinned epoch, no scan, no compile.

        ``ids="raw"`` (default) returns primary-key ids (one single-chunk
        pk-column fetch); ``ids="dense"`` returns the engine's dense ids for
        free.  Unknown seed ids return an empty array."""
        mgr = getattr(self.engine, "epochs", None)
        acquired = None
        if epoch is None and mgr is not None:
            # one pin covers the slice and the pk fetch — they must not
            # straddle an advance()
            epoch = acquired = mgr.acquire()
        try:
            dense = neighbor_ids(self.engine, edge_type, vertex_id,
                                 direction=direction, epoch=epoch)
            if ids == "dense" or not len(dense):
                return dense
            from repro.core.primitives import read_vertex_values

            et = self.engine.schema.edge_types[edge_type]
            far_type = et.dst_type if direction == "out" else et.src_type
            pk = self.engine.schema.vertex_types[far_type].primary_key
            topo = epoch if epoch is not None else self.engine.topology
            return read_vertex_values(topo, self.engine.cache, far_type,
                                      dense, pk)
        finally:
            if acquired is not None:
                mgr.release(acquired)

    def query_batch(self, text_or_name: str, params_list: list,
                    options: Optional[ExecOptions] = None,
                    epoch=None) -> list[QueryResult]:
        """Execute one installed query (or literal text) for many parameter
        bindings as a *single shared-scan pass* (DESIGN.md §9).

        Each entry of ``params_list`` is one rider's parameter dict; the
        riders compile from the same template, pin one epoch together, and
        execute through
        :func:`~repro.core.query.execute_compiled_batch` — one gather per
        hop over the union frontier, one chunk fetch/decode pass per stage,
        per-rider masks — with each rider's result bit-identical to a solo
        ``query()`` call on that epoch.  The serving layer's batch scheduler
        is the intended caller; it groups concurrent same-template requests
        into one ``query_batch``."""
        compiled = [self._compile(text_or_name, p) for p in params_list]
        return execute_compiled_batch(self._exec_engine(), compiled,
                                      options=options or self.options,
                                      epoch=epoch)

    def explain(self, text_or_name: str, **params) -> str:
        """The compiled plan of a query: per hop, the staged column sets,
        compiled zone-map bounds and topology dispatch rule — without
        executing anything."""
        return explain_compiled(self._compile(text_or_name, params))


def connect(store, schema, options: Optional[ExecOptions] = None,
            shards: Optional[int] = None, shard_block_bits: Optional[int] = None,
            **engine_kwargs) -> GraphSession:
    """Open a :class:`GraphSession` over a lake: build the engine, run
    startup (first or second connection, paper §4.3), and hand back the
    session facade.  ``session.close()`` closes the engine it owns.

    ``shards=<n>`` (n >= 2) attaches a :class:`~repro.shard.ShardFabric`
    (DESIGN.md §13): every query the session runs executes as
    coordinator-merged scatter-gather across ``n`` vertex-hash shard
    workers, bit-identical to the single-engine run.  Left ``None``, the
    width comes from the ``shards`` perf flag (``shards=<n>``, default 0 =
    no fabric); ``shard_block_bits`` tunes the ownership block granularity.

    ``engine_kwargs`` pass through to
    :class:`~repro.core.engine.GraphLakeEngine` (``cache_config``,
    ``n_io_threads``, ``materialize_topology``, ...).
    """
    from repro import perf_flags
    from repro.core.engine import GraphLakeEngine

    engine = GraphLakeEngine(store, schema, **engine_kwargs)
    engine.startup()
    n = int(perf_flags.value("shards", 0)) if shards is None else int(shards)
    if n >= 2:
        from repro.shard import ShardFabric

        kwargs = {} if shard_block_bits is None else {
            "block_bits": shard_block_bits}
        ShardFabric.attach(engine, n, **kwargs)
    session = GraphSession(engine, options, own_engine=True)
    engine._gsql_session = session
    return session


# re-exported for convenience: sessions and options travel together
__all__ = ["GraphSession", "InstalledQuery", "ExecOptions", "connect"]
