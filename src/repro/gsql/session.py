"""GraphSession — the unified query facade (DESIGN.md §8).

One object owns everything a caller used to wire by hand: engine lifetime,
epoch acquisition per query, per-session :class:`~repro.core.query.ExecOptions`
defaults (pushdown / pipeline / timeout instead of scattered ``run()``
kwargs), the parse-time validation catalog, and the registry of *installed*
queries — named, pre-validated GSQL texts the serving layer executes with
bound parameters (the paper's "install once, serve many" flow)::

    session = repro.connect(store, ldbc_graph_schema())
    session.install("bi1", \"\"\"
        SELECT p FROM Tag:t -(HasTag:e1)- Comment:c -(HasCreator:e2)- Person:p
        WHERE t.name == $tag AND e2.creationDate > $date
          AND p.gender == "Female"
        ACCUM p.@cnt += 1
    \"\"\")
    res = session.query("bi1", tag="Music", date=20100101)
    print(session.explain("bi1", tag="Music", date=20100101))

``query()`` accepts either an installed name or literal GSQL text.  Every
execution pins one epoch for the whole (possibly multi-statement) query and
resets the accumulators the query writes before running, so repeated calls
are deterministic (the raw builder path mutates accumulators cumulatively).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.query import (
    CompiledQuery,
    ExecOptions,
    QueryResult,
    execute_compiled,
    execute_compiled_batch,
)
from repro.gsql import ir
from repro.gsql.compiler import Catalog, compile_query, explain_compiled, validate_query
from repro.gsql.parser import parse


@dataclasses.dataclass
class InstalledQuery:
    """A named, parse-time-validated GSQL query."""

    name: str
    text: str
    query_ir: ir.LogicalQuery
    param_names: frozenset


class GraphSession:
    """The single public execution entry over one engine."""

    def __init__(self, engine, options: Optional[ExecOptions] = None,
                 own_engine: bool = False):
        self.engine = engine
        self.options = options or ExecOptions()
        self._own_engine = own_engine
        self._installed: dict[str, InstalledQuery] = {}
        self._catalog: Optional[Catalog] = None

    # -- lifecycle --------------------------------------------------------------

    @classmethod
    def for_engine(cls, engine, options: Optional[ExecOptions] = None
                   ) -> "GraphSession":
        """The engine's cached session (created on first use) — what the BI
        wrappers and the server use so every caller shares one installed-query
        registry and one options default."""
        session = getattr(engine, "_gsql_session", None)
        if session is None:
            session = cls(engine, options)
            engine._gsql_session = session
        return session

    def close(self) -> None:
        if self._own_engine:
            self.engine.close()

    def __enter__(self) -> "GraphSession":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- catalog ----------------------------------------------------------------

    def catalog(self) -> Catalog:
        """The validation catalog (schema + lake-table column sets), built
        lazily and cached — table schemas are immutable in this lake."""
        if self._catalog is None:
            self._catalog = Catalog.from_engine(self.engine)
        return self._catalog

    # -- install ----------------------------------------------------------------

    def install(self, name: str, text: str) -> InstalledQuery:
        """Parse + schema-validate a query and register it under ``name``.

        Validation covers everything except parameter values (those bind per
        ``query()`` call), so a bad installed query fails here — at install
        time — never while serving."""
        query_ir = parse(text)
        param_names = frozenset(validate_query(query_ir, self.catalog()))
        iq = InstalledQuery(name=name, text=text, query_ir=query_ir,
                           param_names=param_names)
        self._installed[name] = iq
        return iq

    def installed_queries(self) -> dict[str, InstalledQuery]:
        return dict(self._installed)

    def is_installed(self, name: str) -> bool:
        return name in self._installed

    # -- execution --------------------------------------------------------------

    def _resolve_ir(self, text_or_name: str) -> ir.LogicalQuery:
        iq = self._installed.get(text_or_name)
        if iq is not None:
            return iq.query_ir
        return parse(text_or_name)

    def _compile(self, text_or_name: str, params: dict) -> CompiledQuery:
        return compile_query(self._resolve_ir(text_or_name), self.catalog(),
                             params)

    def query(self, text_or_name: str, options: Optional[ExecOptions] = None,
              epoch=None, **params) -> QueryResult:
        """Execute an installed query (by name) or literal GSQL text.

        The session acquires one snapshot-pinned epoch for the whole query
        (pass ``epoch`` to time-travel onto an explicitly acquired one) and
        runs it against a *private* accumulator store sized to that epoch:
        results are a pure function of (text, params, epoch), concurrent
        server workers can never observe each other's partial accumulator
        state, and the arrays a result carries are never mutated by later
        queries.  ``options`` overrides the session defaults for this call
        only."""
        compiled = self._compile(text_or_name, params)
        return execute_compiled(self.engine, compiled,
                                options=options or self.options, epoch=epoch,
                                private_accums=True)

    def query_batch(self, text_or_name: str, params_list: list,
                    options: Optional[ExecOptions] = None,
                    epoch=None) -> list[QueryResult]:
        """Execute one installed query (or literal text) for many parameter
        bindings as a *single shared-scan pass* (DESIGN.md §9).

        Each entry of ``params_list`` is one rider's parameter dict; the
        riders compile from the same template, pin one epoch together, and
        execute through
        :func:`~repro.core.query.execute_compiled_batch` — one gather per
        hop over the union frontier, one chunk fetch/decode pass per stage,
        per-rider masks — with each rider's result bit-identical to a solo
        ``query()`` call on that epoch.  The serving layer's batch scheduler
        is the intended caller; it groups concurrent same-template requests
        into one ``query_batch``."""
        compiled = [self._compile(text_or_name, p) for p in params_list]
        return execute_compiled_batch(self.engine, compiled,
                                      options=options or self.options,
                                      epoch=epoch)

    def explain(self, text_or_name: str, **params) -> str:
        """The compiled plan of a query: per hop, the staged column sets,
        compiled zone-map bounds and topology dispatch rule — without
        executing anything."""
        return explain_compiled(self._compile(text_or_name, params))


def connect(store, schema, options: Optional[ExecOptions] = None,
            **engine_kwargs) -> GraphSession:
    """Open a :class:`GraphSession` over a lake: build the engine, run
    startup (first or second connection, paper §4.3), and hand back the
    session facade.  ``session.close()`` closes the engine it owns.

    ``engine_kwargs`` pass through to
    :class:`~repro.core.engine.GraphLakeEngine` (``cache_config``,
    ``n_io_threads``, ``materialize_topology``, ...).
    """
    from repro.core.engine import GraphLakeEngine

    engine = GraphLakeEngine(store, schema, **engine_kwargs)
    engine.startup()
    session = GraphSession(engine, options, own_engine=True)
    engine._gsql_session = session
    return session


# re-exported for convenience: sessions and options travel together
__all__ = ["GraphSession", "InstalledQuery", "ExecOptions", "connect"]
