"""GSQL lexer: query text -> position-tagged tokens.

Deliberately small: identifiers, numbers, single- or double-quoted strings
(no escape sequences), ``$param`` markers, the comparison/accumulate
operators and the handful of punctuation the pattern syntax needs.  ``#``
starts a line comment.  Every token carries a 1-based ``(line, col)`` so
parse and compile errors can point at their source.

The link arrows are *not* lexed as units: ``-(HasTag:e)->`` tokenizes as
``- ( ident : ident ) ->`` and ``<-(...)`` as ``< - (`` — the parser
assembles them, which keeps ``-`` and ``<`` usable as ordinary operators
inside WHERE (``a.x < -5``).
"""

from __future__ import annotations

import dataclasses

from repro.gsql.errors import GSQLSyntaxError

# multi-char operators, longest first (``->`` before ``-``, ``==`` before
# ``=``); ``=`` itself only appears as the tail of MAX= / MIN= / OR=
_OPERATORS = ("==", "!=", ">=", "<=", "+=", "->", ">", "<", "=", "-",
              "(", ")", ",", ";", ":", ".", "@", "$")

# token kinds: IDENT NUMBER STRING OP EOF
EOF = "EOF"


@dataclasses.dataclass(frozen=True)
class Token:
    kind: str         # "IDENT" | "NUMBER" | "STRING" | "OP" | EOF
    text: str
    value: object     # parsed value for NUMBER/STRING, text otherwise
    line: int
    col: int

    @property
    def pos(self) -> tuple:
        return (self.line, self.col)


def _is_ident_start(ch: str) -> bool:
    return ch.isalpha() or ch == "_"


def _is_ident(ch: str) -> bool:
    return ch.isalnum() or ch == "_"


def tokenize(text: str) -> list[Token]:
    tokens: list[Token] = []
    line, col = 1, 1
    i, n = 0, len(text)

    def advance(k: int) -> None:
        nonlocal i, line, col
        for _ in range(k):
            if text[i] == "\n":
                line, col = line + 1, 1
            else:
                col += 1
            i += 1

    while i < n:
        ch = text[i]
        if ch in " \t\r\n":
            advance(1)
            continue
        if ch == "#":                       # line comment
            while i < n and text[i] != "\n":
                advance(1)
            continue
        tl, tc = line, col
        if _is_ident_start(ch):
            j = i
            while j < n and _is_ident(text[j]):
                j += 1
            word = text[i:j]
            advance(j - i)
            tokens.append(Token("IDENT", word, word, tl, tc))
            continue
        if ch.isdigit():
            j = i
            while j < n and (text[j].isdigit() or text[j] == "."):
                j += 1
            raw = text[i:j]
            advance(j - i)
            if raw.count(".") > 1:
                raise GSQLSyntaxError(f"malformed number {raw!r}", tl, tc)
            value: object = float(raw) if "." in raw else int(raw)
            tokens.append(Token("NUMBER", raw, value, tl, tc))
            continue
        if ch in "'\"":
            j = text.find(ch, i + 1)
            if j < 0:
                raise GSQLSyntaxError("unterminated string literal", tl, tc)
            value = text[i + 1:j]
            advance(j + 1 - i)
            tokens.append(Token("STRING", value, value, tl, tc))
            continue
        for op in _OPERATORS:
            if text.startswith(op, i):
                advance(len(op))
                tokens.append(Token("OP", op, op, tl, tc))
                break
        else:
            raise GSQLSyntaxError(f"unexpected character {ch!r}", tl, tc)

    tokens.append(Token(EOF, "", None, line, col))
    return tokens
