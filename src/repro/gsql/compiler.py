"""GSQL compiler: :class:`~repro.gsql.ir.LogicalQuery` IR -> the execution
blocks of :mod:`repro.core.query` (DESIGN.md §8).

The compiler is where *everything fails early*: unknown vertex/edge types,
unknown columns, alias misuse, unresolvable hop directions and parameter
problems all raise :class:`~repro.gsql.errors.GSQLCompileError` with the
offending token's line/column — before a single lake read.  What survives
compiles to exactly the ``_SeedBlock``/``_HopBlock`` sequences the fluent
builder produces, so text queries execute bit-identically to builder chains.

Conjunct placement: each top-level WHERE conjunct references exactly one
alias and attaches to that alias's earliest evaluation point — the seed's
``where`` (a VertexMap filter) for the seed alias, a hop's ``edge_where``
for its edge alias, and a hop's ``target_where`` for the vertex alias the
hop introduces.  ``alias.@accum`` conjuncts (runtime accumulator state, no
lake column behind them) are only meaningful on a seed: they filter the
seed set against the accumulator array directly.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

from repro.core import query as q
from repro.gsql import ir
from repro.gsql.errors import GSQLCompileError

_PRED = {"==": q.eq, "!=": q.ne, ">": q.gt, ">=": q.ge, "<": q.lt, "<=": q.le}


# ---------------------------------------------------------------------------
# validation surface
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Catalog:
    """What parse-time validation checks against: the graph schema plus the
    per-table column sets of every mapped lake table."""

    schema: object                      # repro.core.types.GraphSchema
    vertex_columns: dict[str, frozenset]
    edge_columns: dict[str, frozenset]

    @staticmethod
    def from_engine(engine) -> "Catalog":
        vcols = {
            name: frozenset(c.name for c in engine.lake.table(vt.table).schema().columns)
            for name, vt in engine.schema.vertex_types.items()
        }
        ecols = {
            name: frozenset(c.name for c in engine.lake.table(et.table).schema().columns)
            for name, et in engine.schema.edge_types.items()
        }
        return Catalog(schema=engine.schema, vertex_columns=vcols,
                       edge_columns=ecols)


# ---------------------------------------------------------------------------
# compilation
# ---------------------------------------------------------------------------

def compile_query(lq: ir.LogicalQuery, catalog: Catalog,
                  params: Optional[dict] = None) -> q.CompiledQuery:
    """Validate + lower a query, binding ``$params`` from ``params``."""
    params = params or {}
    unknown = set(params) - lq.param_names()
    if unknown:
        raise GSQLCompileError(
            f"unknown parameter(s): {', '.join('$' + p for p in sorted(unknown))}")

    def binder(p: ir.Param):
        if p.name not in params:
            raise GSQLCompileError(f"unbound parameter ${p.name}", *p.pos)
        return params[p.name]

    return _compile(lq, catalog, binder)


def validate_query(lq: ir.LogicalQuery, catalog: Catalog) -> set:
    """Install-time validation: full schema/alias/direction checking with
    parameters left unbound.  Returns the query's parameter names."""
    _compile(lq, catalog, lambda p: 0)   # dummy binding; result discarded
    return lq.param_names()


def _compile(lq: ir.LogicalQuery, catalog: Catalog, binder) -> q.CompiledQuery:
    statements = []
    accum_targets: list = []
    for st in lq.statements:
        statements.append(_compile_statement(st, catalog, binder, accum_targets))
    return q.CompiledQuery(statements=statements, accum_targets=accum_targets)


class _Scope:
    """Alias table of one statement: vertex positions + edge hops."""

    def __init__(self, catalog: Catalog):
        self.catalog = catalog
        self.vertex: dict[str, int] = {}     # alias -> path position
        self.vtypes: list[str] = []          # vtype per position
        self.edge: dict[str, int] = {}       # alias -> hop index
        self.etypes: list[str] = []          # edge type per hop

    def add_vertex(self, pat: ir.VertexPat) -> int:
        if pat.vtype not in self.catalog.vertex_columns:
            raise GSQLCompileError(f"unknown vertex type {pat.vtype!r}", *pat.pos)
        if pat.alias in self.vertex or pat.alias in self.edge:
            raise GSQLCompileError(f"duplicate alias {pat.alias!r}", *pat.pos)
        self.vertex[pat.alias] = len(self.vtypes)
        self.vtypes.append(pat.vtype)
        return len(self.vtypes) - 1

    def add_edge(self, pat: ir.HopPat) -> int:
        if pat.edge_type not in self.catalog.edge_columns:
            raise GSQLCompileError(f"unknown edge type {pat.edge_type!r}", *pat.pos)
        if pat.alias is not None:
            if pat.alias in self.vertex or pat.alias in self.edge:
                raise GSQLCompileError(f"duplicate alias {pat.alias!r}", *pat.pos)
            self.edge[pat.alias] = len(self.etypes)
        self.etypes.append(pat.edge_type)
        return len(self.etypes) - 1

    def check_column(self, ref: ir.ColRef) -> None:
        """Schema-validate one ``alias.column`` reference (parse-time, never
        mid-scan).  Accumulator refs are runtime state — no column check."""
        if ref.is_accum:
            return
        if ref.alias in self.vertex:
            vtype = self.vtypes[self.vertex[ref.alias]]
            if ref.column not in self.catalog.vertex_columns[vtype]:
                raise GSQLCompileError(
                    f"vertex type {vtype!r} has no column {ref.column!r}",
                    *ref.pos)
        elif ref.alias in self.edge:
            etype = self.etypes[self.edge[ref.alias]]
            if ref.column not in self.catalog.edge_columns[etype]:
                raise GSQLCompileError(
                    f"edge type {etype!r} has no column {ref.column!r}",
                    *ref.pos)
        else:
            raise GSQLCompileError(f"unknown alias {ref.alias!r}", *ref.pos)


def _resolve_direction(hop: ir.HopPat, u_vtype: str, v_vtype: str,
                       catalog: Catalog) -> str:
    et = catalog.schema.edge_types[hop.edge_type]
    out_ok = et.src_type == u_vtype and et.dst_type == v_vtype
    in_ok = et.dst_type == u_vtype and et.src_type == v_vtype
    if hop.direction == "out":
        if not out_ok:
            raise GSQLCompileError(
                f"-({hop.edge_type})-> expects {et.src_type} on the left and "
                f"{et.dst_type} on the right, got {u_vtype} and {v_vtype}",
                *hop.pos)
        return "out"
    if hop.direction == "in":
        if not in_ok:
            raise GSQLCompileError(
                f"<-({hop.edge_type})- expects {et.dst_type} on the left and "
                f"{et.src_type} on the right, got {u_vtype} and {v_vtype}",
                *hop.pos)
        return "in"
    if out_ok and in_ok:
        raise GSQLCompileError(
            f"-({hop.edge_type})- is ambiguous between {u_vtype} vertices "
            f"(it connects {et.src_type} to {et.dst_type} of the same type); "
            f"write -({hop.edge_type})-> or <-({hop.edge_type})-", *hop.pos)
    if out_ok:
        return "out"
    if in_ok:
        return "in"
    raise GSQLCompileError(
        f"edge type {hop.edge_type!r} connects {et.src_type} to "
        f"{et.dst_type}; it cannot link {u_vtype} to {v_vtype}", *hop.pos)


def _bind_value(value, binder):
    return binder(value) if isinstance(value, ir.Param) else value


def _simple_pred(cond, binder) -> q.Predicate:
    if isinstance(cond, ir.Cmp):
        if isinstance(cond.value, ir.ColRef):
            raise GSQLCompileError(
                "column-to-column comparisons are not supported in the GSQL "
                "subset; compare each column against a value or $param",
                *cond.pos)
        return _PRED[cond.op](cond.ref.column, _bind_value(cond.value, binder))
    if isinstance(cond, ir.InSet):
        return q.isin(cond.ref.column,
                      [_bind_value(v, binder) for v in cond.values])
    raise GSQLCompileError("unsupported condition", *cond.pos)


def _cond_alias(cond) -> ir.ColRef:
    """The single alias a conjunct binds to (its attachment point)."""
    refs = cond.refs()
    aliases = {r.alias for r in refs}
    if len(aliases) != 1:
        raise GSQLCompileError(
            f"a WHERE conjunct must reference exactly one alias, got "
            f"{', '.join(sorted(aliases))} — split it with AND", *cond.pos)
    return refs[0]


def _and(a: Optional[q.Predicate], b: q.Predicate) -> q.Predicate:
    return b if a is None else a & b


def _compile_statement(st: ir.StatementIR, catalog: Catalog, binder,
                       accum_targets: list) -> q.CompiledStatement:
    scope = _Scope(catalog)
    for v in st.vertices:
        scope.add_vertex(v)
    directions = []
    for i, hop in enumerate(st.hops):
        scope.add_edge(hop)
        directions.append(_resolve_direction(
            hop, scope.vtypes[i], scope.vtypes[i + 1], catalog))

    seed = q._SeedBlock(vertex_type=scope.vtypes[0], where=None, raw_ids=None,
                        accum_where=[])
    hops = [
        q._HopBlock(edge_type=h.edge_type, direction=d, edge_where=None,
                    source_where=None, target_where=None, accum=None)
        for h, d in zip(st.hops, directions)
    ]

    def attach(cond) -> None:
        ref = _cond_alias(cond)
        if ref.is_accum:
            if isinstance(cond, ir.OrCond) or not isinstance(cond, ir.Cmp):
                raise GSQLCompileError(
                    "accumulator predicates must be simple comparisons",
                    *cond.pos)
            if scope.vertex.get(ref.alias) != 0:
                raise GSQLCompileError(
                    f"accumulator predicate on {ref.render()}: @-state filters "
                    f"are only supported on the statement's seed vertex "
                    f"(run them as an earlier statement's seed)", *ref.pos)
            value = _bind_value(cond.value, binder)
            try:
                value = float(value)
            except (TypeError, ValueError):
                raise GSQLCompileError(
                    f"accumulator predicate {ref.render()} needs a numeric "
                    f"value, got {value!r}", *cond.pos)
            seed.accum_where.append((ref.column, cond.op, value))
            return
        if isinstance(cond, ir.OrCond):
            for item in cond.items:
                if item.ref.is_accum:
                    raise GSQLCompileError(
                        "accumulator references cannot appear inside OR",
                        *item.ref.pos)
                scope.check_column(item.ref)
            pred = functools.reduce(
                lambda a, b: a | b,
                (_simple_pred(item, binder) for item in cond.items))
        else:
            scope.check_column(cond.ref)
            pred = _simple_pred(cond, binder)
        if ref.alias in scope.edge:
            h = hops[scope.edge[ref.alias]]
            h.edge_where = _and(h.edge_where, pred)
        else:
            pos = scope.vertex[ref.alias]
            if pos == 0:
                seed.where = _and(seed.where, pred)
            else:
                h = hops[pos - 1]
                h.target_where = _and(h.target_where, pred)

    for cond in st.where:
        attach(cond)

    for a in st.accums:
        _attach_accum(a, scope, hops, None, binder, accum_targets, catalog)

    if st.select_alias not in scope.vertex:
        raise GSQLCompileError(
            f"SELECT alias {st.select_alias!r} is not a vertex alias of the "
            f"pattern", *st.pos)
    select = scope.vertex[st.select_alias]

    post_blocks = []
    for pb in st.post:
        post_blocks.append(_compile_post(pb, scope, catalog, binder,
                                         accum_targets))

    aliases = [v.alias for v in st.vertices]
    return q.CompiledStatement(
        seed=seed, hops=hops, select=select, vertex_aliases=aliases,
        post=post_blocks,
    )


def _attach_accum(a: ir.AccumStmt, scope: _Scope, hops: list,
                  force_hop: Optional[int], binder, accum_targets: list,
                  catalog: Catalog) -> None:
    """Place one ACCUM update on the hop that introduces its target alias."""
    alias = a.target.alias
    if alias not in scope.vertex:
        raise GSQLCompileError(
            f"ACCUM target {a.target.render()}: {alias!r} is not a vertex "
            f"alias", *a.target.pos)
    pos = scope.vertex[alias]
    if force_hop is not None:
        hop_idx = force_hop
        target = "v" if pos == len(scope.vtypes) - 1 else "u"
    elif pos == 0:
        if not hops:
            raise GSQLCompileError(
                "ACCUM needs at least one hop to aggregate over", *a.pos)
        hop_idx, target = 0, "u"
    else:
        hop_idx, target = pos - 1, "v"
    hop = hops[hop_idx]
    if hop.accum is not None:
        raise GSQLCompileError(
            f"hop {hop_idx + 1} already has an ACCUM update; one per hop",
            *a.pos)

    value = a.value
    if isinstance(value, ir.ColRef):
        if value.is_accum:
            raise GSQLCompileError(
                "ACCUM values cannot read other accumulators", *value.pos)
        scope.check_column(value)
        # the value must come from this hop's own frame: its endpoints or
        # its edge
        u_pos, v_pos = hop_idx, hop_idx + 1
        if value.alias in scope.edge and scope.edge[value.alias] == hop_idx:
            value = f"e.{value.column}"
        elif scope.vertex.get(value.alias) == u_pos:
            value = f"u.{value.column}"
        elif scope.vertex.get(value.alias) == v_pos:
            value = f"v.{value.column}"
        else:
            raise GSQLCompileError(
                f"ACCUM value {value.render()} must reference the "
                f"accumulating hop's endpoints or edge", *value.pos)
    else:
        value = _bind_value(value, binder)

    hop.accum = q.AccumUpdate(name=a.target.column, op=a.op, value=value,
                              target=target)
    tgt_vtype = scope.vtypes[pos]
    for other_vtype, other_name in accum_targets:
        if other_name == a.target.column and other_vtype != tgt_vtype:
            # QueryResult.accumulators is keyed by bare name; two vertex
            # types sharing one name would silently shadow each other
            raise GSQLCompileError(
                f"accumulator @{a.target.column} is used on both "
                f"{other_vtype} and {tgt_vtype} in one query; rename one",
                *a.target.pos)
    if (tgt_vtype, a.target.column) not in accum_targets:
        accum_targets.append((tgt_vtype, a.target.column))


def _compile_post(pb: ir.PostAccumIR, scope: _Scope, catalog: Catalog,
                  binder, accum_targets: list) -> q._PostAccumBlock:
    if pb.source_alias not in scope.vertex:
        raise GSQLCompileError(
            f"POST-ACCUM source {pb.source_alias!r} is not a vertex alias of "
            f"the pattern", *pb.pos)
    source = scope.vertex[pb.source_alias]

    # the post hop gets its own mini-scope: source alias + new target alias
    sub = _Scope(catalog)
    sub.add_vertex(ir.VertexPat(vtype=scope.vtypes[source],
                                alias=pb.source_alias, pos=pb.pos))
    sub.add_vertex(pb.target)
    sub.add_edge(pb.hop)
    direction = _resolve_direction(pb.hop, sub.vtypes[0], sub.vtypes[1], catalog)
    hop = q._HopBlock(edge_type=pb.hop.edge_type, direction=direction,
                      edge_where=None, source_where=None, target_where=None,
                      accum=None)

    for cond in pb.where:
        ref = _cond_alias(cond)
        if ref.is_accum:
            raise GSQLCompileError(
                "POST-ACCUM WHERE cannot reference accumulators", *ref.pos)
        if isinstance(cond, ir.OrCond):
            for item in cond.items:
                sub.check_column(item.ref)
            pred = functools.reduce(
                lambda a, b: a | b,
                (_simple_pred(item, binder) for item in cond.items))
        else:
            sub.check_column(cond.ref)
            pred = _simple_pred(cond, binder)
        if ref.alias in sub.edge:
            hop.edge_where = _and(hop.edge_where, pred)
        elif sub.vertex[ref.alias] == 0:
            hop.source_where = _and(hop.source_where, pred)
        else:
            hop.target_where = _and(hop.target_where, pred)

    for a in pb.accums:
        _attach_accum(a, sub, [hop], 0, binder, accum_targets, catalog)

    return q._PostAccumBlock(source=source, hop=hop,
                             target_alias=pb.target.alias)


# ---------------------------------------------------------------------------
# traffic-light route classification (DESIGN.md §10)
# ---------------------------------------------------------------------------

def compile_lookup(lq: ir.LogicalQuery, catalog: Catalog, name: str):
    """Install-time traffic-light classification of one validated template.

    Returns ``(RouteDecision, Optional[LookupPlan])``: a plan for the
    **green**/**yellow** tiers (point lookup or single hop, executable by
    ``core/lookup.py`` against the pinned epoch's CSR + IDM), ``None`` for
    **red** (the full engine).  Callers run :func:`validate_query` first —
    this sees only well-formed queries, so every red verdict is a *shape*
    decision, never an error path.
    """
    from repro.core.lookup import (
        AccumPlan, Conjunct, LookupPlan, ParamRef, RouteDecision,
    )

    def red(reason: str):
        return RouteDecision(tier="red", reason=reason), None

    if len(lq.statements) != 1:
        return red("multi-statement queries run the full engine")
    st = lq.statements[0]
    if st.post:
        return red("POST-ACCUM blocks run the full engine")
    if len(st.hops) > 1:
        return red("multi-hop patterns run the full engine")

    scope = _Scope(catalog)
    for v_pat in st.vertices:
        scope.add_vertex(v_pat)
    direction = "out"
    if st.hops:
        scope.add_edge(st.hops[0])
        direction = _resolve_direction(
            st.hops[0], scope.vtypes[0], scope.vtypes[1], catalog)

    def lower(value):
        return ParamRef(value.name) if isinstance(value, ir.Param) else value

    seed_vtype = scope.vtypes[0]
    pk_col = catalog.schema.vertex_types[seed_vtype].primary_key
    pk_value = None
    seed_where: list = []
    edge_where: list = []
    target_where: list = []
    for cond in st.where:
        if isinstance(cond, ir.OrCond):
            return red("OR conditions run the full engine")
        ref = _cond_alias(cond)
        if ref.is_accum:
            return red("accumulator-state predicates run the full engine")
        if isinstance(cond, ir.Cmp):
            if isinstance(cond.value, ir.ColRef):
                return red("column-to-column comparisons run the full engine")
            # the seed's primary-key equality IS the lookup: it becomes the
            # IDM probe (the IDM is built from the pk column, so the probe
            # and the pk-column filter select the same dense id)
            if (pk_value is None and cond.op == "=="
                    and scope.vertex.get(ref.alias) == 0
                    and ref.column == pk_col):
                pk_value = lower(cond.value)
                continue
            conj = Conjunct(column=ref.column, op=cond.op,
                            value=lower(cond.value))
        elif isinstance(cond, ir.InSet):
            conj = Conjunct(column=ref.column, op="in",
                            value=tuple(lower(v) for v in cond.values))
        else:
            return red("unsupported condition shape runs the full engine")
        if ref.alias in scope.edge:
            edge_where.append(conj)
        elif scope.vertex.get(ref.alias) == 0:
            seed_where.append(conj)
        else:
            target_where.append(conj)
    if pk_value is None:
        return red("no primary-key equality on the seed vertex — not a "
                   "point shape")

    accum = None
    if st.accums:
        if len(st.accums) > 1 or not st.hops:
            return red("multiple ACCUM updates run the full engine")
        a = st.accums[0]
        if a.op != "sum":
            return red(f"ACCUM op {a.op!r} runs the full engine (fast path "
                       f"covers sum/count)")
        value = a.value
        if isinstance(value, ir.ColRef):
            if value.alias in scope.edge:
                value = ("e", value.column)
            elif scope.vertex.get(value.alias) == 0:
                value = ("u", value.column)
            else:
                value = ("v", value.column)
        else:
            value = lower(value)
        accum = AccumPlan(
            name=a.target.column,
            target="u" if scope.vertex[a.target.alias] == 0 else "v",
            value=value,
        )

    needs_columns = bool(seed_where or edge_where or target_where) or (
        accum is not None and isinstance(accum.value, tuple))
    tier = "yellow" if needs_columns else "green"
    reason = ("single-chunk column fetch on the fast path" if needs_columns
              else "IDM probe + CSR slice, no lake column access")
    plan = LookupPlan(
        name=name,
        tier=tier,
        kind="hop" if st.hops else "point",
        vertex_type=seed_vtype,
        pk_value=pk_value,
        seed_where=tuple(seed_where),
        edge_type=st.hops[0].edge_type if st.hops else None,
        direction=direction,
        target_type=scope.vtypes[1] if st.hops else None,
        edge_where=tuple(edge_where),
        target_where=tuple(target_where),
        accum=accum,
        select=scope.vertex[st.select_alias],
        aliases=tuple(v.alias for v in st.vertices),
        param_names=frozenset(lq.param_names()),
    )
    return RouteDecision(tier=tier, reason=reason), plan


# ---------------------------------------------------------------------------
# explain
# ---------------------------------------------------------------------------

def _render_bound(col: str, b) -> str:
    if b.values is not None:
        vals = sorted(b.values, key=repr)
        shown = ", ".join(repr(v) for v in vals[:6])
        if len(vals) > 6:
            shown += f", ... ({len(vals)} values)"
        return f"{col} in {{{shown}}}"
    parts = []
    if b.lo is not None:
        parts.append(f"{col} {'>' if b.lo_strict else '>='} {b.lo!r}")
    if b.hi is not None:
        parts.append(f"{col} {'<' if b.hi_strict else '<='} {b.hi!r}")
    return " and ".join(parts) if parts else f"{col}: unbounded"


def _render_bounds(bounds: dict) -> str:
    if not bounds:
        return "no zone-map bounds"
    return "; ".join(_render_bound(c, b) for c, b in sorted(bounds.items()))


def _topology_line() -> str:
    from repro import perf_flags
    from repro.core.topology_plane import TopologyPlane

    if perf_flags.enabled("csr"):
        thr = TopologyPlane.threshold()
        return (f"adaptive: CSR adjacency gather when frontier selectivity "
                f"<= {thr:g}, else edge-list scan with Min-Max portion pruning")
    return "edge-list scan with Min-Max portion pruning (csr flag off)"


def _explain_hop(lines: list, label: str, hop, indent: str = "  ") -> None:
    plan = q.plan_hop(hop)
    lines.append(f"{indent}{label}: -({hop.edge_type})- direction={hop.direction}")
    lines.append(f"{indent}  topology: {_topology_line()}")
    for stage, cols, bounds in (
        ("E", plan.edge_columns, plan.edge_bounds),
        ("U", plan.u_columns, plan.u_bounds),
        ("V", plan.v_columns, plan.v_bounds),
    ):
        if cols:
            lines.append(f"{indent}  stage {stage}: columns={list(cols)} "
                         f"[{_render_bounds(bounds)}]")
        else:
            lines.append(f"{indent}  stage {stage}: no columns (pass-through)")
    acc_cols = (list(plan.accum_edge_columns) + list(plan.accum_u_columns)
                + list(plan.accum_v_columns))
    if hop.accum is not None:
        a = hop.accum
        lines.append(f"{indent}  accum: {a.target}.@{a.name} {a.op}= {a.value!r}"
                     + (f" (late-materialized columns: {acc_cols})" if acc_cols
                        else ""))


def explain_compiled(compiled: q.CompiledQuery) -> str:
    """Human-readable compiled plan: per hop, the staged column sets, the
    compiled zone-map bounds and the topology-representation dispatch rule
    (the ``session.explain()`` payload)."""
    lines: list[str] = []
    for si, st in enumerate(compiled.statements):
        aliases = st.vertex_aliases or []
        sel = aliases[st.select] if aliases and st.select < len(aliases) else st.select
        lines.append(f"statement {si + 1}: select {sel!r} "
                     f"({len(st.hops)} hop{'s' if len(st.hops) != 1 else ''})")
        seed = st.seed
        seed_desc = f"  seed {seed.vertex_type}"
        if seed.where is not None:
            seed_desc += (f": filter columns={sorted(set(seed.where.columns))} "
                          f"[{_render_bounds(seed.where.bounds())}]")
        if seed.accum_where:
            seed_desc += " accum-filter " + " and ".join(
                f"@{n} {op} {v!r}" for n, op, v in seed.accum_where)
        lines.append(seed_desc)
        for hi, hop in enumerate(st.hops):
            _explain_hop(lines, f"hop {hi + 1}", hop)
        for pi, pb in enumerate(st.post):
            src = aliases[pb.source] if aliases else pb.source
            lines.append(f"  post-accum {pi + 1}: from {src!r}")
            _explain_hop(lines, "hop", pb.hop, indent="    ")
    return "\n".join(lines)
