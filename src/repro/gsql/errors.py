"""GSQL error types, all carrying a 1-based (line, col) source position.

Every failure a query text can produce is raised *before* any lake read:
lexing/parsing problems as :class:`GSQLSyntaxError`, schema or
parameter-binding problems as :class:`GSQLCompileError`.  Both render the
position in their message so callers (and tests) can point at the offending
token.
"""

from __future__ import annotations

from typing import Optional


class GSQLError(Exception):
    """Base of every GSQL front-end error."""

    def __init__(self, message: str, line: Optional[int] = None,
                 col: Optional[int] = None):
        self.line = line
        self.col = col
        if line is not None:
            message = f"{message} (line {line}, col {col})"
        super().__init__(message)


class GSQLSyntaxError(GSQLError):
    """Malformed query text (lexer/parser)."""


class GSQLCompileError(GSQLError):
    """Well-formed text that fails schema validation or parameter binding."""
