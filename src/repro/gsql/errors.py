"""GSQL error types — re-exported from :mod:`repro.errors`.

The typed error surface was consolidated under a common
:class:`~repro.errors.ReproError` base; this module remains as an import
shim for one release.  Import from ``repro.errors`` going forward.
"""

from __future__ import annotations

from repro.errors import GSQLCompileError, GSQLError, GSQLSyntaxError

__all__ = ["GSQLError", "GSQLSyntaxError", "GSQLCompileError"]
