"""GSQL front end (paper §6): textual query language -> logical IR -> compiled
scan plans.

The package is layered so that ``repro.core`` can depend on the IR without
cycles:

- :mod:`repro.gsql.ir` — the declarative :class:`LogicalQuery` IR (pure data,
  no engine imports).  ``repro.core.query``'s fluent builder constructs the
  same IR (``Query.to_ir()``), so text and builder are two front ends over
  one execution path.
- :mod:`repro.gsql.lexer` / :mod:`repro.gsql.parser` — GSQL text -> IR, with
  line/column-positioned syntax errors.
- :mod:`repro.gsql.compiler` — IR -> ``repro.core.query`` execution blocks,
  with parse-time schema validation (unknown vertex/edge types and columns
  fail here, never mid-scan) and ``$param`` binding.
- :mod:`repro.gsql.session` — the :class:`GraphSession` facade
  (``repro.connect() -> session.query()/install()/explain()``) that owns
  epoch acquisition and per-session :class:`~repro.core.query.ExecOptions`.
"""

from __future__ import annotations

from repro.gsql.ir import LogicalQuery  # noqa: F401  (pure-data, cycle-free)

_LAZY = {
    "parse": ("repro.gsql.parser", "parse"),
    "GSQLError": ("repro.gsql.errors", "GSQLError"),
    "GSQLSyntaxError": ("repro.gsql.errors", "GSQLSyntaxError"),
    "GSQLCompileError": ("repro.gsql.errors", "GSQLCompileError"),
    "compile_query": ("repro.gsql.compiler", "compile_query"),
    "validate_query": ("repro.gsql.compiler", "validate_query"),
    "Catalog": ("repro.gsql.compiler", "Catalog"),
    "GraphSession": ("repro.gsql.session", "GraphSession"),
    "connect": ("repro.gsql.session", "connect"),
}

__all__ = ["LogicalQuery", *_LAZY]


def __getattr__(name: str):
    # lazy exports: importing repro.gsql from repro.core.query must not pull
    # the compiler (which imports repro.core.query) back in mid-import
    try:
        mod_name, attr = _LAZY[name]
    except KeyError:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module(mod_name), attr)
