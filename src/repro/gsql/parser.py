"""Recursive-descent GSQL parser: tokens -> :class:`LogicalQuery` IR.

Grammar (DESIGN.md §8; keywords are case-insensitive, ``#`` comments):

    query      := statement (';' statement)* [';']
    statement  := SELECT alias FROM path
                  [WHERE cond] [ACCUM accum (',' accum)*] postaccum*
    path       := vertex (link vertex)*
    vertex     := TypeName ':' alias
    link       := '-' '(' EdgeName [':' alias] ')' '-' ['>']     # auto / out
                | '<' '-' '(' EdgeName [':' alias] ')' '-'      # in
    cond       := disj (AND disj)*
    disj       := prim (OR prim)*
    prim       := '(' cond ')' | comparison
    comparison := ref cmpop value | ref IN '(' value (',' value)* ')'
    ref        := alias '.' ['@'] column
    cmpop      := '==' | '!=' | '>' | '>=' | '<' | '<='
    value      := ['-'] number | string | '$' ident | TRUE | FALSE
    accum      := ref accop (value | ref)                        # ref is alias.@name
    accop      := '+=' | MAX '=' | MIN '=' | OR '='
    postaccum  := POST '-' ACCUM alias link vertex [WHERE cond]
                  ACCUM accum (',' accum)*

Parsing is purely syntactic — alias scoping, schema existence, direction
resolution and parameter binding are the compiler's job — except for one
structural rule enforced here because the IR cannot represent its violation:
OR only joins *simple* comparisons (no nested AND), matching the planner's
"a disjunction compiles to one alias's predicate" contract.
"""

from __future__ import annotations

from repro.gsql import ir
from repro.gsql.errors import GSQLSyntaxError
from repro.gsql.lexer import EOF, Token, tokenize

# note: POST is *not* reserved — it only acts as a keyword when the full
# ``POST - ACCUM`` sequence follows, so "Post" stays usable as a type name
_KEYWORDS = {"SELECT", "FROM", "WHERE", "ACCUM", "AND", "OR", "IN",
             "TRUE", "FALSE", "MAX", "MIN"}


class _Parser:
    def __init__(self, text: str):
        self.tokens = tokenize(text)
        self.i = 0

    # -- token helpers ---------------------------------------------------------

    def peek(self, ahead: int = 0) -> Token:
        return self.tokens[min(self.i + ahead, len(self.tokens) - 1)]

    def next(self) -> Token:
        t = self.peek()
        self.i += 1
        return t

    def at_kw(self, word: str, ahead: int = 0) -> bool:
        t = self.peek(ahead)
        return t.kind == "IDENT" and t.text.upper() == word

    def at_op(self, op: str, ahead: int = 0) -> bool:
        t = self.peek(ahead)
        return t.kind == "OP" and t.text == op

    def expect_kw(self, word: str) -> Token:
        if not self.at_kw(word):
            t = self.peek()
            raise GSQLSyntaxError(f"expected {word}, found {t.text or 'end of query'!r}",
                                  t.line, t.col)
        return self.next()

    def expect_op(self, op: str) -> Token:
        if not self.at_op(op):
            t = self.peek()
            raise GSQLSyntaxError(f"expected {op!r}, found {t.text or 'end of query'!r}",
                                  t.line, t.col)
        return self.next()

    def ident(self, what: str) -> Token:
        t = self.peek()
        if t.kind != "IDENT":
            raise GSQLSyntaxError(f"expected {what}, found {t.text or 'end of query'!r}",
                                  t.line, t.col)
        if t.text.upper() in _KEYWORDS:
            raise GSQLSyntaxError(f"expected {what}, found keyword {t.text!r}",
                                  t.line, t.col)
        return self.next()

    # -- grammar ---------------------------------------------------------------

    def query(self) -> ir.LogicalQuery:
        statements = [self.statement()]
        while self.at_op(";"):
            self.next()
            if self.peek().kind == EOF:
                break
            statements.append(self.statement())
        t = self.peek()
        if t.kind != EOF:
            raise GSQLSyntaxError(f"unexpected {t.text!r} after statement "
                                  f"(missing ';'?)", t.line, t.col)
        return ir.LogicalQuery(statements=tuple(statements))

    def statement(self) -> ir.StatementIR:
        kw = self.expect_kw("SELECT")
        select = self.ident("result alias").text
        self.expect_kw("FROM")
        vertices = [self.vertex()]
        hops = []
        while self.at_op("-") or self.at_op("<"):
            hops.append(self.link())
            vertices.append(self.vertex())
        where = self.where_clause()
        accums = self.accum_clause()
        post = []
        while self.at_kw("POST") and self.at_op("-", 1) and self.at_kw("ACCUM", 2):
            post.append(self.post_accum())
        return ir.StatementIR(
            select_alias=select, vertices=tuple(vertices), hops=tuple(hops),
            where=where, accums=accums, post=tuple(post), pos=kw.pos,
        )

    def vertex(self) -> ir.VertexPat:
        t = self.ident("vertex type")
        self.expect_op(":")
        alias = self.ident("vertex alias").text
        return ir.VertexPat(vtype=t.text, alias=alias, pos=t.pos)

    def link(self) -> ir.HopPat:
        start = self.peek()
        reverse = False
        if self.at_op("<"):
            self.next()
            reverse = True
        self.expect_op("-")
        self.expect_op("(")
        et = self.ident("edge type")
        alias = None
        if self.at_op(":"):
            self.next()
            alias = self.ident("edge alias").text
        self.expect_op(")")
        if reverse:
            self.expect_op("-")
            direction = "in"
        elif self.at_op("->"):
            self.next()
            direction = "out"
        else:
            self.expect_op("-")
            direction = "auto"
        return ir.HopPat(edge_type=et.text, alias=alias, direction=direction,
                         pos=start.pos)

    def where_clause(self) -> tuple:
        if not self.at_kw("WHERE"):
            return ()
        self.next()
        conds = [self.disjunction()]
        while self.at_kw("AND"):
            self.next()
            conds.append(self.disjunction())
        # flatten parenthesized conjunctions back into the top-level list
        flat = []
        for c in conds:
            flat.extend(c if isinstance(c, list) else [c])
        return tuple(flat)

    def disjunction(self):
        """One AND-conjunct: a comparison, an OR-chain, or a parenthesized
        group (which may itself be a conjunction -> returned as a list)."""
        first = self.prim()
        if not self.at_kw("OR"):
            return first
        items = first if isinstance(first, list) else [first]
        if len(items) > 1:
            t = self.peek()
            raise GSQLSyntaxError(
                "OR cannot join an AND-group; parenthesize each disjunct",
                t.line, t.col)
        pos = items[0].pos
        while self.at_kw("OR"):
            self.next()
            t_start = self.peek()
            nxt = self.prim()
            if isinstance(nxt, (list, ir.OrCond)):
                raise GSQLSyntaxError(
                    "OR only joins simple comparisons", t_start.line, t_start.col)
            items.append(nxt)
        return ir.OrCond(items=tuple(items), pos=pos)

    def prim(self):
        if self.at_op("("):
            self.next()
            conds = [self.disjunction()]
            while self.at_kw("AND"):
                self.next()
                conds.append(self.disjunction())
            self.expect_op(")")
            flat = []
            for c in conds:
                flat.extend(c if isinstance(c, list) else [c])
            return flat if len(flat) > 1 else flat[0]
        return self.comparison()

    def comparison(self):
        ref = self.colref()
        if self.at_kw("IN"):
            kw = self.next()
            self.expect_op("(")
            values = [self.value()]
            while self.at_op(","):
                self.next()
                values.append(self.value())
            self.expect_op(")")
            return ir.InSet(ref=ref, values=tuple(values), pos=kw.pos)
        t = self.peek()
        if t.kind == "OP" and t.text in ir.CMP_OPS:
            self.next()
            # the value side may be another column reference — parsed so the
            # compiler can reject it with a schema-aware message
            v = self.peek()
            if v.kind == "IDENT" and v.text.upper() not in _KEYWORDS \
                    and self.at_op(".", ahead=1):
                value: object = self.colref()
            else:
                value = self.value()
            return ir.Cmp(ref=ref, op=t.text, value=value, pos=ref.pos)
        raise GSQLSyntaxError(
            f"expected comparison operator, found {t.text or 'end of query'!r}",
            t.line, t.col)

    def colref(self) -> ir.ColRef:
        alias = self.ident("alias")
        self.expect_op(".")
        is_accum = False
        if self.at_op("@"):
            self.next()
            is_accum = True
        col = self.ident("column name")
        return ir.ColRef(alias=alias.text, column=col.text, is_accum=is_accum,
                         pos=alias.pos)

    def value(self):
        t = self.peek()
        if t.kind == "OP" and t.text == "-":
            self.next()
            num = self.peek()
            if num.kind != "NUMBER":
                raise GSQLSyntaxError("expected number after unary '-'",
                                      num.line, num.col)
            self.next()
            return -num.value
        if t.kind == "NUMBER" or t.kind == "STRING":
            self.next()
            return t.value
        if t.kind == "OP" and t.text == "$":
            self.next()
            name = self.ident("parameter name")
            return ir.Param(name=name.text, pos=t.pos)
        if self.at_kw("TRUE"):
            self.next()
            return True
        if self.at_kw("FALSE"):
            self.next()
            return False
        raise GSQLSyntaxError(
            f"expected a value, found {t.text or 'end of query'!r}",
            t.line, t.col)

    def accum_clause(self) -> tuple:
        if not self.at_kw("ACCUM"):
            return ()
        self.next()
        accums = [self.accum_stmt()]
        while self.at_op(","):
            self.next()
            accums.append(self.accum_stmt())
        return tuple(accums)

    def accum_stmt(self) -> ir.AccumStmt:
        target = self.colref()
        if not target.is_accum:
            raise GSQLSyntaxError(
                f"ACCUM target must be an accumulator "
                f"({target.alias}.@name, not {target.render()})",
                *target.pos)
        t = self.peek()
        if self.at_op("+="):
            self.next()
            op = "sum"
        elif t.kind == "IDENT" and t.text.upper() in ("MAX", "MIN", "OR"):
            self.next()
            self.expect_op("=")
            op = t.text.lower()
        else:
            raise GSQLSyntaxError(
                f"expected '+=', 'MAX=', 'MIN=' or 'OR=', "
                f"found {t.text or 'end of query'!r}", t.line, t.col)
        # value may be a literal, a $param, or a same-hop column reference
        v = self.peek()
        if v.kind == "IDENT" and v.text.upper() not in _KEYWORDS \
                and self.at_op(".", ahead=1):
            value: object = self.colref()
        else:
            value = self.value()
        return ir.AccumStmt(target=target, op=op, value=value, pos=target.pos)

    def post_accum(self) -> ir.PostAccumIR:
        kw = self.expect_kw("POST")
        self.expect_op("-")
        self.expect_kw("ACCUM")
        source = self.ident("source alias").text
        hop = self.link()
        target = self.vertex()
        where = self.where_clause()
        self.expect_kw("ACCUM")
        accums = [self.accum_stmt()]
        while self.at_op(","):
            self.next()
            accums.append(self.accum_stmt())
        return ir.PostAccumIR(source_alias=source, hop=hop, target=target,
                              where=where, accums=tuple(accums), pos=kw.pos)


def parse(text: str) -> ir.LogicalQuery:
    """GSQL text -> :class:`~repro.gsql.ir.LogicalQuery` (syntax only;
    schema validation and ``$param`` binding happen in the compiler)."""
    return _Parser(text).query()
