"""Shard fabric: epoch-consistent scatter-gather execution across graph
shards (DESIGN.md §13).

Public surface:

- :class:`ShardFabric` — build with ``ShardFabric.attach(engine, n)`` or
  via ``connect(store, schema, shards=n)``;
- :class:`ShardedEngine` — the fabric's engine-shaped executor
  (``fabric.executor``), consumed transparently by ``GraphSession``;
- :class:`ShardMap` / :class:`ShardView` — ownership and per-worker views,
  exposed for tests and tooling.
"""

from repro.shard.executor import ShardedEngine, merge_frames
from repro.shard.fabric import FabricEpoch, ShardFabric, ShardWorker
from repro.shard.ownership import ShardMap
from repro.shard.views import (
    ShardView,
    shard_csr_from_bytes,
    shard_csr_key,
    shard_csr_to_bytes,
    slice_csr,
)

__all__ = [
    "FabricEpoch",
    "ShardFabric",
    "ShardWorker",
    "ShardMap",
    "ShardView",
    "ShardedEngine",
    "merge_frames",
    "slice_csr",
    "shard_csr_key",
    "shard_csr_to_bytes",
    "shard_csr_from_bytes",
]
