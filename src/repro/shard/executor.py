"""ShardedEngine: the engine surface, fanned out over shard workers
(DESIGN.md §13).

``execute_compiled`` (and the lookup/batched paths) never learn about
shards: this adapter duck-types exactly the engine surface they consume —
``epochs``, ``schema``, ``all_vertices``, ``vset_from_raw_ids``,
``vertex_map``, ``edge_scan`` — and implements the two primitives as
scatter-gather:

- **scatter**: partition the frontier/seed set by vertex ownership (every
  frontier vertex — hence every incident edge, scanned from its frontier
  side — goes to exactly one worker), run the unmodified single-engine
  primitive per worker against its :class:`ShardView`, private cache and
  IO pool, concurrently;
- **gather**: union the filtered seed masks, or concatenate the per-worker
  edge frames and stable-sort by *global edge id* — both the edge-list and
  CSR views emit rows in global-eid order, and the per-worker row sets
  partition the solo scan's rows, so the merged frame reconstructs the
  single-engine frame bit-for-bit (u, v, eid and every pushed-down
  column).

Accumulator updates, POST-ACCUM, matched sets and SELECT then run *once*
at the coordinator over merged frames, inside the unmodified executor —
the per-hop re-partitioning of the merged frontier is the fabric's
boundary-frontier exchange.

Epochs acquired through the adapter are :class:`FabricEpoch`s; a plain
``GraphEpoch`` passed explicitly (time-travel pins) falls back to the solo
engine path unchanged.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from repro import perf_flags
from repro.core import primitives
from repro.core.plan import new_pruning_counters
from repro.core.primitives import EdgeFrame
from repro.core.types import VSet
from repro.shard.fabric import FabricEpoch


class _WorkerLegCache:
    """Cache facade a worker leg scans through (DESIGN.md §13).

    Vertex chunks are the worker's slice — block-hash ownership makes its
    frontier-side reads disjoint from every other worker's, so they admit
    into the worker's *private* manager and stay hot across queries.  Edge
    chunks belong to the fabric, not a shard: the lake's edge files are
    src-sorted, so a reverse scan's owned-dst edge ids scatter across every
    chunk and any private admission would be re-fetched once per worker.
    Those route to the *shared* coordinator manager, whose single-flight
    admission lets concurrent legs pay each chunk's lake fetch exactly once.

    Only the read surface the scan pipeline uses is routed; everything else
    (stats, invalidation) resolves against the private manager.
    """

    def __init__(self, private, shared):
        self._private = private
        self._shared = shared

    def _route(self, kind: str):
        return self._private if kind == "vertex" else self._shared

    def get_unit(self, ref, meta, kind, pin=False):
        return self._route(kind).get_unit(ref, meta, kind, pin=pin)

    def get_units_batch(self, requests, pool=None):
        out = {}
        for which in (self._private, self._shared):
            batch = [r for r in requests if self._route(r[2]) is which]
            if batch:
                out.update(which.get_units_batch(batch, pool=pool))
        return out

    def read_unit(self, unit, rows):
        # per-unit lock; no manager state involved
        return self._private.read_unit(unit, rows)

    def __getattr__(self, name):
        return getattr(self._private, name)


def _merge_counters(dst: Optional[dict], src: dict) -> None:
    if dst is None:
        return
    for k, v in src.items():
        dst[k] = dst.get(k, 0) + v


def merge_frames(frames: list) -> EdgeFrame:
    """Concatenate per-worker edge frames and restore global edge-id order.

    Worker frames are disjoint row subsets of the solo frame, each already
    in ascending global-eid order; a stable sort of the concatenation by
    eid is therefore exactly the solo row order.  Zero-length frames are
    dropped before concatenation so their placeholder column dtypes can't
    promote the merged columns (bit-parity includes dtype)."""
    nonempty = [f for f in frames if len(f.u)]
    if not nonempty:
        return frames[0]
    if len(nonempty) == 1:
        return nonempty[0]
    u = np.concatenate([f.u for f in nonempty])
    v = np.concatenate([f.v for f in nonempty])
    eid = np.concatenate([f.eid for f in nonempty])
    order = np.argsort(eid, kind="stable")
    columns = {
        k: np.concatenate([f.columns[k] for f in nonempty])[order]
        for k in nonempty[0].columns
    }
    return EdgeFrame(u=u[order], v=v[order], u_type=nonempty[0].u_type,
                     v_type=nonempty[0].v_type, columns=columns,
                     eid=eid[order])


class _FabricEpochs:
    """The ``engine.epochs`` facade the executor pins through: acquire
    returns the current :class:`FabricEpoch`; release routes fabric epochs
    to the fabric and plain epochs to the engine manager."""

    def __init__(self, fabric):
        self._fabric = fabric

    def current(self):
        return self._fabric.current()

    def acquire(self):
        return self._fabric.acquire()

    def release(self, epoch) -> None:
        if isinstance(epoch, FabricEpoch):
            self._fabric.release(epoch)
        else:
            self._fabric.engine.epochs.release(epoch)

    def __getattr__(self, name):
        # advance(), stats, ... — the coordinator manager's business
        return getattr(self._fabric.engine.epochs, name)


class ShardedEngine:
    """Engine-shaped adapter whose primitives fan out across the fabric."""

    def __init__(self, fabric):
        self.fabric = fabric
        self.engine = fabric.engine
        self.schema = fabric.engine.schema
        self.epochs = _FabricEpochs(fabric)
        # workers prefetch through their own pools; the coordinator-side
        # prefetcher would race the per-worker caches for no benefit
        self.prefetcher = None

    # engine state that advances can swap out — resolve live, don't snapshot
    @property
    def topology(self):
        return self.engine.topology

    @property
    def cache(self):
        return self.engine.cache

    @property
    def accums(self):
        return self.engine.accums

    @property
    def pool(self):
        return self.engine.pool

    @property
    def store(self):
        return self.engine.store

    @property
    def ingest(self):
        return getattr(self.engine, "ingest", None)

    def _topo(self, epoch=None):
        return epoch if epoch is not None else self.engine.topology

    def _query_pool(self, pipeline):
        return self.engine._query_pool(pipeline)

    def _worker_pool(self, shard_id, pipeline):
        use = perf_flags.enabled("pipe") if pipeline is None else bool(pipeline)
        return self.fabric.workers[shard_id].pool if use else None

    # -- seed/id surface (coordinator metadata, epoch-delegating) ---------------

    def all_vertices(self, vertex_type: str, epoch=None) -> VSet:
        return self.engine.all_vertices(vertex_type, epoch=epoch)

    def empty_vset(self, vertex_type: str, epoch=None) -> VSet:
        return self.engine.empty_vset(vertex_type, epoch=epoch)

    def vset_from_raw_ids(self, vertex_type: str, raw_ids, epoch=None) -> VSet:
        return self.engine.vset_from_raw_ids(vertex_type, raw_ids, epoch=epoch)

    # -- fanned-out primitives ---------------------------------------------------

    def vertex_map(self, vset: VSet, columns=(), filter_fn=None, map_fn=None,
                   bounds=None, counters=None, pipeline=None, epoch=None,
                   deadline=None):
        fe = epoch if isinstance(epoch, FabricEpoch) else None
        if fe is None or filter_fn is None or map_fn is not None:
            # no fabric epoch pinned (explicit time-travel epoch), or a
            # value-producing map: the solo path
            return self.engine.vertex_map(
                vset, columns=columns, filter_fn=filter_fn, map_fn=map_fn,
                bounds=bounds, counters=counters, pipeline=pipeline,
                epoch=epoch, deadline=deadline)
        parts = [(sid, sub) for sid, sub in fe.smap.split_vset(vset)
                 if sub.size() > 0]
        if not parts:
            return VSet.empty(vset.vertex_type, len(vset.mask)), None

        def _leg(sid, sub):
            self.fabric.heartbeats.tick(f"shard-{sid}")
            wc = new_pruning_counters()
            out_vset, _ = primitives.vertex_map(
                fe.views[sid], self.fabric.workers[sid].cache, sub,
                columns=columns, filter_fn=filter_fn, map_fn=None,
                prefetcher=None, bounds=bounds, counters=wc,
                pool=self._worker_pool(sid, pipeline), deadline=deadline)
            return out_vset, wc

        if len(parts) == 1:
            results = [_leg(*parts[0])]
        else:
            futures = [self.fabric._exec.submit(_leg, sid, sub)
                       for sid, sub in parts]
            results = [f.result() for f in futures]
        mask = np.zeros(len(vset.mask), dtype=bool)
        for out_vset, wc in results:
            mask |= out_vset.mask
            _merge_counters(counters, wc)
        with self.fabric._lock:   # concurrent queries share these counters
            self.fabric.stats["worker_scans"] += len(parts)
        return VSet(vset.vertex_type, mask), None

    def edge_scan(self, frontier: VSet, edge_type: str, direction: str = "out",
                  edge_columns=(), u_columns=(), v_columns=(),
                  edge_filter=None, strategy: str = "auto", plan=None,
                  counters=None, pipeline=None, epoch=None,
                  deadline=None) -> EdgeFrame:
        fe = epoch if isinstance(epoch, FabricEpoch) else None
        if fe is None:
            return self.engine.edge_scan(
                frontier, edge_type, direction, edge_columns=edge_columns,
                u_columns=u_columns, v_columns=v_columns,
                edge_filter=edge_filter, strategy=strategy, plan=plan,
                counters=counters, pipeline=pipeline, epoch=epoch,
                deadline=deadline)
        parts = [(sid, sub) for sid, sub in fe.smap.split_vset(frontier)
                 if sub.size() > 0]
        if not parts:
            # dtype-correct empty frame: one worker scans the empty frontier
            parts = [(fe.smap.live[0], frontier)]

        def _leg(sid, sub):
            self.fabric.heartbeats.tick(f"shard-{sid}")
            wc = new_pruning_counters()

            def _boundary_v(vt, dense, column):
                # Far-side (boundary) attributes belong to *other* shards:
                # fetch them through the coordinator's shared single-flight
                # cache so concurrent legs pay for each boundary chunk once,
                # instead of every worker re-reading the same far-side rows
                # into its private cache.  Values are the real lake values,
                # so predicate verdicts — and thus the surviving row set —
                # are bit-identical to the solo scan's pruned reads.
                vals, _ = primitives.read_vertex_columns_pruned(
                    fe.base, self.engine.cache, vt, dense, [column],
                    counters=wc, pool=self.engine.pool)
                return vals[column]

            leg_cache = _WorkerLegCache(self.fabric.workers[sid].cache,
                                        self.engine.cache)
            frame = primitives.edge_scan(
                fe.views[sid], leg_cache, sub,
                edge_type, direction, edge_columns=edge_columns,
                u_columns=u_columns, v_columns=v_columns,
                edge_filter=edge_filter, prefetcher=None, strategy=strategy,
                plan=plan, counters=wc, read_v_values=_boundary_v,
                pool=self._worker_pool(sid, pipeline), deadline=deadline)
            return frame, wc

        if len(parts) == 1:
            results = [_leg(*parts[0])]
        else:
            futures = [self.fabric._exec.submit(_leg, sid, sub)
                       for sid, sub in parts]
            results = [f.result() for f in futures]
        for _, wc in results:
            _merge_counters(counters, wc)
        with self.fabric._lock:   # concurrent queries share these counters
            stats = self.fabric.stats
            stats["scatter_gathers"] += 1
            stats["worker_scans"] += len(parts)
            stats["boundary_vertices_exchanged"] += frontier.size()
        return merge_frames([frame for frame, _ in results])

    # -- misc engine surface ------------------------------------------------------

    def advance(self):
        return self.engine.advance()

    def current_epoch(self):
        return self.engine.current_epoch()

    def read_vertex_column(self, vertex_type, dense_ids, column, epoch=None):
        return self.engine.read_vertex_column(vertex_type, dense_ids, column,
                                              epoch=epoch)
