"""Vertex ownership for the shard fabric (DESIGN.md §13).

Ownership is a *pure function* of ``(vertex_type, dense index)`` — no
materialized owner arrays, nothing to replicate, nothing that can drift
between the coordinator and a worker:

    owner(dense) = live[ splitmix64((dense >> block_bits) ^ type_salt)
                         % len(live) ]

Two deliberate choices:

- **Block granularity, not per-vertex.**  Hashing the *block index* (a
  contiguous run of ``2**block_bits`` dense ids, sized to the lake's row
  groups) keeps a shard's vertex reads chunk-local: a worker's owned seed
  rows land in whole row groups, and — with generator-ordered edge files —
  its gathered edge ids land in a narrow band of edge chunks.  Per-vertex
  hashing would scatter every shard across every chunk of every file, so
  all N workers would fetch ~all chunks and the fan-out would buy nothing.
- **Stability under append.**  Dense offsets of existing vertices never
  move on an incremental (append-only) advance, so block owners are stable
  and no data re-shards; freshly appended blocks hash to owners by the same
  function.  A topology *rebuild* (vertex removal, or an upsert's
  copy-on-write file rewrite) renumbers the dense space — that is the
  *delta re-shard* case: the fabric bumps the map version and every worker
  re-derives its slice from the new epoch.

``live`` is the tuple of live shard ids: when a worker disconnects, the
map shrinks to the survivors and ownership re-derives modulo the remaining
workers (another delta re-shard), with no rendezvous state to migrate.
"""

from __future__ import annotations

import dataclasses
import zlib

import numpy as np

from repro.core.types import VSet

# one lake row group (the committer default) per ownership block
DEFAULT_BLOCK_BITS = 12

_U = np.uint64


def _splitmix64(x: np.ndarray) -> np.ndarray:
    """SplitMix64 finalizer, vectorized (uint64 wraparound is the point)."""
    x = x.astype(np.uint64)
    x = x + _U(0x9E3779B97F4A7C15)
    x ^= x >> _U(30)
    x *= _U(0xBF58476D1CE4E5B9)
    x ^= x >> _U(27)
    x *= _U(0x94D049BB133111EB)
    x ^= x >> _U(31)
    return x


def type_salt(vertex_type: str) -> int:
    """Stable per-type salt so block 0 of every type doesn't pile onto the
    same shard."""
    return zlib.crc32(vertex_type.encode("utf-8")) & 0xFFFFFFFF


@dataclasses.dataclass(frozen=True)
class ShardMap:
    """The fabric's entire partitioning state: a handful of integers.

    ``version`` increments on every delta re-shard (rebuild advance or
    worker disconnect); workers compare versions instead of diffing owner
    arrays that don't exist.
    """

    n_shards: int
    live: tuple
    block_bits: int = DEFAULT_BLOCK_BITS
    version: int = 1

    @staticmethod
    def fresh(n_shards: int, block_bits: int = DEFAULT_BLOCK_BITS) -> "ShardMap":
        return ShardMap(n_shards=n_shards, live=tuple(range(n_shards)),
                        block_bits=block_bits, version=1)

    def resharded(self, live=None) -> "ShardMap":
        """Next map version: same hash, possibly fewer live shards."""
        return ShardMap(n_shards=self.n_shards,
                        live=tuple(live if live is not None else self.live),
                        block_bits=self.block_bits, version=self.version + 1)

    def slice_token(self) -> str:
        """Content token for everything a per-shard CSR slice depends on
        besides the topology itself: the live tuple and the block
        granularity.  Persisted slice blobs carry this token in their key,
        so a blob can never serve a map it wasn't sliced under — a
        disconnect changes ``live``, hence the token, hence the key.
        (``version`` would not do: two connections can reach the same
        version through different disconnect histories, and the full-live
        map is version 1 on every fresh fabric.)"""
        ident = f"{self.block_bits}:{','.join(str(s) for s in self.live)}"
        return f"{zlib.crc32(ident.encode('utf-8')) & 0xFFFFFFFF:08x}"

    def owner_of(self, vertex_type: str, dense_ids: np.ndarray) -> np.ndarray:
        """Owning shard id per dense id (vectorized)."""
        blocks = np.asarray(dense_ids, dtype=np.int64) >> self.block_bits
        h = _splitmix64(blocks.astype(np.uint64) ^ _U(type_salt(vertex_type)))
        live = np.asarray(self.live, dtype=np.int64)
        return live[(h % _U(len(live))).astype(np.int64)]

    def owned_mask(self, vertex_type: str, n: int, shard_id: int) -> np.ndarray:
        """Boolean mask over the dense space: which of the first ``n``
        vertices ``shard_id`` owns."""
        if n == 0:
            return np.zeros(0, dtype=bool)
        return self.owner_of(vertex_type, np.arange(n, dtype=np.int64)) == shard_id

    def owners_of_range(self, vertex_type: str, lo: int, hi: int) -> set:
        """Shards owning any block intersecting dense range [lo, hi)."""
        if hi <= lo:
            return set()
        first, last = lo >> self.block_bits, (hi - 1) >> self.block_bits
        blocks = np.arange(first, last + 1, dtype=np.int64) << self.block_bits
        return set(int(s) for s in np.unique(self.owner_of(vertex_type, blocks)))

    def split_vset(self, vset: VSet) -> list:
        """Partition a frontier by ownership: ``[(shard_id, sub_vset), ...]``
        over live shards.  The sub-frontiers are disjoint and their union is
        ``vset`` — each frontier vertex (hence each incident edge, scanned
        from its frontier side) goes to exactly one worker."""
        n = len(vset.mask)
        ids = vset.ids()
        out = []
        if len(ids) == 0:
            return [(sid, VSet.empty(vset.vertex_type, n)) for sid in self.live]
        owners = self.owner_of(vset.vertex_type, ids)
        for sid in self.live:
            out.append((sid, VSet.from_dense_ids(
                vset.vertex_type, n, ids[owners == sid])))
        return out
