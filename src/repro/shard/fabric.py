"""ShardFabric: epoch-consistent scatter-gather across graph shards
(DESIGN.md §13).

The fabric is an N-way partitioning of one engine's graph into vertex-hash
shards, each served by an in-process :class:`ShardWorker` with its *own*
cache manager and chunk-fetch IOPool (the paper's per-worker memory/IO
budget), all pinned to slices of the *same* epoch:

- a :class:`FabricEpoch` is the fabric-level unit of consistency — one
  refcounted coordinator epoch plus one :class:`~repro.shard.views.ShardView`
  per live worker, published atomically; in-flight scatter-gather queries
  drain on the fabric epoch they pinned while the next query picks up the
  new one (exactly the single-engine epoch contract, one level up);
- ``sync_to`` is the sharded half of ``advance()``: called after the epoch
  manager publishes, it routes each new table/file delta to the shards that
  own its rows (per-worker delta buffers), re-arms every worker's sliced
  CSR from the new epoch's carried/extended indexes, and — when the advance
  was a *rebuild* (dense renumbering: vertex removal or a copy-on-write
  upsert rewrite) — performs a **delta re-shard**: new map version, every
  worker re-derives its slice;
- ``disconnect_worker`` is the mid-advance failure path: the dead worker's
  delta buffers clear, armed lookup plans drop (they were planned against
  the old shard layout), ownership remaps modulo the survivors, and a new
  fabric epoch publishes over the remaining live views — no leaked refs.

Execution never forks the query planner: :class:`ShardedEngine`
(``fabric.executor``) duck-types the engine surface ``execute_compiled``
consumes, fanning ``vertex_map``/``edge_scan`` out across workers and
merging per-worker frames back into global edge-id order, so the
coordinator runs the *unmodified* single-engine executor over merged
frames — accumulators, POST-ACCUM, matched sets and SELECT all happen
once, at the coordinator, bit-identical to the solo run by construction.
"""

from __future__ import annotations

import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Optional

import numpy as np

from repro import perf_flags
from repro.core.cache.manager import CacheManager
from repro.distributed.fault import HeartbeatRegistry
from repro.lakehouse.io_pool import IOPool
from repro.shard.ownership import DEFAULT_BLOCK_BITS, ShardMap
from repro.shard.views import ShardView, shard_csr_key, shard_csr_to_bytes


class ShardWorker:
    """One shard's executor-side state: private cache + IO pool (the
    per-worker resource budget), liveness, and the per-epoch delta buffers
    ``sync_to`` routes to it."""

    def __init__(self, shard_id: int, engine, cache_config=None,
                 n_io_threads: int = 16):
        self.shard_id = shard_id
        self.engine = engine
        self.cache = CacheManager(engine.store, cache_config)
        self.pool = IOPool(n_threads=n_io_threads)
        self.alive = True
        # epoch_id -> [file keys] whose rows this shard owns (routed deltas)
        self.delta_buffers: dict[int, list] = {}

    def reset_cache(self, cache_config=None) -> None:
        """Cold-cache reset (benchmark arms)."""
        self.cache = CacheManager(self.engine.store, cache_config)

    def close(self) -> None:
        self.alive = False
        self.delta_buffers.clear()
        self.pool.close()


class FabricEpoch:
    """One fabric-wide consistent snapshot: a monotonic fabric id, one ref
    on the coordinator epoch, and the per-shard views carved from it.

    Everything the executor asks of an epoch (``epoch_id``,
    ``staleness_s``, ``n_vertices``, ``idm``, ``lookup_plans`` ...)
    delegates to the base epoch, so result stamping, accumulator sizing and
    raw-id translation are exactly the single-engine code paths.
    """

    def __init__(self, fabric_id: int, base, views: dict, smap: ShardMap):
        self.fabric_id = fabric_id
        self.base = base
        self.views = views
        self.smap = smap
        self._refs = 0
        self.retired_fabric = False

    def __getattr__(self, name):
        return getattr(object.__getattribute__(self, "base"), name)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (f"FabricEpoch(fabric_id={self.fabric_id}, "
                f"epoch={self.base.epoch_id}, shards={sorted(self.views)})")


class ShardFabric:
    """Coordinator-side fabric state machine (attach → serve → sync →
    disconnect/close).  All publishes happen under one fabric lock; queries
    pin fabric epochs through ``acquire``/``release`` just like engine
    epochs."""

    def __init__(self, engine, n_shards: int,
                 block_bits: Optional[int] = None, cache_config=None,
                 n_io_threads: int = 16, heartbeat_timeout_s: float = 30.0):
        if n_shards < 2:
            raise ValueError(f"a shard fabric needs >= 2 shards, got {n_shards}")
        self.engine = engine
        self.n_shards = n_shards
        self.smap = ShardMap.fresh(n_shards, block_bits or DEFAULT_BLOCK_BITS)
        self.workers = {
            sid: ShardWorker(sid, engine, cache_config, n_io_threads)
            for sid in range(n_shards)
        }
        # in-process workers tick the same failure-detection registry a
        # multi-host deployment would bind to the coordination service
        # (distributed/fault.py): every scan leg is a heartbeat, and
        # reap_dead_workers() turns a lapsed one into disconnect_worker()
        self.heartbeats = HeartbeatRegistry(timeout_s=heartbeat_timeout_s)
        for sid in range(n_shards):
            self.heartbeats.tick(f"shard-{sid}")
        self._lock = threading.Lock()
        # worker_scans watermark at the last reap check: lapsed heartbeats
        # with no scan legs in between mean an idle fabric, not dead workers
        self._scans_at_reap = 0
        self._exec = ThreadPoolExecutor(max_workers=n_shards,
                                        thread_name_prefix="shard")
        self._next_fabric_id = 1
        self._current: Optional[FabricEpoch] = None
        self.stats = {
            "fabric_epochs": 0,        # FabricEpochs published
            "syncs": 0,                # advance() syncs observed
            "delta_reshards": 0,       # ownership remaps (rebuild/disconnect)
            "incremental_rearms": 0,   # append-only syncs (ownership stable)
            "delta_files_routed": 0,   # file deltas routed to owning shards
            "scatter_gathers": 0,      # fanned-out edge scans
            "worker_scans": 0,         # per-worker scan legs
            "boundary_vertices_exchanged": 0,  # frontier ids re-partitioned
            "shard_csr_blobs": 0,      # per-shard CSR blobs uploaded
            "lookups_routed": 0,       # point reads attributed to an owner
            "lookup_route_by_shard": {},
            "disconnects": 0,
            "retired_fabric_epochs": 0,
        }
        # persisted per-shard CSR blobs ride the same flag + engine setting
        # as the coordinator's CSR materialization
        self._persist = bool(getattr(engine, "materialize_topology", False)
                             and perf_flags.enabled("csr"))
        from repro.shard.executor import ShardedEngine
        self.executor = ShardedEngine(self)

    # -- lifecycle ---------------------------------------------------------------

    @classmethod
    def attach(cls, engine, n_shards: int, **kwargs) -> "ShardFabric":
        """Build a fabric over a started engine and register it as
        ``engine._shard_fabric`` (the seam ``GraphSession`` and the server
        route through)."""
        if getattr(engine, "_shard_fabric", None) is not None:
            raise RuntimeError("engine already has a shard fabric attached")
        if getattr(engine, "epochs", None) is None:
            raise RuntimeError("engine.startup() must run before ShardFabric.attach")
        fabric = cls(engine, n_shards, **kwargs)
        base = engine.epochs.acquire()
        with fabric._lock:
            fabric._publish_locked(base)
        engine._shard_fabric = fabric
        return fabric

    def close(self) -> None:
        with self._lock:
            cur, self._current = self._current, None
            # a pinned in-flight query still reads cur (and its base epoch
            # ref): defer retirement to its release(), which retires any
            # non-current fabric epoch whose refs drain to zero
            if cur is not None and cur._refs == 0:
                self._retire_locked(cur)
        self._exec.shutdown(wait=False)
        for w in self.workers.values():
            w.close()
        if getattr(self.engine, "_shard_fabric", None) is self:
            self.engine._shard_fabric = None

    # -- fabric epochs -----------------------------------------------------------

    def current(self) -> FabricEpoch:
        with self._lock:
            return self._current

    def acquire(self) -> FabricEpoch:
        with self._lock:
            fe = self._current
            fe._refs += 1
            return fe

    def release(self, fe: FabricEpoch) -> None:
        with self._lock:
            fe._refs = max(0, fe._refs - 1)
            if fe._refs == 0 and fe is not self._current:
                self._retire_locked(fe)

    def _retire_locked(self, fe: FabricEpoch) -> None:
        if fe.retired_fabric:
            # idempotent: close() may race a pinned query's final release()
            # to the same fabric epoch — the base ref must drop exactly once
            return
        fe.retired_fabric = True
        for v in fe.views.values():
            v.plane.invalidate()
        fe.views = {}
        cur = self._current
        if cur is None or cur.base is not fe.base:
            # a disconnect republishes a new fabric epoch over the SAME
            # base: its routed delta state is keyed by the still-current
            # epoch id, so only clear buffers when no live fabric epoch
            # wraps this base anymore
            for w in self.workers.values():
                w.delta_buffers.pop(fe.base.epoch_id, None)
        self.stats["retired_fabric_epochs"] += 1
        self.engine.epochs.release(fe.base)

    def _publish_locked(self, base) -> FabricEpoch:
        """Publish a new fabric epoch over ``base`` (caller holds the fabric
        lock and has already acquired one ref on ``base`` for the fabric)."""
        store = self.engine.store if self._persist else None
        views = {}
        for sid in self.smap.live:
            view = ShardView(base, sid, self.smap)
            view.attach_sliced_csrs(base.plane, store)
            views[sid] = view
        if store is not None:
            self._persist_shard_csrs(base, views, store)
        # registry for EpochManager._retire: a retiring base epoch drops its
        # shard views (and their sliced CSRs) along with its own plane
        base.shard_views = views
        fe = FabricEpoch(self._next_fabric_id, base, views, self.smap)
        self._next_fabric_id += 1
        old, self._current = self._current, fe
        self.stats["fabric_epochs"] += 1
        if old is not None and old._refs == 0:
            self._retire_locked(old)
        return fe

    def _persist_shard_csrs(self, base, views: dict, store) -> None:
        version = getattr(base, "topology_version", 0)
        for sid, view in views.items():
            for ename, csr in view.plane.built_csrs().items():
                key = shard_csr_key(ename, version, sid, view.smap)
                if not store.exists(key):
                    store.put(key, shard_csr_to_bytes(csr))
                    self.stats["shard_csr_blobs"] += 1

    # -- advance integration -----------------------------------------------------

    def sync_to(self, new_epoch, report=None) -> None:
        """The sharded half of ``advance()``: called by the epoch manager
        right after it publishes ``new_epoch``.  Routes file deltas to the
        owning shards, re-shards on dense renumbering, republishes the
        fabric epoch over the fresh base."""
        base = self.engine.epochs.acquire()
        with self._lock:
            prev = self._current
            if prev is not None and prev.base is base:
                self.engine.epochs.release(base)   # nothing new to sync
                return
            rebuild = bool(report is not None
                           and getattr(report, "mode", "") == "rebuild")
            if rebuild:
                # dense ids renumbered: every block's owner derivation is
                # void — bump the map version, workers re-derive their slice
                self.smap = self.smap.resharded()
                self.stats["delta_reshards"] += 1
            else:
                self.stats["incremental_rearms"] += 1
            if prev is not None:
                self._route_delta(prev.base, base)
            self._publish_locked(base)
            self.stats["syncs"] += 1

    def _route_delta(self, prev_base, new_base) -> None:
        """Shard-aware epoch diffing: attribute each file-level delta to the
        shards that own its rows, into those workers' per-epoch delta
        buffers (cleared when the fabric epoch retires or the worker
        disconnects)."""
        eid = new_base.epoch_id
        routed = {sid: [] for sid in self.smap.live}
        for vt, info in new_base.vertex_info.items():
            prev_info = prev_base.vertex_info.get(vt)
            old_keys = ({f.key for f in prev_info.files}
                        if prev_info is not None else set())
            for f in info.files:
                if f.key in old_keys:
                    continue
                for sid in self.smap.owners_of_range(
                        vt, f.dense_offset, f.dense_offset + f.n_rows):
                    if sid in routed:
                        routed[sid].append(f.key)
        for ename, et in new_base.schema.edge_types.items():
            old_keys = {el.file_key for el in prev_base.all_edge_lists(ename)}
            for el in new_base.all_edge_lists(ename):
                if el.file_key in old_keys:
                    continue
                owners = set()
                if len(el.src_dense):
                    owners.update(int(s) for s in np.unique(
                        self.smap.owner_of(et.src_type, el.src_dense)))
                if len(el.dst_dense):
                    owners.update(int(s) for s in np.unique(
                        self.smap.owner_of(et.dst_type, el.dst_dense)))
                for sid in owners:
                    if sid in routed:
                        routed[sid].append(el.file_key)
        n = 0
        for sid, keys in routed.items():
            if keys:
                self.workers[sid].delta_buffers[eid] = keys
                n += len(keys)
        self.stats["delta_files_routed"] += n

    # -- worker failure ----------------------------------------------------------

    def disconnect_worker(self, shard_id: int) -> None:
        """A shard worker drops out (possibly mid-advance): clear its delta
        buffers, drop armed lookup plans (planned against the old layout),
        remap ownership modulo the survivors (a delta re-shard) and publish
        a new fabric epoch over the remaining live views.  In-flight queries
        drain on the fabric epoch they pinned."""
        with self._lock:
            w = self.workers.get(shard_id)
            if w is None or not w.alive:
                return
            live = tuple(s for s in self.smap.live if s != shard_id)
            if not live:
                raise RuntimeError("cannot disconnect the last live shard")
            w.alive = False
            w.delta_buffers.clear()
            self.smap = self.smap.resharded(live)
            self.stats["disconnects"] += 1
            self.stats["delta_reshards"] += 1
            base = self._current.base
            with base.lookup_lock:
                base.lookup_plans.clear()
            self.engine.epochs.acquire()   # the new fabric epoch's base ref
            self._publish_locked(base)

    def reap_dead_workers(self) -> list[int]:
        """Failure detection → membership change: disconnect every live
        worker whose heartbeat (ticked by its scan legs) has lapsed past
        the registry timeout.  Returns the shard ids reaped.  The in-process
        analog of the coordination-service monitor in a multi-host
        deployment (distributed/fault.py).

        Heartbeats are ticked by query scan legs, so on a fabric that is
        merely *idle* every worker's heartbeat lapses together — that is
        not failure, and reaping on it would irreversibly disconnect every
        healthy worker but one.  A reap therefore requires evidence of
        activity: scan legs since the last reap check AND at least one
        live worker still fresh (a genuine failure is a lapse *while peers
        stay fresh*; everyone lapsing at once is an idle gap).  Otherwise
        the live heartbeats refresh instead."""
        with self._lock:
            scans = self.stats["worker_scans"]
            idle = scans == self._scans_at_reap
            self._scans_at_reap = scans
            live_names = [f"shard-{sid}" for sid in self.smap.live
                          if self.workers[sid].alive]
        dead = set(self.heartbeats.dead_workers())
        if idle or all(n in dead for n in live_names):
            for n in live_names:
                self.heartbeats.tick(n)
            return []
        reaped = []
        for name in self.heartbeats.dead_workers():
            sid = int(name.rsplit("-", 1)[1])
            w = self.workers.get(sid)
            if w is not None and w.alive and len(self.smap.live) > 1:
                self.disconnect_worker(sid)
                reaped.append(sid)
        return reaped

    # -- observability -----------------------------------------------------------

    def note_lookup(self, vertex_type: Optional[str] = None,
                    dense_id: Optional[int] = None) -> None:
        """Route-stats hook for point reads: attribute the read to the
        owning shard (in-process here; the dispatch seam in a real
        cluster)."""
        with self._lock:
            self.stats["lookups_routed"] += 1
            if vertex_type is not None and dense_id is not None:
                sid = int(self.smap.owner_of(
                    vertex_type, np.asarray([dense_id], dtype=np.int64))[0])
                by = self.stats["lookup_route_by_shard"]
                by[sid] = by.get(sid, 0) + 1

    def stats_snapshot(self) -> dict:
        with self._lock:
            out = dict(self.stats)
            out["lookup_route_by_shard"] = dict(
                self.stats["lookup_route_by_shard"])
            out["n_shards"] = self.n_shards
            out["live_shards"] = list(self.smap.live)
            out["map_version"] = self.smap.version
            out["block_bits"] = self.smap.block_bits
            out["heartbeats_healthy"] = self.heartbeats.healthy()
            cur = self._current
            out["fabric_epoch"] = None if cur is None else {
                "fabric_id": cur.fabric_id,
                "epoch_id": cur.base.epoch_id,
                "refs": cur._refs,
            }
            out["workers"] = {
                sid: {"alive": w.alive,
                      "delta_buffered_files": sum(
                          len(v) for v in w.delta_buffers.values())}
                for sid, w in self.workers.items()
            }
        return out
