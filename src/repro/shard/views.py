"""Per-worker shard views over one pinned epoch (DESIGN.md §13).

A :class:`ShardView` is what a shard worker sees instead of the epoch: it
delegates *all* graph metadata (schema, IDM, edge lists, file registries,
vertex counts) to the coordinator's :class:`~repro.core.epochs.GraphEpoch`
unchanged — global dense ids, global edge ids, global attribute addressing
— and carries only what is genuinely per-worker:

- its **own** :class:`~repro.core.topology_plane.TopologyPlane` with
  ``auto_build_csr = False`` (a worker must never quietly materialize the
  *full* CSR from the shared edge lists), optionally armed with a
  **sliced CSR**: the coordinator's CSR with the adjacency of non-owned
  frontier-side vertices zeroed out, global edge ids preserved;
- the identity of the shard it serves.

Because the fabric's scatter step already partitions every frontier by
ownership, a worker only ever expands vertices it owns — so the sliced
CSR answers exactly like the full one on every gather the worker will be
asked, at ~1/N of the memory.  Slices serialize to their own blob format
(fwd/rev kept-edge counts differ, so the symmetric ``CSRIndex.to_bytes``
layout cannot carry them) under keys suffixed with the topology version
AND the shard map's slice token (live tuple + block bits):
``topology/csr/{edge_type}-v{version}.s{shard}of{n}.m{token}.csr`` —
so a blob sliced under a pre-disconnect ownership map can never be
mistaken for the post-reshard slice at the same topology version.
"""

from __future__ import annotations

import struct

import numpy as np

from repro.core.csr import CSRIndex
from repro.core.topology_plane import TopologyPlane

_SHARD_CSR_MAGIC = b"RSCS"


def slice_csr(csr: CSRIndex, src_owned: np.ndarray,
              dst_owned: np.ndarray) -> CSRIndex:
    """The worker's slice of one CSR: forward adjacency kept only for owned
    source vertices, reverse adjacency only for owned destinations, edge ids
    (and neighbor ids) global and untouched.  For any frontier containing
    only owned vertices, ``expand`` over the slice is bit-identical to the
    full index."""

    def _side(indptr, far, eid, owned):
        deg = np.diff(indptr)
        own = np.zeros(len(deg), dtype=bool)
        k = min(len(deg), len(owned))
        own[:k] = owned[:k]
        keep = np.repeat(own, deg)
        new_indptr = np.zeros(len(indptr), dtype=np.int64)
        np.cumsum(np.where(own, deg, 0), out=new_indptr[1:])
        return new_indptr, far[keep], eid[keep]

    fi, fd, fe = _side(csr.fwd_indptr, csr.fwd_dst, csr.fwd_eid, src_owned)
    ri, rs, re = _side(csr.rev_indptr, csr.rev_src, csr.rev_eid, dst_owned)
    return CSRIndex(csr.edge_type, csr.n_src, csr.n_dst, fi, fd, fe, ri, rs, re)


def shard_csr_to_bytes(csr: CSRIndex) -> bytes:
    """Serialize a sliced CSR (asymmetric fwd/rev edge counts)."""
    name = csr.edge_type.encode("utf-8")
    parts = [_SHARD_CSR_MAGIC,
             struct.pack("<qqqqq", csr.n_src, csr.n_dst,
                         len(csr.fwd_dst), len(csr.rev_src), len(name)),
             name]
    for arr in (csr.fwd_indptr, csr.fwd_dst, csr.fwd_eid,
                csr.rev_indptr, csr.rev_src, csr.rev_eid):
        parts.append(np.asarray(arr, dtype=np.int64).tobytes())
    return b"".join(parts)


def shard_csr_from_bytes(blob: bytes) -> CSRIndex:
    if blob[:4] != _SHARD_CSR_MAGIC:
        raise ValueError("not a shard CSR blob")
    n_src, n_dst, n_fwd, n_rev, n_name = struct.unpack_from("<qqqqq", blob, 4)
    off = 4 + 5 * 8
    name = blob[off:off + n_name].decode("utf-8")
    off += n_name

    def take(n):
        nonlocal off
        out = np.frombuffer(blob, dtype=np.int64, count=n, offset=off).copy()
        off += n * 8
        return out

    fwd_indptr = take(n_src + 1)
    fwd_dst = take(n_fwd)
    fwd_eid = take(n_fwd)
    rev_indptr = take(n_dst + 1)
    rev_src = take(n_rev)
    rev_eid = take(n_rev)
    return CSRIndex(name, n_src, n_dst, fwd_indptr, fwd_dst, fwd_eid,
                    rev_indptr, rev_src, rev_eid)


def shard_csr_key(edge_type: str, version: int, shard_id: int, smap) -> str:
    """Per-shard CSR blob key — the sharded leg of the per-epoch CSR blob
    scheme (coordinator CSRs live at
    ``topology/csr/{edge_type}-v{version}.csr``).  Suffixed with the map's
    slice token so a re-shard (disconnect) at the same topology version
    addresses different blobs than the map the old ones were sliced
    under."""
    return (f"topology/csr/{edge_type}-v{version}"
            f".s{shard_id}of{smap.n_shards}.m{smap.slice_token()}.csr")


class ShardView:
    """One shard worker's view of one pinned epoch.

    Everything the read path asks of a "topology" — ``schema``, ``idm``,
    ``all_edge_lists``, ``n_vertices``, ``dense_to_file_row``, vertex/edge
    file registries — delegates to the base epoch, so global addressing
    (dense ids, edge ids, attribute (file, row) pointers) is identical on
    every worker.  Only the plane is private: per-worker strategy choice and
    the sliced CSR, never an auto-built full one.
    """

    def __init__(self, base_epoch, shard_id: int, smap):
        self._base = base_epoch
        self.shard_id = shard_id
        self.smap = smap
        self.plane = TopologyPlane(self)
        self.plane.auto_build_csr = False

    def __getattr__(self, name):
        return getattr(object.__getattribute__(self, "_base"), name)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (f"ShardView(shard={self.shard_id}, "
                f"epoch={self._base.epoch_id}, map_v{self.smap.version})")

    @property
    def base_epoch(self):
        return self._base

    def attach_sliced_csrs(self, source_plane, store=None) -> int:
        """Arm this view's plane with its slice of every CSR the coordinator
        has built, preferring a persisted per-shard blob (second connections
        / post-advance re-arms) over slicing in memory.  Returns the number
        of edge types armed."""
        armed = 0
        schema = self._base.schema
        version = getattr(self._base, "topology_version", 0)
        for ename, csr in source_plane.built_csrs().items():
            sliced = None
            if store is not None:
                key = shard_csr_key(ename, version, self.shard_id, self.smap)
                if store.exists(key):
                    sliced = shard_csr_from_bytes(store.get(key))
            if sliced is None:
                et = schema.edge_types[ename]
                src_owned = self.smap.owned_mask(
                    et.src_type, csr.n_src, self.shard_id)
                dst_owned = self.smap.owned_mask(
                    et.dst_type, csr.n_dst, self.shard_id)
                sliced = slice_csr(csr, src_owned, dst_owned)
            self.plane.attach_csr(ename, sliced)
            armed += 1
        return armed
