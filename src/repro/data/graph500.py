"""RMAT graph generator (Graph500 / LDBC Graphalytics style, paper §7.4).

Kronecker R-MAT with the Graph500 parameters (A=0.57, B=0.19, C=0.19), edge
factor 16 (the paper's Graph500-22 has 2.4M vertices / 64.2M edges; we scale
down with the same proportions).  Written as a single-vertex-type graph:

    Node(id)
    Node_Edge_Node(src, dst, weight)
"""

from __future__ import annotations

import numpy as np

from repro.core.types import GraphSchema
from repro.lakehouse.objectstore import ObjectStore
from repro.lakehouse.table import ColumnSpec, TableSchema
from repro.lakehouse.writer import write_table


def rmat_edges(scale: int, edge_factor: int = 16, seed: int = 1,
               a: float = 0.57, b: float = 0.19, c: float = 0.19) -> tuple[np.ndarray, np.ndarray]:
    """Generate 2^scale vertices, edge_factor * 2^scale edges (vectorized)."""
    rng = np.random.default_rng(seed)
    n_edges = edge_factor * (1 << scale)
    src = np.zeros(n_edges, dtype=np.int64)
    dst = np.zeros(n_edges, dtype=np.int64)
    ab, abc = a + b, a + b + c
    for bit in range(scale):
        r = rng.random(n_edges)
        right = (r >= a) & (r < ab)          # quadrant B: dst bit set
        down = (r >= ab) & (r < abc)         # quadrant C: src bit set
        both = r >= abc                      # quadrant D: both bits set
        src |= ((down | both).astype(np.int64)) << bit
        dst |= ((right | both).astype(np.int64)) << bit
    return src, dst


def graph500_schema() -> GraphSchema:
    g = GraphSchema()
    g.add_vertex_type("Node", table="Node", primary_key="id")
    g.add_edge_type("Edge", table="Node_Edge_Node", src_type="Node",
                    dst_type="Node", src_column="src", dst_column="dst")
    return g


def generate_graph500(
    store: ObjectStore,
    scale: int = 12,
    edge_factor: int = 16,
    n_files: int = 4,
    row_group_rows: int = 65536,
    seed: int = 1,
    sort_by_src: bool = True,
) -> GraphSchema:
    src, dst = rmat_edges(scale, edge_factor, seed)
    n = 1 << scale
    node_ids = np.arange(n, dtype=np.int64)
    write_table(
        store,
        TableSchema("Node", [ColumnSpec("id", "int64", role="primary_key")]),
        {"id": node_ids}, n_files=max(1, n_files // 2), row_group_rows=row_group_rows,
    )
    if sort_by_src:
        order = np.argsort(src, kind="stable")
        src, dst = src[order], dst[order]
    rng = np.random.default_rng(seed + 1)
    write_table(
        store,
        TableSchema("Node_Edge_Node", [
            ColumnSpec("src", "int64", role="foreign_key"),
            ColumnSpec("dst", "int64", role="foreign_key"),
            ColumnSpec("weight", "float64"),
        ]),
        {"src": src, "dst": dst, "weight": rng.random(len(src))},
        n_files=n_files, row_group_rows=row_group_rows,
    )
    return graph500_schema()
