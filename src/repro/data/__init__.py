"""Dataset generation + input pipelines.

- ``ldbc``     — LDBC_SNB-style social network generator (scale-factor param),
                 written into Lakehouse tables (the paper's primary workload),
- ``graph500`` — RMAT generator (Graph500/Graphalytics-style, Table 2),
- ``synthetic``— token/recsys/molecule data for the assigned architectures,
- ``sampler``  — fanout neighbor sampler (minibatch GNN training),
- ``pipeline`` — deterministic, resumable, sharded training data pipeline.
"""
