"""LDBC_SNB-style social-network generator written into Lakehouse tables.

Keeps the benchmark's *shape* (schema + power-law degree skew + correlated
properties) at container scale.  ``scale_factor=1.0`` would approximate
LDBC SF1 proportions (~3M vertices/17M edges); benchmarks here use
0.001-0.1.  Vertex/edge counts scale linearly with the scale factor like the
real generator's.

Schema (the subset the paper's example queries touch):

    Person(id, firstName, gender, birthday, locationCity)
    Comment(id, creationDate, length, browserUsed)
    Tag(id, name)
    Person_Knows_Person(src, dst, creationDate)
    Comment_HasCreator_Person(src, dst, creationDate)
    Comment_HasTag_Tag(src, dst)

Edge tables are written sorted by source FK (the layout the paper notes makes
Min-Max pruning most effective); a ``shuffle_edges`` flag disables that for
ablations.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.types import GraphSchema
from repro.lakehouse.objectstore import ObjectStore
from repro.lakehouse.table import ColumnSpec, TableSchema
from repro.lakehouse.writer import write_table

_TAG_NAMES = [
    "Music", "Sports", "Politics", "Movies", "Science", "Travel", "Food",
    "Art", "History", "Fashion", "Gaming", "Books", "Nature", "Tech", "Cars",
]
_BROWSERS = ["Chrome", "Firefox", "Safari", "Edge"]
_CITIES = [f"city_{i}" for i in range(50)]

# SF1 reference counts (approximate LDBC proportions)
_SF1 = {"persons": 10_000, "comments": 2_000_000, "tags": 16_000}


@dataclasses.dataclass
class LDBCDataset:
    schema: GraphSchema
    n_persons: int
    n_comments: int
    n_tags: int
    n_edges: int
    counts: dict[str, int]


def ldbc_graph_schema() -> GraphSchema:
    g = GraphSchema()
    g.add_vertex_type("Person", table="Person", primary_key="id")
    g.add_vertex_type("Comment", table="Comment", primary_key="id")
    g.add_vertex_type("Tag", table="Tag", primary_key="id")
    g.add_edge_type("Knows", table="Person_Knows_Person",
                    src_type="Person", dst_type="Person",
                    src_column="src", dst_column="dst")
    g.add_edge_type("HasCreator", table="Comment_HasCreator_Person",
                    src_type="Comment", dst_type="Person",
                    src_column="src", dst_column="dst")
    g.add_edge_type("HasTag", table="Comment_HasTag_Tag",
                    src_type="Comment", dst_type="Tag",
                    src_column="src", dst_column="dst")
    return g


def _powerlaw_targets(rng, n_draws: int, n_targets: int, alpha: float = 1.3) -> np.ndarray:
    """Zipf-ish target selection producing skewed in-degree."""
    ranks = rng.zipf(alpha, size=n_draws).astype(np.int64)
    return (ranks - 1) % max(n_targets, 1)


def generate_ldbc(
    store: ObjectStore,
    scale_factor: float = 0.01,
    n_files: int = 4,
    row_group_rows: int = 16384,
    seed: int = 7,
    shuffle_edges: bool = False,
) -> LDBCDataset:
    rng = np.random.default_rng(seed)
    n_persons = max(20, int(_SF1["persons"] * scale_factor))
    n_comments = max(50, int(_SF1["comments"] * scale_factor))
    n_tags = max(len(_TAG_NAMES), int(_SF1["tags"] * scale_factor))
    schema = ldbc_graph_schema()

    # ---- vertex tables -------------------------------------------------------
    person_ids = np.arange(1, n_persons + 1, dtype=np.int64) * 10 + 1  # sparse raw IDs
    persons = {
        "id": person_ids,
        "firstName": np.array([f"name_{i % 997}" for i in range(n_persons)], dtype=object),
        "gender": np.array(
            rng.choice(["Female", "Male"], size=n_persons), dtype=object
        ),
        "birthday": rng.integers(19400101, 20051231, size=n_persons).astype(np.int64),
        "locationCity": np.array(rng.choice(_CITIES, size=n_persons), dtype=object),
    }
    write_table(
        store,
        TableSchema("Person", [
            ColumnSpec("id", "int64", role="primary_key"),
            ColumnSpec("firstName", "str"),
            ColumnSpec("gender", "str"),
            ColumnSpec("birthday", "int64"),
            ColumnSpec("locationCity", "str"),
        ]),
        persons, n_files=n_files, row_group_rows=row_group_rows,
    )

    comment_ids = np.arange(1, n_comments + 1, dtype=np.int64) * 10 + 3
    # comments are created over time, so creationDate trends with the id (row
    # order) like a real event table; this row-order clustering is what makes
    # per-chunk Min/Max statistics selective for date predicates (zone-map
    # pruning, DESIGN.md §4) — jitter keeps neighboring chunks overlapping
    date_base = np.linspace(20080101, 20221231, n_comments)
    date_jitter = rng.integers(-5000, 5001, size=n_comments)
    comments = {
        "id": comment_ids,
        "creationDate": np.clip(date_base + date_jitter, 20080101, 20221231).astype(np.int64),
        "length": rng.integers(1, 2000, size=n_comments).astype(np.int64),
        "browserUsed": np.array(rng.choice(_BROWSERS, size=n_comments), dtype=object),
    }
    write_table(
        store,
        TableSchema("Comment", [
            ColumnSpec("id", "int64", role="primary_key"),
            ColumnSpec("creationDate", "int64"),
            ColumnSpec("length", "int64"),
            ColumnSpec("browserUsed", "str"),
        ]),
        comments, n_files=n_files, row_group_rows=row_group_rows,
    )

    tag_ids = np.arange(1, n_tags + 1, dtype=np.int64) * 10 + 7
    tags = {
        "id": tag_ids,
        "name": np.array(
            [_TAG_NAMES[i % len(_TAG_NAMES)] + ("" if i < len(_TAG_NAMES) else f"_{i}")
             for i in range(n_tags)],
            dtype=object,
        ),
    }
    write_table(
        store,
        TableSchema("Tag", [
            ColumnSpec("id", "int64", role="primary_key"),
            ColumnSpec("name", "str"),
        ]),
        tags, n_files=max(1, n_files // 2), row_group_rows=row_group_rows,
    )

    # ---- edge tables ---------------------------------------------------------
    def _write_edges(name, src_ids, dst_ids, extra=None, sort=True):
        order = np.argsort(src_ids, kind="stable") if (sort and not shuffle_edges) \
            else rng.permutation(len(src_ids))
        cols = {"src": src_ids[order], "dst": dst_ids[order]}
        specs = [
            ColumnSpec("src", "int64", role="foreign_key"),
            ColumnSpec("dst", "int64", role="foreign_key"),
        ]
        for cname, arr in (extra or {}).items():
            cols[cname] = arr[order]
            specs.append(ColumnSpec(cname, str(arr.dtype) if arr.dtype != object else "str"))
        write_table(
            store, TableSchema(name, specs), cols,
            n_files=n_files, row_group_rows=row_group_rows,
        )
        return len(src_ids)

    n_edges = 0
    # Knows: ~18 per person, power-law targets
    n_knows = n_persons * 18
    k_src = person_ids[rng.integers(0, n_persons, size=n_knows)]
    k_dst = person_ids[_powerlaw_targets(rng, n_knows, n_persons)]
    keep = k_src != k_dst
    n_edges += _write_edges(
        "Person_Knows_Person", k_src[keep], k_dst[keep],
        {"creationDate": rng.integers(20080101, 20221231, size=int(keep.sum())).astype(np.int64)},
    )

    # HasCreator: every comment has exactly one creator (power-law over persons)
    hc_src = comment_ids
    hc_dst = person_ids[_powerlaw_targets(rng, n_comments, n_persons)]
    n_edges += _write_edges(
        "Comment_HasCreator_Person", hc_src, hc_dst,
        {"creationDate": comments["creationDate"]},
    )

    # HasTag: ~2 tags per comment, skewed toward popular tags
    n_ht = n_comments * 2
    ht_src = comment_ids[rng.integers(0, n_comments, size=n_ht)]
    ht_dst = tag_ids[_powerlaw_targets(rng, n_ht, n_tags)]
    n_edges += _write_edges("Comment_HasTag_Tag", ht_src, ht_dst)

    return LDBCDataset(
        schema=schema,
        n_persons=n_persons,
        n_comments=n_comments,
        n_tags=n_tags,
        n_edges=n_edges,
        counts={"persons": n_persons, "comments": n_comments, "tags": n_tags},
    )
