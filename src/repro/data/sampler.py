"""Fanout neighbor sampler for minibatch GNN training (minibatch_lg shape).

GraphSAGE-style layered sampling over a CSR adjacency: for each seed batch,
sample up to ``fanout[l]`` neighbors per node at hop ``l``, relabel to a
compact padded subgraph (fixed shapes for jit), and emit the batch dict the
GNN archs consume.  The sampler is deterministic in (seed, step) — the
stateless-pipeline contract — and runs on hosts (it is part of the data
pipeline, exactly where real systems put it).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SampledSubgraph:
    src: np.ndarray            # (E_pad,) compact edge endpoints
    dst: np.ndarray
    edge_mask: np.ndarray      # (E_pad,)
    node_ids: np.ndarray       # (N_pad,) original ids of compact nodes (-1 pad)
    node_mask: np.ndarray      # (N_pad,)
    seed_rows: np.ndarray      # (B,) compact indices of the seed nodes


class NeighborSampler:
    def __init__(self, src: np.ndarray, dst: np.ndarray, n_nodes: int):
        """Builds CSR over (src -> dst) once; sampling reuses it."""
        order = np.argsort(src, kind="stable")
        self.dst_sorted = np.ascontiguousarray(dst[order]).astype(np.int64)
        counts = np.bincount(src, minlength=n_nodes)
        self.indptr = np.zeros(n_nodes + 1, dtype=np.int64)
        np.cumsum(counts, out=self.indptr[1:])
        self.n_nodes = n_nodes

    @classmethod
    def from_lookup(cls, session, edge_type: str,
                    direction: str = "out") -> "NeighborSampler":
        """Draw adjacency from the engine's lookup service instead of raw
        edge arrays: the pinned epoch's CSR (``core/lookup.csr_adjacency``)
        is the same stable-argsort build this constructor would redo, so the
        sampler adopts its ``(indptr, neighbors)`` arrays zero-copy — and
        samples identically for the same rng seed."""
        from repro.core.lookup import csr_adjacency

        engine = session.engine if hasattr(session, "engine") else session
        indptr, far = csr_adjacency(engine, edge_type, direction=direction)
        sampler = cls.__new__(cls)
        sampler.indptr = np.asarray(indptr, dtype=np.int64)
        sampler.dst_sorted = np.asarray(far, dtype=np.int64)
        sampler.n_nodes = len(sampler.indptr) - 1
        return sampler

    def _sample_neighbors(self, rng, nodes: np.ndarray, fanout: int):
        """For each node, sample up to `fanout` out-neighbors (vectorized)."""
        starts = self.indptr[nodes]
        degs = self.indptr[nodes + 1] - starts
        take = np.minimum(degs, fanout)
        total = int(take.sum())
        if total == 0:
            return (np.empty(0, np.int64),) * 2
        # random offsets within each adjacency range
        reps = np.repeat(np.arange(len(nodes)), take)
        offs = (rng.random(total) * degs[reps]).astype(np.int64)
        nbrs = self.dst_sorted[starts[reps] + offs]
        return np.repeat(nodes, take), nbrs

    def sample(
        self,
        seeds: np.ndarray,
        fanout: tuple[int, ...],
        n_pad: int,
        e_pad: int,
        seed: int = 0,
    ) -> SampledSubgraph:
        rng = np.random.default_rng(seed)
        frontier = np.unique(seeds)
        all_nodes = [frontier]
        all_src, all_dst = [], []
        for f in fanout:
            u, v = self._sample_neighbors(rng, frontier, f)
            all_src.append(v)   # message flows neighbor -> node
            all_dst.append(u)
            frontier = np.unique(v)
            all_nodes.append(frontier)

        nodes = np.unique(np.concatenate(all_nodes))
        src = np.concatenate(all_src) if all_src else np.empty(0, np.int64)
        dst = np.concatenate(all_dst) if all_dst else np.empty(0, np.int64)
        if len(nodes) > n_pad:
            # cap: keep seeds + earliest-sampled nodes; drop edges touching cut
            keep = set(nodes[:n_pad].tolist()) | set(seeds.tolist())
            nodes = np.array(sorted(keep))[:n_pad]
            in_keep = np.isin(src, nodes) & np.isin(dst, nodes)
            src, dst = src[in_keep], dst[in_keep]
        if len(src) > e_pad:
            src, dst = src[:e_pad], dst[:e_pad]

        # relabel to compact ids
        lut = {int(n): i for i, n in enumerate(nodes)}
        c_src = np.fromiter((lut[int(s)] for s in src), np.int64, len(src))
        c_dst = np.fromiter((lut[int(d)] for d in dst), np.int64, len(dst))

        node_ids = np.full(n_pad, -1, dtype=np.int64)
        node_ids[: len(nodes)] = nodes
        node_mask = node_ids >= 0
        out_src = np.zeros(e_pad, dtype=np.int32)
        out_dst = np.zeros(e_pad, dtype=np.int32)
        out_src[: len(c_src)] = c_src
        out_dst[: len(c_dst)] = c_dst
        edge_mask = np.zeros(e_pad, dtype=bool)
        edge_mask[: len(c_src)] = True
        seed_rows = np.fromiter((lut[int(s)] for s in seeds), np.int64, len(seeds))
        return SampledSubgraph(
            src=out_src, dst=out_dst, edge_mask=edge_mask,
            node_ids=node_ids, node_mask=node_mask, seed_rows=seed_rows,
        )
