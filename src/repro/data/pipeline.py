"""Deterministic, resumable, sharded training data pipeline (DESIGN.md §6).

Batches are a pure function of (seed, step, host_shard): a restarted or
re-scaled job resumes *exactly* where it left off by restoring only the step
counter — no iterator state to checkpoint.  Host-side generation is wrapped
with a prefetch depth (I/O pool) so batch k+1 materializes while step k runs,
and slow shards can be speculatively re-fetched (straggler mitigation).
"""

from __future__ import annotations

from typing import Callable, Iterator, Optional

import numpy as np

from repro.lakehouse.io_pool import IOPool


class StatelessPipeline:
    """make_batch(seed, step, shard, n_shards) -> batch pytree."""

    def __init__(
        self,
        make_batch: Callable[[int, int, int, int], dict],
        seed: int = 0,
        shard: int = 0,
        n_shards: int = 1,
        prefetch_depth: int = 2,
        pool: Optional[IOPool] = None,
    ):
        self.make_batch = make_batch
        self.seed = seed
        self.shard = shard
        self.n_shards = n_shards
        self.prefetch_depth = prefetch_depth
        self.pool = pool or IOPool(n_threads=2, max_in_flight=prefetch_depth + 1)

    def batch_at(self, step: int) -> dict:
        return self.make_batch(self.seed, step, self.shard, self.n_shards)

    def iterate(self, start_step: int, n_steps: int) -> Iterator[tuple[int, dict]]:
        """Prefetching iterator over [start_step, start_step + n_steps)."""
        steps = range(start_step, start_step + n_steps)
        for step, batch in _prefetched(self.pool, steps, self.batch_at,
                                       self.prefetch_depth):
            yield step, batch

    def close(self) -> None:
        self.pool.close()


def _prefetched(pool, steps, fn, depth):
    from repro.lakehouse.io_pool import prefetch_iter
    yield from prefetch_iter(pool, steps, fn, depth=depth)


# ---------------------------------------------------------------------------
# stock batch makers
# ---------------------------------------------------------------------------

def lm_batch_maker(vocab: int, batch: int, seq: int):
    """Synthetic-token LM batches (structured so loss is learnable: next
    token = (token * 31 + 7) % vocab with noise)."""

    def make(seed: int, step: int, shard: int, n_shards: int) -> dict:
        rng = np.random.default_rng(hash((seed, step, shard)) % (2 ** 63))
        b = batch // n_shards
        toks = np.empty((b, seq + 1), dtype=np.int32)
        toks[:, 0] = rng.integers(0, vocab, b)
        for t in range(seq):
            toks[:, t + 1] = (toks[:, t] * 31 + 7) % vocab
        flip = rng.random((b, seq + 1)) < 0.05
        toks[flip] = rng.integers(0, vocab, int(flip.sum()))
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:].astype(np.int32)}

    return make


def recsys_batch_maker(cfg, batch: int):
    """Click batches with a planted logistic structure over field embeddings."""

    f_single = cfg.n_fields - cfg.n_multihot
    offs = cfg.field_offsets

    def make(seed: int, step: int, shard: int, n_shards: int) -> dict:
        rng = np.random.default_rng(hash((seed, step, shard)) % (2 ** 63))
        b = batch // n_shards
        idx_single = np.stack(
            [rng.integers(0, cfg.vocab_sizes[f], b) + offs[f]
             for f in range(f_single)], axis=1).astype(np.int32)
        idx_multi = np.stack(
            [rng.integers(0, cfg.vocab_sizes[f_single + f], (b, cfg.bag_size))
             + offs[f_single + f] for f in range(cfg.n_multihot)],
            axis=1).astype(np.int32)
        w_multi = (rng.random((b, cfg.n_multihot, cfg.bag_size)) < 0.7
                   ).astype(np.float32)
        # planted signal: parity of the first field drives the label
        labels = ((idx_single[:, 0] % 2) ^ (rng.random(b) < 0.1)).astype(np.int32)
        return {"idx_single": idx_single, "idx_multi": idx_multi,
                "w_multi": w_multi, "labels": labels}

    return make
