"""Synthetic dataset helpers (tokens / clicks / molecules).

Token and recsys batch makers live in ``repro.data.pipeline`` (the stateless
pipeline contract); this module adds the batched-small-graph (molecule)
generator used by examples and re-exports the others for a single entry point.
"""

from __future__ import annotations

import numpy as np

from repro.data.pipeline import lm_batch_maker, recsys_batch_maker  # noqa: F401


def molecule_batch(n_graphs: int = 32, nodes_per: int = 24, edges_per: int = 52,
                   n_atom_types: int = 20, seed: int = 0) -> dict:
    """A batch of disjoint random molecules in block-diagonal layout."""
    rng = np.random.default_rng(seed)
    n = n_graphs * nodes_per
    e = n_graphs * edges_per
    src = np.empty(e, np.int32)
    dst = np.empty(e, np.int32)
    for g in range(n_graphs):
        lo = g * nodes_per
        src[g * edges_per:(g + 1) * edges_per] = lo + rng.integers(0, nodes_per, edges_per)
        dst[g * edges_per:(g + 1) * edges_per] = lo + rng.integers(0, nodes_per, edges_per)
    return {
        "z": rng.integers(0, n_atom_types, n).astype(np.int32),
        "pos": (rng.standard_normal((n, 3)) * 2).astype(np.float32),
        "x": rng.standard_normal((n, 16)).astype(np.float32),
        "src": src, "dst": dst,
        "edge_mask": np.ones(e, bool), "node_mask": np.ones(n, bool),
        "graph_ids": np.repeat(np.arange(n_graphs), nodes_per).astype(np.int32),
        "graph_mask": np.ones(n_graphs, bool),
        "targets": rng.standard_normal(n_graphs).astype(np.float32),
    }
