"""Registry of the 10 assigned architectures (+ the paper's own config)."""

from __future__ import annotations

import importlib

_MODULES = {
    "deepseek-v2-lite-16b": "repro.configs.deepseek_v2_lite_16b",
    "phi3.5-moe-42b-a6.6b": "repro.configs.phi35_moe_42b",
    "qwen2-1.5b": "repro.configs.qwen2_1p5b",
    "llama3.2-3b": "repro.configs.llama32_3b",
    "codeqwen1.5-7b": "repro.configs.codeqwen15_7b",
    "gin-tu": "repro.configs.gin_tu",
    "meshgraphnet": "repro.configs.meshgraphnet",
    "schnet": "repro.configs.schnet",
    "dimenet": "repro.configs.dimenet",
    "xdeepfm": "repro.configs.xdeepfm",
}

ARCH_IDS = list(_MODULES)


def get_arch(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return importlib.import_module(_MODULES[arch_id]).ARCH
