"""Arch protocol: uniform wrapper the launcher, dry-run and smoke tests use.

Every architecture exposes:

- ``shapes()``                 — its assigned ShapeCells (with skip reasons),
- ``init_state(rng)``          — train state (params + optimizer) or serve state,
- ``make_step(cell)``          — the jit-able step function for a cell,
- ``state_specs(cell)``        — ShapeDtypeStructs for the state argument,
- ``batch_specs(cell)``        — ShapeDtypeStructs for the data argument,
- ``example_batch(cell, rng)`` — a real (reduced-size) batch for smoke tests,
- ``shardings(mesh, cell)``    — (state, batch) NamedShardings,
- ``model_flops(cell)``        — analytic MODEL_FLOPS for the roofline.

``reduced=True`` swaps in a small same-family config (smoke tests on CPU);
the FULL configs are only ever touched abstractly (eval_shape / dry-run).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import sharding as shd
from repro.models import transformer as tf
from repro.models.gnn.common import GNNDist, local_dist, sharded_dist
from repro.train.optimizer import AdamW, OptimizerConfig


@dataclasses.dataclass
class ShapeCell:
    name: str
    kind: str                    # train | prefill | decode | serve | retrieval
    dims: dict
    skip: Optional[str] = None


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


def _pad_to(x: int, mult: int) -> int:
    return -(-x // mult) * mult


# ===========================================================================
# LM family
# ===========================================================================

LM_SHAPE_DIMS = {
    "train_4k": dict(seq=4096, batch=256),
    "prefill_32k": dict(seq=32768, batch=32),
    "decode_32k": dict(seq=32768, batch=128),
    "long_500k": dict(seq=524288, batch=1),
}

LONG_SKIP = (
    "long_500k skipped: pure full-softmax-attention arch (GQA/MLA); the pool "
    "instructions require sub-quadratic attention for this cell (DESIGN.md §4)"
)


class LMArch:
    family = "lm"

    def __init__(self, arch_id: str, full: tf.LMConfig, reduced: tf.LMConfig):
        self.arch_id = arch_id
        self._full = full
        self._reduced = reduced
        self.optimizer = AdamW(OptimizerConfig())

    def config(self, reduced: bool = False) -> tf.LMConfig:
        return self._reduced if reduced else self._full

    def shapes(self) -> list[ShapeCell]:
        return [
            ShapeCell("train_4k", "train", LM_SHAPE_DIMS["train_4k"]),
            ShapeCell("prefill_32k", "prefill", LM_SHAPE_DIMS["prefill_32k"]),
            ShapeCell("decode_32k", "decode", LM_SHAPE_DIMS["decode_32k"]),
            ShapeCell("long_500k", "decode", LM_SHAPE_DIMS["long_500k"],
                      skip=LONG_SKIP),
        ]

    # -- state -----------------------------------------------------------------

    def init_state(self, rng, cell: ShapeCell, reduced: bool = False):
        cfg = self.config(reduced)
        if cell.kind == "train":
            params = tf.init_params(rng, cfg)
            return {"params": params, "opt": self.optimizer.init(params),
                    "step": jnp.zeros((), jnp.int32)}
        params = tf.init_params(rng, cfg)
        if cell.kind == "decode":
            dims = self._dims(cell, reduced)
            caches = tf.init_caches(cfg, dims["batch"], dims["seq"])
            return {"params": params, "caches": caches}
        return {"params": params}

    def state_specs(self, cell: ShapeCell, reduced: bool = False):
        rng = jax.random.PRNGKey(0)
        return jax.eval_shape(lambda: self.init_state(rng, cell, reduced))

    # -- batches -----------------------------------------------------------------

    def _dims(self, cell: ShapeCell, reduced: bool) -> dict:
        if not reduced:
            return cell.dims
        return dict(seq=max(32, cell.dims["seq"] // 512),
                    batch=max(2, cell.dims["batch"] // 64))

    def batch_specs(self, cell: ShapeCell, reduced: bool = False):
        d = self._dims(cell, reduced)
        if cell.kind == "train":
            return {"tokens": _sds((d["batch"], d["seq"]), jnp.int32),
                    "labels": _sds((d["batch"], d["seq"]), jnp.int32)}
        if cell.kind == "prefill":
            return {"tokens": _sds((d["batch"], d["seq"]), jnp.int32)}
        return {"token": _sds((d["batch"], 1), jnp.int32),
                "index": _sds((), jnp.int32)}

    def example_batch(self, cell: ShapeCell, seed: int = 0, reduced: bool = True):
        cfg = self.config(reduced)
        rng = np.random.default_rng(seed)
        specs = self.batch_specs(cell, reduced)
        out = {}
        for k, s in specs.items():
            if k == "index":
                out[k] = jnp.asarray(self._dims(cell, reduced)["seq"] // 2,
                                     jnp.int32)
            else:
                out[k] = jnp.asarray(rng.integers(0, cfg.vocab, size=s.shape),
                                     s.dtype)
        return out

    # -- steps -----------------------------------------------------------------

    def make_step(self, cell: ShapeCell, reduced: bool = False) -> Callable:
        cfg = self.config(reduced)
        if cell.kind == "train":
            return tf.make_train_step(cfg, self.optimizer)
        if cell.kind == "prefill":
            def prefill(state, batch):
                b, s = batch["tokens"].shape
                caches = tf.init_caches(cfg, b, s)
                return tf.prefill_step(cfg, state["params"], batch["tokens"], caches)
            return prefill
        def decode(state, batch):
            logits, caches = tf.decode_step(
                cfg, state["params"], state["caches"], batch["token"],
                batch["index"],
            )
            return logits, {"params": state["params"], "caches": caches}
        return decode

    # -- shardings ----------------------------------------------------------------

    def shardings(self, mesh, cell: ShapeCell, reduced: bool = False):
        state_specs = self.state_specs(cell, reduced)
        cfg = self.config(reduced)
        if cell.kind == "train":
            state_sh = shd.lm_state_shardings(mesh, state_specs)
        else:
            state_sh = {"params": shd.lm_param_shardings(mesh, state_specs["params"])}
            if "caches" in state_specs:
                state_sh["caches"] = shd.lm_cache_shardings(
                    mesh, state_specs["caches"], mla=cfg.mla is not None
                )
        batch_sh = {}
        for k, s in self.batch_specs(cell, reduced).items():
            if k == "index":
                batch_sh[k] = shd.named(mesh)
            else:
                batch_sh[k] = shd.named(mesh, shd.dp_axes(mesh),
                                        *([None] * (len(s.shape) - 1)))
        return state_sh, batch_sh

    # -- roofline ----------------------------------------------------------------

    def model_flops(self, cell: ShapeCell) -> float:
        cfg = self.config(False)
        d = cell.dims
        n_active = cfg.active_param_count()
        if cell.kind == "train":
            return 6.0 * n_active * d["batch"] * d["seq"]
        if cell.kind == "prefill":
            return 2.0 * n_active * d["batch"] * d["seq"]
        return 2.0 * n_active * d["batch"]

    def cost_variant(self, n_layers: int) -> "LMArch":
        """Same arch with n_layers layers, fully unrolled scans — used by the
        dry-run's exact-cost compiles (cost_analysis counts loop bodies once;
        per-layer costs extrapolate exactly for layer-homogeneous models)."""
        cfg = dataclasses.replace(
            self._full, n_layers=n_layers, scan_unroll=True,
            name=f"{self._full.name}-cost{n_layers}",
        )
        return LMArch(f"{self.arch_id}-cost{n_layers}", cfg, self._reduced)


# ===========================================================================
# GNN family
# ===========================================================================

GNN_SHAPE_DIMS = {
    # padded to multiples of 512 (total devices) for shard_map collectives
    "full_graph_sm": dict(n_nodes=3072, n_edges=10752, d_feat=1433,
                          n_classes=7, n_graphs=1, real_nodes=2708,
                          real_edges=10556),
    "minibatch_lg": dict(n_nodes=169_984, n_edges=168_960, d_feat=602,
                         n_classes=41, n_graphs=1, seeds=1024,
                         fanout=(15, 10), real_nodes=232_965,
                         real_edges=114_615_892),
    "ogb_products": dict(n_nodes=2_449_408, n_edges=61_859_328, d_feat=100,
                         n_classes=47, n_graphs=1, real_nodes=2_449_029,
                         real_edges=61_859_140),
    "molecule": dict(n_nodes=4096, n_edges=8192, d_feat=16, n_classes=10,
                     n_graphs=128, real_nodes=3840, real_edges=8192),
}

TRIPLET_CAP = 8  # max incoming edges per target edge for DimeNet triplets

_REDUCED_GRAPH = dict(n_nodes=96, n_edges=320, d_feat=12, n_classes=5,
                      n_graphs=4, real_nodes=90, real_edges=300)


class GNNArch:
    family = "gnn"

    def __init__(self, arch_id: str, model_ctor: Callable, full_cfg, reduced_cfg,
                 needs: tuple[str, ...]):
        """``needs``: subset of {x, pos, z, edge_feat, triplets}."""
        self.arch_id = arch_id
        self.model_ctor = model_ctor
        self._full = full_cfg
        self._reduced = reduced_cfg
        self.needs = needs
        self.optimizer = AdamW(OptimizerConfig())

    def config(self, reduced: bool = False):
        return self._reduced if reduced else self._full

    def shapes(self) -> list[ShapeCell]:
        return [ShapeCell(name, "train", dims)
                for name, dims in GNN_SHAPE_DIMS.items()]

    def _graph_dims(self, cell: ShapeCell, reduced: bool) -> dict:
        return _REDUCED_GRAPH if reduced else cell.dims

    def _model(self, mesh, reduced: bool):
        dist = local_dist() if mesh is None else sharded_dist(mesh)
        cfg = self.config(reduced)
        cfg = dataclasses.replace(cfg)  # copy
        return self.model_ctor(cfg, dist)

    # -- batches -----------------------------------------------------------------

    def _task(self, cell: ShapeCell) -> str:
        return "graph" if cell.name == "molecule" else "node"

    def batch_specs(self, cell: ShapeCell, reduced: bool = False):
        g = self._graph_dims(cell, reduced)
        n, e, gg = g["n_nodes"], g["n_edges"], g["n_graphs"]
        spec = {
            "src": _sds((e,), jnp.int32),
            "dst": _sds((e,), jnp.int32),
            "edge_mask": _sds((e,), jnp.bool_),
            "node_mask": _sds((n,), jnp.bool_),
            "graph_ids": _sds((n,), jnp.int32),
            "graph_mask": _sds((gg,), jnp.bool_),
        }
        if "x" in self.needs:
            spec["x"] = _sds((n, g["d_feat"]), jnp.float32)
        if "z" in self.needs:
            spec["z"] = _sds((n,), jnp.int32)
        if "pos" in self.needs:
            spec["pos"] = _sds((n, 3), jnp.float32)
        if "edge_feat" in self.needs:
            spec["edge_feat"] = _sds((e, 4), jnp.float32)
        if "triplets" in self.needs:
            t = _pad_to(e * TRIPLET_CAP, 512)
            spec["t_in"] = _sds((t,), jnp.int32)
            spec["t_out"] = _sds((t,), jnp.int32)
            spec["triplet_mask"] = _sds((t,), jnp.bool_)
        # labels / targets
        if self.arch_id in ("gin-tu",):
            if self._task(cell) == "graph":
                spec["labels"] = _sds((gg,), jnp.int32)
            else:
                spec["labels"] = _sds((n,), jnp.int32)
                spec["label_mask"] = _sds((n,), jnp.bool_)
        elif self.arch_id == "meshgraphnet":
            spec["targets"] = _sds((n, self.config(reduced).d_out), jnp.float32)
        else:  # schnet / dimenet: per-graph regression
            spec["targets"] = _sds((gg,), jnp.float32)
        return spec

    def example_batch(self, cell: ShapeCell, seed: int = 0, reduced: bool = True):
        g = self._graph_dims(cell, reduced)
        rng = np.random.default_rng(seed)
        n, e, gg = g["n_nodes"], g["n_edges"], g["n_graphs"]
        rn, re = g["real_nodes"], min(g["real_edges"], e)
        specs = self.batch_specs(cell, reduced)
        src = rng.integers(0, rn, e)
        dst = rng.integers(0, rn, e)
        out = {
            "src": src.astype(np.int32),
            "dst": dst.astype(np.int32),
            "edge_mask": (np.arange(e) < re),
            "node_mask": (np.arange(n) < rn),
            "graph_ids": (rng.integers(0, gg, n)).astype(np.int32),
            "graph_mask": np.ones(gg, bool),
        }
        if "x" in specs:
            out["x"] = rng.standard_normal((n, g["d_feat"])).astype(np.float32)
        if "z" in specs:
            out["z"] = rng.integers(0, 20, n).astype(np.int32)
        if "pos" in specs:
            out["pos"] = (rng.standard_normal((n, 3)) * 3).astype(np.float32)
        if "edge_feat" in specs:
            out["edge_feat"] = rng.standard_normal((e, 4)).astype(np.float32)
        if "t_in" in specs:
            t = specs["t_in"].shape[0]
            out["t_in"] = rng.integers(0, re, t).astype(np.int32)
            out["t_out"] = rng.integers(0, re, t).astype(np.int32)
            out["triplet_mask"] = np.ones(t, bool)
        if "labels" in specs:
            out["labels"] = rng.integers(
                0, g["n_classes"], specs["labels"].shape
            ).astype(np.int32)
        if "label_mask" in specs:
            out["label_mask"] = out["node_mask"]
        if "targets" in specs:
            out["targets"] = rng.standard_normal(specs["targets"].shape).astype(np.float32)
        out["n_graphs"] = gg
        return {k: (jnp.asarray(v) if not isinstance(v, int) else v)
                for k, v in out.items()}

    # -- state / steps ------------------------------------------------------------

    def init_state(self, rng, cell: ShapeCell, reduced: bool = False, mesh=None):
        model = self._model(mesh, reduced)
        if self.arch_id == "gin-tu":
            model.cfg.task = self._task(cell)
        params = model.init(rng)
        return {"params": params, "opt": self.optimizer.init(params),
                "step": jnp.zeros((), jnp.int32)}

    def state_specs(self, cell: ShapeCell, reduced: bool = False, mesh=None):
        rng = jax.random.PRNGKey(0)
        return jax.eval_shape(lambda: self.init_state(rng, cell, reduced, mesh))

    def make_step(self, cell: ShapeCell, reduced: bool = False, mesh=None) -> Callable:
        model = self._model(mesh, reduced)
        if self.arch_id == "gin-tu":
            model.cfg.task = self._task(cell)
        n_graphs = self._graph_dims(cell, reduced)["n_graphs"]
        opt = self.optimizer

        def train_step(state, batch):
            batch = dict(batch, n_graphs=n_graphs)
            loss, grads = jax.value_and_grad(model.loss)(state["params"], batch)
            new_params, new_opt = opt.update(state["params"], grads,
                                             state["opt"], state["step"])
            return ({"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1},
                    {"loss": loss, "grad_norm": opt.last_grad_norm(grads)})

        return train_step

    def shardings(self, mesh, cell: ShapeCell, reduced: bool = False):
        state_specs = self.state_specs(cell, reduced, mesh)
        state_sh = shd.gnn_state_shardings(mesh, state_specs)
        batch_sh = shd.gnn_batch_shardings(mesh, self.batch_specs(cell, reduced))
        return state_sh, batch_sh

    def model_flops(self, cell: ShapeCell) -> float:
        cfg = self.config(False)
        g = cell.dims
        n, e = g["n_nodes"], g["n_edges"]
        h = getattr(cfg, "d_hidden", 64)
        layers = getattr(cfg, "n_layers", None) or getattr(
            cfg, "n_interactions", None) or getattr(cfg, "n_blocks", 6)
        flops = 0.0
        if self.arch_id == "gin-tu":
            # messages are raw gathers (no per-edge matmul); cost = node MLPs
            # (first layer d_feat -> h) + E*h aggregation adds
            d_in = g["d_feat"]
            flops += 2.0 * n * d_in * h + e * h
            flops += (layers - 1) * (2.0 * n * h * h * 2 + e * h)
        elif self.arch_id == "meshgraphnet":
            # per-edge MLP(3h -> h -> h) + per-node MLP(2h -> h -> h)
            per_edge = 2.0 * e * (3 * h * h + h * h)
            per_node = 2.0 * n * (2 * h * h + h * h)
            flops += layers * (per_edge + per_node)
        elif self.arch_id == "schnet":
            n_rbf = getattr(cfg, "n_rbf", 300)
            per_edge = 2.0 * e * (n_rbf * h + h * h + h)   # filter MLP + modulate
            per_node = 2.0 * n * (3 * h * h)               # in/mid/out denses
            flops += layers * (per_edge + per_node)
        else:  # dimenet: triplet bilinear dominates
            t = e * TRIPLET_CAP
            nb = getattr(cfg, "n_bilinear", 8)
            per_block = 2.0 * t * (nb * h + nb * h * h / nb) + 2.0 * e * (3 * h * h)
            flops += layers * per_block + 2.0 * e * 3 * h * h
        # 3x for fwd+bwd
        return 3.0 * flops


# ===========================================================================
# RecSys family
# ===========================================================================

RECSYS_SHAPE_DIMS = {
    "train_batch": dict(batch=65_536),
    "serve_p99": dict(batch=512),
    "serve_bulk": dict(batch=262_144),
    "retrieval_cand": dict(batch=1, n_candidates=1_048_576),
}


class RecSysArch:
    family = "recsys"

    def __init__(self, arch_id: str, full_cfg, reduced_cfg):
        from repro.models.recsys import XDeepFM  # local import to avoid cycles
        self.arch_id = arch_id
        self._full = full_cfg
        self._reduced = reduced_cfg
        self._ctor = XDeepFM
        self.optimizer = AdamW(OptimizerConfig(lr=1e-3))

    def config(self, reduced: bool = False):
        return self._reduced if reduced else self._full

    def shapes(self) -> list[ShapeCell]:
        return [
            ShapeCell("train_batch", "train", RECSYS_SHAPE_DIMS["train_batch"]),
            ShapeCell("serve_p99", "serve", RECSYS_SHAPE_DIMS["serve_p99"]),
            ShapeCell("serve_bulk", "serve", RECSYS_SHAPE_DIMS["serve_bulk"]),
            ShapeCell("retrieval_cand", "retrieval",
                      RECSYS_SHAPE_DIMS["retrieval_cand"]),
        ]

    def _model(self, mesh, reduced: bool):
        return self._ctor(self.config(reduced), mesh=mesh)

    def _batch_size(self, cell: ShapeCell, reduced: bool) -> int:
        if cell.kind == "retrieval":
            b = cell.dims["n_candidates"]
        else:
            b = cell.dims["batch"]
        return max(4, b // 1024) if reduced else b

    def batch_specs(self, cell: ShapeCell, reduced: bool = False):
        cfg = self.config(reduced)
        b = self._batch_size(cell, reduced)
        f_single = cfg.n_fields - cfg.n_multihot
        spec = {
            "idx_single": _sds((b, f_single), jnp.int32),
            "idx_multi": _sds((b, cfg.n_multihot, cfg.bag_size), jnp.int32),
            "w_multi": _sds((b, cfg.n_multihot, cfg.bag_size), jnp.float32),
        }
        if cell.kind == "train":
            spec["labels"] = _sds((b,), jnp.int32)
        return spec

    def example_batch(self, cell: ShapeCell, seed: int = 0, reduced: bool = True):
        cfg = self.config(reduced)
        rng = np.random.default_rng(seed)
        b = self._batch_size(cell, reduced)
        f_single = cfg.n_fields - cfg.n_multihot
        offs = cfg.field_offsets
        idx_single = np.stack(
            [rng.integers(0, cfg.vocab_sizes[f], b) + offs[f]
             for f in range(f_single)], axis=1,
        ).astype(np.int32)
        idx_multi = np.stack(
            [rng.integers(0, cfg.vocab_sizes[f_single + f],
                          (b, cfg.bag_size)) + offs[f_single + f]
             for f in range(cfg.n_multihot)], axis=1,
        ).astype(np.int32)
        out = {
            "idx_single": jnp.asarray(idx_single),
            "idx_multi": jnp.asarray(idx_multi),
            "w_multi": jnp.asarray(
                (rng.random((b, cfg.n_multihot, cfg.bag_size)) < 0.7)
                .astype(np.float32)),
        }
        if cell.kind == "train":
            out["labels"] = jnp.asarray(rng.integers(0, 2, b), jnp.int32)
        return out

    def init_state(self, rng, cell: ShapeCell, reduced: bool = False, mesh=None):
        model = self._model(mesh, reduced)
        params = model.init(rng)
        if cell.kind == "train":
            return {"params": params, "opt": self.optimizer.init(params),
                    "step": jnp.zeros((), jnp.int32)}
        return {"params": params}

    def state_specs(self, cell: ShapeCell, reduced: bool = False, mesh=None):
        rng = jax.random.PRNGKey(0)
        return jax.eval_shape(lambda: self.init_state(rng, cell, reduced, mesh))

    def make_step(self, cell: ShapeCell, reduced: bool = False, mesh=None) -> Callable:
        model = self._model(mesh, reduced)
        opt = self.optimizer
        if cell.kind == "train":
            def train_step(state, batch):
                loss, grads = jax.value_and_grad(model.loss)(state["params"], batch)
                new_params, new_opt = opt.update(state["params"], grads,
                                                 state["opt"], state["step"])
                return ({"params": new_params, "opt": new_opt,
                         "step": state["step"] + 1},
                        {"loss": loss, "grad_norm": opt.last_grad_norm(grads)})
            return train_step

        def serve(state, batch):
            return model.serve_step(state["params"], batch)
        return serve

    def shardings(self, mesh, cell: ShapeCell, reduced: bool = False):
        state_specs = self.state_specs(cell, reduced, mesh)
        if cell.kind == "train":
            state_sh = shd.recsys_state_shardings(mesh, state_specs)
        else:
            state_sh = {"params": shd.recsys_param_shardings(
                mesh, state_specs["params"])}
        batch_sh = shd.recsys_batch_shardings(
            mesh, self.batch_specs(cell, reduced))
        return state_sh, batch_sh

    def model_flops(self, cell: ShapeCell) -> float:
        cfg = self.config(False)
        b = self._batch_size(cell, False)
        f, d = cfg.n_fields, cfg.embed_dim
        flops = 0.0
        h_prev = f
        for h in cfg.cin_layers:
            flops += 2.0 * b * h * h_prev * f * d
            h_prev = h
        dims = [f * d] + list(cfg.mlp_dims) + [1]
        for i in range(len(dims) - 1):
            flops += 2.0 * b * dims[i] * dims[i + 1]
        mult = 3.0 if cell.kind == "train" else 1.0
        return mult * flops
