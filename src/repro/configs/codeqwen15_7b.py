"""codeqwen1.5-7b [dense]: 32L d_model=4096 32H (GQA kv=32 = MHA)
d_ff=13440 vocab=92416, QKV bias (qwen1.5 arch)
[hf:Qwen/CodeQwen1.5-7B; hf]."""

from repro.configs.base import LMArch
from repro.models.transformer import LMConfig

FULL = LMConfig(
    name="codeqwen1.5-7b",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32,
    d_ff=13440, vocab=92416, qkv_bias=True,
)

REDUCED = LMConfig(
    name="codeqwen-reduced",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=512,
    qkv_bias=True, remat=False,
)

ARCH = LMArch("codeqwen1.5-7b", FULL, REDUCED)
