"""dimenet [gnn]: 6 blocks, hidden 128, 8 bilinear, 7 spherical x 6 radial
[arXiv:2003.03123; pool-marked unverified — listed values used].

Large-graph shapes cap triplets at K=8 incoming edges per target edge
(DESIGN.md §4); the ogb_products cell uses the ring edge-gather.
"""

from repro.configs.base import GNNArch
from repro.models.gnn import DimeNet, DimeNetConfig


def _ctor(cfg, dist):
    return DimeNet(cfg, dist)


FULL = DimeNetConfig(name="dimenet", n_blocks=6, d_hidden=128, n_bilinear=8,
                     n_spherical=7, n_radial=6, cutoff=5.0)
REDUCED = DimeNetConfig(name="dimenet-reduced", n_blocks=2, d_hidden=16,
                        n_bilinear=4, n_spherical=3, n_radial=4, cutoff=5.0)

ARCH = GNNArch("dimenet", _ctor, FULL, REDUCED,
               needs=("z", "pos", "triplets"))
