"""phi3.5-moe-42b-a6.6b [moe]: 32L d_model=4096 32H (GQA kv=8) d_ff=6400
vocab=32064, 16 experts top-2 [hf:microsoft/Phi-3.5-MoE-instruct; hf]."""

from repro.configs.base import LMArch
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig

FULL = LMConfig(
    name="phi3.5-moe-42b-a6.6b",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=6400, vocab=32064,
    moe=MoEConfig(d_model=4096, d_ff_expert=6400, n_experts=16, top_k=2),
)

REDUCED = LMConfig(
    name="phi3.5-moe-reduced",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96, vocab=512,
    remat=False,
    moe=MoEConfig(d_model=64, d_ff_expert=96, n_experts=4, top_k=2),
)

ARCH = LMArch("phi3.5-moe-42b-a6.6b", FULL, REDUCED)
