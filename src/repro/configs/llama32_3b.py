"""llama3.2-3b [dense]: 28L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=128256 [hf:meta-llama/Llama-3.2-1B; pool-marked UNVERIFIED — the
assignment's listed values are used verbatim]."""

from repro.configs.base import LMArch
from repro.models.transformer import LMConfig

FULL = LMConfig(
    name="llama3.2-3b",
    n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8,
    d_ff=8192, vocab=128256, rope_theta=500000.0,
)

REDUCED = LMConfig(
    name="llama32-reduced",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    remat=False,
)

ARCH = LMArch("llama3.2-3b", FULL, REDUCED)
