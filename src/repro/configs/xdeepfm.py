"""xdeepfm [recsys]: 39 sparse fields, embed_dim 10, CIN 200-200-200,
MLP 400-400 [arXiv:1803.05170; paper].  Vocab sizes are criteo-skewed
(8 x 2^21 + 10 x 2^17 + 10 x 2^13 + 11 x 2^9 = 18.2M rows); 4 fields are
multi-hot (bag 8) to exercise the EmbeddingBag kernel."""

from repro.configs.base import RecSysArch
from repro.models.recsys import XDeepFMConfig

FULL = XDeepFMConfig(
    name="xdeepfm", embed_dim=10, cin_layers=(200, 200, 200),
    mlp_dims=(400, 400),
    vocab_sizes=tuple([2 ** 21] * 8 + [2 ** 17] * 10 + [2 ** 13] * 10
                      + [2 ** 9] * 11),
    n_multihot=4, bag_size=8,
)

REDUCED = XDeepFMConfig(
    name="xdeepfm-reduced", embed_dim=4, cin_layers=(8, 8), mlp_dims=(16, 16),
    vocab_sizes=tuple([256] * 4 + [64] * 4), n_multihot=2, bag_size=4,
)

ARCH = RecSysArch("xdeepfm", FULL, REDUCED)
