"""deepseek-v2-lite-16b [moe]: 27L d_model=2048 16H d_ff(expert)=1408
vocab=102400, MLA kv_lora=512, 64 routed experts top-6 + 2 shared
[arXiv:2405.04434; hf].

The assignment line also mentions "160 routed" (the 236B V2-full config);
we follow the published V2-Lite values consistent with "16b" and
"64e top-6" (DESIGN.md §4).
"""

from repro.configs.base import LMArch
from repro.models.layers import MLAConfig
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig

FULL = LMConfig(
    name="deepseek-v2-lite-16b",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=102400,
    mla=MLAConfig(d_model=2048, n_heads=16, kv_lora_rank=512,
                  qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128),
    moe=MoEConfig(d_model=2048, d_ff_expert=1408, n_experts=64, top_k=6,
                  n_shared=2),
)

REDUCED = LMConfig(
    name="deepseek-v2-lite-16b-reduced",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=96, vocab=512,
    remat=False,
    mla=MLAConfig(d_model=64, n_heads=4, kv_lora_rank=32,
                  qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16),
    moe=MoEConfig(d_model=64, d_ff_expert=48, n_experts=8, top_k=2, n_shared=2),
)

ARCH = LMArch("deepseek-v2-lite-16b", FULL, REDUCED)
