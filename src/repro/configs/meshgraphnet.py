"""meshgraphnet [gnn]: 15 layers, hidden 128, sum aggregator, 2-layer MLPs
[arXiv:2010.03409; pool-marked unverified — listed values used]."""

import dataclasses

from repro.configs.base import GNNArch
from repro.models.gnn import MeshGraphNet, MGNConfig


def _ctor(cfg, dist):
    return MeshGraphNet(cfg, dist)


FULL = MGNConfig(name="meshgraphnet", n_layers=15, d_hidden=128, d_in=16,
                 d_edge_in=4, d_out=3, mlp_layers=2)
REDUCED = MGNConfig(name="meshgraphnet-reduced", n_layers=3, d_hidden=24,
                    d_in=12, d_edge_in=4, d_out=3, mlp_layers=2)


class MGNArch(GNNArch):
    def make_step(self, cell, reduced=False, mesh=None):
        g = self._graph_dims(cell, reduced)
        self._full = dataclasses.replace(self._full, d_in=g["d_feat"])
        return super().make_step(cell, reduced, mesh)

    def init_state(self, rng, cell, reduced=False, mesh=None):
        g = self._graph_dims(cell, reduced)
        self._full = dataclasses.replace(self._full, d_in=g["d_feat"])
        return super().init_state(rng, cell, reduced, mesh)


ARCH = MGNArch("meshgraphnet", _ctor, FULL, REDUCED,
               needs=("x", "pos", "edge_feat"))
