"""gin-tu [gnn]: 5 layers, hidden 64, sum aggregator, learnable eps
[arXiv:1810.00826; paper]."""

import dataclasses

from repro.configs.base import GNNArch, GNN_SHAPE_DIMS
from repro.models.gnn import GIN, GINConfig


def _ctor(cfg, dist):
    return GIN(cfg, dist)


FULL = GINConfig(name="gin-tu", n_layers=5, d_hidden=64, d_in=1433,
                 n_classes=47, task="node")
REDUCED = GINConfig(name="gin-tu-reduced", n_layers=2, d_hidden=16, d_in=12,
                    n_classes=5, task="node")


class GINArch(GNNArch):
    """GIN's input dim / classes track the dataset shape cell."""

    def make_step(self, cell, reduced=False, mesh=None):
        # adapt d_in / n_classes to the cell's dataset before building
        g = self._graph_dims(cell, reduced)
        self._full = dataclasses.replace(
            self._full, d_in=g["d_feat"], n_classes=g["n_classes"],
            task=self._task(cell))
        self._reduced = dataclasses.replace(
            self._reduced, task=self._task(cell))
        return super().make_step(cell, reduced, mesh)

    def init_state(self, rng, cell, reduced=False, mesh=None):
        g = self._graph_dims(cell, reduced)
        self._full = dataclasses.replace(
            self._full, d_in=g["d_feat"], n_classes=g["n_classes"],
            task=self._task(cell))
        self._reduced = dataclasses.replace(
            self._reduced, task=self._task(cell))
        return super().init_state(rng, cell, reduced, mesh)


ARCH = GINArch("gin-tu", _ctor, FULL, REDUCED, needs=("x",))
