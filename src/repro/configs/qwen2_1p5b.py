"""qwen2-1.5b [dense]: 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936, QKV bias [arXiv:2407.10671; hf]."""

from repro.configs.base import LMArch
from repro.models.transformer import LMConfig

FULL = LMConfig(
    name="qwen2-1.5b",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
    d_ff=8960, vocab=151936, qkv_bias=True, tie_embeddings=True,
)

REDUCED = LMConfig(
    name="qwen2-reduced",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    qkv_bias=True, tie_embeddings=True, remat=False,
)

ARCH = LMArch("qwen2-1.5b", FULL, REDUCED)
