"""The paper's own workload configuration: GraphLake over LDBC_SNB tables.

Not one of the 10 assigned architectures — this is the engine-side config
the benchmarks and examples consume (scale factors, cache budgets, file
counts), mirroring the paper's §7.1 experimental setup at container scale.
"""

import dataclasses


@dataclasses.dataclass
class GraphLakeConfig:
    scale_factor: float = 0.01
    n_files_per_table: int = 4        # paper uses 32 (one per vCPU)
    row_group_rows: int = 16384
    memory_budget_mb: int = 256
    disk_budget_mb: int = 2048
    edge_window: int = 4096
    n_io_threads: int = 8
    enable_prefetch: bool = True
    materialize_topology: bool = True
    store_latency_scale: float = 0.0  # 1.0 = simulate S3 latency


DEFAULT = GraphLakeConfig()
BENCH = GraphLakeConfig(scale_factor=0.03, store_latency_scale=1.0)
