"""schnet [gnn]: 3 interactions, hidden 64, 300 RBF, cutoff 10
[arXiv:1706.08566; paper]."""

from repro.configs.base import GNNArch
from repro.models.gnn import SchNet, SchNetConfig


def _ctor(cfg, dist):
    return SchNet(cfg, dist)


FULL = SchNetConfig(name="schnet", n_interactions=3, d_hidden=64,
                    n_rbf=300, cutoff=10.0)
REDUCED = SchNetConfig(name="schnet-reduced", n_interactions=2, d_hidden=16,
                       n_rbf=24, cutoff=10.0)

ARCH = GNNArch("schnet", _ctor, FULL, REDUCED, needs=("z", "pos"))
