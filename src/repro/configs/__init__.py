"""Architecture configs: one module per assigned arch + the paper's own
GraphLake/LDBC config.  ``registry.get_arch(arch_id)`` is the public entry."""

from repro.configs.registry import ARCH_IDS, get_arch

__all__ = ["ARCH_IDS", "get_arch"]
