"""Serving layer: batched graph-analytics query serving over GraphLake."""

from repro.serving.server import (
    QueryServer,
    ServerConfig,
    ServerOverloadedError,
    TenantQuotaExceededError,
    latency_stats,
)

__all__ = ["QueryServer", "ServerConfig", "ServerOverloadedError",
           "TenantQuotaExceededError", "latency_stats"]
