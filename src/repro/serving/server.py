"""Batched query serving over GraphLake (the paper's wrk2-driven evaluation,
§7.5, as an in-process server).

Clients submit named queries with parameters; worker threads drain the queue
and execute against a shared engine (the engine's cache manager is
thread-safe, so concurrent queries share warmed cache units exactly like the
paper's multi-connection evaluation).  Latency percentiles and throughput
are recorded for the scalability benchmark.

Concurrent queries also share the engine's query-time ``IOPool``
(DESIGN.md §5): each worker's scans issue their chunk-fetch batches through
the one pool, so the modeled object-store parallel-stream budget is a
per-engine resource — adding server workers raises concurrency without
multiplying in-flight lake requests.  The cache manager's single-flight
admission guarantees that two workers racing over the same cold chunk pay
its lake fetch once.

**Freshness (DESIGN.md §7).**  A background refresher thread periodically
calls the engine's ``advance()``: the epoch manager diffs the lake, applies
incremental deltas and atomically publishes a new epoch, while queries
already in flight keep draining on the epoch they pinned at start.  Serving
therefore picks up lake commits continuously — no engine restart — and
every ``repro.core.query.QueryResult`` carries the epoch id + staleness it
was served at.  The interval comes from ``ServerConfig.refresh_interval_s``
or, when unset, the ``refresh`` perf flag (``refresh=<seconds>``).
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Callable, Optional

from repro import perf_flags


@dataclasses.dataclass
class ServerConfig:
    n_workers: int = 2
    max_queue: int = 256
    # background epoch-refresh interval; None defers to the ``refresh`` perf
    # flag (its numeric value, default 30 s), <= 0 disables outright
    refresh_interval_s: Optional[float] = None


@dataclasses.dataclass
class QueryResult:
    request_id: int
    ok: bool
    value: object
    error: Optional[str]
    queued_s: float
    service_s: float


class QueryServer:
    """query_fns: name -> fn(engine, **params) -> value."""

    def __init__(self, engine, query_fns: dict[str, Callable],
                 config: Optional[ServerConfig] = None):
        self.engine = engine
        self.query_fns = query_fns
        self.config = config or ServerConfig()
        self._q: queue.Queue = queue.Queue(maxsize=self.config.max_queue)
        self._results: dict[int, QueryResult] = {}
        self._done = threading.Event()
        self._lock = threading.Lock()
        self._next_id = 0
        self._workers = [
            threading.Thread(target=self._worker, daemon=True)
            for _ in range(self.config.n_workers)
        ]
        for w in self._workers:
            w.start()
        # background epoch refresher (DESIGN.md §7)
        self.refresh_stats = {"ticks": 0, "advanced": 0, "errors": 0,
                              "last_epoch": -1}
        self._refresh_stop = threading.Event()
        self._refresher: Optional[threading.Thread] = None
        interval = self.config.refresh_interval_s
        if interval is None and perf_flags.enabled("refresh"):
            interval = perf_flags.value("refresh", 30.0)
        if interval is not None and interval > 0 and hasattr(engine, "advance"):
            self._refresher = threading.Thread(
                target=self._refresh_loop, args=(float(interval),), daemon=True
            )
            self._refresher.start()

    # -- client API -------------------------------------------------------------

    def submit(self, query: str, **params) -> int:
        with self._lock:
            rid = self._next_id
            self._next_id += 1
        self._q.put((rid, query, params, time.perf_counter()))
        return rid

    def result(self, rid: int, timeout_s: float = 60.0) -> QueryResult:
        deadline = time.monotonic() + timeout_s
        while time.monotonic() < deadline:
            with self._lock:
                if rid in self._results:
                    return self._results.pop(rid)
            time.sleep(0.001)
        raise TimeoutError(f"request {rid}")

    def run_batch(self, requests: list[tuple[str, dict]]) -> list[QueryResult]:
        """Submit a batch, wait for all, return results in order."""
        rids = [self.submit(q, **p) for q, p in requests]
        return [self.result(r) for r in rids]

    def close(self) -> None:
        self._refresh_stop.set()
        for _ in self._workers:
            self._q.put(None)
        for w in self._workers:
            w.join()
        if self._refresher is not None:
            self._refresher.join(timeout=10.0)

    # -- background refresher ------------------------------------------------------

    def _refresh_loop(self, interval_s: float) -> None:
        """Periodically advance the engine's epoch: in-flight queries drain
        on their pinned epoch, the next query picks up the new one."""
        while not self._refresh_stop.wait(interval_s):
            try:
                report = self.engine.advance()
                self.refresh_stats["ticks"] += 1
                self.refresh_stats["last_epoch"] = report.to_epoch
                if report.changed:   # last: pollers key off this counter
                    self.refresh_stats["advanced"] += 1
            except Exception:  # keep refreshing; queries stay on the old epoch
                self.refresh_stats["errors"] += 1

    # -- worker -------------------------------------------------------------------

    def _worker(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            rid, name, params, t_submit = item
            t_start = time.perf_counter()
            try:
                fn = self.query_fns[name]
                value = fn(self.engine, **params)
                ok, err = True, None
            except Exception as e:  # report, don't kill the worker
                value, ok, err = None, False, f"{type(e).__name__}: {e}"
            t_end = time.perf_counter()
            with self._lock:
                self._results[rid] = QueryResult(
                    request_id=rid, ok=ok, value=value, error=err,
                    queued_s=t_start - t_submit, service_s=t_end - t_start,
                )


def latency_stats(results: list[QueryResult]) -> dict:
    lats = sorted(r.service_s for r in results if r.ok)
    if not lats:
        return {"count": 0}
    pick = lambda q: lats[min(len(lats) - 1, int(q * len(lats)))]
    return {
        "count": len(lats),
        "mean_s": sum(lats) / len(lats),
        "p50_s": pick(0.50),
        "p95_s": pick(0.95),
        "p99_s": pick(0.99),
        "max_s": lats[-1],
    }
