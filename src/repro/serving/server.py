"""Batched query serving over GraphLake (the paper's wrk2-driven evaluation,
§7.5, as an in-process server).

Clients submit named queries with parameters; a scheduler thread groups
them, worker threads execute against a shared engine (the engine's cache
manager is thread-safe, so concurrent queries share warmed cache units
exactly like the paper's multi-connection evaluation).  Latency percentiles
and throughput are recorded for the scalability benchmark.

**Shared-scan batching (DESIGN.md §9).**  Requests for the *same installed
template* that arrive within a short window coalesce into one *shared-scan
batch*: the scheduler holds a template's first request for
``batch_window_ms``, collects riders, and dispatches the group as a single
``session.query_batch()`` — one gather per hop over the union frontier, one
chunk fetch/decode pass per stage, per-rider masks, one pinned epoch for
the whole group (the (template, epoch) grouping is implicit: a batch
acquires its epoch at execution, so all riders see the same snapshot).
Each rider's result is bit-identical to a solo ``session.query()`` on that
epoch.  The window comes from ``ServerConfig.batch_window_ms`` or, when
unset, the ``batch`` perf flag (``batch=<window_ms>``, default 2 ms);
``<= 0`` or the flag off restores the per-request path.

**Point-lookup routing (DESIGN.md §10).**  Requests for installed
green/yellow templates — point lookups and single-hop reads classified at
``install()`` time — route *around* the batching scheduler: they dispatch
immediately (never waiting out ``batch_window_ms``) and execute through
``session.lookup()``'s plan-cached fast path (IDM probe + CSR slice against
the pinned epoch, no compile, no staged scan).  ``stats["lookup_requests"]``
/ ``stats["route_green"]`` / ``stats["route_yellow"]`` count them; results
are bit-identical to the full engine, stamped ``route="lookup"``.

**Priority lanes + tenant quotas.**  Requests carry a ``priority`` lane
(0 = high, larger = later; batches never mix lanes) and a ``tenant`` label:
with ``ServerConfig.tenant_quota`` set, a tenant may only hold that many
requests in flight — the excess is shed with :class:`TenantQuotaExceededError`
(a :class:`ServerOverloadedError`), so one hot tenant cannot starve the
queue for everyone else.

Concurrent queries also share the engine's query-time ``IOPool``
(DESIGN.md §5): each scan issues its chunk-fetch batches through the one
pool, so the modeled object-store parallel-stream budget is a per-engine
resource.  The cache manager's single-flight admission guarantees that two
workers racing over the same cold chunk pay its lake fetch once.

**Freshness (DESIGN.md §7).**  A background refresher thread periodically
calls the engine's ``advance()``: the epoch manager diffs the lake, applies
incremental deltas and atomically publishes a new epoch, while queries
already in flight keep draining on the epoch they pinned at start.  The
interval comes from ``ServerConfig.refresh_interval_s`` or, when unset, the
``refresh`` perf flag (``refresh=<seconds>``).

**Degrade-to-stale (DESIGN.md §11).**  The refresher carries a circuit
breaker: failed advances back off exponentially and record ``last_error``;
``breaker_threshold`` *consecutive* failures open the breaker.  Open means
the server stops paying for doomed refresh attempts and keeps serving the
last good pinned epoch — results stay bit-correct for that snapshot, with
``QueryResult.staleness_s`` honestly growing and ``degraded=True`` stamped
on both the serving envelope and the engine result.  After
``breaker_cooldown_s`` the refresher goes *half-open*: one probe advance;
success closes the breaker (degraded stamping stops), failure re-opens it.
``health()`` snapshots the whole picture: breaker state, last advance
error, refresh/retry/hedge counters, epoch freshness, queue depth.

**Installed queries (DESIGN.md §8).**  The server fronts a
:class:`~repro.gsql.session.GraphSession`: any query *installed* on the
session is servable by name with bound parameters —
``submit("bi1", tag="Music", date=20100101)``.  Plain callables
(``query_fns``) remain for result-shaping wrappers; they receive the engine
and always execute solo (opaque callables cannot ride a shared scan).

**Admission control + timeouts.**  ``submit()`` never blocks the client: a
full bounded queue raises :class:`ServerOverloadedError` (typed, so callers
can shed load / retry with backoff).  ``ServerConfig.timeout_s`` bounds
each installed query's execution; ``ServerConfig.total_timeout_s`` is the
*queue-time-aware* budget — a request whose queue wait already exhausted it
fails as a ``QueryTimeoutError`` result **without executing**, and an
admitted request runs with only its remaining budget.  A shared-scan batch
runs on the most patient rider's remaining budget (already-expired riders
were failed out before dispatch, so batching never extends anyone's wait
past what admission allowed).

**Results.**  ``result(rid)`` parks on a per-request ``threading.Event`` —
completion wakes the waiter immediately; queue-time/service-time accounting
is measured at dispatch, not collection.  Completed results a caller never
collects are evicted after ``ServerConfig.result_ttl_s`` (counted in
``server.stats["evicted_results"]``) so an abandoning client cannot leak
the results dict.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Callable, Optional

from repro import perf_flags
from repro.core.query import ExecOptions
# the server's typed errors now live in repro.errors (the consolidated
# typed-error surface, common ReproError base); re-exported here for one
# release
from repro.errors import (  # noqa: F401
    QueryTimeoutError,
    ServerOverloadedError,
    TenantQuotaExceededError,
)
from repro.gsql.session import GraphSession


@dataclasses.dataclass
class ServerConfig:
    n_workers: int = 2
    max_queue: int = 256
    # background epoch-refresh interval; None defers to the ``refresh`` perf
    # flag (its numeric value, default 30 s), <= 0 disables outright
    refresh_interval_s: Optional[float] = None
    # per-query execution timeout for installed queries (None = no bound);
    # overrides the session's ExecOptions.timeout_s while serving
    timeout_s: Optional[float] = None
    # queue-time-aware total budget per request (None = no bound): queue
    # wait counts against it, an expired request fails without executing,
    # and an admitted one runs with the remaining budget only
    total_timeout_s: Optional[float] = None
    # shared-scan batching window (DESIGN.md §9); None defers to the
    # ``batch`` perf flag (``batch=<window_ms>``, default 2 ms), <= 0 (or
    # the flag off) disables batching — the per-request parity path
    batch_window_ms: Optional[float] = None
    # riders per shared-scan batch cap (a flush happens at whichever of
    # window expiry / max_batch_riders comes first)
    max_batch_riders: int = 64
    # max in-flight requests per tenant (None = unlimited)
    tenant_quota: Optional[int] = None
    # completed-but-uncollected results are evicted after this many seconds
    result_ttl_s: float = 60.0
    # refresh circuit breaker (DESIGN.md §11): this many *consecutive*
    # failed advances open it ...
    breaker_threshold: int = 3
    # ... and after this long open, one half-open probe decides whether it
    # closes (success) or re-opens (failure)
    breaker_cooldown_s: float = 5.0


@dataclasses.dataclass
class QueryResult:
    request_id: int
    ok: bool
    value: object
    error: Optional[str]
    queued_s: float
    service_s: float
    # True when the refresh breaker was non-closed at execution: the result
    # was served from the last good pinned epoch (stale but bit-correct for
    # that snapshot); the engine-level value carries the same stamp
    degraded: bool = False


@dataclasses.dataclass
class _Request:
    rid: int
    name: str
    params: dict
    tenant: str
    priority: int
    t_submit: float             # perf_counter at submit (queue accounting)
    t_mono: float               # monotonic at submit (total-budget clock)


class QueryServer:
    """Serves a session's installed GSQL queries by name, plus optional
    result-shaping callables (``query_fns``: name -> fn(engine, **params)).
    ``backend`` is a :class:`GraphSession` or a bare engine (a cached
    session is created for it); installed names resolve through
    ``session.query()`` / ``session.query_batch()``, callables win on a
    name clash."""

    def __init__(self, backend, query_fns: Optional[dict[str, Callable]] = None,
                 config: Optional[ServerConfig] = None):
        if isinstance(backend, GraphSession):
            self.session = backend
        else:
            self.session = GraphSession.for_engine(backend)
        self.engine = self.session.engine
        self.query_fns = query_fns or {}
        self.config = config or ServerConfig()
        # serving-time execution defaults: the session's, capped by the
        # server's per-query timeout when one is configured
        self._exec_options: Optional[ExecOptions] = None
        if self.config.timeout_s is not None:
            self._exec_options = dataclasses.replace(
                self.session.options, timeout_s=self.config.timeout_s)
        window = self.config.batch_window_ms
        if window is None:
            window = (perf_flags.value("batch", 2.0)
                      if perf_flags.enabled("batch") else 0.0)
        self._window_s = max(0.0, float(window)) / 1000.0
        self._q: queue.Queue = queue.Queue(maxsize=self.config.max_queue)
        # scheduler -> workers: ((priority, seq), unit); unit is
        # ("lookup", req) | ("single", req) | ("batch", [reqs]) | None
        # (worker shutdown)
        self._exec_q: queue.PriorityQueue = queue.PriorityQueue()
        self._seq = 0
        self._results: dict[int, QueryResult] = {}
        self._done_at: dict[int, float] = {}
        self._waiters: dict[int, threading.Event] = {}
        self._tenant_inflight: dict[str, int] = {}
        self._lock = threading.Lock()
        self._next_id = 0
        self.stats = {
            "batches": 0,            # shared-scan groups dispatched
            "batched_requests": 0,   # requests served by a shared scan
            "solo_requests": 0,      # requests served per-request
            "max_batch_riders": 0,   # largest group so far
            "shed_queue_full": 0,    # ServerOverloadedError (queue)
            "shed_tenant_quota": 0,  # TenantQuotaExceededError
            "expired_in_queue": 0,   # total budget gone before dispatch
            "evicted_results": 0,    # TTL-evicted uncollected results
            "lookup_requests": 0,    # served by the point-lookup fast path
            "route_green": 0,        # ... of which needed no lake columns
            "route_yellow": 0,       # ... of which paid a column fetch path
        }
        # wire-surface dispatch counters (handle()): per-route hits + errors,
        # surfaced by health() under "routes"
        self.route_stats = {"/vertex": 0, "/neighbors": 0, "/query": 0,
                            "/lookup": 0, "/health": 0, "errors": 0}
        self._scheduler = threading.Thread(target=self._schedule, daemon=True)
        self._scheduler.start()
        self._workers = [
            threading.Thread(target=self._worker, daemon=True)
            for _ in range(self.config.n_workers)
        ]
        for w in self._workers:
            w.start()
        # background epoch refresher (DESIGN.md §7) + circuit breaker (§11)
        self.refresh_stats = {"ticks": 0, "advanced": 0, "errors": 0,
                              "last_epoch": -1, "last_error": None,
                              "consecutive_failures": 0, "breaker_opens": 0,
                              "half_open_probes": 0, "breaker_closes": 0}
        self._breaker_state = "closed"   # "closed" | "open" | "half_open"
        self._refresh_stop = threading.Event()
        self._refresher: Optional[threading.Thread] = None
        interval = self.config.refresh_interval_s
        if interval is None and perf_flags.enabled("refresh"):
            interval = perf_flags.value("refresh", 30.0)
        if interval is not None and interval > 0 and hasattr(self.engine, "advance"):
            self._refresher = threading.Thread(
                target=self._refresh_loop, args=(float(interval),), daemon=True
            )
            self._refresher.start()

    # -- client API -------------------------------------------------------------

    def submit(self, query: str, *, tenant: str = "default",
               priority: int = 1, **params) -> int:
        """Enqueue one request; raises :class:`ServerOverloadedError` when
        the bounded queue is full and :class:`TenantQuotaExceededError` when
        ``tenant`` already holds its quota of in-flight requests (admission
        control — never blocks).  ``priority`` selects the dispatch lane
        (0 = high, larger = later; default 1)."""
        with self._lock:
            quota = self.config.tenant_quota
            held = self._tenant_inflight.get(tenant, 0)
            if quota is not None and held >= quota:
                self.stats["shed_tenant_quota"] += 1
                raise TenantQuotaExceededError(
                    f"tenant {tenant!r} holds {held} in-flight requests "
                    f"(quota {quota}); shed request ({query})")
            rid = self._next_id
            self._next_id += 1
            self._tenant_inflight[tenant] = held + 1
        req = _Request(rid=rid, name=query, params=params, tenant=tenant,
                       priority=priority, t_submit=time.perf_counter(),
                       t_mono=time.monotonic())
        try:
            self._q.put_nowait(req)
        except queue.Full:
            with self._lock:
                self._release_tenant(req.tenant)
                self.stats["shed_queue_full"] += 1
            raise ServerOverloadedError(
                f"request queue full ({self.config.max_queue} pending); "
                f"shed request {rid!r} ({query})") from None
        return rid

    def result(self, rid: int, timeout_s: float = 60.0) -> QueryResult:
        """Wait for one request's result (parks on the request's completion
        event — no polling; collection removes the entry)."""
        with self._lock:
            if rid in self._results:
                self._done_at.pop(rid, None)
                self._waiters.pop(rid, None)
                return self._results.pop(rid)
            ev = self._waiters.setdefault(rid, threading.Event())
        if not ev.wait(timeout_s):
            with self._lock:
                self._waiters.pop(rid, None)
            raise TimeoutError(f"request {rid}")
        with self._lock:
            self._done_at.pop(rid, None)
            self._waiters.pop(rid, None)
            res = self._results.pop(rid, None)
        if res is None:  # evicted between wake-up and collection
            raise TimeoutError(f"request {rid}")
        return res

    def run_batch(self, requests: list[tuple[str, dict]]) -> list[QueryResult]:
        """Submit a batch, wait for all, return results in order.

        A batch driver *chooses* to wait, so overload here backs off and
        retries instead of propagating :class:`ServerOverloadedError` —
        batches larger than the bounded queue drain through it; only direct
        ``submit()`` callers see admission rejections."""
        rids = []
        for q, p in requests:
            while True:
                try:
                    rids.append(self.submit(q, **p))
                    break
                except ServerOverloadedError:
                    time.sleep(0.001)
        return [self.result(r) for r in rids]

    def close(self) -> None:
        self._refresh_stop.set()
        self._q.put(None)           # scheduler: drain, flush, stop workers
        self._scheduler.join()
        for w in self._workers:
            w.join()
        if self._refresher is not None:
            self._refresher.join(timeout=10.0)

    # -- background refresher ------------------------------------------------------

    def _refresh_loop(self, interval_s: float) -> None:
        """Periodically advance the engine's epoch: in-flight queries drain
        on their pinned epoch, the next query picks up the new one.

        Failure handling (DESIGN.md §11): each failed tick records
        ``last_error`` and doubles the wait (exponential backoff, capped at
        ``breaker_cooldown_s``-or-32x) instead of hammering a broken lake at
        full cadence.  ``breaker_threshold`` consecutive failures open the
        circuit breaker: serving degrades to the last good pinned epoch
        (results stamped ``degraded``), and after ``breaker_cooldown_s``
        one half-open probe advance decides re-open vs close.
        """
        cfg = self.config
        wait_s = interval_s
        while not self._refresh_stop.wait(wait_s):
            with self._lock:
                if self._breaker_state == "open":
                    # cooldown elapsed (wait_s was the cooldown): probe
                    self._breaker_state = "half_open"
                    self.refresh_stats["half_open_probes"] += 1
            try:
                report = self.engine.advance()
            except Exception as e:  # queries stay on the pinned epoch
                with self._lock:
                    self.refresh_stats["errors"] += 1
                    self.refresh_stats["last_error"] = f"{type(e).__name__}: {e}"
                    self.refresh_stats["consecutive_failures"] += 1
                    n = self.refresh_stats["consecutive_failures"]
                    if (self._breaker_state == "half_open"
                            or n >= cfg.breaker_threshold):
                        if self._breaker_state != "open":
                            if self._breaker_state == "closed":
                                self.refresh_stats["breaker_opens"] += 1
                            self._breaker_state = "open"
                        wait_s = cfg.breaker_cooldown_s
                    else:
                        wait_s = min(interval_s * (2 ** n),
                                     max(cfg.breaker_cooldown_s,
                                         interval_s * 32))
                continue
            with self._lock:
                self.refresh_stats["ticks"] += 1
                self.refresh_stats["last_epoch"] = report.to_epoch
                self.refresh_stats["consecutive_failures"] = 0
                if self._breaker_state != "closed":
                    self._breaker_state = "closed"
                    self.refresh_stats["breaker_closes"] += 1
                wait_s = interval_s
                if report.changed:   # last: pollers key off this counter
                    self.refresh_stats["advanced"] += 1

    def _stamp_degraded(self, value) -> bool:
        """True (and stamp ``value.degraded``) when the refresh breaker is
        non-closed: the result is served from the last good pinned epoch."""
        with self._lock:
            deg = self._breaker_state != "closed"
        if deg and value is not None and hasattr(value, "degraded"):
            value.degraded = True
        return deg

    def health(self) -> dict:
        """One self-describing snapshot of the server's resilience state:
        breaker + refresh history, epoch freshness, queue depth, shed/serve
        counters, and the lake-I/O retry / hedge / fault-injection counters
        (DESIGN.md §11)."""
        from repro.lakehouse.retry import retry_stats
        with self._lock:
            out = {
                "breaker": self._breaker_state,
                "refresh": dict(self.refresh_stats),
                "stats": dict(self.stats),
                "queue_depth": self._q.qsize(),
            }
        epochs = getattr(self.engine, "epochs", None)
        ep = epochs.current() if epochs is not None else None
        if ep is not None:
            out["epoch_id"] = ep.epoch_id
            out["staleness_s"] = ep.staleness_s()
        out["retry"] = retry_stats()
        pool = getattr(self.engine, "pool", None)
        if pool is not None:
            out["io_pool"] = dict(pool.stats)
        store = getattr(self.engine, "store", None)
        if store is not None and getattr(store, "faults", None) is not None:
            out["faults"] = store.faults.snapshot()
        ingest = getattr(self.engine, "ingest", None)
        if ingest is not None:
            out["ingest"] = ingest.stats()
        fabric = getattr(self.engine, "_shard_fabric", None)
        if fabric is not None:
            out["fabric"] = fabric.stats_snapshot()
        with self._lock:
            out["routes"] = dict(self.route_stats)
        return out

    # -- wire surface -------------------------------------------------------------

    def handle(self, method: str, path: str,
               params: Optional[dict] = None) -> dict:
        """HTTP-style request dispatch, mirroring the installed-query
        surface over a wire shape (the in-process stand-in for a listener):

        - ``GET /vertex/{vtype}/{pk}`` — point-read one vertex
          (``params["columns"]`` selects lake columns);
        - ``GET /neighbors/{etype}/{pk}`` — one CSR adjacency slice
          (``params``: ``direction`` =out|in, ``ids`` =raw|dense);
        - ``GET|POST /query/{name}`` — an installed query through the full
          scheduler (batching, lanes, budgets; params are the bindings);
        - ``GET /lookup/{name}`` — the point-lookup tier, synchronous;
        - ``GET /health`` — the resilience snapshot.

        Returns ``{"status": <code>, "value": ...}`` or ``{"status": ...,
        "error": "..."}`` — never raises; per-route hits and errors are
        counted in ``route_stats`` (see ``health()["routes"]``)."""
        params = dict(params or {})
        parts = [p for p in path.split("/") if p]
        route = "/" + parts[0] if parts else path
        try:
            status, value = self._route(method.upper(), route, parts, params)
        except KeyError as e:
            status, value = 404, f"{type(e).__name__}: {e}"
        except (TypeError, ValueError) as e:
            status, value = 400, f"{type(e).__name__}: {e}"
        except Exception as e:
            status, value = 500, f"{type(e).__name__}: {e}"
        with self._lock:
            if route in self.route_stats:
                self.route_stats[route] += 1
            if status >= 400:
                self.route_stats["errors"] += 1
        if status >= 400:
            return {"status": status, "error": value}
        return {"status": status, "value": value}

    def _route(self, method: str, route: str, parts: list,
               params: dict) -> tuple[int, object]:
        if route == "/health" and len(parts) == 1:
            if method != "GET":
                return 405, f"{method} not allowed on {route}"
            return 200, self.health()
        if route == "/vertex" and len(parts) == 3:
            if method != "GET":
                return 405, f"{method} not allowed on {route}"
            columns = tuple(params.pop("columns", ()))
            out = self.session.get_vertex(parts[1], _wire_id(parts[2]),
                                          columns=columns, **params)
            if out is None:
                return 404, f"no {parts[1]!r} vertex with id {parts[2]!r}"
            return 200, out
        if route == "/neighbors" and len(parts) == 3:
            if method != "GET":
                return 405, f"{method} not allowed on {route}"
            out = self.session.neighbors(parts[1], _wire_id(parts[2]),
                                         direction=params.pop("direction", "out"),
                                         ids=params.pop("ids", "raw"), **params)
            return 200, {"edge_type": parts[1], "vertex_id": _wire_id(parts[2]),
                         "n": int(len(out)), "neighbors": out}
        if route == "/query" and len(parts) == 2:
            if method not in ("GET", "POST"):
                return 405, f"{method} not allowed on {route}"
            rid = self.submit(parts[1], **params)
            res = self.result(rid)
            if not res.ok:
                return 500, res.error
            return 200, res
        if route == "/lookup" and len(parts) == 2:
            if method != "GET":
                return 405, f"{method} not allowed on {route}"
            value = self.session.lookup(
                parts[1], options=self._exec_options, **params)
            deg = self._stamp_degraded(value)
            with self._lock:
                self.stats["lookup_requests"] += 1
                if value is not None and value.tier in ("green", "yellow"):
                    self.stats[f"route_{value.tier}"] += 1
            return 200, QueryResult(request_id=-1, ok=True, value=value,
                                    error=None, queued_s=0.0, service_s=0.0,
                                    degraded=deg)
        return 404, f"no route for {method} {'/' + '/'.join(parts)}"

    # -- scheduler ----------------------------------------------------------------

    def _lookup_fast(self, req: _Request) -> bool:
        """True when the request serves through the point-lookup tier
        (DESIGN.md §10): an installed green/yellow template.  Lookups route
        *around* the batching scheduler — a sub-millisecond point read must
        never wait out ``batch_window_ms`` behind a scan it doesn't need."""
        if req.name in self.query_fns:
            return False
        iq = self.session.installed(req.name)
        return iq is not None and iq.lookup_plan is not None

    def _batchable(self, req: _Request) -> bool:
        return (self._window_s > 0
                and req.name not in self.query_fns
                and self.session.is_installed(req.name))

    def _dispatch(self, priority: int, unit) -> None:
        with self._lock:
            seq = self._seq
            self._seq += 1
        self._exec_q.put(((priority, seq), unit))

    def _schedule(self) -> None:
        """Drain submissions into dispatch units.

        Batchable requests (installed template, batching on) collect in a
        per-(template, lane) bucket flushed ``batch_window_ms`` after its
        first rider arrived — or immediately at ``max_batch_riders`` — so a
        burst of same-template requests becomes one shared scan while an
        isolated request pays at most one window of extra latency.
        Everything else dispatches immediately.  Buckets never cross
        priority lanes; a flushed unit keeps its lane's priority.
        """
        buckets: dict[tuple, list[_Request]] = {}
        flush_at: dict[tuple, float] = {}
        last_sweep = time.monotonic()
        closing = False
        while True:
            now = time.monotonic()
            if buckets:
                wait = max(0.0, min(flush_at.values()) - now)
            elif closing:
                break
            else:
                wait = 0.05   # idle heartbeat: TTL sweeps keep running
            try:
                req = self._q.get(timeout=wait) if not closing else self._q.get_nowait()
            except queue.Empty:
                req = False   # timeout (None is the shutdown sentinel)
            if req is None:
                closing = True
            elif req is not False:
                if self._lookup_fast(req):
                    self._dispatch(req.priority, ("lookup", req))
                elif self._batchable(req):
                    key = (req.name, req.priority)
                    bucket = buckets.setdefault(key, [])
                    if not bucket:
                        flush_at[key] = time.monotonic() + self._window_s
                    bucket.append(req)
                    if len(bucket) >= self.config.max_batch_riders:
                        self._dispatch(req.priority, ("batch", bucket))
                        del buckets[key], flush_at[key]
                else:
                    self._dispatch(req.priority, ("single", req))
            now = time.monotonic()
            for key in [k for k, t in flush_at.items() if t <= now or closing]:
                self._dispatch(key[1], ("batch", buckets.pop(key)))
                del flush_at[key]
            if now - last_sweep >= 1.0:
                last_sweep = now
                self._evict_stale(now)
        for i in range(len(self._workers)):
            self._exec_q.put(((1 << 30, i), None))

    def _evict_stale(self, now: float) -> None:
        """Drop completed results nobody collected within ``result_ttl_s``
        (satellite of DESIGN.md §9: an abandoning client must not leak)."""
        ttl = self.config.result_ttl_s
        with self._lock:
            stale = [rid for rid, t in self._done_at.items()
                     if now - t > ttl]
            for rid in stale:
                self._done_at.pop(rid, None)
                self._results.pop(rid, None)
                self._waiters.pop(rid, None)
                self.stats["evicted_results"] += 1

    # -- worker -------------------------------------------------------------------

    def _release_tenant(self, tenant: str) -> None:
        # caller holds self._lock
        held = self._tenant_inflight.get(tenant, 0)
        if held <= 1:
            self._tenant_inflight.pop(tenant, None)
        else:
            self._tenant_inflight[tenant] = held - 1

    def _complete(self, req: _Request, ok: bool, value, err: Optional[str],
                  t_start: float, t_end: float,
                  degraded: bool = False) -> None:
        res = QueryResult(
            request_id=req.rid, ok=ok, value=value, error=err,
            queued_s=t_start - req.t_submit, service_s=t_end - t_start,
            degraded=degraded,
        )
        with self._lock:
            self._results[req.rid] = res
            self._done_at[req.rid] = time.monotonic()
            self._release_tenant(req.tenant)
            ev = self._waiters.get(req.rid)
        if ev is not None:
            ev.set()

    def _remaining_budget(self, req: _Request, now_mono: float) -> Optional[float]:
        total = self.config.total_timeout_s
        if total is None:
            return None
        return total - (now_mono - req.t_mono)

    def _split_expired(self, reqs: list[_Request], t_start: float
                       ) -> tuple[list[_Request], list[_Request]]:
        """Queue-time-aware admission at dispatch: riders whose total budget
        is already gone fail as ``QueryTimeoutError`` results *without
        executing* (their queue wait was the timeout)."""
        now = time.monotonic()
        live, expired = [], []
        for req in reqs:
            rem = self._remaining_budget(req, now)
            (expired if rem is not None and rem <= 0 else live).append(req)
        for req in expired:
            with self._lock:
                self.stats["expired_in_queue"] += 1
            self._complete(
                req, False, None,
                f"{QueryTimeoutError.__name__}: total budget "
                f"({self.config.total_timeout_s}s) exhausted in queue",
                t_start, t_start)
        return live, expired

    def _options_for(self, reqs: list[_Request]) -> Optional[ExecOptions]:
        """Execution options for one dispatch unit: the serving defaults,
        with ``timeout_s`` tightened to the remaining total budget.  A batch
        runs on its most patient rider's remaining budget — expired riders
        were already failed out, so nobody waits longer than admission
        allowed."""
        base = self._exec_options
        total = self.config.total_timeout_s
        if total is None:
            return base
        now = time.monotonic()
        remaining = max(self._remaining_budget(r, now) for r in reqs)
        current = base.timeout_s if base is not None else None
        if current is None or remaining < current:
            base = dataclasses.replace(base or self.session.options,
                                       timeout_s=remaining)
        return base

    def _run_single(self, req: _Request) -> None:
        t_start = time.perf_counter()
        live, _ = self._split_expired([req], t_start)
        if not live:
            return
        try:
            if req.name in self.query_fns:
                value = self.query_fns[req.name](self.engine, **req.params)
            elif self.session.is_installed(req.name):
                value = self.session.query(
                    req.name, options=self._options_for([req]), **req.params)
            else:
                raise KeyError(
                    f"no installed query or handler named {req.name!r}")
            ok, err = True, None
        except Exception as e:  # report (typed), don't kill the worker
            value, ok, err = None, False, f"{type(e).__name__}: {e}"
        deg = self._stamp_degraded(value if ok else None)
        with self._lock:
            self.stats["solo_requests"] += 1
        self._complete(req, ok, value, err, t_start, time.perf_counter(),
                       degraded=deg)

    def _run_lookup(self, req: _Request) -> None:
        """One point-lookup request: session fast path, no compile, no
        batch window, same completion/accounting protocol as solo."""
        t_start = time.perf_counter()
        live, _ = self._split_expired([req], t_start)
        if not live:
            return
        try:
            value = self.session.lookup(
                req.name, options=self._options_for([req]), **req.params)
            ok, err = True, None
        except Exception as e:  # report (typed), don't kill the worker
            value, ok, err = None, False, f"{type(e).__name__}: {e}"
        deg = self._stamp_degraded(value if ok else None)
        with self._lock:
            self.stats["lookup_requests"] += 1
            if ok and value is not None and value.tier in ("green", "yellow"):
                self.stats[f"route_{value.tier}"] += 1
        self._complete(req, ok, value, err, t_start, time.perf_counter(),
                       degraded=deg)

    def _run_shared(self, reqs: list[_Request]) -> None:
        """One shared-scan pass for a group of same-template riders."""
        t_start = time.perf_counter()
        live, _ = self._split_expired(reqs, t_start)
        if not live:
            return
        try:
            values = self.session.query_batch(
                live[0].name, [r.params for r in live],
                options=self._options_for(live))
            errs = [None] * len(live)
        except Exception as e:  # one failure fails the group, typed
            values = [None] * len(live)
            errs = [f"{type(e).__name__}: {e}"] * len(live)
        with self._lock:
            self.stats["batches"] += 1
            self.stats["batched_requests"] += len(live)
            self.stats["max_batch_riders"] = max(
                self.stats["max_batch_riders"], len(live))
        t_end = time.perf_counter()
        for req, value, err in zip(live, values, errs):
            deg = self._stamp_degraded(value if err is None else None)
            self._complete(req, err is None, value, err, t_start, t_end,
                           degraded=deg)

    def _worker(self) -> None:
        while True:
            _, unit = self._exec_q.get()
            if unit is None:
                return
            kind, payload = unit
            if kind == "lookup":
                self._run_lookup(payload)
            elif kind == "single":
                self._run_single(payload)
            elif len(payload) == 1:   # one-rider bucket: the solo path
                self._run_single(payload[0])
            else:
                self._run_shared(payload)


def _wire_id(raw: str):
    """Path-segment vertex id -> lookup key (ids are int64 in this lake;
    a non-numeric segment passes through for string-keyed schemas)."""
    try:
        return int(raw)
    except (TypeError, ValueError):
        return raw


def latency_stats(results: list[QueryResult]) -> dict:
    """Service-latency percentiles over the successful results (plus mean
    queue wait — batching trades a bounded window of queueing for shared
    work, and the serving benchmark reports both sides)."""
    lats = sorted(r.service_s for r in results if r.ok)
    if not lats:
        return {"count": 0}
    queued = [r.queued_s for r in results if r.ok]
    pick = lambda q: lats[min(len(lats) - 1, int(q * len(lats)))]
    return {
        "count": len(lats),
        "mean_s": sum(lats) / len(lats),
        "p50_s": pick(0.50),
        "p95_s": pick(0.95),
        "p99_s": pick(0.99),
        "max_s": lats[-1],
        "mean_queued_s": sum(queued) / len(queued),
    }
