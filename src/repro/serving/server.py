"""Batched query serving over GraphLake (the paper's wrk2-driven evaluation,
§7.5, as an in-process server).

Clients submit named queries with parameters; worker threads drain the queue
and execute against a shared engine (the engine's cache manager is
thread-safe, so concurrent queries share warmed cache units exactly like the
paper's multi-connection evaluation).  Latency percentiles and throughput
are recorded for the scalability benchmark.

Concurrent queries also share the engine's query-time ``IOPool``
(DESIGN.md §5): each worker's scans issue their chunk-fetch batches through
the one pool, so the modeled object-store parallel-stream budget is a
per-engine resource — adding server workers raises concurrency without
multiplying in-flight lake requests.  The cache manager's single-flight
admission guarantees that two workers racing over the same cold chunk pay
its lake fetch once.

**Freshness (DESIGN.md §7).**  A background refresher thread periodically
calls the engine's ``advance()``: the epoch manager diffs the lake, applies
incremental deltas and atomically publishes a new epoch, while queries
already in flight keep draining on the epoch they pinned at start.  Serving
therefore picks up lake commits continuously — no engine restart — and
every ``repro.core.query.QueryResult`` carries the epoch id + staleness it
was served at.  The interval comes from ``ServerConfig.refresh_interval_s``
or, when unset, the ``refresh`` perf flag (``refresh=<seconds>``).

**Installed queries (DESIGN.md §8).**  The server fronts a
:class:`~repro.gsql.session.GraphSession`: any query *installed* on the
session (named, pre-validated GSQL text) is servable by name with bound
parameters — ``submit("bi1", tag="Music", date=20100101)`` — and executes
through ``session.query()``, the stack's single execution entry.  Plain
callables (``query_fns``) remain for result-shaping wrappers; they receive
the engine.

**Admission control + timeouts.**  ``submit()`` never blocks the client: a
full bounded queue raises :class:`ServerOverloadedError` (typed, so callers
can shed load / retry with backoff) instead of parking the caller until a
worker drains.  ``ServerConfig.timeout_s`` bounds each installed query's
execution (``ExecOptions.timeout_s`` checked at ``edge_scan`` stage
boundaries); a timed-out request comes back as a failed ``QueryResult``
naming :class:`~repro.core.plan.QueryTimeoutError`, and the worker lives on.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Callable, Optional

from repro import perf_flags
from repro.core.query import ExecOptions
from repro.gsql.session import GraphSession


class ServerOverloadedError(RuntimeError):
    """The bounded request queue is full — the server sheds the request
    instead of blocking the submitting client (backpressure surfaces at the
    edge, where the caller can retry, rather than as hidden queueing)."""


@dataclasses.dataclass
class ServerConfig:
    n_workers: int = 2
    max_queue: int = 256
    # background epoch-refresh interval; None defers to the ``refresh`` perf
    # flag (its numeric value, default 30 s), <= 0 disables outright
    refresh_interval_s: Optional[float] = None
    # per-query execution timeout for installed queries (None = no bound);
    # overrides the session's ExecOptions.timeout_s while serving
    timeout_s: Optional[float] = None


@dataclasses.dataclass
class QueryResult:
    request_id: int
    ok: bool
    value: object
    error: Optional[str]
    queued_s: float
    service_s: float


class QueryServer:
    """Serves a session's installed GSQL queries by name, plus optional
    result-shaping callables (``query_fns``: name -> fn(engine, **params)).
    ``backend`` is a :class:`GraphSession` or a bare engine (a cached
    session is created for it); installed names resolve through
    ``session.query()``, callables win on a name clash."""

    def __init__(self, backend, query_fns: Optional[dict[str, Callable]] = None,
                 config: Optional[ServerConfig] = None):
        if isinstance(backend, GraphSession):
            self.session = backend
        else:
            self.session = GraphSession.for_engine(backend)
        self.engine = self.session.engine
        self.query_fns = query_fns or {}
        self.config = config or ServerConfig()
        # serving-time execution defaults: the session's, capped by the
        # server's per-query timeout when one is configured
        self._exec_options: Optional[ExecOptions] = None
        if self.config.timeout_s is not None:
            self._exec_options = dataclasses.replace(
                self.session.options, timeout_s=self.config.timeout_s)
        self._q: queue.Queue = queue.Queue(maxsize=self.config.max_queue)
        self._results: dict[int, QueryResult] = {}
        self._done = threading.Event()
        self._lock = threading.Lock()
        self._next_id = 0
        self._workers = [
            threading.Thread(target=self._worker, daemon=True)
            for _ in range(self.config.n_workers)
        ]
        for w in self._workers:
            w.start()
        # background epoch refresher (DESIGN.md §7)
        self.refresh_stats = {"ticks": 0, "advanced": 0, "errors": 0,
                              "last_epoch": -1}
        self._refresh_stop = threading.Event()
        self._refresher: Optional[threading.Thread] = None
        interval = self.config.refresh_interval_s
        if interval is None and perf_flags.enabled("refresh"):
            interval = perf_flags.value("refresh", 30.0)
        if interval is not None and interval > 0 and hasattr(self.engine, "advance"):
            self._refresher = threading.Thread(
                target=self._refresh_loop, args=(float(interval),), daemon=True
            )
            self._refresher.start()

    # -- client API -------------------------------------------------------------

    def submit(self, query: str, **params) -> int:
        """Enqueue one request; raises :class:`ServerOverloadedError` when
        the bounded queue is full (admission control — never blocks)."""
        with self._lock:
            rid = self._next_id
            self._next_id += 1
        try:
            self._q.put_nowait((rid, query, params, time.perf_counter()))
        except queue.Full:
            raise ServerOverloadedError(
                f"request queue full ({self.config.max_queue} pending); "
                f"shed request {rid!r} ({query})") from None
        return rid

    def result(self, rid: int, timeout_s: float = 60.0) -> QueryResult:
        deadline = time.monotonic() + timeout_s
        while time.monotonic() < deadline:
            with self._lock:
                if rid in self._results:
                    return self._results.pop(rid)
            time.sleep(0.001)
        raise TimeoutError(f"request {rid}")

    def run_batch(self, requests: list[tuple[str, dict]]) -> list[QueryResult]:
        """Submit a batch, wait for all, return results in order.

        A batch driver *chooses* to wait, so overload here backs off and
        retries instead of propagating :class:`ServerOverloadedError` —
        batches larger than the bounded queue drain through it; only direct
        ``submit()`` callers see admission rejections."""
        rids = []
        for q, p in requests:
            while True:
                try:
                    rids.append(self.submit(q, **p))
                    break
                except ServerOverloadedError:
                    time.sleep(0.001)
        return [self.result(r) for r in rids]

    def close(self) -> None:
        self._refresh_stop.set()
        for _ in self._workers:
            self._q.put(None)
        for w in self._workers:
            w.join()
        if self._refresher is not None:
            self._refresher.join(timeout=10.0)

    # -- background refresher ------------------------------------------------------

    def _refresh_loop(self, interval_s: float) -> None:
        """Periodically advance the engine's epoch: in-flight queries drain
        on their pinned epoch, the next query picks up the new one."""
        while not self._refresh_stop.wait(interval_s):
            try:
                report = self.engine.advance()
                self.refresh_stats["ticks"] += 1
                self.refresh_stats["last_epoch"] = report.to_epoch
                if report.changed:   # last: pollers key off this counter
                    self.refresh_stats["advanced"] += 1
            except Exception:  # keep refreshing; queries stay on the old epoch
                self.refresh_stats["errors"] += 1

    # -- worker -------------------------------------------------------------------

    def _worker(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            rid, name, params, t_submit = item
            t_start = time.perf_counter()
            try:
                if name in self.query_fns:
                    value = self.query_fns[name](self.engine, **params)
                elif self.session.is_installed(name):
                    value = self.session.query(name, options=self._exec_options,
                                               **params)
                else:
                    raise KeyError(f"no installed query or handler named {name!r}")
                ok, err = True, None
            except Exception as e:  # report (typed), don't kill the worker
                value, ok, err = None, False, f"{type(e).__name__}: {e}"
            t_end = time.perf_counter()
            with self._lock:
                self._results[rid] = QueryResult(
                    request_id=rid, ok=ok, value=value, error=err,
                    queued_s=t_start - t_submit, service_s=t_end - t_start,
                )


def latency_stats(results: list[QueryResult]) -> dict:
    lats = sorted(r.service_s for r in results if r.ok)
    if not lats:
        return {"count": 0}
    pick = lambda q: lats[min(len(lats) - 1, int(q * len(lats)))]
    return {
        "count": len(lats),
        "mean_s": sum(lats) / len(lats),
        "p50_s": pick(0.50),
        "p95_s": pick(0.95),
        "p99_s": pick(0.99),
        "max_s": lats[-1],
    }
