"""Asynchronous I/O thread pool used to pipeline lake I/O with compute.

Reproduces the paper's §4.2 pipelining: "while I/O threads fetch column
chunks or persist edge lists, compute threads concurrently build the Vertex
IDM and subsequent edge lists".  The pool is a thin, instrumented wrapper
around ``concurrent.futures.ThreadPoolExecutor`` with:

- bounded in-flight depth (models the store's parallel stream budget),
- per-task timing so benchmarks can report overlap efficiency,
- a ``map_pipelined`` helper that runs ``fetch`` on I/O threads and ``compute``
  on the caller thread, keeping ``depth`` fetches in flight ahead of compute —
  the exact producer/consumer structure of the startup loader.
- speculative ``fetch_with_backup``: if a fetch exceeds a deadline, a backup
  request is issued and the first completion wins (straggler mitigation for
  slow object-store reads).
"""

from __future__ import annotations

import threading
import time
from concurrent.futures import FIRST_COMPLETED, Future, ThreadPoolExecutor, wait
from typing import Callable, Iterable, Iterator, Sequence, TypeVar

T = TypeVar("T")
R = TypeVar("R")


class IOPool:
    def __init__(self, n_threads: int = 8, max_in_flight: int = 32):
        self.n_threads = n_threads
        self._pool = ThreadPoolExecutor(max_workers=n_threads, thread_name_prefix="io")
        self._sem = threading.Semaphore(max_in_flight)
        self._lock = threading.Lock()
        self.stats = {"tasks": 0, "io_seconds": 0.0, "backup_fetches": 0, "backup_wins": 0}

    def close(self) -> None:
        self._pool.shutdown(wait=True)

    def __enter__(self) -> "IOPool":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- basic submission ----------------------------------------------------

    def submit(self, fn: Callable[..., R], *args, **kwargs) -> Future:
        self._sem.acquire()

        def _run():
            t0 = time.perf_counter()
            try:
                return fn(*args, **kwargs)
            finally:
                dt = time.perf_counter() - t0
                with self._lock:
                    self.stats["tasks"] += 1
                    self.stats["io_seconds"] += dt
                self._sem.release()

        try:
            return self._pool.submit(_run)
        except BaseException:
            # executor rejected the task (pool shut down mid-query): _run
            # will never run, so the in-flight slot it would have released
            # must be released here or the semaphore leaks one permit per
            # rejection until submit deadlocks
            self._sem.release()
            raise

    # -- pipelined map ---------------------------------------------------------

    def map_pipelined(
        self,
        items: Sequence[T],
        fetch: Callable[[T], R],
        compute: Callable[[T, R], object],
        depth: int = 4,
    ) -> list[object]:
        """For each item: ``compute(item, fetch(item))`` with fetches pipelined.

        ``fetch`` runs on I/O threads with ``depth`` requests in flight ahead
        of the (caller-thread) ``compute``; results are consumed in order so
        compute stays deterministic.
        """
        results: list[object] = []
        futures: list[tuple[T, Future]] = []
        it: Iterator[T] = iter(items)

        def _refill():
            while len(futures) < depth:
                try:
                    item = next(it)
                except StopIteration:
                    return
                futures.append((item, self.submit(fetch, item)))

        _refill()
        while futures:
            item, fut = futures.pop(0)
            payload = fut.result()
            _refill()  # keep the pipe full while we compute
            results.append(compute(item, payload))
        return results

    # -- speculative fetch (straggler mitigation) -------------------------------

    def fetch_with_backup(
        self, fn: Callable[[], R], backup_after_s: float = 0.25
    ) -> R:
        primary = self.submit(fn)
        done, _ = wait([primary], timeout=backup_after_s, return_when=FIRST_COMPLETED)
        if done:
            return primary.result()
        with self._lock:
            self.stats["backup_fetches"] += 1
        backup = self.submit(fn)
        done, _ = wait([primary, backup], return_when=FIRST_COMPLETED)
        winner = done.pop()
        if winner is backup:
            with self._lock:
                self.stats["backup_wins"] += 1
        return winner.result()


def prefetch_iter(
    pool: IOPool, items: Iterable[T], fetch: Callable[[T], R], depth: int = 4
) -> Iterator[tuple[T, R]]:
    """Generator flavour of :meth:`IOPool.map_pipelined`."""
    futures: list[tuple[T, Future]] = []
    it = iter(items)

    def _refill():
        while len(futures) < depth:
            try:
                item = next(it)
            except StopIteration:
                return
            futures.append((item, pool.submit(fetch, item)))

    _refill()
    while futures:
        item, fut = futures.pop(0)
        value = fut.result()
        _refill()
        yield item, value
