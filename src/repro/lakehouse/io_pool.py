"""Asynchronous I/O thread pool used to pipeline lake I/O with compute.

Reproduces the paper's §4.2 pipelining: "while I/O threads fetch column
chunks or persist edge lists, compute threads concurrently build the Vertex
IDM and subsequent edge lists".  The pool is a thin, instrumented wrapper
around ``concurrent.futures.ThreadPoolExecutor`` with:

- bounded in-flight depth (models the store's parallel stream budget),
- per-task timing so benchmarks can report overlap efficiency,
- a ``map_pipelined`` helper that runs ``fetch`` on I/O threads and ``compute``
  on the caller thread, keeping ``depth`` fetches in flight ahead of compute —
  the exact producer/consumer structure of the startup loader.
- hedged ``fetch_with_backup``: if a fetch exceeds a deadline *or fails
  with a retryable fault*, a backup request is issued and the first
  **successful** completion wins (straggler + fault mitigation for slow
  object-store reads); the loser's exception is always consumed, never
  leaked to the pool as an unraised-future warning.
"""

from __future__ import annotations

import threading
import time
from concurrent.futures import FIRST_COMPLETED, Future, ThreadPoolExecutor, wait
from typing import Callable, Iterable, Iterator, Sequence, TypeVar

T = TypeVar("T")
R = TypeVar("R")


class IOPool:
    def __init__(self, n_threads: int = 8, max_in_flight: int = 32):
        self.n_threads = n_threads
        self._pool = ThreadPoolExecutor(max_workers=n_threads, thread_name_prefix="io")
        self._sem = threading.Semaphore(max_in_flight)
        self._lock = threading.Lock()
        self.stats = {"tasks": 0, "io_seconds": 0.0, "backup_fetches": 0,
                      "backup_wins": 0, "hedged_errors": 0}

    def close(self) -> None:
        self._pool.shutdown(wait=True)

    def __enter__(self) -> "IOPool":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- basic submission ----------------------------------------------------

    def submit(self, fn: Callable[..., R], *args, **kwargs) -> Future:
        self._sem.acquire()

        def _run():
            t0 = time.perf_counter()
            try:
                return fn(*args, **kwargs)
            finally:
                dt = time.perf_counter() - t0
                with self._lock:
                    self.stats["tasks"] += 1
                    self.stats["io_seconds"] += dt
                self._sem.release()

        try:
            return self._pool.submit(_run)
        except BaseException:
            # executor rejected the task (pool shut down mid-query): _run
            # will never run, so the in-flight slot it would have released
            # must be released here or the semaphore leaks one permit per
            # rejection until submit deadlocks
            self._sem.release()
            raise

    # -- pipelined map ---------------------------------------------------------

    def map_pipelined(
        self,
        items: Sequence[T],
        fetch: Callable[[T], R],
        compute: Callable[[T, R], object],
        depth: int = 4,
    ) -> list[object]:
        """For each item: ``compute(item, fetch(item))`` with fetches pipelined.

        ``fetch`` runs on I/O threads with ``depth`` requests in flight ahead
        of the (caller-thread) ``compute``; results are consumed in order so
        compute stays deterministic.
        """
        results: list[object] = []
        futures: list[tuple[T, Future]] = []
        it: Iterator[T] = iter(items)

        def _refill():
            while len(futures) < depth:
                try:
                    item = next(it)
                except StopIteration:
                    return
                futures.append((item, self.submit(fetch, item)))

        _refill()
        while futures:
            item, fut = futures.pop(0)
            payload = fut.result()
            _refill()  # keep the pipe full while we compute
            results.append(compute(item, payload))
        return results

    # -- hedged fetch (straggler + fault mitigation) ----------------------------

    def fetch_with_backup(
        self, fn: Callable[[], R], backup_after_s: float = 0.25
    ) -> R:
        """Run ``fn`` with a hedged backup; first *success* wins.

        The backup launches when the primary is still running at
        ``backup_after_s`` (classic straggler hedge) — or immediately when
        the primary *fails* before the deadline (error-promoted hedge: a
        failed future is never returned as the "winner" while an untried
        backup could still succeed).  Loser exceptions are consumed via a
        done-callback so they can't surface as unraised-future warnings.
        Only when both attempts fail does the primary's exception propagate.
        """
        primary = self.submit(fn)
        done, _ = wait([primary], timeout=backup_after_s, return_when=FIRST_COMPLETED)
        if done and primary.exception() is None:
            return primary.result()
        with self._lock:
            self.stats["backup_fetches"] += 1
            if done:  # primary already failed: hedge promoted by the error
                self.stats["hedged_errors"] += 1
        backup = self.submit(fn)
        futures = (primary, backup)
        pending = {f for f in futures if not f.done()}
        while True:
            for fut in futures:  # prefer primary when both landed together
                if fut.done() and fut.exception() is None:
                    if fut is backup:
                        with self._lock:
                            self.stats["backup_wins"] += 1
                    loser = backup if fut is primary else primary
                    loser.add_done_callback(lambda f: f.exception())
                    return fut.result()
            if not pending:
                break
            _, pending = wait(pending, return_when=FIRST_COMPLETED)
        # both attempts failed: surface the primary's exception (the backup's
        # is consumed above the raise so neither future leaks unraised)
        backup.exception()
        raise primary.exception()


def prefetch_iter(
    pool: IOPool, items: Iterable[T], fetch: Callable[[T], R], depth: int = 4
) -> Iterator[tuple[T, R]]:
    """Generator flavour of :meth:`IOPool.map_pipelined`."""
    futures: list[tuple[T, Future]] = []
    it = iter(items)

    def _refill():
        while len(futures) < depth:
            try:
                item = next(it)
            except StopIteration:
                return
            futures.append((item, pool.submit(fetch, item)))

    _refill()
    while futures:
        item, fut = futures.pop(0)
        value = fut.result()
        _refill()
        yield item, value
