"""Simulated object store (stands in for S3) plus a local-disk cache tier.

The container has no network, so remote latency is *modeled*: every GET pays a
configurable per-request latency plus bytes/bandwidth transfer time (defaults
loosely match the paper's platform: ~30 ms first-byte latency to S3 and
1.1 GB/s sustained throughput).  Range reads are supported because the column
file reader fetches (footer-length, footer, column chunks) as separate ranged
requests exactly like a Parquet reader over S3 — this is what the paper's
pipelined startup (§4.2) overlaps.

The latency model can be disabled (``latency_scale=0``) for unit tests and
enabled for the startup/cold-run benchmarks.

Chaos: a seeded :class:`~repro.lakehouse.faults.FaultInjector` can be
installed (``StoreConfig.faults``, or the ``chaos`` / ``chaos=<rate>`` perf
flag) to inject classified faults — transient errors, latency spikes, torn
reads, missing keys — on get/put/put_if (DESIGN.md §11).  Missing files
never escape as raw ``FileNotFoundError``/``OSError``: ``get``/``size``
map them into the typed :class:`~repro.errors.MissingObjectError`.
"""

from __future__ import annotations

import dataclasses
import os
import threading
import time
from typing import Optional

from repro import perf_flags
from repro.errors import MissingObjectError
from repro.lakehouse.faults import FaultDecision, FaultInjector, transient_chaos


@dataclasses.dataclass
class StoreConfig:
    root: str
    request_latency_s: float = 0.030     # per-request first-byte latency
    bandwidth_bytes_per_s: float = 1.1e9  # sustained transfer rate
    latency_scale: float = 0.0            # 0 => latency model off (unit tests)
    parallel_streams: int = 8             # concurrent streams the link sustains
    faults: Optional[FaultInjector] = None  # chaos injector (None = perf flag)
    fault_seed: int = 0                   # seed for the flag-built injector


class ObjectStore:
    """Flat key -> bytes store on the local filesystem with a latency model.

    Thread-safe; the I/O pool issues many concurrent GETs against it.  A
    counters dict tracks requests/bytes so benchmarks can report I/O volume.
    """

    def __init__(self, config: StoreConfig):
        self.config = config
        os.makedirs(config.root, exist_ok=True)
        self._lock = threading.Lock()
        self._cas_lock = threading.Lock()   # serializes conditional puts
        self.faults = config.faults
        if self.faults is None and perf_flags.enabled("chaos"):
            self.faults = transient_chaos(
                rate=float(perf_flags.value("chaos", 0.05)),
                seed=config.fault_seed)
        self.counters = {
            "get_requests": 0,
            "put_requests": 0,
            "cas_failures": 0,
            "bytes_read": 0,
            "bytes_written": 0,
            "simulated_wait_s": 0.0,
        }

    # -- internals ---------------------------------------------------------

    def _path(self, key: str) -> str:
        if ".." in key or key.startswith("/"):
            raise ValueError(f"bad key {key!r}")
        return os.path.join(self.config.root, key)

    def _simulate(self, n_bytes: int, mult: float = 1.0) -> None:
        # ``mult`` > 1 models an injected latency spike: the spike scales the
        # *modeled* wait, so it is a no-op when the latency model is off and
        # unit tests stay fast
        cfg = self.config
        if cfg.latency_scale <= 0:
            return
        wait = mult * cfg.latency_scale * (
            cfg.request_latency_s
            + n_bytes / (cfg.bandwidth_bytes_per_s / max(1, cfg.parallel_streams))
        )
        with self._lock:
            self.counters["simulated_wait_s"] += wait
        time.sleep(wait)

    def _intercept(self, op: str, key: str) -> FaultDecision:
        if self.faults is None:
            return FaultDecision()
        return self.faults.intercept(op, key)

    def _count(self, **deltas) -> None:
        with self._lock:
            for k, v in deltas.items():
                self.counters[k] += v

    # -- API ----------------------------------------------------------------

    def put(self, key: str, data: bytes) -> None:
        decision = self._intercept("put", key)
        path = self._path(key)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        tmp = path + f".tmp.{threading.get_ident()}"
        with open(tmp, "wb") as f:
            f.write(data)
        os.replace(tmp, path)  # atomic publish, like S3 PUT visibility
        self._count(put_requests=1, bytes_written=len(data))
        self._simulate(len(data), mult=decision.spike_mult)

    def put_if(self, key: str, data: bytes, expected: Optional[bytes]) -> bool:
        """Conditional put (compare-and-swap), like S3's If-Match /
        If-None-Match conditional writes.

        Succeeds — and writes atomically — only when the key's current
        content equals ``expected`` (``None`` means *the key must not
        exist*, i.e. put-if-absent).  Returns False, writing nothing, on a
        mismatch.  This is what makes the table layer's optimistic
        metadata-swap commit safe under concurrent committers: the
        read-modify-write of the snapshot log is fenced by the CAS, so a
        lost race is detected and retried instead of silently dropping the
        other committer's snapshot.
        """
        decision = self._intercept("put_if", key)  # fault fires pre-write,
        path = self._path(key)                     # like a throttled request
        with self._cas_lock:
            try:
                with open(path, "rb") as f:
                    current: Optional[bytes] = f.read()
            except FileNotFoundError:
                current = None
            if current != expected:
                self._count(cas_failures=1)
                return False
            os.makedirs(os.path.dirname(path), exist_ok=True)
            tmp = path + f".tmp.{threading.get_ident()}"
            with open(tmp, "wb") as f:
                f.write(data)
            os.replace(tmp, path)
        self._count(put_requests=1, bytes_written=len(data))
        self._simulate(len(data), mult=decision.spike_mult)
        return True

    def get(self, key: str, offset: int = 0, length: Optional[int] = None) -> bytes:
        decision = self._intercept("get", key)
        path = self._path(key)
        try:
            with open(path, "rb") as f:
                if offset < 0:  # suffix read, like HTTP Range: bytes=-N
                    f.seek(offset, os.SEEK_END)
                else:
                    f.seek(offset)
                data = f.read() if length is None else f.read(length)
        except (FileNotFoundError, IsADirectoryError, NotADirectoryError) as e:
            raise MissingObjectError("object not found", key=key) from e
        if decision.torn and self.faults is not None:
            data = self.faults.tear(data)
        self._count(get_requests=1, bytes_read=len(data))
        self._simulate(len(data), mult=decision.spike_mult)
        return data

    def size(self, key: str) -> int:
        try:
            return os.path.getsize(self._path(key))
        except OSError as e:
            raise MissingObjectError("object not found", key=key) from e

    def exists(self, key: str) -> bool:
        return os.path.exists(self._path(key))

    def delete(self, key: str) -> None:
        path = self._path(key)
        if os.path.exists(path):
            os.remove(path)

    def list(self, prefix: str = "") -> list[str]:
        out = []
        for dirpath, _dirnames, filenames in os.walk(self.config.root):
            for fn in filenames:
                full = os.path.join(dirpath, fn)
                key = os.path.relpath(full, self.config.root)
                if key.startswith(prefix) and not fn.startswith("."):
                    out.append(key)
        return sorted(out)

    def reset_counters(self) -> None:
        with self._lock:
            for k in self.counters:
                self.counters[k] = 0 if not isinstance(self.counters[k], float) else 0.0
