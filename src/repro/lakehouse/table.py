"""Iceberg-like open table format on top of the object store.

A table is a directory of immutable column files plus a metadata layer:

    <table>/metadata/v<N>.json      -- table metadata (schema + snapshot log)
    <table>/metadata/snap-<id>.json -- manifest: the data files of a snapshot
    <table>/metadata/VERSION        -- pointer to the current metadata version
    <table>/data/part-<k>.col       -- immutable data files (columnfile format)

Commits follow Iceberg's optimistic metadata-swap protocol: write new data
files, write a new manifest + metadata version, then swap the VERSION
pointer.  Readers resolve VERSION -> metadata -> manifest -> files, which
gives snapshot isolation and lets GraphLake's catalog watch for
added/removed files (the paper's incremental edge-list maintenance).

Concurrent committers are safe: every commit creates its next metadata
version file with a **conditional put** (``ObjectStore.put_if`` with
put-if-absent semantics — the compare-and-swap fence), so exactly one
racing committer wins each version and the losers re-read the fresh
snapshot log and retry.  Manifests and data files carry a per-commit token
in their keys, so a losing attempt can never overwrite a winner's objects.
The old protocol's unguarded read-modify-write of ``metadata/VERSION``
could silently drop a concurrent committer's snapshot.
"""

from __future__ import annotations

import dataclasses
import json
import time
import uuid
from typing import Callable, Optional, Sequence

import numpy as np

from repro.errors import TransientLakeError
from repro.lakehouse.columnfile import (
    ColumnFileMeta,
    read_columns,
    read_footer,
    write_column_file,
)
from repro.lakehouse.encoding import Encoding
from repro.lakehouse.objectstore import ObjectStore
from repro.lakehouse.retry import default_policy, lake_get_json


@dataclasses.dataclass
class ColumnSpec:
    name: str
    dtype: str                      # "int64" | "float32" | "str" | ...
    role: str = "property"         # "primary_key" | "foreign_key" | "property"
    references: Optional[str] = None  # vertex-table name for FK columns


@dataclasses.dataclass
class TableSchema:
    name: str
    columns: list[ColumnSpec]

    @property
    def primary_key(self) -> Optional[str]:
        for c in self.columns:
            if c.role == "primary_key":
                return c.name
        return None

    @property
    def foreign_keys(self) -> list[ColumnSpec]:
        return [c for c in self.columns if c.role == "foreign_key"]

    @property
    def property_columns(self) -> list[str]:
        return [c.name for c in self.columns if c.role == "property"]

    def to_json(self) -> dict:
        return {
            "name": self.name,
            "columns": [dataclasses.asdict(c) for c in self.columns],
        }

    @staticmethod
    def from_json(d: dict) -> "TableSchema":
        return TableSchema(
            name=d["name"], columns=[ColumnSpec(**c) for c in d["columns"]]
        )


@dataclasses.dataclass
class Snapshot:
    snapshot_id: int
    timestamp: float
    manifest_key: str
    n_files: int
    n_rows: int


@dataclasses.dataclass
class UpsertResult:
    """What one :meth:`LakeTable.upsert_rows` commit did.

    ``snapshot`` is ``None`` when the call turned out to be a no-op (no new
    rows and no matching delete keys) — nothing was committed."""

    snapshot: Optional[Snapshot]
    rows_inserted: int = 0      # upsert keys not present before the commit
    rows_updated: int = 0       # distinct upsert keys whose old rows were replaced
    rows_deleted: int = 0       # old rows removed for delete keys
    files_rewritten: int = 0    # replaced files that kept >=1 surviving row
    files_removed: int = 0      # data files dropped from the manifest


class LakeTable:
    """Handle to one Iceberg-like table."""

    def __init__(self, store: ObjectStore, name: str):
        self.store = store
        self.name = name
        self._prefix = f"tables/{name}"

    # -- paths ---------------------------------------------------------------

    def _meta_key(self, version: int) -> str:
        return f"{self._prefix}/metadata/v{version}.json"

    def _version_key(self) -> str:
        return f"{self._prefix}/metadata/VERSION"

    def _manifest_key(self, snapshot_id: int, token: str = "") -> str:
        suffix = f"-{token}" if token else ""
        return f"{self._prefix}/metadata/snap-{snapshot_id}{suffix}.json"

    def data_key(self, file_index: int, token: str = "") -> str:
        suffix = f"-{token}" if token else ""
        return f"{self._prefix}/data/part-{file_index:05d}{suffix}.col"

    # -- metadata ------------------------------------------------------------

    def exists(self) -> bool:
        return self.store.exists(self._version_key())

    def current_version(self) -> int:
        # metadata reads retry transient faults, and an unparsable pointer
        # is classified transient too (the VERSION object is written
        # atomically, so garbage means a torn response — see retry.py)
        key = self._version_key()

        def attempt() -> int:
            raw = self.store.get(key)
            try:
                return int(raw.decode())
            except (ValueError, UnicodeDecodeError) as e:
                raise TransientLakeError("torn VERSION read", key=key) from e

        return default_policy().call(attempt, key=key)

    def _read_meta(self) -> dict:
        return lake_get_json(self.store, self._meta_key(self.current_version()))

    def schema(self) -> TableSchema:
        return TableSchema.from_json(self._read_meta()["schema"])

    def snapshots(self) -> list[Snapshot]:
        return [Snapshot(**s) for s in self._read_meta()["snapshots"]]

    def current_snapshot(self) -> Snapshot:
        snaps = self.snapshots()
        if not snaps:
            raise RuntimeError(f"table {self.name} has no snapshots")
        return snaps[-1]

    def data_files(self, snapshot_id: Optional[int] = None) -> list[str]:
        """Data-file keys of a snapshot (default: current)."""
        if snapshot_id is None:
            snap = self.current_snapshot()
        else:
            snap = next(s for s in self.snapshots() if s.snapshot_id == snapshot_id)
        manifest = lake_get_json(self.store, snap.manifest_key)
        return list(manifest["files"])

    def file_metas(self) -> list[ColumnFileMeta]:
        return [read_footer(self.store, k) for k in self.data_files()]

    # -- writes ---------------------------------------------------------------

    def create(self, schema: TableSchema) -> None:
        if self.exists():
            raise RuntimeError(f"table {self.name} already exists")
        meta = {"schema": schema.to_json(), "snapshots": [], "next_file_index": 0}
        self.store.put(self._meta_key(1), json.dumps(meta).encode())
        self.store.put(self._version_key(), b"1")

    _COMMIT_RETRIES = 64

    def recover_orphan_version(self) -> int:
        """Janitor: finish the VERSION swap of a committer that crashed
        after winning the metadata CAS (ROADMAP open item).

        The crash window is tiny but real: ``_commit`` writes
        ``metadata/v<N+1>.json`` (the CAS win) and then moves ``VERSION``.
        A crash in between leaves the table *wedged*: every future committer
        reads ``VERSION == N``, loses the put-if-absent on ``v<N+1>`` forever
        and exhausts its retries, while the crashed committer's snapshot —
        durably written — stays invisible.

        Recovery is the swap the winner would have done, fenced by a CAS on
        VERSION's current content so a slow-but-alive winner (or another
        janitor) racing us can never move the pointer backwards.  Rolling
        forward is always safe: ``v<N+1>`` is immutable and complete before
        the CAS that created it returns.  Returns how many versions were
        rolled forward (0 = nothing orphaned).
        """
        recovered = 0
        while True:
            version = self.current_version()
            if not self.store.exists(self._meta_key(version + 1)):
                return recovered
            if self.store.put_if(self._version_key(),
                                 str(version + 1).encode(),
                                 expected=str(version).encode()):
                recovered += 1
            # CAS failure: someone else advanced VERSION — loop re-reads

    def _commit(self, build: Callable[[dict, str], Snapshot]) -> Snapshot:
        """Optimistic commit loop fenced by a conditional put.

        ``build(meta, token)`` derives the next snapshot from a *fresh* read
        of the metadata (appending to ``meta["snapshots"]`` in place) and
        returns it.  The new metadata version file is then created with
        put-if-absent: exactly one racing committer wins each version; a
        loser re-reads the advanced snapshot log and rebuilds its commit on
        top, so no concurrent snapshot is ever dropped.

        Every VERSION move is a CAS on its current content, and a loser
        whose ``v<N+1>`` already exists runs the janitor
        (:meth:`recover_orphan_version`) before retrying — so a committer
        crashing between its metadata CAS win and its VERSION swap delays
        the next writer by one roll-forward instead of wedging the table,
        and the crashed commit's snapshot survives into the log.
        """
        token = uuid.uuid4().hex[:8]
        for _ in range(self._COMMIT_RETRIES):
            version = self.current_version()
            meta = lake_get_json(self.store, self._meta_key(version))
            snap = build(meta, token)
            payload = json.dumps(meta).encode()
            if not self.store.put_if(self._meta_key(version + 1), payload, expected=None):
                # lost the race for version+1: either the winner is about to
                # move VERSION, or it crashed and never will — roll forward
                # on its behalf (CAS-fenced, so a live winner racing us is
                # harmless), then retry on top of the advanced log
                self.recover_orphan_version()
                time.sleep(0.0005)
                continue
            self.store.put_if(self._version_key(), str(version + 1).encode(),
                              expected=str(version).encode())
            return snap
        raise RuntimeError(
            f"commit contention on table {self.name}: "
            f"gave up after {self._COMMIT_RETRIES} CAS attempts"
        )

    def append_files(
        self,
        file_columns: list[dict[str, np.ndarray]],
        row_group_rows: int = 65536,
        encodings: Optional[dict[str, Encoding]] = None,
        replace: bool = False,
    ) -> Snapshot:
        """Write data files and commit a new snapshot (append or replace).

        Data files are written once, up front, under commit-unique keys
        (the token keeps racing appenders from colliding on a file index);
        only the metadata commit retries on contention.
        """
        token = uuid.uuid4().hex[:8]
        start_idx = self._read_meta()["next_file_index"]
        new_keys: list[str] = []
        n_new_rows = 0
        for i, cols in enumerate(file_columns):
            key = self.data_key(start_idx + i, token)
            fm = write_column_file(
                self.store, key, cols, row_group_rows=row_group_rows, encodings=encodings
            )
            n_new_rows += fm.n_rows
            new_keys.append(key)

        def build(meta: dict, tok: str) -> Snapshot:
            if replace or not meta["snapshots"]:
                base_files: list[str] = []
                base_rows = 0
            else:
                prev = Snapshot(**meta["snapshots"][-1])
                manifest = lake_get_json(self.store, prev.manifest_key)
                base_files = list(manifest["files"])
                base_rows = prev.n_rows
            snapshot_id = len(meta["snapshots"]) + 1
            manifest_key = self._manifest_key(snapshot_id, tok)
            self.store.put(
                manifest_key, json.dumps({"files": base_files + new_keys}).encode()
            )
            snap = Snapshot(
                snapshot_id=snapshot_id,
                timestamp=time.time(),
                manifest_key=manifest_key,
                n_files=len(base_files) + len(new_keys),
                n_rows=base_rows + n_new_rows,
            )
            meta["snapshots"].append(dataclasses.asdict(snap))
            meta["next_file_index"] = max(
                meta["next_file_index"], start_idx + len(new_keys)
            )
            return snap

        return self._commit(build)

    def delete_file(self, key: str) -> Snapshot:
        """Commit a snapshot with one data file removed (logical delete).

        The data object itself stays in the store — older snapshots (and
        older pinned epochs) can keep reading it after the logical delete.
        """
        removed_rows = read_footer(self.store, key).n_rows

        def build(meta: dict, tok: str) -> Snapshot:
            if not meta["snapshots"]:
                raise RuntimeError(f"table {self.name} has no snapshots")
            prev = Snapshot(**meta["snapshots"][-1])
            manifest = lake_get_json(self.store, prev.manifest_key)
            files = [f for f in manifest["files"] if f != key]
            snapshot_id = len(meta["snapshots"]) + 1
            manifest_key = self._manifest_key(snapshot_id, tok)
            self.store.put(manifest_key, json.dumps({"files": files}).encode())
            snap = Snapshot(
                snapshot_id=snapshot_id,
                timestamp=time.time(),
                manifest_key=manifest_key,
                n_files=len(files),
                n_rows=prev.n_rows - removed_rows,
            )
            meta["snapshots"].append(dataclasses.asdict(snap))
            return snap

        return self._commit(build)

    def upsert_rows(
        self,
        rows: Optional[dict[str, np.ndarray]],
        key_columns: Sequence[str],
        delete_keys: Optional[Sequence] = None,
        row_group_rows: int = 65536,
        encodings: Optional[dict[str, Encoding]] = None,
    ) -> UpsertResult:
        """Row-level upsert/delete as **one** copy-on-write snapshot commit.

        ``rows`` is a dict of equal-length columns (every schema column
        required); a row whose ``key_columns`` tuple already exists replaces
        the old row(s), otherwise it is a plain insert.  ``delete_keys`` is
        a sequence of key tuples (or scalars for single-column keys) whose
        matching rows are removed.  Mechanics (the Iceberg copy-on-write
        shape, built from the same pieces ``append_files``/``delete_file``
        use): data files containing an affected key are rewritten without
        those rows, the new rows land in one delta file, and a single
        manifest swap drops the replaced files and adds the new ones — so
        readers (and pinned epochs) never observe a delete-then-append gap,
        and ``EpochManager.advance()`` sees exactly one snapshot step.

        Single-writer contract: affected files are resolved against the
        snapshot current at call time, so concurrent ``upsert_rows`` calls
        on the *same table* may both rewrite the same file.  The ingest
        committer serializes per table; concurrent *append* committers
        remain safe (the CAS commit loop rebuilds the manifest on top of
        theirs).
        """
        rows = {k: np.asarray(v) for k, v in (rows or {}).items()}
        key_columns = list(key_columns)
        schema_cols = [c.name for c in self.schema().columns]
        if rows and sorted(rows) != sorted(schema_cols):
            raise ValueError(
                f"upsert rows must carry exactly the table columns "
                f"{schema_cols}, got {sorted(rows)}")
        n_new = len(rows[schema_cols[0]]) if rows else 0

        def as_keys(cols: dict) -> list[tuple]:
            arrays = [np.asarray(cols[c]).tolist() for c in key_columns]
            return [tuple(vals) for vals in zip(*arrays)]

        new_key_list = as_keys(rows) if n_new else []
        upsert_keys = set(new_key_list)
        if len(upsert_keys) != len(new_key_list):
            raise ValueError("duplicate keys within one upsert batch "
                             "(coalesce to last-write-wins first)")
        del_keys = {k if isinstance(k, tuple) else
                    (tuple(k) if isinstance(k, list) else (k,))
                    for k in (delete_keys or [])}
        del_keys -= upsert_keys     # an upsert of the same key supersedes
        affected = upsert_keys | del_keys

        current = self.data_files() if self._read_meta()["snapshots"] else []
        token = uuid.uuid4().hex[:8]
        next_idx = self._read_meta()["next_file_index"]
        replaced: list[str] = []
        removed_rows = 0
        new_files: list[tuple[str, int]] = []     # (key, n_rows)
        matched_upserts: set = set()
        rows_deleted = 0
        files_rewritten = 0
        for fkey in current if affected else []:
            meta = read_footer(self.store, fkey)
            kcols = read_columns(self.store, meta, key_columns)
            fkeys = as_keys(kcols)
            hit = np.fromiter((k in affected for k in fkeys),
                              dtype=bool, count=len(fkeys))
            if not hit.any():
                continue
            for k, h in zip(fkeys, hit):
                if h:
                    if k in upsert_keys:
                        matched_upserts.add(k)
                    else:
                        rows_deleted += 1
            replaced.append(fkey)
            removed_rows += meta.n_rows
            if not hit.all():
                full = read_columns(self.store, meta, meta.columns)
                survivors = {c: v[~hit] for c, v in full.items()}
                nk = self.data_key(next_idx, token)
                next_idx += 1
                write_column_file(self.store, nk, survivors,
                                  row_group_rows=row_group_rows,
                                  encodings=encodings)
                new_files.append((nk, int((~hit).sum())))
                files_rewritten += 1
        if n_new:
            nk = self.data_key(next_idx, token)
            next_idx += 1
            write_column_file(self.store, nk,
                              {c: rows[c] for c in schema_cols},
                              row_group_rows=row_group_rows,
                              encodings=encodings)
            new_files.append((nk, n_new))
        if not replaced and not new_files:
            return UpsertResult(snapshot=None)

        replaced_set = set(replaced)
        n_added = sum(n for _, n in new_files)
        end_idx = next_idx

        def build(meta: dict, tok: str) -> Snapshot:
            if meta["snapshots"]:
                prev = Snapshot(**meta["snapshots"][-1])
                manifest = lake_get_json(self.store, prev.manifest_key)
                base_files = list(manifest["files"])
                base_rows = prev.n_rows
            else:
                base_files, base_rows = [], 0
            files = [f for f in base_files if f not in replaced_set] \
                + [k for k, _ in new_files]
            snapshot_id = len(meta["snapshots"]) + 1
            manifest_key = self._manifest_key(snapshot_id, tok)
            self.store.put(manifest_key, json.dumps({"files": files}).encode())
            snap = Snapshot(
                snapshot_id=snapshot_id,
                timestamp=time.time(),
                manifest_key=manifest_key,
                n_files=len(files),
                n_rows=base_rows - removed_rows + n_added,
            )
            meta["snapshots"].append(dataclasses.asdict(snap))
            meta["next_file_index"] = max(meta["next_file_index"], end_idx)
            return snap

        snap = self._commit(build)
        return UpsertResult(
            snapshot=snap,
            rows_inserted=n_new - len(matched_upserts),
            rows_updated=len(matched_upserts),
            rows_deleted=rows_deleted,
            files_rewritten=files_rewritten,
            files_removed=len(replaced),
        )


class LakeCatalog:
    """Hive-metastore-ish catalog: name -> LakeTable, plus change detection."""

    def __init__(self, store: ObjectStore):
        self.store = store

    def table(self, name: str) -> LakeTable:
        return LakeTable(self.store, name)

    def list_tables(self) -> list[str]:
        names = set()
        for key in self.store.list("tables/"):
            parts = key.split("/")
            if len(parts) >= 2:
                names.add(parts[1])
        return sorted(names)

    def table_state(self, name: str) -> tuple[int, list[str]]:
        """(snapshot_id, data files) — what the graph catalog polls."""
        t = self.table(name)
        snap = t.current_snapshot()
        return snap.snapshot_id, t.data_files(snap.snapshot_id)
