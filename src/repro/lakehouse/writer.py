"""Bulk table writer used by dataset generators.

Splits a dict of columns into ``n_files`` data files (the paper splits every
LDBC table into 32 files to match vCPU counts; we default lower for CPU-scale
tests) and commits them as one snapshot.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from repro.lakehouse.encoding import Encoding
from repro.lakehouse.objectstore import ObjectStore
from repro.lakehouse.table import LakeCatalog, LakeTable, TableSchema


def write_table(
    store: ObjectStore,
    schema: TableSchema,
    columns: dict[str, np.ndarray],
    n_files: int = 4,
    row_group_rows: int = 65536,
    encodings: Optional[dict[str, Encoding]] = None,
    replace_table: bool = False,
) -> LakeTable:
    """Create (or replace) a table and write its columns across data files."""
    table = LakeCatalog(store).table(schema.name)
    if not table.exists():
        table.create(schema)
    names = [c.name for c in schema.columns]
    missing = [n for n in names if n not in columns]
    if missing:
        raise ValueError(f"missing columns {missing} for table {schema.name}")
    n_rows = len(columns[names[0]])

    file_columns: list[dict[str, np.ndarray]] = []
    n_files = max(1, min(n_files, n_rows) if n_rows else 1)
    bounds = np.linspace(0, n_rows, n_files + 1).astype(np.int64)
    for i in range(n_files):
        lo, hi = int(bounds[i]), int(bounds[i + 1])
        file_columns.append({n: np.asarray(columns[n])[lo:hi] for n in names})

    table.append_files(
        file_columns,
        row_group_rows=row_group_rows,
        encodings=encodings,
        replace=replace_table,
    )
    return table
