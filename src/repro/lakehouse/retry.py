"""Typed retry with exponential backoff + decorrelated jitter (DESIGN.md §11).

Every lake read the engine issues flows through here — the column-file
readers, the table metadata layer, the cache manager's chunk fetches and
the topology loaders all call :func:`lake_get` (or wrap their own attempt
in :meth:`RetryPolicy.call`) instead of raw ``ObjectStore.get``:

- only :class:`~repro.errors.TransientLakeError` retries (throttles,
  connection resets, short/torn reads detected against the expected byte
  count); :class:`~repro.errors.MissingObjectError` and
  :class:`~repro.errors.LakeCorruptionError` fail fast, carrying the key
  and the trace of any transient attempts that preceded them;
- backoff is exponential with *decorrelated jitter* (AWS-style:
  ``sleep = min(cap, uniform(base, 3 * prev))``) from a seeded RNG, so
  retry storms desynchronize instead of thundering in lockstep;
- attempts are budget-capped (``retry=<attempts>`` perf flag, default 5;
  flag off = single attempt, the fail-fast parity baseline) and
  **deadline-aware**: a caller-supplied monotonic deadline (the query's
  ``ExecOptions.timeout_s`` budget) is never slept past — an exhausted
  deadline surfaces as :class:`~repro.errors.QueryTimeoutError`, composing
  with the executor's stage-boundary checks.

Module-level stats (the default policy's) feed the server's ``health()``
snapshot: attempts, retries, give-ups, time slept.
"""

from __future__ import annotations

import random
import threading
import time
from typing import Callable, Optional, TypeVar

from repro import perf_flags
from repro.errors import (
    LakeCorruptionError,
    MissingObjectError,
    QueryTimeoutError,
    TransientLakeError,
)

R = TypeVar("R")


class RetryPolicy:
    """Budget-capped, deadline-aware retry for transient lake faults."""

    def __init__(self, max_attempts: int = 5, base_s: float = 0.002,
                 cap_s: float = 0.050, seed: int = 0,
                 sleep: Callable[[float], None] = time.sleep):
        self.max_attempts = max(1, int(max_attempts))
        self.base_s = base_s
        self.cap_s = cap_s
        self._sleep = sleep
        self._rng = random.Random(seed)
        self._lock = threading.Lock()
        self.stats = {"calls": 0, "attempts": 0, "retries": 0, "giveups": 0,
                      "fatal": 0, "deadline_aborts": 0, "slept_s": 0.0}

    def _count(self, **deltas) -> None:
        with self._lock:
            for k, v in deltas.items():
                self.stats[k] += v

    def snapshot(self) -> dict:
        with self._lock:
            return dict(self.stats)

    def call(self, fn: Callable[[], R], *, key: Optional[str] = None,
             deadline: Optional[float] = None) -> R:
        """Run ``fn`` with retries on transient faults.

        ``deadline`` is a ``time.monotonic()`` instant: backoff sleeps are
        clipped to it and an attempt is never *started* after it passes
        (the attempt in flight when it expires still completes — reads are
        not cancelled mid-flight, mirroring the executor's stage-boundary
        timeout contract).
        """
        self._count(calls=1)
        trace: list[str] = []
        prev_sleep = self.base_s
        last: Optional[TransientLakeError] = None
        for attempt in range(1, self.max_attempts + 1):
            self._count(attempts=1)
            try:
                return fn()
            except (MissingObjectError, LakeCorruptionError) as e:
                # fatal: surface immediately, with the transient attempts
                # that preceded it on record
                e.attempt_trace = trace + [f"#{attempt} {type(e).__name__}"]
                self._count(fatal=1)
                raise
            except TransientLakeError as e:
                last = e
                trace.append(f"#{attempt} {type(e).__name__}: "
                             f"{str(e.args[0] if e.args else e)[:80]}")
            if attempt >= self.max_attempts:
                break
            now = time.monotonic()
            if deadline is not None and now >= deadline:
                self._count(deadline_aborts=1)
                raise QueryTimeoutError(
                    f"deadline exhausted retrying {key or 'lake read'} "
                    f"({attempt} attempts: " + " | ".join(trace) + ")"
                ) from last
            with self._lock:
                sleep_s = min(self.cap_s,
                              self._rng.uniform(self.base_s, 3 * prev_sleep))
            prev_sleep = sleep_s
            if deadline is not None:
                sleep_s = min(sleep_s, max(0.0, deadline - now))
            self._count(retries=1, slept_s=sleep_s)
            self._sleep(sleep_s)
        self._count(giveups=1)
        raise TransientLakeError(
            f"retry budget exhausted ({self.max_attempts} attempts)",
            key=key, attempts=trace,
        ) from last


# the shared default policy: rebuilt when the ``retry`` flag changes (tests
# flip REPRO_OPTS mid-process), shared otherwise so its stats accumulate
# engine-wide for the health snapshot
_default: Optional[RetryPolicy] = None
_default_sig: Optional[tuple] = None
_default_lock = threading.Lock()


def default_policy() -> RetryPolicy:
    attempts = (int(perf_flags.value("retry", 5))
                if perf_flags.enabled("retry") else 1)
    sig = (attempts,)
    global _default, _default_sig
    with _default_lock:
        if _default is None or _default_sig != sig:
            _default = RetryPolicy(max_attempts=attempts)
            _default_sig = sig
        return _default


def retry_stats() -> dict:
    """The default policy's counters (health snapshot / benchmarks)."""
    return default_policy().snapshot()


def lake_get(store, key: str, offset: int = 0, length: Optional[int] = None,
             *, expect_len: Optional[int] = None,
             policy: Optional[RetryPolicy] = None,
             deadline: Optional[float] = None) -> bytes:
    """``store.get`` with retry + short-read (torn-read) detection.

    When the expected byte count is known (``length``, or ``expect_len``
    for suffix reads), a response with fewer bytes is classified as a
    :class:`TransientLakeError` — a torn read of an immutable object is
    retryable by definition — so truncated bytes can never flow onward
    into decoders or the cache.
    """
    pol = policy or default_policy()
    want = expect_len if expect_len is not None else length

    def attempt() -> bytes:
        data = store.get(key, offset=offset, length=length)
        if want is not None and len(data) != want:
            raise TransientLakeError(
                f"short read: {len(data)}/{want} bytes", key=key)
        return data

    return pol.call(attempt, key=key, deadline=deadline)


def lake_get_json(store, key: str, *, policy: Optional[RetryPolicy] = None,
                  deadline: Optional[float] = None):
    """Fetch + JSON-decode a metadata object with retry.

    Undecodable JSON is classified *transient*: for an object the format
    guarantees was written atomically, garbage bytes mean a torn response,
    and the retry either heals it or surfaces the exhausted budget with the
    full attempt trace (the "torn manifest" failure mode)."""
    import json

    pol = policy or default_policy()

    def attempt():
        data = store.get(key)
        try:
            return json.loads(data.decode("utf-8"))
        except (ValueError, UnicodeDecodeError) as e:
            raise TransientLakeError(
                f"torn metadata read ({type(e).__name__})", key=key) from e

    return pol.call(attempt, key=key, deadline=deadline)


__all__ = ["RetryPolicy", "default_policy", "retry_stats", "lake_get",
           "lake_get_json"]
