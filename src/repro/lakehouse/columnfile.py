"""Parquet-like column file format.

Layout (all little-endian):

    [chunk bytes...] [footer json] [footer_len uint32] [MAGIC 4B]

A file holds ``n_row_groups`` horizontal slices; within a row group each
column's values form one *column chunk* (the unit GraphLake caches).  The
footer carries, per chunk: byte offset/length, row count, encoding, and
min/max statistics for numeric columns — the statistics drive the paper's
frontier Min-Max prefetch pruning (§5.3).

Readers follow the S3 access pattern the paper describes in §4.2:
  1. suffix request for (footer_len, magic),
  2. request for the footer bytes,
  3. ranged requests for the column chunks actually needed.

Every read goes through :func:`~repro.lakehouse.retry.lake_get` with the
expected byte count, so transient faults retry and torn (short) reads are
detected *before* decoding.  A full-length read whose contents still fail
the format's promises (bad magic, undecodable footer or chunk) is the
fatal class: :class:`~repro.errors.LakeCorruptionError`.
"""

from __future__ import annotations

import dataclasses
import json
import struct
from typing import Optional, Sequence

import numpy as np

from repro.errors import LakeCorruptionError
from repro.lakehouse.encoding import Encoding, choose_encoding, decode_column, encode_column
from repro.lakehouse.objectstore import ObjectStore
from repro.lakehouse.retry import lake_get

MAGIC = b"RPF1"


@dataclasses.dataclass
class ColumnChunkMeta:
    column: str
    row_group: int
    offset: int
    length: int
    n_rows: int
    encoding: int
    min_value: Optional[float] = None
    max_value: Optional[float] = None

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(d: dict) -> "ColumnChunkMeta":
        return ColumnChunkMeta(**d)


@dataclasses.dataclass
class RowGroupMeta:
    index: int
    n_rows: int
    first_row: int  # global row offset of this group within the file


@dataclasses.dataclass
class ColumnFileMeta:
    key: str
    n_rows: int
    columns: list[str]
    row_groups: list[RowGroupMeta]
    chunks: list[ColumnChunkMeta]

    def chunks_for(self, column: str) -> list[ColumnChunkMeta]:
        return [c for c in self.chunks if c.column == column]

    def chunk(self, column: str, row_group: int) -> ColumnChunkMeta:
        for c in self.chunks:
            if c.column == column and c.row_group == row_group:
                return c
        raise KeyError((column, row_group))

    def to_json(self) -> dict:
        return {
            "key": self.key,
            "n_rows": self.n_rows,
            "columns": self.columns,
            "row_groups": [dataclasses.asdict(g) for g in self.row_groups],
            "chunks": [c.to_json() for c in self.chunks],
        }

    @staticmethod
    def from_json(d: dict) -> "ColumnFileMeta":
        return ColumnFileMeta(
            key=d["key"],
            n_rows=d["n_rows"],
            columns=list(d["columns"]),
            row_groups=[RowGroupMeta(**g) for g in d["row_groups"]],
            chunks=[ColumnChunkMeta.from_json(c) for c in d["chunks"]],
        )


def _stats(arr: np.ndarray) -> tuple[Optional[float], Optional[float]]:
    if arr.size == 0 or arr.dtype.kind not in ("i", "u", "f"):
        return None, None
    return float(arr.min()), float(arr.max())


def write_column_file(
    store: ObjectStore,
    key: str,
    columns: dict[str, np.ndarray],
    row_group_rows: int = 65536,
    encodings: Optional[dict[str, Encoding]] = None,
) -> ColumnFileMeta:
    """Write a dict of equal-length 1-D columns as one column file."""
    names = list(columns.keys())
    if not names:
        raise ValueError("no columns")
    n_rows = len(columns[names[0]])
    for name in names:
        if len(columns[name]) != n_rows:
            raise ValueError("ragged columns")

    body = bytearray()
    chunk_metas: list[ColumnChunkMeta] = []
    group_metas: list[RowGroupMeta] = []
    n_groups = max(1, -(-n_rows // row_group_rows))
    for g in range(n_groups):
        lo = g * row_group_rows
        hi = min(n_rows, lo + row_group_rows)
        group_metas.append(RowGroupMeta(index=g, n_rows=hi - lo, first_row=lo))
        for name in names:
            sl = np.asarray(columns[name])[lo:hi]
            enc = (encodings or {}).get(name) or choose_encoding(sl)
            payload = encode_column(sl, enc)
            mn, mx = _stats(sl)
            chunk_metas.append(
                ColumnChunkMeta(
                    column=name,
                    row_group=g,
                    offset=len(body),
                    length=len(payload),
                    n_rows=hi - lo,
                    encoding=int(enc),
                    min_value=mn,
                    max_value=mx,
                )
            )
            body.extend(payload)

    meta = ColumnFileMeta(
        key=key, n_rows=n_rows, columns=names, row_groups=group_metas, chunks=chunk_metas
    )
    footer = json.dumps(meta.to_json()).encode("utf-8")
    blob = bytes(body) + footer + struct.pack("<I", len(footer)) + MAGIC
    store.put(key, blob)
    return meta


def read_footer(store: ObjectStore, key: str) -> ColumnFileMeta:
    """Read footer via the 2-request suffix pattern (paper §4.2)."""
    tail = lake_get(store, key, offset=-8, expect_len=8)  # footer_len + magic
    (footer_len,) = struct.unpack_from("<I", tail, 0)
    if tail[4:] != MAGIC:
        # the full 8 tail bytes arrived (short reads retried above), so the
        # magic mismatch is durable on-disk corruption, not a torn response
        raise LakeCorruptionError("bad column file magic", key=key)
    total = store.size(key)
    footer = lake_get(store, key, offset=total - 8 - footer_len, length=footer_len)
    try:
        return ColumnFileMeta.from_json(json.loads(footer.decode("utf-8")))
    except (ValueError, KeyError, TypeError, UnicodeDecodeError) as e:
        raise LakeCorruptionError(
            f"undecodable column file footer ({type(e).__name__})", key=key
        ) from e


def read_column_chunk(
    store: ObjectStore,
    meta: ColumnFileMeta,
    column: str,
    row_group: int,
    row_limit: Optional[int] = None,
) -> np.ndarray:
    """Ranged-read one column chunk and decode it (optionally a prefix)."""
    c = meta.chunk(column, row_group)
    raw = lake_get(store, meta.key, offset=c.offset, length=c.length)
    try:
        return decode_column(raw, row_limit=row_limit)
    except (ValueError, struct.error) as e:
        raise LakeCorruptionError(
            f"undecodable column chunk {column}/rg{row_group} "
            f"({type(e).__name__})", key=meta.key) from e


def read_column_chunk_raw(
    store: ObjectStore, meta: ColumnFileMeta, column: str, row_group: int
) -> bytes:
    """Fetch the encoded bytes of a chunk without decoding (disk-tier cache)."""
    c = meta.chunk(column, row_group)
    return lake_get(store, meta.key, offset=c.offset, length=c.length)


def read_columns(
    store: ObjectStore, meta: ColumnFileMeta, columns: Sequence[str]
) -> dict[str, np.ndarray]:
    """Read full columns (all row groups concatenated)."""
    out: dict[str, np.ndarray] = {}
    for col in columns:
        parts = [
            read_column_chunk(store, meta, col, g.index) for g in meta.row_groups
        ]
        out[col] = np.concatenate(parts) if len(parts) > 1 else parts[0]
    return out
