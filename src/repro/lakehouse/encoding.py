"""Column-chunk encodings for the Parquet-like column files.

Four encodings are implemented, mirroring the ones Parquet uses for the data
GraphLake cares about (integer keys, low-cardinality strings, floats):

- ``PLAIN``      — raw little-endian values (any dtype, incl. variable-length
                   UTF-8 strings framed as ``(offsets, payload)``),
- ``RLE``        — run-length encoding of (value, run) pairs; good for sorted
                   FK columns and repeated categorical values,
- ``DICTIONARY`` — distinct-value dictionary page + bit-packed code stream;
                   the standard encoding for strings and low-cardinality ints,
- ``BITPACK``    — fixed-width bit packing of non-negative integers (used for
                   dictionary codes and small ID columns).

Every encoder returns ``bytes`` and every decoder returns a numpy array.  The
decoders support *partial* decode (``row_limit``): GraphLake's vertex cache
units decode a contiguous prefix of a chunk on demand (paper §5.1), so the
substrate must be able to stop decoding early without paying for the full
chunk.
"""

from __future__ import annotations

import enum
import struct
from typing import Optional

import numpy as np

_MAGIC = b"RPC1"  # repro-column v1


class Encoding(enum.IntEnum):
    PLAIN = 0
    RLE = 1
    DICTIONARY = 2
    BITPACK = 3


# dtype tokens serialized into chunk headers ------------------------------------

_DTYPE_TOKENS = {
    "int8": 0,
    "int16": 1,
    "int32": 2,
    "int64": 3,
    "uint32": 4,
    "uint64": 5,
    "float32": 6,
    "float64": 7,
    "str": 8,
    "bool": 9,
}
_TOKEN_DTYPES = {v: k for k, v in _DTYPE_TOKENS.items()}


def _dtype_token(arr: np.ndarray) -> int:
    if arr.dtype.kind in ("U", "O", "S"):
        return _DTYPE_TOKENS["str"]
    return _DTYPE_TOKENS[arr.dtype.name]


def _is_string(arr: np.ndarray) -> bool:
    return arr.dtype.kind in ("U", "O", "S")


# ---------------------------------------------------------------------------
# bit packing helpers
# ---------------------------------------------------------------------------

def bit_width(max_value: int) -> int:
    """Number of bits needed to represent ``max_value`` (>=1 even for 0)."""
    if max_value <= 0:
        return 1
    return int(max_value).bit_length()


def pack_bits(values: np.ndarray, width: int) -> bytes:
    """Pack non-negative ints into a dense little-endian bit stream."""
    values = np.ascontiguousarray(values, dtype=np.uint64)
    n = len(values)
    if n == 0:
        return b""
    # expand each value into `width` bits (LSB first), then pack
    shifts = np.arange(width, dtype=np.uint64)
    bits = ((values[:, None] >> shifts) & np.uint64(1)).astype(np.uint8)
    flat = bits.reshape(-1)
    return np.packbits(flat, bitorder="little").tobytes()


def unpack_bits(buf: bytes, width: int, count: int) -> np.ndarray:
    """Inverse of :func:`pack_bits`."""
    if count == 0:
        return np.zeros(0, dtype=np.uint64)
    flat = np.unpackbits(np.frombuffer(buf, dtype=np.uint8), bitorder="little")
    flat = flat[: count * width].reshape(count, width).astype(np.uint64)
    shifts = np.arange(width, dtype=np.uint64)
    return (flat << shifts).sum(axis=1, dtype=np.uint64)


# ---------------------------------------------------------------------------
# string framing: (offsets int64, utf8 payload)
# ---------------------------------------------------------------------------

def _strings_to_frames(arr: np.ndarray) -> tuple[np.ndarray, bytes]:
    encoded = [str(s).encode("utf-8") for s in arr.tolist()]
    lengths = np.fromiter((len(e) for e in encoded), dtype=np.int64, count=len(encoded))
    offsets = np.zeros(len(encoded) + 1, dtype=np.int64)
    np.cumsum(lengths, out=offsets[1:])
    return offsets, b"".join(encoded)


def _frames_to_strings(offsets: np.ndarray, payload: bytes, row_limit: Optional[int]) -> np.ndarray:
    n = len(offsets) - 1 if row_limit is None else min(row_limit, len(offsets) - 1)
    out = np.empty(n, dtype=object)
    for i in range(n):
        out[i] = payload[offsets[i]: offsets[i + 1]].decode("utf-8")
    return out


# ---------------------------------------------------------------------------
# encoders
# ---------------------------------------------------------------------------

def _encode_plain(arr: np.ndarray) -> bytes:
    if _is_string(arr):
        offsets, payload = _strings_to_frames(arr)
        return struct.pack("<q", len(arr)) + offsets.tobytes() + payload
    return np.ascontiguousarray(arr).tobytes()


def _encode_rle(arr: np.ndarray) -> bytes:
    if _is_string(arr):
        # RLE over strings: dictionary-ize first, RLE the codes.
        uniques, codes = np.unique(np.asarray(arr, dtype=object).astype(str), return_inverse=True)
        dict_blob = _encode_plain(uniques)
        body = _encode_rle(codes.astype(np.int64))
        return struct.pack("<q", len(dict_blob)) + dict_blob + body
    arr = np.ascontiguousarray(arr)
    if len(arr) == 0:
        return struct.pack("<q", 0)
    change = np.empty(len(arr), dtype=bool)
    change[0] = True
    np.not_equal(arr[1:], arr[:-1], out=change[1:])
    starts = np.flatnonzero(change)
    run_values = arr[starts]
    run_lengths = np.diff(np.append(starts, len(arr))).astype(np.int64)
    return (
        struct.pack("<q", len(starts))
        + run_lengths.tobytes()
        + run_values.tobytes()
    )


def _encode_dictionary(arr: np.ndarray) -> bytes:
    if _is_string(arr):
        uniques, codes = np.unique(np.asarray(arr, dtype=object).astype(str), return_inverse=True)
    else:
        uniques, codes = np.unique(arr, return_inverse=True)
    width = bit_width(len(uniques) - 1 if len(uniques) else 0)
    dict_blob = _encode_plain(uniques)
    packed = pack_bits(codes.astype(np.uint64), width)
    return (
        struct.pack("<qqq", len(uniques), width, len(arr))
        + struct.pack("<q", len(dict_blob))
        + dict_blob
        + packed
    )


def _encode_bitpack(arr: np.ndarray) -> bytes:
    if _is_string(arr):
        raise ValueError("BITPACK does not support strings")
    vals = np.ascontiguousarray(arr).astype(np.int64)
    if len(vals) and vals.min() < 0:
        raise ValueError("BITPACK requires non-negative integers")
    width = bit_width(int(vals.max()) if len(vals) else 0)
    return struct.pack("<qq", width, len(vals)) + pack_bits(vals.astype(np.uint64), width)


# ---------------------------------------------------------------------------
# decoders (with partial-decode support)
# ---------------------------------------------------------------------------

def _decode_plain(buf: bytes, dtype: str, n_rows: int, row_limit: Optional[int]) -> np.ndarray:
    if dtype == "str":
        (n,) = struct.unpack_from("<q", buf, 0)
        offsets = np.frombuffer(buf, dtype=np.int64, count=n + 1, offset=8)
        payload = buf[8 + (n + 1) * 8:]
        return _frames_to_strings(offsets, payload, row_limit)
    count = n_rows if row_limit is None else min(row_limit, n_rows)
    return np.frombuffer(buf, dtype=np.dtype(dtype), count=count).copy()


def _decode_rle(buf: bytes, dtype: str, n_rows: int, row_limit: Optional[int]) -> np.ndarray:
    if dtype == "str":
        (dict_len,) = struct.unpack_from("<q", buf, 0)
        dict_blob = buf[8: 8 + dict_len]
        uniques = _decode_plain(dict_blob, "str", -1, None)
        codes = _decode_rle(buf[8 + dict_len:], "int64", n_rows, row_limit)
        out = np.empty(len(codes), dtype=object)
        for i, c in enumerate(codes):
            out[i] = uniques[c]
        return out
    (n_runs,) = struct.unpack_from("<q", buf, 0)
    run_lengths = np.frombuffer(buf, dtype=np.int64, count=n_runs, offset=8)
    run_values = np.frombuffer(
        buf, dtype=np.dtype(dtype), count=n_runs, offset=8 + n_runs * 8
    )
    full = np.repeat(run_values, run_lengths)
    if row_limit is not None:
        full = full[:row_limit]
    return full.copy()


def _decode_dictionary(buf: bytes, dtype: str, n_rows: int, row_limit: Optional[int]) -> np.ndarray:
    n_uniques, width, n = struct.unpack_from("<qqq", buf, 0)
    (dict_len,) = struct.unpack_from("<q", buf, 24)
    dict_blob = buf[32: 32 + dict_len]
    uniques = _decode_plain(dict_blob, dtype, n_uniques, None)
    count = n if row_limit is None else min(row_limit, n)
    # note: partial decode still unpacks from the stream start; the bit stream
    # is positionally addressable so we only unpack `count` entries.
    codes = unpack_bits(buf[32 + dict_len:], width, count).astype(np.int64)
    if dtype == "str":
        out = np.empty(count, dtype=object)
        for i, c in enumerate(codes):
            out[i] = uniques[c]
        return out
    return uniques[codes]


def _decode_bitpack(buf: bytes, dtype: str, n_rows: int, row_limit: Optional[int]) -> np.ndarray:
    width, n = struct.unpack_from("<qq", buf, 0)
    count = n if row_limit is None else min(row_limit, n)
    vals = unpack_bits(buf[16:], width, count)
    return vals.astype(np.dtype(dtype) if dtype != "str" else np.int64)


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

_ENCODERS = {
    Encoding.PLAIN: _encode_plain,
    Encoding.RLE: _encode_rle,
    Encoding.DICTIONARY: _encode_dictionary,
    Encoding.BITPACK: _encode_bitpack,
}

_DECODERS = {
    Encoding.PLAIN: _decode_plain,
    Encoding.RLE: _decode_rle,
    Encoding.DICTIONARY: _decode_dictionary,
    Encoding.BITPACK: _decode_bitpack,
}


def encode_column(arr: np.ndarray, encoding: Encoding) -> bytes:
    """Encode a 1-D column into a self-describing chunk payload."""
    arr = np.asarray(arr)
    if arr.ndim != 1:
        raise ValueError(f"columns must be 1-D, got shape {arr.shape}")
    body = _ENCODERS[encoding](arr)
    header = _MAGIC + struct.pack("<BBq", int(encoding), _dtype_token(arr), len(arr))
    return header + body


def decode_column(buf: bytes, row_limit: Optional[int] = None) -> np.ndarray:
    """Decode a chunk payload. ``row_limit`` decodes only a prefix."""
    if buf[:4] != _MAGIC:
        raise ValueError("bad column chunk magic")
    enc_token, dt_token, n_rows = struct.unpack_from("<BBq", buf, 4)
    body = buf[4 + 10:]
    dtype = _TOKEN_DTYPES[dt_token]
    return _DECODERS[Encoding(enc_token)](body, dtype, n_rows, row_limit)


def chunk_row_count(buf: bytes) -> int:
    """Row count of an encoded chunk without decoding it."""
    if buf[:4] != _MAGIC:
        raise ValueError("bad column chunk magic")
    _, _, n_rows = struct.unpack_from("<BBq", buf, 4)
    return n_rows


def choose_encoding(arr: np.ndarray) -> Encoding:
    """Pick a reasonable encoding the way a Parquet writer would."""
    arr = np.asarray(arr)
    if _is_string(arr):
        n_unique = len(set(arr.tolist()))
        return Encoding.DICTIONARY if n_unique <= max(16, len(arr) // 4) else Encoding.PLAIN
    if arr.dtype.kind == "f":
        return Encoding.PLAIN
    if len(arr) == 0:
        return Encoding.PLAIN
    # integer columns: RLE when sorted-ish / repetitive, else plain
    n_runs = int(np.count_nonzero(np.diff(arr)) + 1)
    if n_runs <= len(arr) // 4:
        return Encoding.RLE
    if arr.min() >= 0 and bit_width(int(arr.max())) <= arr.dtype.itemsize * 8 // 2:
        return Encoding.BITPACK
    return Encoding.PLAIN
