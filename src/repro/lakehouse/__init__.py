"""Simulated Lakehouse substrate: columnar open-format files on an object store.

This package implements the storage layer GraphLake reads from:

- ``encoding``    — column-chunk encodings (PLAIN / RLE / DICTIONARY / BITPACK),
- ``columnfile``  — Parquet-like files: row groups -> column chunks -> pages,
                    footer metadata with min/max statistics,
- ``table``       — Iceberg-like table format: schema, snapshots, manifests,
                    immutable data files, ACID-ish commits via metadata swap,
- ``objectstore`` — object store with a configurable latency/bandwidth model
                    (stands in for S3) plus a local-disk tier,
- ``io_pool``     — async I/O thread pool used to pipeline downloads with compute,
- ``writer``      — bulk table writer used by the dataset generators,
- ``faults``      — seeded deterministic fault injection on the store,
- ``retry``       — typed retry/backoff every lake read flows through.
"""

from repro.lakehouse.encoding import Encoding, encode_column, decode_column
from repro.lakehouse.columnfile import (
    ColumnChunkMeta,
    ColumnFileMeta,
    RowGroupMeta,
    read_column_chunk,
    read_footer,
    write_column_file,
)
from repro.lakehouse.faults import FaultInjector, FaultRule, transient_chaos
from repro.lakehouse.objectstore import ObjectStore, StoreConfig
from repro.lakehouse.retry import RetryPolicy, default_policy, lake_get, retry_stats
from repro.lakehouse.table import LakeTable, TableSchema, ColumnSpec, LakeCatalog
from repro.lakehouse.io_pool import IOPool
from repro.lakehouse.writer import write_table

__all__ = [
    "Encoding",
    "encode_column",
    "decode_column",
    "ColumnChunkMeta",
    "ColumnFileMeta",
    "RowGroupMeta",
    "read_column_chunk",
    "read_footer",
    "write_column_file",
    "ObjectStore",
    "StoreConfig",
    "LakeTable",
    "TableSchema",
    "ColumnSpec",
    "LakeCatalog",
    "IOPool",
    "write_table",
    "FaultInjector",
    "FaultRule",
    "transient_chaos",
    "RetryPolicy",
    "default_policy",
    "lake_get",
    "retry_stats",
]
