"""Seeded, deterministic fault injection for the object store (DESIGN.md §11).

The paper's whole premise is querying graphs over *remote* Lakehouse
storage, where throttled GETs, latency spikes and torn reads are the steady
state — so the reproduction injects them on purpose.  A
:class:`FaultInjector` installs on :class:`~repro.lakehouse.objectstore.
ObjectStore` (via ``StoreConfig.faults`` or the ``chaos`` perf flag) and
intercepts every ``get`` / ``put`` / ``put_if``, drawing from a seeded RNG
against per-key-prefix :class:`FaultRule` rates:

- **transient** — raises :class:`~repro.errors.TransientLakeError`
  (throttle / connection reset); the retry layer's bread and butter;
- **spike**     — multiplies the store's modeled latency for this one
  request (``spike_mult`` on the latency model; a no-op when the latency
  model is off, so unit tests stay fast);
- **torn**      — the returned bytes are truncated (``get`` only): the
  short-read the checked readers detect and classify as transient;
- **missing**   — raises :class:`~repro.errors.MissingObjectError`: the
  fatal class, for testing that fatal faults surface typed and untried.

Per-class / per-rule counters record exactly what fired, so chaos tests can
assert both "faults actually happened" and "no user-visible failure
happened anyway".  Draws are serialized under a lock from one seeded
``random.Random``: a single-threaded op sequence is exactly reproducible;
under concurrency the *schedule* of which op draws which fault depends on
interleaving, but rates, counters and determinism-per-seed are preserved —
and the engine above must produce bit-identical results either way.
"""

from __future__ import annotations

import dataclasses
import random
import threading
from typing import Optional, Sequence

from repro.errors import MissingObjectError, TransientLakeError

FAULT_CLASSES = ("transient", "spike", "torn", "missing")


@dataclasses.dataclass
class FaultRule:
    """Fault rates for one key prefix (first matching rule wins)."""

    prefix: str = ""                 # "" matches every key
    ops: tuple = ("get",)            # which store ops this rule intercepts
    transient_rate: float = 0.0
    spike_rate: float = 0.0
    spike_mult: float = 10.0         # latency-model multiplier while spiking
    torn_rate: float = 0.0           # get only: truncate the returned bytes
    missing_rate: float = 0.0
    max_faults: Optional[int] = None  # cap total injections for this rule


@dataclasses.dataclass
class FaultDecision:
    """What the store should do to the intercepted op (transient/missing
    faults raise inside :meth:`FaultInjector.intercept` instead)."""

    torn: bool = False
    spike_mult: float = 1.0


class FaultInjector:
    def __init__(self, rules: Sequence[FaultRule], seed: int = 0):
        self.rules = list(rules)
        self.seed = seed
        self._rng = random.Random(seed)
        self._lock = threading.Lock()
        self.counters = {c: 0 for c in FAULT_CLASSES}
        self.counters["ops_seen"] = 0
        # per-(rule index, class) fire counts — tests assert exactly what fired
        self.per_rule: list[dict] = [
            {c: 0 for c in FAULT_CLASSES} for _ in self.rules
        ]

    def _rule_for(self, op: str, key: str) -> Optional[tuple[int, FaultRule]]:
        for i, rule in enumerate(self.rules):
            if op in rule.ops and key.startswith(rule.prefix):
                return i, rule
        return None

    def fired(self, cls: Optional[str] = None) -> int:
        """Total injections (optionally of one class) — what chaos tests
        assert to prove the schedule actually exercised the engine."""
        with self._lock:
            if cls is not None:
                return self.counters[cls]
            return sum(self.counters[c] for c in FAULT_CLASSES)

    def intercept(self, op: str, key: str) -> FaultDecision:
        """Decide (and partly apply) the fault for one store op.

        Raises for transient/missing faults; returns a
        :class:`FaultDecision` telling the store to tear the read and/or
        spike its modeled latency.  At most one fault class fires per op
        (classes are drawn in a fixed order), so counters partition cleanly.
        """
        hit = self._rule_for(op, key)
        with self._lock:
            self.counters["ops_seen"] += 1
            if hit is None:
                return FaultDecision()
            i, rule = hit
            if rule.max_faults is not None and \
                    sum(self.per_rule[i][c] for c in FAULT_CLASSES) >= rule.max_faults:
                return FaultDecision()
            draw = self._rng.random()
            # one draw walks the class ladder: deterministic per seed, one
            # fault max per op
            edge = rule.transient_rate
            if draw < edge:
                self.counters["transient"] += 1
                self.per_rule[i]["transient"] += 1
                raise TransientLakeError(
                    f"injected transient fault (op={op})", key=key)
            edge += rule.missing_rate
            if draw < edge:
                self.counters["missing"] += 1
                self.per_rule[i]["missing"] += 1
                raise MissingObjectError(
                    f"injected missing-key fault (op={op})", key=key)
            decision = FaultDecision()
            edge += rule.torn_rate
            if op == "get" and draw < edge:
                self.counters["torn"] += 1
                self.per_rule[i]["torn"] += 1
                decision.torn = True
                return decision
            edge += rule.spike_rate
            if draw < edge:
                self.counters["spike"] += 1
                self.per_rule[i]["spike"] += 1
                decision.spike_mult = rule.spike_mult
            return decision

    def tear(self, data: bytes) -> bytes:
        """Truncate a read result — at least one byte, up to a third — so a
        checked reader always sees fewer bytes than it asked for."""
        if not data:
            return data
        cut = max(1, len(data) // 3)
        return data[: len(data) - cut]

    def snapshot(self) -> dict:
        """Counters for health/bench reporting (copy, lock-consistent)."""
        with self._lock:
            return dict(self.counters)


def transient_chaos(rate: float, seed: int = 0,
                    prefix: str = "tables/") -> FaultInjector:
    """The default chaos schedule (``chaos`` perf flag / ``chaos=<rate>``):
    transient faults + latency spikes + torn reads on lake-table reads at
    the given rate each (spikes at 2x the rate — cheap, non-erroring)."""
    return FaultInjector([FaultRule(
        prefix=prefix, ops=("get",),
        transient_rate=rate, torn_rate=rate / 2, spike_rate=2 * rate,
    )], seed=seed)


__all__ = ["FaultRule", "FaultDecision", "FaultInjector", "transient_chaos",
           "FAULT_CLASSES"]
