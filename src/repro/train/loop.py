"""Fault-tolerant training loop (DESIGN.md §6).

Wires together: stateless data pipeline (exact resume), periodic + preemption
checkpointing (atomic, async), straggler detection, heartbeats, optional
gradient compression, and metrics logging.  The loop is family-agnostic: it
drives any Arch from the registry.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.data.pipeline import StatelessPipeline
from repro.distributed.fault import HeartbeatRegistry, PreemptionGuard, StragglerDetector
from repro.train.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.train.metrics import MetricsLogger


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    keep_checkpoints: int = 3
    log_path: Optional[str] = None
    log_every: int = 10
    async_checkpoint: bool = True
    straggler_threshold: float = 3.0


@dataclasses.dataclass
class TrainResult:
    final_state: dict
    steps_run: int
    resumed_from: Optional[int]
    losses: list
    straggler_steps: list
    preempted: bool


def run_training(
    init_state_fn: Callable[[], dict],
    step_fn: Callable,
    pipeline: StatelessPipeline,
    config: TrainLoopConfig,
    preemption: Optional[PreemptionGuard] = None,
    shardings=None,
) -> TrainResult:
    """Run (or resume) training to ``total_steps``."""
    logger = MetricsLogger(config.log_path, config.log_every)
    straggler = StragglerDetector(threshold=config.straggler_threshold)
    heartbeat = HeartbeatRegistry()
    preemption = preemption or PreemptionGuard(install=False)

    # ---- resume ------------------------------------------------------------
    resumed_from = None
    state = init_state_fn()
    if config.checkpoint_dir:
        last = latest_step(config.checkpoint_dir)
        if last is not None:
            state = restore_checkpoint(config.checkpoint_dir, state,
                                       step=last, shardings=shardings)
            resumed_from = last
    start_step = int(np.asarray(state["step"]))

    ckpt = (AsyncCheckpointer(config.checkpoint_dir, keep=config.keep_checkpoints)
            if config.checkpoint_dir and config.async_checkpoint else None)

    step_jit = jax.jit(step_fn, donate_argnums=(0,))
    losses = []
    preempted = False
    steps_run = 0
    try:
        for step, batch in pipeline.iterate(start_step,
                                            config.total_steps - start_step):
            t0 = time.perf_counter()
            batch = jax.tree.map(jax.numpy.asarray, batch)
            state, metrics = step_jit(state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            steps_run += 1
            heartbeat.tick("trainer")
            dt = time.perf_counter() - t0
            straggler.record(step, dt)
            logger.log(step, {**metrics, "lr_step": step})

            at_boundary = config.checkpoint_dir and (
                (step + 1) % config.checkpoint_every == 0
                or step + 1 == config.total_steps
            )
            if preemption.should_stop():
                preempted = True
                at_boundary = bool(config.checkpoint_dir)
            if at_boundary:
                if ckpt is not None:
                    ckpt.save(step + 1, state)
                else:
                    from repro.train.checkpoint import save_checkpoint
                    save_checkpoint(config.checkpoint_dir, step + 1, state,
                                    keep=config.keep_checkpoints)
            if preempted:
                break
    finally:
        if ckpt is not None:
            ckpt.close()

    return TrainResult(
        final_state=state,
        steps_run=steps_run,
        resumed_from=resumed_from,
        losses=losses,
        straggler_steps=straggler.flagged_steps,
        preempted=preempted,
    )
