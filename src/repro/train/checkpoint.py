"""Checkpointing: atomic, content-hashed, sharded-by-leaf, async-capable,
elastic-restore (DESIGN.md §6).

Layout (one directory per step):

    <root>/step_000120/MANIFEST.json     # tree structure + hashes + shapes
    <root>/step_000120/leaf_00000.npy    # one file per pytree leaf
    <root>/LATEST                        # atomic pointer, written last

Writing goes to ``step_X.tmp/`` then renames — a crash mid-save never
corrupts the latest checkpoint (the pointer still names the previous one).
Every leaf carries a SHA-256 in the manifest; restore verifies integrity.
Restore is mesh-agnostic: leaves are stored as logical (global) arrays, so a
job restarted on a different mesh simply shards them differently (elastic
scaling).  ``AsyncCheckpointer`` runs saves on a background thread with a
bounded queue (training never blocks on I/O unless two saves overlap).
"""

from __future__ import annotations

import hashlib
import json
import os
import queue
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _tree_paths(tree) -> list[str]:
    paths = []
    for path, _leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            else:
                parts.append(str(p))
        paths.append("/".join(parts))
    return paths


def save_checkpoint(root: str, step: int, state: Any, keep: int = 3) -> str:
    """Synchronous save. Returns the checkpoint directory."""
    name = f"step_{step:08d}"
    final_dir = os.path.join(root, name)
    tmp_dir = final_dir + ".tmp"
    if os.path.exists(tmp_dir):
        shutil.rmtree(tmp_dir)
    os.makedirs(tmp_dir, exist_ok=True)

    leaves, treedef = jax.tree_util.tree_flatten(state)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "paths": _tree_paths(state),
        "leaves": [],
    }
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        fn = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp_dir, fn), arr)
        with open(os.path.join(tmp_dir, fn), "rb") as f:
            digest = hashlib.sha256(f.read()).hexdigest()
        manifest["leaves"].append({
            "file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "sha256": digest,
        })
    with open(os.path.join(tmp_dir, "MANIFEST.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final_dir):
        shutil.rmtree(final_dir)
    os.replace(tmp_dir, final_dir)

    # atomic pointer update, then retention sweep
    ptr_tmp = os.path.join(root, "LATEST.tmp")
    with open(ptr_tmp, "w") as f:
        f.write(name)
    os.replace(ptr_tmp, os.path.join(root, "LATEST"))
    _apply_retention(root, keep)
    return final_dir


def _apply_retention(root: str, keep: int) -> None:
    ckpts = sorted(d for d in os.listdir(root)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for victim in ckpts[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(root, victim), ignore_errors=True)


def latest_step(root: str) -> Optional[int]:
    ptr = os.path.join(root, "LATEST")
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        return int(f.read().strip().split("_")[1])


def restore_checkpoint(root: str, example_state: Any, step: Optional[int] = None,
                       shardings: Any = None, verify: bool = True) -> Any:
    """Restore into the structure of ``example_state`` (tree must match).

    ``shardings``: optional matching pytree of NamedShardings — leaves are
    device_put with them (this is the elastic-restore path: any mesh works).
    """
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {root}")
    ckpt_dir = os.path.join(root, f"step_{step:08d}")
    with open(os.path.join(ckpt_dir, "MANIFEST.json")) as f:
        manifest = json.load(f)

    leaves, treedef = jax.tree_util.tree_flatten(example_state)
    if len(leaves) != len(manifest["leaves"]):
        raise ValueError(
            f"checkpoint has {len(manifest['leaves'])} leaves; "
            f"state expects {len(leaves)}"
        )
    sh_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                 if shardings is not None else [None] * len(leaves))
    out = []
    for i, (meta, ref_leaf) in enumerate(zip(manifest["leaves"], leaves)):
        path = os.path.join(ckpt_dir, meta["file"])
        if verify:
            with open(path, "rb") as f:
                digest = hashlib.sha256(f.read()).hexdigest()
            if digest != meta["sha256"]:
                raise IOError(f"checksum mismatch in {path}")
        arr = np.load(path)
        if list(arr.shape) != list(np.shape(ref_leaf)):
            raise ValueError(
                f"leaf {i} shape {arr.shape} != expected {np.shape(ref_leaf)}"
            )
        if sh_leaves[i] is not None:
            out.append(jax.device_put(arr, sh_leaves[i]))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


class AsyncCheckpointer:
    """Background-thread checkpointing with a bounded queue."""

    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        self._q: queue.Queue = queue.Queue(maxsize=1)
        self._errors: list[Exception] = []
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, state = item
            try:
                save_checkpoint(self.root, step, state, keep=self.keep)
            except Exception as e:  # surfaced on next save/close
                self._errors.append(e)

    def save(self, step: int, state: Any) -> None:
        if self._errors:
            raise self._errors.pop(0)
        # snapshot to host first so training can mutate device state freely
        host_state = jax.tree.map(lambda x: np.asarray(x), state)
        self._q.put((step, host_state))

    def close(self) -> None:
        self._q.put(None)
        self._thread.join()
        if self._errors:
            raise self._errors.pop(0)
