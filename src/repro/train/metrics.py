"""Training metrics: running aggregation + JSONL logging."""

from __future__ import annotations

import json
import os
import time
from typing import Optional


class MetricsLogger:
    def __init__(self, path: Optional[str] = None, log_every: int = 10):
        self.path = path
        self.log_every = log_every
        self.history: list[dict] = []
        self._t_last = time.perf_counter()
        if path:
            os.makedirs(os.path.dirname(path) or ".", exist_ok=True)

    def log(self, step: int, metrics: dict) -> dict:
        now = time.perf_counter()
        rec = {"step": int(step), "time_s": round(now - self._t_last, 4)}
        self._t_last = now
        for k, v in metrics.items():
            try:
                rec[k] = float(v)
            except (TypeError, ValueError):
                rec[k] = v
        self.history.append(rec)
        if self.path and (step % self.log_every == 0):
            with open(self.path, "a") as f:
                f.write(json.dumps(rec) + "\n")
        return rec

    def smoothed(self, key: str, window: int = 20) -> float:
        vals = [h[key] for h in self.history[-window:] if key in h]
        return sum(vals) / max(len(vals), 1)
