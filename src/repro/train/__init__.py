"""Training substrate: optimizer, loop, checkpointing, metrics."""
