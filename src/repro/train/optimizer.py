"""AdamW with global-norm clipping and cosine schedule (pure JAX pytrees).

Optimizer state shards exactly like the parameters (same tree structure =>
same PartitionSpecs), which is what makes elastic restore a pure reshard.
An optional gradient-compression hook (``repro.distributed.compression``)
wraps the gradient tree before the update — used on the cross-pod axis.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class OptimizerConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class AdamW:
    def __init__(self, config: Optional[OptimizerConfig] = None,
                 grad_transform: Optional[Callable] = None):
        self.config = config or OptimizerConfig()
        self.grad_transform = grad_transform

    # -- state ----------------------------------------------------------------

    def init(self, params) -> dict:
        zeros = lambda p: jnp.zeros_like(p)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
        }

    # -- schedule ----------------------------------------------------------------

    def learning_rate(self, step) -> jax.Array:
        c = self.config
        step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
        warm = step / max(c.warmup_steps, 1)
        prog = jnp.clip(
            (step - c.warmup_steps) / max(c.decay_steps - c.warmup_steps, 1), 0.0, 1.0
        )
        cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
        decayed = c.min_lr_ratio + (1 - c.min_lr_ratio) * cos
        return c.lr * jnp.where(step < c.warmup_steps, warm, decayed)

    # -- update ----------------------------------------------------------------

    def last_grad_norm(self, grads) -> jax.Array:
        return jnp.sqrt(
            sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads))
        )

    def update(self, params, grads, opt_state, step):
        c = self.config
        if self.grad_transform is not None:
            grads = self.grad_transform(grads)
        gnorm = self.last_grad_norm(grads)
        scale = jnp.minimum(1.0, c.clip_norm / jnp.maximum(gnorm, 1e-9))
        lr = self.learning_rate(step)
        b1, b2 = c.beta1, c.beta2
        t = (step + 1).astype(jnp.float32) if hasattr(step, "astype") else float(step + 1)
        bias1 = 1 - b1 ** t
        bias2 = 1 - b2 ** t

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m_new = b1 * m + (1 - b1) * g
            v_new = b2 * v + (1 - b2) * g * g
            m_hat = m_new / bias1
            v_hat = v_new / bias2
            step_val = m_hat / (jnp.sqrt(v_hat) + c.eps) + c.weight_decay * p
            return (p - lr * step_val).astype(p.dtype), m_new, v_new

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(opt_state["m"])
        flat_v = treedef.flatten_up_to(opt_state["v"])
        out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v}


def make_train_state(rng, init_fn, optimizer: AdamW) -> dict:
    params = init_fn(rng)
    return {
        "params": params,
        "opt": optimizer.init(params),
        "step": jnp.zeros((), jnp.int32),
    }
