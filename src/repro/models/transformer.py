"""Decoder-only transformer LMs (dense / MoE / MLA) with train + serve steps.

Layer stacks are scanned (``lax.scan`` over stacked per-layer params) with
selective remat — the HLO stays small enough that 512-way SPMD lowering on
CPU placeholder devices compiles in seconds, and activation memory stays at
one (B, S, D) residual per layer.

Step functions (what the dry-run lowers and the launcher runs):

- ``train_step(state, batch)``      — fwd + bwd + fused AdamW update,
- ``prefill_step(params, tokens)``  — build KV caches + first logits,
- ``decode_step(params, caches, token, index)`` — one-token serve step.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.meshctx import constrain
from repro.models import layers as L
from repro.models.layers import wuse
from repro.models.moe import MoEConfig, moe_apply, moe_init


@dataclasses.dataclass
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    mla: Optional[L.MLAConfig] = None
    dtype: str = "bfloat16"
    remat: bool = True
    # unroll the layer scan (dry-run cost variants; cost_analysis counts
    # while-loop bodies once, so exact FLOP audits need straight-line HLO)
    scan_unroll: bool = False

    def __post_init__(self):
        if self.d_head is None:
            self.d_head = self.d_model // self.n_heads

    @property
    def attention(self) -> str:
        return "mla" if self.mla is not None else "gqa"

    @property
    def gqa(self) -> L.GQAConfig:
        return L.GQAConfig(
            d_model=self.d_model, n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads, d_head=self.d_head,
            qkv_bias=self.qkv_bias, rope_theta=self.rope_theta,
        )

    # ---- analytic parameter / FLOP model (roofline §8) ----------------------

    def param_count(self) -> int:
        d = self.d_model
        if self.mla is not None:
            m = self.mla
            attn = (d * self.n_heads * m.qk_dim + d * m.kv_lora_rank
                    + d * m.qk_rope_dim + m.kv_lora_rank * self.n_heads
                    * (m.qk_nope_dim + m.v_head_dim)
                    + self.n_heads * m.v_head_dim * d)
        else:
            attn = d * self.d_head * (self.n_heads * 2 + self.n_kv_heads * 2)
        if self.moe is not None:
            ffn = (self.moe.n_experts * 3 * d * self.moe.d_ff_expert
                   + d * self.moe.n_experts
                   + (3 * d * self.moe.d_ff_expert * self.moe.n_shared))
        else:
            ffn = 3 * d * self.d_ff
        per_layer = attn + ffn + 2 * d
        embed = self.vocab * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + embed + d

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k + shared experts only)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        inactive = (self.moe.n_experts - self.moe.top_k) * 3 \
            * self.d_model * self.moe.d_ff_expert * self.n_layers
        return full - inactive


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _layer_init(rng, cfg: LMConfig) -> dict:
    ka, kf = jax.random.split(rng)
    p = {
        "ln_attn": jnp.ones(cfg.d_model, jnp.float32),
        "ln_ffn": jnp.ones(cfg.d_model, jnp.float32),
    }
    if cfg.mla is not None:
        p["attn"] = L.mla_init(ka, cfg.mla)
    else:
        p["attn"] = L.gqa_init(ka, cfg.gqa)
    if cfg.moe is not None:
        p["moe"] = moe_init(kf, cfg.moe)
    else:
        p["ffn"] = L.swiglu_init(kf, cfg.d_model, cfg.d_ff)
    return p


def init_params(rng, cfg: LMConfig) -> dict:
    ke, kl, ko = jax.random.split(rng, 3)
    # stacked layers: vmap the per-layer init over layer keys
    layer_keys = jax.random.split(kl, cfg.n_layers)
    layers = jax.vmap(lambda k: _layer_init(k, cfg))(layer_keys)
    params = {
        "embed": jax.random.normal(ke, (cfg.vocab, cfg.d_model), jnp.float32) * 0.02,
        "layers": layers,
        "ln_final": jnp.ones(cfg.d_model, jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(ko, cfg.d_model, cfg.vocab)
    return params


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _block(cfg: LMConfig, lp: dict, x: jax.Array, positions: jax.Array,
           cache: Optional[jax.Array], cache_index, causal: bool):
    h = L.rms_norm(x, lp["ln_attn"])
    if cfg.mla is not None:
        attn_out, new_cache = L.mla_attention(
            lp["attn"], cfg.mla, h, positions, cache, cache_index, causal
        )
    else:
        attn_out, new_cache = L.gqa_attention(
            lp["attn"], cfg.gqa, h, positions, cache, cache_index, causal
        )
    x = x + attn_out
    h = L.rms_norm(x, lp["ln_ffn"])
    if cfg.moe is not None:
        ffn_out, aux = moe_apply(lp["moe"], cfg.moe, h)
    else:
        ffn_out, aux = L.swiglu(lp["ffn"], h), jnp.zeros((), jnp.float32)
    return x + ffn_out, new_cache, aux


def _trunk(
    cfg: LMConfig, params: dict, tokens: jax.Array,
    caches: Optional[jax.Array] = None, cache_index=None, causal: bool = True,
    positions: Optional[jax.Array] = None,
):
    """Embed + layer stack + final norm -> (x (B, S, D), caches, aux)."""
    compute = jnp.dtype(cfg.dtype)
    x = constrain(params["embed"][tokens].astype(compute), "dp", None, None)
    b, s = tokens.shape
    if positions is None:
        start = 0 if cache_index is None else cache_index
        positions = start + jnp.arange(s)[None, :].astype(jnp.int32)
        positions = jnp.broadcast_to(positions, (b, s))

    def scan_fn(carry, layer_in):
        x = constrain(carry, "dp", None, None)
        lp, layer_cache = layer_in
        x, new_cache, aux = _block(cfg, lp, x, positions, layer_cache,
                                   cache_index, causal)
        return constrain(x, "dp", None, None), (new_cache, aux)

    body = scan_fn
    if cfg.remat:
        body = jax.checkpoint(
            scan_fn, policy=jax.checkpoint_policies.nothing_saveable
        )
    x, (new_caches, aux) = jax.lax.scan(
        body, x, (params["layers"], caches),
        unroll=cfg.n_layers if cfg.scan_unroll else 1,
    )
    return L.rms_norm(x, params["ln_final"]), new_caches, aux.sum()


def forward(
    cfg: LMConfig, params: dict, tokens: jax.Array,
    caches: Optional[jax.Array] = None, cache_index=None, causal: bool = True,
    positions: Optional[jax.Array] = None,
):
    """tokens: (B, S) -> (logits (B, S, V), new_caches, aux_loss).

    ``caches``: stacked per-layer KV (or MLA latent) caches with leading layer
    axis, or None for cache-less training.
    """
    compute = jnp.dtype(cfg.dtype)
    x, new_caches, aux = _trunk(cfg, params, tokens, caches, cache_index,
                                causal, positions)
    if cfg.tie_embeddings:
        head = params["embed"].T.astype(compute)
    else:
        head = wuse(params["lm_head"], compute, "fsdp", "model")
    logits = constrain((x @ head).astype(jnp.float32), "dp", None, "model")
    return logits, new_caches, aux


# ---------------------------------------------------------------------------
# training
# ---------------------------------------------------------------------------

def _nll(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]


def loss_fn(cfg: LMConfig, params: dict, batch: dict) -> jax.Array:
    from repro.perf_flags import enabled

    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    chunk = 512
    s = batch["tokens"].shape[1]
    if enabled("chunkloss") and s % chunk == 0 and s // chunk >= 2:
        # chunked loss: never materialize the (B, S, V) f32 logits (§Perf).
        # run the trunk once, then head+log-softmax+NLL per sequence chunk.
        compute = jnp.dtype(cfg.dtype)
        x, _, aux = _trunk(cfg, params, batch["tokens"])
        if cfg.tie_embeddings:
            head = params["embed"].T.astype(compute)
        else:
            head = wuse(params["lm_head"], compute, "fsdp", "model")
        total = jnp.zeros((), jnp.float32)
        for i in range(s // chunk):  # static loop: straight-line schedule
            sl = slice(i * chunk, (i + 1) * chunk)
            logits_c = constrain(
                (x[:, sl] @ head).astype(jnp.float32),
                "dp", None, "model")
            total = total + (_nll(logits_c, labels[:, sl]) * mask[:, sl]).sum()
        return total / jnp.maximum(mask.sum(), 1.0) + aux
    logits, _, aux = forward(cfg, params, batch["tokens"])
    nll = _nll(logits, labels)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0) + aux


def make_train_step(cfg: LMConfig, optimizer):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def train_step(state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch)
        )(state["params"])
        new_params, new_opt = optimizer.update(state["params"], grads,
                                               state["opt"], state["step"])
        metrics = {
            "loss": loss,
            "grad_norm": optimizer.last_grad_norm(grads),
        }
        return {
            "params": new_params, "opt": new_opt, "step": state["step"] + 1
        }, metrics

    return train_step


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_caches(cfg: LMConfig, batch: int, max_len: int, dtype=jnp.bfloat16,
                quantized: Optional[bool] = None):
    """Stacked per-layer caches (leading layer axis).  With the ``kv_int8``
    perf flag (or quantized=True), caches are int8 + per-vector bf16 scales —
    half the persistent decode memory."""
    if quantized is None:
        from repro.perf_flags import enabled
        quantized = enabled("kv_int8")
    if cfg.mla is not None:
        shape = (cfg.n_layers, batch, max_len, cfg.mla.cache_dim)
        if quantized:
            return (jnp.zeros(shape, jnp.int8),
                    jnp.zeros(shape[:-1] + (1,), jnp.bfloat16))
        return jnp.zeros(shape, dtype)
    kshape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.d_head)
    if quantized:
        sshape = kshape[:-1] + (1,)
        mk = lambda: (jnp.zeros(kshape, jnp.int8), jnp.zeros(sshape, jnp.bfloat16))
        return (mk(), mk())
    return (jnp.zeros(kshape, dtype), jnp.zeros(kshape, dtype))


def prefill_step(cfg: LMConfig, params: dict, tokens: jax.Array, caches):
    """Prefill: run the prompt, fill caches, return last-position logits."""
    logits, new_caches, _ = forward(
        cfg, params, tokens, caches=caches, cache_index=0, causal=True
    )
    return logits[:, -1, :], new_caches


def decode_step(cfg: LMConfig, params: dict, caches, token: jax.Array,
                index: jax.Array):
    """One decode step. token: (B, 1); index: scalar current length."""
    logits, new_caches, _ = forward(
        cfg, params, token, caches=caches, cache_index=index, causal=False,
        positions=jnp.full(token.shape, index, dtype=jnp.int32),
    )
    return logits[:, -1, :], new_caches
