"""Mixture-of-Experts FFN: top-k routing with capacity-bucketed scatter
dispatch (MegaBlocks-style, linear memory).

Classic GShard dispatch materializes a (tokens, experts, capacity) one-hot —
O(T²) at large batch.  Here dispatch is a scatter-add into an (E*C, D) expert
buffer and combine is K gathers back, so memory stays O(T·D + E·C·D):

    slot(t, k) = expert(t, k) * C + position-within-expert(t, k)
    xe          = zeros(E*C, D).at[slot].add(x)      # K sequential scatters
    ye          = expert_ffn(xe)                     # stacked (E, C, D) einsums
    out(t)      = sum_k gate(t,k) * ye[slot(t, k)]   # K gathers

With experts sharded over the ``model`` axis and tokens over ``data``, XLA
SPMD lowers the scatter/gather across shards to the expected all-to-alls.
Overflow beyond capacity C = ceil(cf * T * k / E) drops (standard capacity
semantics); the aux loss keeps the router balanced.

Structural kinship with the paper's engine: the (token -> expert) assignment
is an edge list, dispatch/combine are EdgeScan's gather/segment-sum
(DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.distributed.meshctx import constrain
from repro.models.layers import dense_init, swiglu, swiglu_init, wuse


@dataclasses.dataclass
class MoEConfig:
    d_model: int
    d_ff_expert: int           # per-expert intermediate size
    n_experts: int
    top_k: int
    n_shared: int = 0          # DeepSeek shared experts (always-on)
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01


def moe_init(rng, cfg: MoEConfig) -> dict:
    ks = jax.random.split(rng, 3)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff_expert
    scale = (6.0 / (d + f)) ** 0.5
    p = {
        "router": dense_init(ks[0], d, e),
        # stacked expert weights (E, ...) — sharded over the model axis (EP)
        "w_gate": jax.random.uniform(ks[1], (e, d, f), jnp.float32, -scale, scale),
        "w_up": jax.random.uniform(jax.random.fold_in(ks[1], 1), (e, d, f),
                                   jnp.float32, -scale, scale),
        "w_down": jax.random.uniform(jax.random.fold_in(ks[1], 2), (e, f, d),
                                     jnp.float32, -scale, scale),
    }
    if cfg.n_shared:
        p["shared"] = swiglu_init(ks[2], d, cfg.d_ff_expert * cfg.n_shared)
    return p


def moe_apply(p: dict, cfg: MoEConfig, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out, aux_loss)."""
    b, s, d = x.shape
    compute = x.dtype
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    xt = x.reshape(t, d)

    logits = (xt.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, top_idx = jax.lax.top_k(probs, k)                          # (T, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # explicit expert-parallel dispatch (§Perf "moe_ep"): under a mesh, the
    # pjit scatter-dispatch below lowers to full expert-buffer all-reduces
    # (measured 94% of deepseek train collectives); the shard_map path
    # scatters locally per (data, model) device and only psums (T_local, D)
    from repro.distributed.meshctx import current_mesh
    from repro.perf_flags import enabled
    mesh = current_mesh()
    if (enabled("moe_ep") and mesh is not None and "model" in mesh.axis_names
            and e % mesh.shape["model"] == 0):
        out_t, aux = _moe_ep_shardmap(p, cfg, mesh, xt, top_idx, gate_vals,
                                      probs)
        out = out_t.reshape(b, s, d)
        if cfg.n_shared and "shared" in p:
            out = out + swiglu(p["shared"], x)
        return out, aux

    # small token counts (decode steps, smoke tests) run dropless: capacity
    # covers the worst case so serving quality never degrades from drops
    if t <= 256:
        capacity = t
    else:
        capacity = max(1, int(cfg.capacity_factor * t * k / e))

    # position-within-expert for every (t, k) assignment, sort-based
    # (MegaBlocks-style).  The one-hot cumsum alternative is O(T*K*E) with a
    # reduce-window lowering — measured 235x FLOP inflation (EXPERIMENTS.md
    # §Perf); stable argsort keeps first-come-first-served capacity semantics.
    flat_e = top_idx.reshape(t * k)
    order = jnp.argsort(flat_e, stable=True)                              # (T*K,)
    sorted_e = flat_e[order]
    expert_starts = jnp.searchsorted(sorted_e, jnp.arange(e))             # (E,)
    pos_sorted = jnp.arange(t * k, dtype=jnp.int32) - expert_starts[sorted_e]
    pos = jnp.zeros(t * k, jnp.int32).at[order].set(
        pos_sorted, mode="drop").reshape(t, k)                            # (T, K)
    keep = pos < capacity

    oob = e * capacity                                                    # drop slot
    slot = jnp.where(keep, top_idx * capacity + pos, oob)                 # (T, K)

    # dispatch: K sequential scatter-adds into the expert buffer.  The buffer
    # is constrained to the expert (model) axis at creation so GSPMD lowers
    # each scatter as partial-scatter + combine instead of replicating the
    # whole dispatch across the model axis (15x measured, EXPERIMENTS.md §Perf)
    xe = constrain(jnp.zeros((e * capacity, d), compute), "model", None)
    for kk in range(k):
        xe = constrain(
            xe.at[slot[:, kk]].add(
                xt * keep[:, kk, None].astype(compute), mode="drop"
            ),
            "model", None,
        )
    xe = constrain(xe.reshape(e, capacity, d), "model", None, None)

    # expert computation: stacked SwiGLU over (E, C, D)
    g = constrain(
        jax.nn.silu(jnp.einsum(
            "ecd,edf->ecf", xe, wuse(p["w_gate"], compute, "model", "fsdp", None))),
        "model", None, None)
    u = constrain(jnp.einsum(
        "ecd,edf->ecf", xe, wuse(p["w_up"], compute, "model", "fsdp", None)),
        "model", None, None)
    ye = constrain(jnp.einsum(
        "ecf,efd->ecd", g * u, wuse(p["w_down"], compute, "model", None, "fsdp")),
        "model", None, None)
    ye_flat = ye.reshape(e * capacity, d)

    # combine: K gathers weighted by gates
    out_t = jnp.zeros((t, d), compute)
    for kk in range(k):
        gathered = jnp.take(ye_flat, jnp.minimum(slot[:, kk], oob - 1), axis=0)
        w = (gate_vals[:, kk] * keep[:, kk]).astype(compute)
        out_t = out_t + gathered * w[:, None]

    # load-balance aux loss (Switch/GShard form)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(top_idx[:, 0], e, dtype=jnp.float32), axis=0
    )
    frac_probs = probs.mean(axis=0)
    aux = cfg.aux_loss_weight * e * jnp.sum(frac_tokens * frac_probs)

    out = out_t.reshape(b, s, d)
    if cfg.n_shared and "shared" in p:
        out = out + swiglu(p["shared"], x)
    return out, aux


def _moe_ep_shardmap(p, cfg, mesh, xt, top_idx, gate_vals, probs):
    """Explicit EP dispatch (§Perf): tokens stay on their data shard
    (replicated across the model axis), experts live on their model shard.
    Device (s, m) scatters shard-s tokens into its OWN experts' capacity
    buffer — a purely local scatter — runs the expert FFN on gathered-over-
    data (FSDP) weights, and the per-token partials psum over ``model``
    (each token's expert lives on exactly one model shard).

    Communication per layer: weight all-gather (bf16, the FSDP cost) +
    one (T_local, D) psum — vs. the pjit path's (E*C, D) all-reduce per
    scatter (measured ~50x less collective volume on deepseek train_4k).
    """
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import dp_axes
    from repro.perf_flags import enabled

    d = xt.shape[1]
    e, k = cfg.n_experts, cfg.top_k
    dp = dp_axes(mesh)
    import numpy as np
    p_data = int(np.prod([mesh.shape[a] for a in dp]))
    m_size = mesh.shape["model"]
    e_per = e // m_size
    t_local = xt.shape[0] // p_data
    if t_local <= 512:
        c_local = t_local                      # dropless for small shards
    else:
        c_local = max(1, int(cfg.capacity_factor * t_local * k / e))
    compute = xt.dtype
    f = cfg.d_ff_expert

    def _local(xt_l, idx_l, gate_l, wg, wu, wd):
        # xt_l: (T_l, D); idx_l/gate_l: (T_l, K)
        # wg/wu: (E_per, D/p_data, F); wd: (E_per, F, D/p_data)  [FSDP slices]
        me = jax.lax.axis_index("model")
        e_lo = me * e_per

        # local positions per expert (sort-based, local tokens only)
        flat_e = idx_l.reshape(-1)
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        starts = jnp.searchsorted(sorted_e, jnp.arange(e))
        pos_sorted = jnp.arange(flat_e.shape[0], dtype=jnp.int32) - starts[sorted_e]
        pos = jnp.zeros(flat_e.shape[0], jnp.int32).at[order].set(
            pos_sorted, mode="drop").reshape(idx_l.shape)

        local_e = idx_l - e_lo
        owned = (local_e >= 0) & (local_e < e_per) & (pos < c_local)
        oob = e_per * c_local
        slot = jnp.where(owned, local_e * c_local + pos, oob)

        xe = jnp.zeros((e_per * c_local, d), compute)
        for kk in range(k):
            xe = xe.at[slot[:, kk]].add(
                xt_l * owned[:, kk, None].astype(compute), mode="drop")
        xe = xe.reshape(e_per, c_local, d)

        # FSDP weight gather over the data axes (bf16 on the wire when the
        # bf16gather flag is on — the §Perf "bf16gather" applied explicitly)
        def gather_w(w, axis):
            if enabled("bf16gather") and w.dtype == jnp.float32:
                w = w.astype(compute)
            return jax.lax.all_gather(w, dp, axis=axis, tiled=True).astype(compute)

        wg_full = gather_w(wg, 1)              # (E_per, D, F)
        wu_full = gather_w(wu, 1)
        wd_full = gather_w(wd, 2)              # (E_per, F, D)

        g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wg_full))
        u = jnp.einsum("ecd,edf->ecf", xe, wu_full)
        ye = jnp.einsum("ecf,efd->ecd", g * u, wd_full).reshape(
            e_per * c_local, d)

        out = jnp.zeros((xt_l.shape[0], d), compute)
        for kk in range(k):
            got = jnp.take(ye, jnp.minimum(slot[:, kk], oob - 1), axis=0)
            w = (gate_l[:, kk] * owned[:, kk]).astype(compute)
            out = out + got * w[:, None]
        # each token's expert lives on exactly one model shard
        return jax.lax.psum(out, "model")

    out_t = jax.shard_map(
        _local, mesh=mesh,
        in_specs=(P(dp, None), P(dp, None), P(dp, None),
                  P("model", dp, None), P("model", dp, None),
                  P("model", None, dp)),
        out_specs=P(dp, None),
        check_vma=False,
    )(xt, top_idx, gate_vals.astype(compute),
      p["w_gate"], p["w_up"], p["w_down"])

    frac_tokens = jnp.mean(jax.nn.one_hot(top_idx[:, 0], e, dtype=jnp.float32),
                           axis=0)
    aux = cfg.aux_loss_weight * e * jnp.sum(frac_tokens * probs.mean(axis=0))
    return out_t, aux
