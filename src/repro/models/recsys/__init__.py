from repro.models.recsys.xdeepfm import XDeepFM, XDeepFMConfig

__all__ = ["XDeepFM", "XDeepFMConfig"]
