"""xDeepFM (arXiv:1803.05170): sparse embeddings + CIN + DNN + linear.

Assigned config: 39 sparse fields, embed_dim 10, CIN layers 200-200-200,
MLP 400-400.

The embedding layer is the GraphLake-analogous hot path (vertex-property
fetch by transformed ID == table-row lookup): all fields live in one unified
table, **row-sharded over the model axis**; lookup inside ``shard_map`` is
a local masked take + ``psum`` — each row lives on exactly one shard, so the
psum is the "batched remote fetch combine" of the paper's two-pass EdgeScan
(DESIGN.md §4).  Multi-hot fields go through the EmbeddingBag kernel.

CIN (compressed interaction network):

    x^{l+1}[b,h,d] = sum_{i,j} W^l[h,i,j] * x^l[b,i,d] * x^0[b,j,d]

computed as one einsum per layer; sum-pool over d per layer -> concat ->
linear; plus a 400-400 DNN over flattened embeddings and a first-order
linear term.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels import ops as kops
from repro.models.layers import dense_init, mlp_init, mlp_apply


@dataclasses.dataclass
class XDeepFMConfig:
    name: str = "xdeepfm"
    embed_dim: int = 10
    cin_layers: tuple[int, ...] = (200, 200, 200)
    mlp_dims: tuple[int, ...] = (400, 400)
    # 39 sparse fields with skewed vocab sizes (criteo-like); the last
    # `n_multihot` fields are multi-hot with bags of `bag_size`
    vocab_sizes: tuple[int, ...] = tuple(
        [2 ** 21] * 8 + [2 ** 17] * 10 + [2 ** 13] * 10 + [2 ** 9] * 11
    )
    n_multihot: int = 4
    bag_size: int = 8

    @property
    def n_fields(self) -> int:
        return len(self.vocab_sizes)

    @property
    def total_vocab(self) -> int:
        return int(sum(self.vocab_sizes))

    @property
    def field_offsets(self):
        import numpy as np
        return np.concatenate([[0], np.cumsum(self.vocab_sizes)[:-1]]).astype("int64")

    def param_count(self) -> int:
        d = self.embed_dim
        n = self.total_vocab * (d + 1)          # embeddings + linear term
        f = self.n_fields
        h_prev = f
        for h in self.cin_layers:
            n += h * h_prev * f + h
            h_prev = h
        dims = [f * d] + list(self.mlp_dims) + [1]
        for i in range(len(dims) - 1):
            n += dims[i] * dims[i + 1] + dims[i + 1]
        n += sum(self.cin_layers) + 1
        return n


class XDeepFM:
    def __init__(self, cfg: XDeepFMConfig, mesh=None, model_axis: str = "model",
                 dp_axes: tuple[str, ...] = ("data",)):
        self.cfg = cfg
        self.mesh = mesh
        self.model_axis = model_axis
        self.dp_axes = dp_axes

    # ------------------------------------------------------------------ init

    def init(self, rng) -> dict:
        cfg = self.cfg
        ks = jax.random.split(rng, 6)
        v, d, f = cfg.total_vocab, cfg.embed_dim, cfg.n_fields
        params = {
            "embed": jax.random.normal(ks[0], (v, d), jnp.float32) * 0.01,
            "linear": jax.random.normal(ks[1], (v, 1), jnp.float32) * 0.01,
            "cin": [],
            "mlp": mlp_init(ks[2], [f * d] + list(cfg.mlp_dims) + [1]),
            "out_cin": dense_init(ks[3], sum(cfg.cin_layers), 1),
            "bias": jnp.zeros((), jnp.float32),
        }
        h_prev = f
        for li, h in enumerate(cfg.cin_layers):
            params["cin"].append({
                "w": jax.random.normal(jax.random.fold_in(ks[4], li),
                                       (h, h_prev, f), jnp.float32)
                * (2.0 / (h_prev * f)) ** 0.5,
                "b": jnp.zeros(h, jnp.float32),
            })
            h_prev = h
        return params

    # ------------------------------------------------------------------ lookup

    def _lookup(self, table: jax.Array, idx: jax.Array,
                weights: Optional[jax.Array] = None) -> jax.Array:
        """Sharded lookup: table (V, D) row-sharded over model; idx (B, ...)
        batch-sharded over data and replicated over model."""
        if self.mesh is None:
            if weights is None:
                return table[idx]
            # multi-hot: (B, F_mh, L) -> (B, F_mh, D) via EmbeddingBag
            b, fm, l = idx.shape
            out = kops.embedding_bag(
                table, idx.reshape(b * fm, l), weights.reshape(b * fm, l)
            )
            return out.reshape(b, fm, table.shape[1])

        v = table.shape[0]
        p = self.mesh.shape[self.model_axis]
        vp = v // p
        axis = self.model_axis

        def _local(table_local, idx_rep, w_rep):
            lo = jax.lax.axis_index(axis) * vp
            in_range = (idx_rep >= lo) & (idx_rep < lo + vp)
            local_idx = jnp.clip(idx_rep - lo, 0, vp - 1)
            if w_rep is None:
                got = jnp.take(table_local, local_idx, axis=0)
                got = got * in_range[..., None].astype(got.dtype)
            else:
                b, fm, l = idx_rep.shape
                w_mask = w_rep * in_range.astype(w_rep.dtype)
                got = kops.embedding_bag(
                    table_local, local_idx.reshape(b * fm, l),
                    w_mask.reshape(b * fm, l),
                ).reshape(b, fm, table_local.shape[1])
            return jax.lax.psum(got, axis)    # each row lives on one shard

        in_specs = (
            P(self.model_axis, None),
            P(self.dp_axes, *([None] * (idx.ndim - 1))),
            (P(self.dp_axes, None, None) if weights is not None else P()),
        )
        out_specs = P(self.dp_axes, *([None] * (idx.ndim - 1)), None) \
            if weights is None else P(self.dp_axes, None, None)
        return jax.shard_map(
            _local, mesh=self.mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False,
        )(table, idx, weights)

    def embed_fields(self, params: dict, batch: dict) -> jax.Array:
        """-> (B, F, D) field embeddings."""
        cfg = self.cfg
        single = self._lookup(params["embed"], batch["idx_single"])  # (B,Fs,D)
        if cfg.n_multihot:
            multi = self._lookup(params["embed"], batch["idx_multi"],
                                 batch["w_multi"])                   # (B,Fm,D)
            return jnp.concatenate([single, multi], axis=1)
        return single

    # ------------------------------------------------------------------ forward

    def forward(self, params: dict, batch: dict) -> jax.Array:
        cfg = self.cfg
        x0 = self.embed_fields(params, batch)                        # (B, F, D)
        b = x0.shape[0]

        # first-order linear term
        lin_s = self._lookup(params["linear"], batch["idx_single"])[..., 0]
        linear = lin_s.sum(-1)
        if cfg.n_multihot:
            lin_m = self._lookup(params["linear"], batch["idx_multi"],
                                 batch["w_multi"])[..., 0]
            linear = linear + lin_m.sum(-1)

        # CIN — explicitly ordered contraction (§Perf P11): the naive
        # 3-operand einsum 'bid,bjd,hij->bhd' lets opt_einsum pick a
        # (B,H,Hp,F) d-free intermediate costing ~30x the optimal path;
        # materializing the (B, Hp*F, D) outer product then one matmul is
        # the analytic-minimum 2*B*D*Hp*F*H flops.
        x_l = x0
        pooled = []
        f = x0.shape[1]
        for lp in params["cin"]:
            hp = x_l.shape[1]
            outer = (x_l[:, :, None, :] * x0[:, None, :, :]).reshape(
                b, hp * f, -1)                                       # (B, Hp*F, D)
            w2 = lp["w"].reshape(lp["w"].shape[0], hp * f)           # (H, Hp*F)
            x_l = jax.nn.relu(
                jnp.einsum("bpd,hp->bhd", outer, w2)
                + lp["b"][None, :, None]
            )
            pooled.append(x_l.sum(-1))                               # (B, H_l)
        cin_out = (jnp.concatenate(pooled, axis=-1) @ params["out_cin"])[:, 0]

        # DNN
        dnn_out = mlp_apply(params["mlp"], x0.reshape(b, -1))[:, 0]

        return linear + cin_out + dnn_out + params["bias"]

    def loss(self, params: dict, batch: dict) -> jax.Array:
        logits = self.forward(params, batch)
        y = batch["labels"].astype(jnp.float32)
        return jnp.mean(
            jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
        )

    # ------------------------------------------------------------------ serving

    def serve_step(self, params: dict, batch: dict) -> jax.Array:
        return jax.nn.sigmoid(self.forward(params, batch))

    def retrieval_step(self, params: dict, user_batch: dict,
                       cand_idx: jax.Array) -> jax.Array:
        """Score one user against C candidates: broadcast user fields over the
        candidate axis, swap in candidate item fields, score all rows."""
        c = cand_idx.shape[0]
        n_user = user_batch["idx_single"].shape[1] - cand_idx.shape[1]
        idx_single = jnp.concatenate(
            [
                jnp.broadcast_to(user_batch["idx_single"][:1, :n_user],
                                 (c, n_user)),
                cand_idx,
            ],
            axis=1,
        )
        batch = {
            "idx_single": idx_single,
            "idx_multi": jnp.broadcast_to(
                user_batch["idx_multi"][:1], (c,) + user_batch["idx_multi"].shape[1:]
            ),
            "w_multi": jnp.broadcast_to(
                user_batch["w_multi"][:1], (c,) + user_batch["w_multi"].shape[1:]
            ),
        }
        return jax.nn.sigmoid(self.forward(params, batch))
