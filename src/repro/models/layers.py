"""Transformer building blocks (pure JAX; params are plain pytrees).

Conventions:
- params are nested dicts of jnp arrays; layer stacks carry a leading layer
  axis and run under ``lax.scan`` (small HLO -> fast 512-way SPMD compiles);
- activations default to bfloat16, parameters/optimizer to float32;
- attention dispatches through ``repro.kernels.ops.flash_attention`` (Pallas
  on TPU, blockwise-scan reference elsewhere).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.meshctx import constrain
from repro.kernels import ops as kops


def _uniform(rng, shape, scale, dtype=jnp.float32):
    return jax.random.uniform(rng, shape, dtype, -scale, scale)


def dense_init(rng, d_in: int, d_out: int, dtype=jnp.float32):
    scale = (6.0 / (d_in + d_out)) ** 0.5
    return _uniform(rng, (d_in, d_out), scale, dtype)


def wuse(w: jax.Array, compute, *roles):
    """Weight-at-use cast.  With the ``bf16gather`` flag, the bf16 cast is
    sharding-constrained to the weight's own (FSDP) layout so XLA all-gathers
    the HALF-width tensor instead of gathering f32 then converting —
    the f32 master stays sharded (§Perf)."""
    from repro.perf_flags import enabled
    if (enabled("bf16gather") and w.dtype == jnp.float32
            and jnp.dtype(compute) != jnp.float32 and roles):
        # barrier: pin the cast to the sharded layout so the (GSPMD-inserted)
        # unshard all-gather runs on the half-width tensor
        return jax.lax.optimization_barrier(constrain(w.astype(compute), *roles))
    return w.astype(compute)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * weight.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_angles(positions: jax.Array, dim: int, theta: float = 10000.0):
    """positions: (...,) int -> (…, dim/2) angles."""
    inv_freq = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv_freq
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x: (B, S, H, Dh); sin/cos: (B, S, Dh/2) or (S, Dh/2)."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    if sin.ndim == 2:
        sin = sin[None, :, None, :]
        cos = cos[None, :, None, :]
    else:
        sin = sin[:, :, None, :]
        cos = cos[:, :, None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dtype)


# ---------------------------------------------------------------------------
# int8 KV quantization (perf flag kv_int8)
# ---------------------------------------------------------------------------

def quantize_kv(x: jax.Array):
    """Per-vector (last-dim) symmetric int8: returns (q int8, scale bf16)."""
    scale = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                                keepdims=True), 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def dequantize_kv(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# grouped-query attention
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class GQAConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    qkv_bias: bool = False
    rope_theta: float = 10000.0


def gqa_init(rng, cfg: GQAConfig) -> dict:
    ks = jax.random.split(rng, 5)
    p = {
        "wq": dense_init(ks[0], cfg.d_model, cfg.n_heads * cfg.d_head),
        "wk": dense_init(ks[1], cfg.d_model, cfg.n_kv_heads * cfg.d_head),
        "wv": dense_init(ks[2], cfg.d_model, cfg.n_kv_heads * cfg.d_head),
        "wo": dense_init(ks[3], cfg.n_heads * cfg.d_head, cfg.d_model),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros(cfg.n_heads * cfg.d_head, jnp.float32)
        p["bk"] = jnp.zeros(cfg.n_kv_heads * cfg.d_head, jnp.float32)
        p["bv"] = jnp.zeros(cfg.n_kv_heads * cfg.d_head, jnp.float32)
    return p


def _expand_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """(B, Hk, S, Dh) -> (B, H, S, Dh) by group broadcast."""
    b, hk, s, dh = k.shape
    groups = n_heads // hk
    return jnp.repeat(k, groups, axis=1)


def gqa_project_qkv(p: dict, cfg: GQAConfig, x: jax.Array, positions: jax.Array):
    """x: (B, S, D) -> q (B,S,H,Dh), k/v (B,S,Hk,Dh) with RoPE applied."""
    b, s, _ = x.shape
    compute = x.dtype
    q = x @ wuse(p["wq"], compute, "fsdp", "model")
    k = x @ wuse(p["wk"], compute, "fsdp", "model")
    v = x @ wuse(p["wv"], compute, "fsdp", "model")
    if "bq" in p:
        q = q + p["bq"].astype(compute)
        k = k + p["bk"].astype(compute)
        v = v + p["bv"].astype(compute)
    q = constrain(q.reshape(b, s, cfg.n_heads, cfg.d_head),
                  "dp", None, "model", None)
    k = constrain(k.reshape(b, s, cfg.n_kv_heads, cfg.d_head),
                  "dp", None, "model", None)
    v = constrain(v.reshape(b, s, cfg.n_kv_heads, cfg.d_head),
                  "dp", None, "model", None)
    sin, cos = rope_angles(positions, cfg.d_head, cfg.rope_theta)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    return q, k, v


def gqa_attention(
    p: dict, cfg: GQAConfig, x: jax.Array, positions: jax.Array,
    kv_cache: Optional[tuple[jax.Array, jax.Array]] = None,
    cache_index: Optional[jax.Array] = None,
    causal: bool = True,
):
    """Returns (out (B,S,D), new_kv_cache or None).

    kv_cache: (k, v) each (B, S_max, Hk, Dh); cache_index = current length.
    Prefill (S > 1) attends over the fresh prompt keys only; decode (S == 1)
    attends over the cache masked to the live length.
    """
    b, s, _ = x.shape
    q, k, v = gqa_project_qkv(p, cfg, x, positions)
    kv_len_mask = None
    if kv_cache is not None:
        quantized = isinstance(kv_cache[0], tuple)
        if quantized:  # int8 cache: ((k_q, k_s), (v_q, v_s))
            (kq, ks), (vq, vs) = kv_cache
            nkq, nks = quantize_kv(k)
            nvq, nvs = quantize_kv(v)
            kq = jax.lax.dynamic_update_slice(kq, nkq, (0, cache_index, 0, 0))
            ks = jax.lax.dynamic_update_slice(ks, nks, (0, cache_index, 0, 0))
            vq = jax.lax.dynamic_update_slice(vq, nvq, (0, cache_index, 0, 0))
            vs = jax.lax.dynamic_update_slice(vs, nvs, (0, cache_index, 0, 0))
            new_cache = ((kq, ks), (vq, vs))
            if s > 1:
                k_all, v_all = k, v
            else:
                k_all = dequantize_kv(kq, ks, k.dtype)
                v_all = dequantize_kv(vq, vs, v.dtype)
                kv_len_mask = cache_index + s
                causal = False
        else:
            ck, cv = kv_cache
            ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                              (0, cache_index, 0, 0))
            cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                              (0, cache_index, 0, 0))
            new_cache = (ck, cv)
            if s > 1:  # prefill: prompt attends only the prompt
                k_all, v_all = k, v
            else:      # decode: attend the cache up to the live length
                k_all, v_all = ck, cv
                kv_len_mask = cache_index + s
                causal = False
    else:
        k_all, v_all = k, v
        new_cache = None
    # (B, H, S, Dh) layout for the attention kernel
    qh = constrain(q.transpose(0, 2, 1, 3), "dp", "model", None, None)
    kh = constrain(
        _expand_kv(k_all.transpose(0, 2, 1, 3).astype(q.dtype), cfg.n_heads),
        "dp", "model", None, None)
    vh = constrain(
        _expand_kv(v_all.transpose(0, 2, 1, 3).astype(q.dtype), cfg.n_heads),
        "dp", "model", None, None)
    out = kops.flash_attention(qh, kh, vh, causal=causal, kv_len_mask=kv_len_mask)
    out = constrain(out, "dp", "model", None, None)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.d_head)
    out = constrain(out, "dp", None, "model")
    return constrain(out @ wuse(p["wo"], out.dtype, "model", "fsdp"),
                     "dp", None, None), new_cache


# ---------------------------------------------------------------------------
# multi-head latent attention (MLA, DeepSeek-V2)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MLAConfig:
    d_model: int
    n_heads: int
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    rope_theta: float = 10000.0

    @property
    def qk_dim(self) -> int:
        return self.qk_nope_dim + self.qk_rope_dim

    @property
    def cache_dim(self) -> int:
        return self.kv_lora_rank + self.qk_rope_dim


def mla_init(rng, cfg: MLAConfig) -> dict:
    ks = jax.random.split(rng, 6)
    return {
        # queries: full-rank projection (V2-Lite has no q-LoRA)
        "wq": dense_init(ks[0], cfg.d_model, cfg.n_heads * cfg.qk_dim),
        # latent kv down-projection + shared rope key
        "w_dkv": dense_init(ks[1], cfg.d_model, cfg.kv_lora_rank),
        "w_krope": dense_init(ks[2], cfg.d_model, cfg.qk_rope_dim),
        # up-projections from the latent
        "w_uk": dense_init(ks[3], cfg.kv_lora_rank, cfg.n_heads * cfg.qk_nope_dim),
        "w_uv": dense_init(ks[4], cfg.kv_lora_rank, cfg.n_heads * cfg.v_head_dim),
        "wo": dense_init(ks[5], cfg.n_heads * cfg.v_head_dim, cfg.d_model),
        "norm_ckv": jnp.ones(cfg.kv_lora_rank, jnp.float32),
    }


def mla_attention(
    p: dict, cfg: MLAConfig, x: jax.Array, positions: jax.Array,
    latent_cache: Optional[jax.Array] = None,
    cache_index: Optional[jax.Array] = None,
    causal: bool = True,
):
    """MLA with the compressed latent as the cached state (paper-exact cache:
    c_kv (kv_lora) + shared rope key). Returns (out, new_latent_cache).

    latent_cache: (B, S_max, kv_lora + qk_rope).
    """
    b, s, _ = x.shape
    compute = x.dtype
    q = (x @ wuse(p["wq"], compute, "fsdp", "model")).reshape(
        b, s, cfg.n_heads, cfg.qk_dim)
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope_dim], axis=-1)
    sin, cos = rope_angles(positions, cfg.qk_rope_dim, cfg.rope_theta)
    q_rope = apply_rope(q_rope, sin, cos)

    c_kv = rms_norm(x @ p["w_dkv"].astype(compute), p["norm_ckv"])
    k_rope = (x @ p["w_krope"].astype(compute)).reshape(b, s, 1, cfg.qk_rope_dim)
    k_rope = apply_rope(k_rope, sin, cos)
    latent = jnp.concatenate([c_kv, k_rope[:, :, 0, :]], axis=-1)  # (B,S,cache_dim)

    kv_len_mask = None
    if latent_cache is not None:
        quantized = isinstance(latent_cache, tuple)
        if quantized:  # int8 latent cache: (c_q, c_s)
            cq, cs = latent_cache
            nq, nscale = quantize_kv(latent)
            cq = jax.lax.dynamic_update_slice(cq, nq, (0, cache_index, 0))
            cs = jax.lax.dynamic_update_slice(cs, nscale, (0, cache_index, 0))
            new_cache = (cq, cs)
            if s > 1:
                lat_all = latent
            else:
                lat_all = dequantize_kv(cq, cs, compute)
                kv_len_mask = cache_index + s
                causal = False
        else:
            latent_cache = jax.lax.dynamic_update_slice(
                latent_cache, latent.astype(latent_cache.dtype),
                (0, cache_index, 0)
            )
            new_cache = latent_cache
            if s > 1:  # prefill: prompt attends only the prompt
                lat_all = latent
            else:      # decode: attend the full latent cache up to live length
                lat_all = latent_cache.astype(compute)
                kv_len_mask = cache_index + s
                causal = False
    else:
        lat_all = latent
        new_cache = None
    c_all, krope_all = jnp.split(lat_all, [cfg.kv_lora_rank], axis=-1)
    s_kv = c_all.shape[1]

    # expand keys/values from the latent (B, S_kv, H, *)
    k_nope = (c_all @ wuse(p["w_uk"], compute, None, "model")).reshape(
        b, s_kv, cfg.n_heads, cfg.qk_nope_dim
    )
    v = (c_all @ wuse(p["w_uv"], compute, None, "model")).reshape(
        b, s_kv, cfg.n_heads, cfg.v_head_dim
    )
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(krope_all[:, :, None, :],
                                  (b, s_kv, cfg.n_heads, cfg.qk_rope_dim))],
        axis=-1,
    )
    qh = constrain(
        jnp.concatenate([q_nope, q_rope], axis=-1).transpose(0, 2, 1, 3),
        "dp", "model", None, None)
    kh = constrain(k.transpose(0, 2, 1, 3), "dp", "model", None, None)
    # pad v head dim up to qk_dim for the shared kernel, slice after
    vh = constrain(v.transpose(0, 2, 1, 3), "dp", "model", None, None)
    if cfg.v_head_dim != cfg.qk_dim:
        vh = jnp.pad(vh, ((0, 0), (0, 0), (0, 0), (0, cfg.qk_dim - cfg.v_head_dim)))
    out = kops.flash_attention(qh, kh, vh, causal=causal, kv_len_mask=kv_len_mask)
    out = constrain(out[..., : cfg.v_head_dim], "dp", "model", None, None)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.v_head_dim)
    out = constrain(out, "dp", None, "model")
    return constrain(out @ wuse(p["wo"], compute, "model", "fsdp"),
                     "dp", None, None), new_cache


# ---------------------------------------------------------------------------
# SwiGLU FFN
# ---------------------------------------------------------------------------

def swiglu_init(rng, d_model: int, d_ff: int) -> dict:
    ks = jax.random.split(rng, 3)
    return {
        "w_gate": dense_init(ks[0], d_model, d_ff),
        "w_up": dense_init(ks[1], d_model, d_ff),
        "w_down": dense_init(ks[2], d_ff, d_model),
    }


def swiglu(p: dict, x: jax.Array) -> jax.Array:
    compute = x.dtype
    ndim = x.ndim
    ff_spec = ["dp"] + [None] * (ndim - 2) + ["model"]
    g = constrain(jax.nn.silu(x @ wuse(p["w_gate"], compute, "fsdp", "model")),
                  *ff_spec)
    u = constrain(x @ wuse(p["w_up"], compute, "fsdp", "model"), *ff_spec)
    out_spec = ["dp"] + [None] * (ndim - 1)
    return constrain((g * u) @ wuse(p["w_down"], compute, "model", "fsdp"),
                     *out_spec)


# ---------------------------------------------------------------------------
# generic MLP (GNN / recsys substrate)
# ---------------------------------------------------------------------------

def mlp_init(rng, dims: list[int]) -> dict:
    ks = jax.random.split(rng, len(dims) - 1)
    return {
        f"w{i}": dense_init(ks[i], dims[i], dims[i + 1])
        for i in range(len(dims) - 1)
    } | {
        f"b{i}": jnp.zeros(dims[i + 1], jnp.float32)
        for i in range(len(dims) - 1)
    }


def mlp_apply(p: dict, x: jax.Array, act=jax.nn.relu, final_act: bool = False) -> jax.Array:
    n = len([k for k in p if k.startswith("w")])
    compute = x.dtype
    for i in range(n):
        x = x @ p[f"w{i}"].astype(compute) + p[f"b{i}"].astype(compute)
        if i < n - 1 or final_act:
            x = act(x)
    return x
