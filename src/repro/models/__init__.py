"""Model definitions for the assigned architectures.

- ``layers``      — RMSNorm / RoPE / GQA / MLA / SwiGLU primitives (pure JAX,
                    params as pytrees; attention dispatches to kernels.ops),
- ``moe``         — GShard-style top-k expert dispatch (EP over the model axis),
- ``transformer`` — dense + MoE decoder LMs (train/prefill/decode steps),
- ``gnn``         — GIN / MeshGraphNet / SchNet / DimeNet on the edge-sharded
                    two-pass EdgeScan pattern (shard_map),
- ``recsys``      — xDeepFM with sharded EmbeddingBag tables + CIN,
- ``api``         — the Arch protocol the launcher and dry-run consume.
"""
