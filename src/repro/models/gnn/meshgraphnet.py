"""MeshGraphNet (arXiv:2010.03409) — encode-process-decode mesh simulation:
15 message-passing layers, hidden 128, 2-layer MLPs, sum aggregation.

    encode:  v_i = MLP_v(x_i);  e_ij = MLP_e([edge_feat_ij, |u_ij|, u_ij])
    process: e'_ij = e_ij + MLP([e_ij, v_i, v_j])
             v'_i  = v_i  + MLP([v_i, sum_j e'_ji])
    decode:  y_i = MLP_d(v_i)            (per-node regression targets)
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.gnn import common
from repro.models.gnn.common import GNNDist
from repro.models.layers import mlp_init, mlp_apply


@dataclasses.dataclass
class MGNConfig:
    name: str = "meshgraphnet"
    n_layers: int = 15
    d_hidden: int = 128
    d_in: int = 16              # node input features
    d_edge_in: int = 4          # edge input features (+ 4 derived from pos)
    d_out: int = 3              # per-node regression targets
    mlp_layers: int = 2


def _mlp_dims(d_in, d_hidden, n):
    return [d_in] + [d_hidden] * n


class MeshGraphNet:
    def __init__(self, cfg: MGNConfig, dist: GNNDist):
        self.cfg = cfg
        self.dist = dist

    def init(self, rng) -> dict:
        cfg = self.cfg
        ks = jax.random.split(rng, 3 + 2 * cfg.n_layers)
        h = cfg.d_hidden
        params = {
            "enc_v": mlp_init(ks[0], _mlp_dims(cfg.d_in, h, cfg.mlp_layers)),
            "enc_e": mlp_init(ks[1], _mlp_dims(cfg.d_edge_in + 4, h, cfg.mlp_layers)),
            "dec": mlp_init(ks[2], [h, h, cfg.d_out]),
            "layers": [],
        }
        for l in range(cfg.n_layers):
            params["layers"].append({
                "edge_mlp": mlp_init(ks[3 + 2 * l], _mlp_dims(3 * h, h, cfg.mlp_layers)),
                "node_mlp": mlp_init(ks[4 + 2 * l], _mlp_dims(2 * h, h, cfg.mlp_layers)),
            })
        return params

    def forward(self, params: dict, batch: dict) -> jax.Array:
        """batch: x (N, d_in), pos (N, 3), edge_feat (E, d_edge_in),
        src/dst (E,), edge_mask (E,)."""
        cfg, dist = self.cfg, self.dist
        x = dist.constrain_nodes(batch["x"].astype(jnp.float32))
        pos = dist.constrain_nodes(batch["pos"].astype(jnp.float32))
        src = dist.constrain_edges(batch["src"])
        dst = dist.constrain_edges(batch["dst"])
        emask = batch["edge_mask"].astype(jnp.float32)[:, None]
        n = x.shape[0]

        d, unit = common.edge_distances(pos, src, dst, dist)
        e_in = jnp.concatenate(
            [batch["edge_feat"].astype(jnp.float32), unit, d[:, None]], axis=-1
        )
        v = mlp_apply(params["enc_v"], x)
        e = mlp_apply(params["enc_e"], e_in) * emask

        for lp in params["layers"]:
            v_src = dist.gather_nodes(v, src)                      # pass 1
            v_dst = dist.gather_nodes(v, dst)
            e = e + mlp_apply(lp["edge_mlp"],
                              jnp.concatenate([e, v_src, v_dst], -1)) * emask
            agg = dist.edge_aggregate(e, dst, n)                   # pass 2
            v = v + mlp_apply(lp["node_mlp"], jnp.concatenate([v, agg], -1))
            v = dist.constrain_nodes(v)

        return mlp_apply(params["dec"], v)

    def loss(self, params: dict, batch: dict) -> jax.Array:
        pred = self.forward(params, batch)
        err = ((pred - batch["targets"].astype(jnp.float32)) ** 2).mean(-1)
        return common.masked_mean(err, batch["node_mask"])
