"""SchNet (arXiv:1706.08566) — continuous-filter convolutions:
3 interaction blocks, hidden 64, 300 RBF centers, cutoff 10 Å.

    h_i = embed(z_i)
    interaction: W_ij = filter_MLP(rbf(d_ij));  m_i = sum_j (h_j W1) ⊙ W_ij
                 h_i = h_i + W3 · ssp(W2 · m_i)
    readout: per-atom MLP -> atomic energy -> per-molecule sum
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.gnn import common
from repro.models.gnn.common import GNNDist
from repro.models.layers import dense_init, mlp_init, mlp_apply


def ssp(x):  # shifted softplus, SchNet's activation
    return jax.nn.softplus(x) - jnp.log(2.0)


@dataclasses.dataclass
class SchNetConfig:
    name: str = "schnet"
    n_interactions: int = 3
    d_hidden: int = 64
    n_rbf: int = 300
    cutoff: float = 10.0
    n_atom_types: int = 100


class SchNet:
    def __init__(self, cfg: SchNetConfig, dist: GNNDist):
        self.cfg = cfg
        self.dist = dist

    def init(self, rng) -> dict:
        cfg = self.cfg
        ks = jax.random.split(rng, 2 + 4 * cfg.n_interactions)
        h = cfg.d_hidden
        params = {
            "embed": jax.random.normal(ks[0], (cfg.n_atom_types, h)) * 0.1,
            "out": mlp_init(ks[1], [h, h // 2, 1]),
            "blocks": [],
        }
        for b in range(cfg.n_interactions):
            params["blocks"].append({
                "filter": mlp_init(ks[2 + 4 * b], [cfg.n_rbf, h, h]),
                "w_in": dense_init(ks[3 + 4 * b], h, h),
                "w_mid": dense_init(ks[4 + 4 * b], h, h),
                "w_out": dense_init(ks[5 + 4 * b], h, h),
            })
        return params

    def forward(self, params: dict, batch: dict) -> jax.Array:
        """batch: z (N,) atom types, pos (N, 3), src/dst (E,), edge_mask,
        graph_ids (N,), n_graphs. Returns per-graph energies."""
        cfg, dist = self.cfg, self.dist
        z = batch["z"]
        pos = dist.constrain_nodes(batch["pos"].astype(jnp.float32))
        src = dist.constrain_edges(batch["src"])
        dst = dist.constrain_edges(batch["dst"])
        emask = batch["edge_mask"].astype(jnp.float32)[:, None]
        n = pos.shape[0]

        h = dist.constrain_nodes(params["embed"][z])
        d, _ = common.edge_distances(pos, src, dst, dist)
        rbf = common.rbf_expand(d, cfg.n_rbf, cfg.cutoff)
        # smooth cutoff envelope
        env = 0.5 * (jnp.cos(jnp.pi * jnp.clip(d / cfg.cutoff, 0, 1)) + 1.0)

        for bp in params["blocks"]:
            w_ij = mlp_apply(bp["filter"], rbf, act=ssp, final_act=True)
            w_ij = w_ij * (env[:, None] * emask)
            h_in = h @ bp["w_in"]
            msgs = dist.gather_nodes(h_in, src) * w_ij            # pass 1 + UDF
            m = dist.edge_aggregate(msgs, dst, n)                 # pass 2
            h = h + (ssp(m @ bp["w_mid"]) @ bp["w_out"])
            h = dist.constrain_nodes(h)

        atom_e = mlp_apply(params["out"], h, act=ssp)             # (N, 1)
        atom_e = atom_e * batch["node_mask"][:, None].astype(jnp.float32)
        pooled = common.graph_pool(atom_e, batch["graph_ids"], batch["n_graphs"], dist)
        return pooled[:, 0]

    def loss(self, params: dict, batch: dict) -> jax.Array:
        pred = self.forward(params, batch)
        err = (pred - batch["targets"].astype(jnp.float32)) ** 2
        return common.masked_mean(err, batch["graph_mask"])
