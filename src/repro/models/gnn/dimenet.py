"""DimeNet (arXiv:2003.03123) — directional message passing with triplet
interactions: 6 blocks, hidden 128, 8 bilinear, 7 spherical x 6 radial basis.

Messages live on directed edges m_ji; interaction blocks couple each edge
(j->i) with its incoming triplets (k->j, j->i) through a spherical-harmonic
angular basis and a bilinear layer — the triplet-gather kernel regime of the
taxonomy (§B.3), NOT expressible as SpMM.

Faithful structure kept: RBF/SBF bases with envelope, embedding block,
bilinear triplet interaction, per-edge aggregation to atoms in every block
(output blocks), summed per-molecule readout.  Simplified vs the release
code: residual-stack depths are 1 MLP each (documented in DESIGN.md §4);
large-graph shapes cap triplets at K=8 incoming edges per target edge.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.gnn import common
from repro.models.gnn.common import GNNDist
from repro.models.layers import dense_init, mlp_init, mlp_apply


@dataclasses.dataclass
class DimeNetConfig:
    name: str = "dimenet"
    n_blocks: int = 6
    d_hidden: int = 128
    n_bilinear: int = 8
    n_spherical: int = 7
    n_radial: int = 6
    cutoff: float = 5.0
    n_atom_types: int = 100
    envelope_p: int = 6
    # triplet gathers: "allgather" replicates the edge-message table per
    # device; "ring" streams it (dimenet @ ogb_products); "auto" picks by size
    triplet_gather: str = "auto"


def _envelope(x: jax.Array, p: int) -> jax.Array:
    """DimeNet polynomial envelope u(d) with u(1)=0, smooth at 1."""
    a = -(p + 1) * (p + 2) / 2
    b = p * (p + 2)
    c = -p * (p + 1) / 2
    return jnp.where(x < 1.0, 1 / jnp.maximum(x, 1e-6) + a * x ** (p - 1)
                     + b * x ** p + c * x ** (p + 1), 0.0)


class DimeNet:
    def __init__(self, cfg: DimeNetConfig, dist: GNNDist):
        self.cfg = cfg
        self.dist = dist

    def init(self, rng) -> dict:
        cfg = self.cfg
        h = cfg.d_hidden
        n_sbf = cfg.n_spherical * cfg.n_radial
        ks = jax.random.split(rng, 4 + 4 * cfg.n_blocks)
        params = {
            "embed": jax.random.normal(ks[0], (cfg.n_atom_types, h)) * 0.1,
            "rbf_proj": dense_init(ks[1], cfg.n_radial, h),
            "emb_mlp": mlp_init(ks[2], [3 * h, h, h]),
            "out_final": mlp_init(ks[3], [h, h, 1]),
            "blocks": [],
        }
        for b in range(cfg.n_blocks):
            params["blocks"].append({
                "sbf_proj": dense_init(ks[4 + 4 * b], n_sbf, cfg.n_bilinear),
                "w_kj": dense_init(ks[5 + 4 * b], h, h),
                # bilinear: (n_bilinear, h, h)
                "w_bil": jax.random.normal(ks[6 + 4 * b],
                                           (cfg.n_bilinear, h, h)) * (1.0 / h),
                "upd_mlp": mlp_init(ks[7 + 4 * b], [h, h, h]),
                "out_proj": dense_init(jax.random.fold_in(ks[7 + 4 * b], 1), h, h),
            })
        return params

    # -- bases -----------------------------------------------------------------

    def _rbf(self, d: jax.Array) -> jax.Array:
        """Bessel-style radial basis (E, n_radial) with envelope."""
        cfg = self.cfg
        x = d / cfg.cutoff
        n = jnp.arange(1, cfg.n_radial + 1, dtype=jnp.float32)
        # env(x) ~ 1/x as x->0 and sin(n pi x) ~ n pi x cancel: finite limit
        basis = jnp.sqrt(2.0 / cfg.cutoff) * jnp.sin(n[None, :] * jnp.pi * x[:, None])
        return basis * _envelope(x, cfg.envelope_p)[:, None]

    def _sbf(self, d_kj: jax.Array, angle: jax.Array) -> jax.Array:
        """Angular-radial basis (T, n_spherical * n_radial)."""
        cfg = self.cfg
        ls = jnp.arange(cfg.n_spherical, dtype=jnp.float32)
        ang = jnp.cos(angle[:, None] * (ls[None, :] + 1.0))          # (T, S)
        x = d_kj / cfg.cutoff
        n = jnp.arange(1, cfg.n_radial + 1, dtype=jnp.float32)
        rad = jnp.sin(n[None, :] * jnp.pi * x[:, None]) * _envelope(
            x, cfg.envelope_p
        )[:, None]                                                    # (T, R)
        return (ang[:, :, None] * rad[:, None, :]).reshape(len(d_kj), -1)

    # -- forward -----------------------------------------------------------------

    def forward(self, params: dict, batch: dict) -> jax.Array:
        """batch: z (N,), pos (N, 3), src/dst (E,), edge_mask (E,),
        t_in/t_out (T,) triplet edge indices (k->j = t_in, j->i = t_out),
        triplet_mask (T,), graph_ids (N,), n_graphs."""
        cfg, dist = self.cfg, self.dist
        pos = dist.constrain_nodes(batch["pos"].astype(jnp.float32))
        src, dst = batch["src"], batch["dst"]
        emask = batch["edge_mask"].astype(jnp.float32)[:, None]
        n = pos.shape[0]
        n_edges = src.shape[0]

        h = params["embed"][batch["z"]]
        d, unit = common.edge_distances(pos, src, dst, dist)
        rbf_e = self._rbf(d) * emask[:, : 1]
        rbf_h = rbf_e @ params["rbf_proj"]

        # embedding block: m_ji = MLP([h_j, h_i, rbf])
        h_src = dist.gather_nodes(h, src)
        h_dst = dist.gather_nodes(h, dst)
        m = mlp_apply(params["emb_mlp"],
                      jnp.concatenate([h_src, h_dst, rbf_h], -1)) * emask

        # triplet geometry: angle between (k->j) and (j->i)
        t_in, t_out = batch["t_in"], batch["t_out"]
        tmask = batch["triplet_mask"].astype(jnp.float32)[:, None]
        mode = cfg.triplet_gather
        if mode == "auto":
            mode = "ring" if (dist.mesh is not None and n_edges > 4_000_000) \
                else "allgather"
        geo = jnp.concatenate([unit, d[:, None]], axis=-1)         # (E, 4)
        geo_in = dist.gather_rows(geo, t_in, mode)
        geo_out = dist.gather_rows(geo, t_out, mode)
        u_in = -geo_in[:, :3]       # vector j->k reversed = k->j incoming at j
        u_out = geo_out[:, :3]
        cos_a = jnp.clip((u_in * u_out).sum(-1), -1.0, 1.0)
        angle = jnp.arccos(cos_a)
        sbf = self._sbf(geo_in[:, 3], angle) * tmask              # (T, S*R)

        atom_out = jnp.zeros((n, cfg.d_hidden), jnp.float32)
        for bp in params["blocks"]:
            # triplet interaction: gather m_kj, modulate by angular basis,
            # bilinear-project, aggregate back to the target edge (j->i)
            m_kj = dist.gather_rows(m @ bp["w_kj"], t_in, mode)   # (T, H)
            sbf_b = sbf @ bp["sbf_proj"]                          # (T, B)
            inter = jnp.einsum("tb,bhf,th->tf", sbf_b, bp["w_bil"], m_kj)
            agg_e = dist.edge_aggregate(inter * tmask, t_out, n_edges)  # (E, H)
            m = m + mlp_apply(bp["upd_mlp"], m + agg_e) * emask
            # output block: aggregate edge messages at target atoms
            contrib = dist.edge_aggregate((m * emask) @ bp["out_proj"], dst, n)
            atom_out = atom_out + contrib

        atom_e = mlp_apply(params["out_final"], atom_out)
        atom_e = atom_e * batch["node_mask"][:, None].astype(jnp.float32)
        pooled = common.graph_pool(atom_e, batch["graph_ids"], batch["n_graphs"], dist)
        return pooled[:, 0]

    def loss(self, params: dict, batch: dict) -> jax.Array:
        pred = self.forward(params, batch)
        err = (pred - batch["targets"].astype(jnp.float32)) ** 2
        return common.masked_mean(err, batch["graph_mask"])


