"""GIN (Graph Isomorphism Network, arXiv:1810.00826) — the gin-tu config:
5 layers, hidden 64, sum aggregator, learnable eps.

    h_i^{l+1} = MLP_l( (1 + eps_l) * h_i^l  +  sum_{j in N(i)} h_j^l )

Supports node classification (full-graph shapes) and graph classification
(molecule shape, sum readout over every layer's representation — the paper's
jumping-knowledge readout).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.gnn import common
from repro.models.gnn.common import GNNDist
from repro.models.layers import mlp_init, mlp_apply, dense_init


@dataclasses.dataclass
class GINConfig:
    name: str = "gin-tu"
    n_layers: int = 5
    d_hidden: int = 64
    d_in: int = 64
    n_classes: int = 16
    task: str = "node"          # "node" | "graph"
    mlp_layers: int = 2


class GIN:
    def __init__(self, cfg: GINConfig, dist: GNNDist):
        self.cfg = cfg
        self.dist = dist

    def init(self, rng) -> dict:
        cfg = self.cfg
        ks = jax.random.split(rng, cfg.n_layers + 2)
        layers = []
        d_prev = cfg.d_in
        for l in range(cfg.n_layers):
            dims = [d_prev] + [cfg.d_hidden] * cfg.mlp_layers
            layers.append({
                "mlp": mlp_init(ks[l], dims),
                "eps": jnp.zeros((), jnp.float32),
            })
            d_prev = cfg.d_hidden
        return {
            "layers": layers,
            "head": dense_init(ks[-1], cfg.d_hidden, cfg.n_classes),
        }

    def forward(self, params: dict, batch: dict) -> jax.Array:
        """batch: x (N, d_in), src/dst (E,), node_mask (N,), [graph_ids]."""
        cfg, dist = self.cfg, self.dist
        from repro.perf_flags import enabled

        h = dist.constrain_nodes(batch["x"].astype(jnp.float32))
        src = dist.constrain_edges(batch["src"])
        dst = dist.constrain_edges(batch["dst"])
        n = h.shape[0]
        readout = None
        pushdown = enabled("pushdown")
        for lp in params["layers"]:
            if pushdown:
                # projection pushdown (§Perf): the first MLP linear commutes
                # with the sum aggregation, so project to d_hidden BEFORE the
                # remote gather — the all_gather ships d_hidden-wide rows
                # instead of d_in-wide (22x narrower on full_graph_sm).
                h1 = h @ lp["mlp"]["w0"]                          # (N, hidden)
                msgs = dist.gather_nodes(h1, src)                 # pass 1
                agg = dist.edge_aggregate(msgs, dst, n)           # pass 2
                z = jax.nn.relu((1.0 + lp["eps"]) * h1 + agg + lp["mlp"]["b0"])
                n_lin = len([k for k in lp["mlp"] if k.startswith("w")])
                for i in range(1, n_lin):
                    z = jax.nn.relu(z @ lp["mlp"][f"w{i}"] + lp["mlp"][f"b{i}"])
                h = z
            else:
                msgs = dist.gather_nodes(h, src)                  # pass 1
                agg = dist.edge_aggregate(msgs, dst, n)           # pass 2
                h = mlp_apply(lp["mlp"], (1.0 + lp["eps"]) * h + agg,
                              act=jax.nn.relu, final_act=True)
            h = dist.constrain_nodes(h)
            if cfg.task == "graph":
                pooled = common.graph_pool(
                    h * batch["node_mask"][:, None].astype(h.dtype),
                    batch["graph_ids"], batch["n_graphs"], dist,
                )
                readout = pooled if readout is None else readout + pooled
        if cfg.task == "graph":
            return readout @ params["head"]
        return h @ params["head"]

    def loss(self, params: dict, batch: dict) -> jax.Array:
        logits = self.forward(params, batch)
        if self.cfg.task == "graph":
            mask = batch["graph_mask"]
        else:
            mask = batch["label_mask"]
        return common.cross_entropy(logits, batch["labels"], mask)
