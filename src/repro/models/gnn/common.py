"""Distributed message-passing primitives — the per-device realization of
GraphLake's two-pass distributed EdgeScan (paper §6.2, DESIGN.md §2/§5).

File-based sharding maps to mesh sharding: every device owns E/P edges and
N/P vertex rows (a "file").  One message-passing step is:

  pass 1  ``gather_nodes``  — ``all_gather`` the (projected) node features
          over the edge-owning axis = the batched remote-vertex fetch with
          projection pushdown (only the columns the UDF touches move);
  UDF     vectorized edge function on materialized (u, v, edge) rows;
  pass 2  ``edge_aggregate`` — local segment-sum partials (the per-node
          combine) + ``psum_scatter`` back to the vertex owners = the
          accumulator push-back-and-combine.

``GNNDist`` carries the mesh/axis context; ``local_dist()`` is the
single-device variant used by smoke tests and examples.  Both share exact
semantics — tested against each other.

The segment-sum inside pass 2 dispatches to the Pallas ``edge_scan`` kernel
on TPU (kernels/edge_scan.py) — min-max block pruning included.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.kernels import ops as kops


@dataclasses.dataclass
class GNNDist:
    """Distribution context for message passing."""

    mesh: Optional[Mesh] = None
    axes: tuple[str, ...] = ()          # mesh axes flattened for edge parallelism

    @property
    def n_shards(self) -> int:
        if self.mesh is None:
            return 1
        import numpy as np
        return int(np.prod([self.mesh.shape[a] for a in self.axes]))

    # ------------------------------------------------------------- pass 1

    def gather_nodes(self, h: jax.Array, idx: jax.Array) -> jax.Array:
        """Materialize far-side rows: h (N, D) node-sharded, idx (E,) edge-
        sharded -> (E, D) edge-sharded."""
        if self.mesh is None:
            return h[idx]
        from repro.perf_flags import enabled
        bf16_wire = enabled("gnnbf16") and h.dtype == jnp.float32

        def _gather(h_local, idx_local):
            if bf16_wire:
                # barriers pin the half-width wire format: without them
                # XLA's convert-mover rewrites the pattern back to an f32
                # all-gather (verified in the lowered HLO)
                wire = jax.lax.optimization_barrier(h_local.astype(jnp.bfloat16))
                h_full = jax.lax.optimization_barrier(
                    jax.lax.all_gather(wire, self.axes, axis=0, tiled=True))
                return h_full[idx_local].astype(h_local.dtype)
            h_full = jax.lax.all_gather(h_local, self.axes, axis=0, tiled=True)
            return h_full[idx_local]

        return jax.shard_map(
            _gather, mesh=self.mesh,
            in_specs=(P(self.axes, None), P(self.axes)),
            out_specs=P(self.axes, None),
            check_vma=False,
        )(h, idx)

    def gather_rows(self, table: jax.Array, idx: jax.Array,
                    mode: str = "allgather") -> jax.Array:
        """Generic distributed row gather (edges-by-triplet etc.).

        ``mode="ring"`` streams the table around the device ring with
        ``ppermute`` instead of all-gathering it — O(rows/P) resident memory,
        for tables too large to replicate (dimenet @ ogb_products: the 62M-row
        edge-message table).  Communication volume is identical (each device
        sees every block once); peak memory drops by P.
        """
        if self.mesh is None:
            return table[idx]
        if mode != "ring":
            return self.gather_nodes(table, idx)

        p = self.n_shards
        ep = table.shape[0] // p
        axes = self.axes
        perm_down = [(i, (i - 1) % p) for i in range(p)]

        @jax.custom_vjp
        def _ring(tl, il):
            return _ring_fwd(tl, il)[0]

        def _ring_fwd(tl, il):
            me = jax.lax.axis_index(axes)

            def body(s, carry):
                block, out = carry
                owner = (me + s) % p
                lo = owner * ep
                sel = (il >= lo) & (il < lo + ep)
                rows = jnp.clip(il - lo, 0, ep - 1)
                out = out + jnp.where(sel[:, None], block[rows], 0.0)
                block = jax.lax.ppermute(block, axes, perm_down)
                return block, out

            out0 = jnp.zeros((il.shape[0], tl.shape[1]), tl.dtype)
            _, out = jax.lax.fori_loop(0, p, body, (tl, out0))
            return out, (il,)

        def _ring_bwd(res, g):
            """Ring-reduce: owner o's grad buffer circulates the ring; every
            device adds its scatter-contribution for o exactly once; after P
            rotations the buffer is home with the complete row gradients."""
            (il,) = res
            me = jax.lax.axis_index(axes)

            def body(s, buf):
                owner = (me + s) % p
                lo = owner * ep
                sel = (il >= lo) & (il < lo + ep)
                rows = jnp.where(sel, il - lo, ep)  # ep = drop row
                contrib = jax.ops.segment_sum(
                    g * sel[:, None].astype(g.dtype), rows, num_segments=ep + 1
                )[:ep]
                buf = buf + contrib
                return jax.lax.ppermute(buf, axes, perm_down)

            buf0 = jnp.zeros((ep, g.shape[1]), g.dtype)
            grad_tl = jax.lax.fori_loop(0, p, body, buf0)
            return grad_tl, None

        _ring.defvjp(_ring_fwd, _ring_bwd)

        return jax.shard_map(
            _ring, mesh=self.mesh,
            in_specs=(P(axes, None), P(axes)),
            out_specs=P(axes, None),
            check_vma=False,
        )(table, idx)

    # ------------------------------------------------------------- pass 2

    def edge_aggregate(self, values: jax.Array, dst: jax.Array, n: int) -> jax.Array:
        """Combine edge values at their target vertices: values (E, D) edge-
        sharded, dst (E,) -> (N, D) node-sharded."""
        if self.mesh is None:
            return kops.edge_segment_sum(values, dst, n)

        def _agg(values_local, dst_local):
            partial_out = kops.edge_segment_sum(values_local, dst_local, n)
            return jax.lax.psum_scatter(
                partial_out, self.axes, scatter_dimension=0, tiled=True
            )

        return jax.shard_map(
            _agg, mesh=self.mesh,
            in_specs=(P(self.axes, None), P(self.axes)),
            out_specs=P(self.axes, None),
            check_vma=False,
        )(values, dst)

    # ------------------------------------------------------------- helpers

    def constrain_nodes(self, x: jax.Array) -> jax.Array:
        if self.mesh is None:
            return x
        spec = P(self.axes, *([None] * (x.ndim - 1)))
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(self.mesh, spec)
        )

    def constrain_edges(self, x: jax.Array) -> jax.Array:
        if self.mesh is None:
            return x
        spec = P(self.axes, *([None] * (x.ndim - 1)))
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(self.mesh, spec)
        )


def local_dist() -> GNNDist:
    return GNNDist(mesh=None, axes=())


def sharded_dist(mesh: Mesh, axes: Optional[tuple[str, ...]] = None) -> GNNDist:
    return GNNDist(mesh=mesh, axes=axes or tuple(mesh.axis_names))


# ---------------------------------------------------------------------------
# shared batch utilities
# ---------------------------------------------------------------------------

def masked_mean(values: jax.Array, mask: jax.Array) -> jax.Array:
    m = mask.astype(jnp.float32)
    return (values * m).sum() / jnp.maximum(m.sum(), 1.0)


def cross_entropy(logits: jax.Array, labels: jax.Array, mask: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return masked_mean(nll, mask)


def graph_pool(node_values: jax.Array, graph_ids: jax.Array, n_graphs: int,
               dist: GNNDist) -> jax.Array:
    """Per-graph sum pooling (batched small graphs) via segment-sum.

    The segment target is padded to the shard count for psum_scatter, then
    sliced back to the true graph count."""
    pooled = dist.edge_aggregate(node_values, graph_ids,
                                 _pad_graphs(n_graphs, dist))
    return pooled[:n_graphs]


def _pad_graphs(n_graphs: int, dist: GNNDist) -> int:
    p = dist.n_shards
    return -(-n_graphs // p) * p


def rbf_expand(dist_vals: jax.Array, n_rbf: int, cutoff: float) -> jax.Array:
    """Gaussian radial basis (SchNet-style). dist_vals (E,) -> (E, n_rbf)."""
    centers = jnp.linspace(0.0, cutoff, n_rbf)
    gamma = 10.0 / cutoff
    return jnp.exp(-gamma * (dist_vals[:, None] - centers[None, :]) ** 2)


def edge_distances(pos: jax.Array, src: jax.Array, dst: jax.Array,
                   dist: GNNDist) -> tuple[jax.Array, jax.Array]:
    """Returns (d_ij (E,), unit vectors (E, 3)) from positions."""
    p_src = dist.gather_nodes(pos, src)
    p_dst = dist.gather_nodes(pos, dst)
    diff = p_dst - p_src
    d = jnp.sqrt(jnp.maximum((diff ** 2).sum(-1), 1e-12))
    return d, diff / d[:, None]
