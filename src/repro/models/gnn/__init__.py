"""GNN architectures on the edge-sharded two-pass EdgeScan pattern."""

from repro.models.gnn.common import GNNDist, local_dist, sharded_dist
from repro.models.gnn.gin import GIN, GINConfig
from repro.models.gnn.meshgraphnet import MeshGraphNet, MGNConfig
from repro.models.gnn.schnet import SchNet, SchNetConfig
from repro.models.gnn.dimenet import DimeNet, DimeNetConfig

__all__ = [
    "GNNDist", "local_dist", "sharded_dist",
    "GIN", "GINConfig", "MeshGraphNet", "MGNConfig",
    "SchNet", "SchNetConfig", "DimeNet", "DimeNetConfig",
]
