"""repro: GraphLake (graph compute engine for Lakehouse) on JAX/TPU."""

__version__ = "1.0.0"
