"""repro: GraphLake (graph compute engine for Lakehouse) on JAX/TPU.

The front door is :func:`connect` — build an engine over a lake and get the
GSQL session facade back::

    import repro
    session = repro.connect(store, schema)
    res = session.query("SELECT p FROM Tag:t -(HasTag:e)- Comment:p "
                        "WHERE t.name == $tag", tag="Music")
"""

__version__ = "1.0.0"

_LAZY = {
    "connect": ("repro.gsql.session", "connect"),
    "GraphSession": ("repro.gsql.session", "GraphSession"),
    "ExecOptions": ("repro.core.query", "ExecOptions"),
    # the consolidated typed-error hierarchy (repro/errors.py): everything
    # the engine raises on purpose derives from ReproError
    "ReproError": ("repro.errors", "ReproError"),
    "GSQLError": ("repro.errors", "GSQLError"),
    "GSQLSyntaxError": ("repro.errors", "GSQLSyntaxError"),
    "GSQLCompileError": ("repro.errors", "GSQLCompileError"),
    "QueryTimeoutError": ("repro.errors", "QueryTimeoutError"),
    "ServerOverloadedError": ("repro.errors", "ServerOverloadedError"),
    "TenantQuotaExceededError": ("repro.errors", "TenantQuotaExceededError"),
    "MissingTableError": ("repro.errors", "MissingTableError"),
    # lake-I/O fault taxonomy (DESIGN.md §11)
    "LakeError": ("repro.errors", "LakeError"),
    "TransientLakeError": ("repro.errors", "TransientLakeError"),
    "MissingObjectError": ("repro.errors", "MissingObjectError"),
    "LakeCorruptionError": ("repro.errors", "LakeCorruptionError"),
    "FaultInjector": ("repro.lakehouse.faults", "FaultInjector"),
    "FaultRule": ("repro.lakehouse.faults", "FaultRule"),
    "transient_chaos": ("repro.lakehouse.faults", "transient_chaos"),
    "RetryPolicy": ("repro.lakehouse.retry", "RetryPolicy"),
    # streaming ingestion plane (DESIGN.md §12)
    "IngestBackpressureError": ("repro.errors", "IngestBackpressureError"),
    "ChangeEvent": ("repro.ingest", "ChangeEvent"),
    "ChangeLog": ("repro.ingest", "ChangeLog"),
    "FileTailSource": ("repro.ingest", "FileTailSource"),
    "IngestConfig": ("repro.ingest", "IngestConfig"),
    "IngestPipeline": ("repro.ingest", "IngestPipeline"),
}


def __getattr__(name: str):
    # lazy: importing bare ``repro`` must stay light (configs/models pull jax)
    try:
        mod_name, attr = _LAZY[name]
    except KeyError:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module(mod_name), attr)
