"""The CDC-to-epoch ingestion pipeline (DESIGN.md §12).

Couples the pieces into a running plane::

    producers --offer()--> IngestQueue --drain--> MicroBatchCommitter
        (typed backpressure)    (bounded)        (coalesce + lake commit)
                                                      | CommitRecord
                                                      v
                                              EpochDriver.advance()
                                         (commit -> queryable freshness)

Three daemon threads, all owned by :class:`IngestPipeline`:

- the **committer loop** drains the bounded queue, coalesces into the
  micro-batch committer, and flushes on cadence (``flush_interval_s``,
  defaulting to the ``ingest=<cadence_ms>`` perf flag) or when a batch
  fills;
- the **epoch driver** turns committed micro-batches into queryable data
  by calling the engine's ``advance()`` — the same serialized entry point
  the query server's background refresher uses (``EpochManager`` holds the
  advance lock, so pipeline and refresher compose without coordination) —
  and samples the two freshness latencies per batch: *commit->queryable*
  (lake commit landed -> epoch published) and *ingest->queryable*
  (event admitted -> epoch published, the end-to-end SLO number);
- one **pump** per attached source polls ``source.poll()`` and submits,
  pausing (not dropping) when admission raises
  :class:`~repro.errors.IngestBackpressureError`.

The pipeline registers itself as ``engine.ingest`` so the query server's
``health()`` can surface ingestion counters next to serving stats.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Optional

from repro import perf_flags
from repro.errors import IngestBackpressureError
from repro.ingest.committer import CommitRecord, IngestQueue, MicroBatchCommitter
from repro.ingest.events import ChangeEvent

_MAX_SAMPLES = 4096      # freshness reservoir bound (recent-window percentiles)


@dataclasses.dataclass
class IngestConfig:
    """Tunables of one pipeline.  ``None`` defers to the perf flags:
    ``flush_interval_s`` to ``ingest=<cadence_ms>`` (default 50 ms),
    ``max_queue`` to ``ingest_queue=<depth>`` (default 4096 events)."""

    flush_interval_s: Optional[float] = None
    max_queue: Optional[int] = None
    max_batch_events: int = 2048        # flush early once a batch fills
    high_watermark: float = 0.75        # queue fraction: saturated latches on
    low_watermark: float = 0.25         # queue fraction: saturated clears
    auto_advance: bool = True           # epoch driver calls engine.advance()
    advance_interval_s: Optional[float] = None  # default: flush interval
    row_group_rows: int = 4096          # micro-batch files are small
    source_poll_interval_s: float = 0.01

    def resolved_flush_interval(self) -> float:
        if self.flush_interval_s is not None:
            return self.flush_interval_s
        return perf_flags.value("ingest", 50.0) / 1000.0

    def resolved_max_queue(self) -> int:
        if self.max_queue is not None:
            return int(self.max_queue)
        return int(perf_flags.value("ingest_queue", 4096))


class EpochDriver:
    """Turns committed micro-batches into queryable epochs and measures the
    commit->queryable gap.

    Batches drained *before* an ``advance()`` starts are guaranteed visible
    in the epoch it publishes (their snapshots predate the diff), so the
    sample ``t_published - t_commit`` is an honest upper bound on how long
    a committed change stayed invisible.  A failed advance requeues its
    batch — records are only counted visible once an advance succeeds."""

    def __init__(self, engine, interval_s: float):
        self.engine = engine
        self.interval_s = interval_s
        self._pending: list[CommitRecord] = []
        self._busy = False      # an advance is in flight for a popped batch
        self._lock = threading.Lock()
        self._wake = threading.Event()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.counters = {"advances": 0, "advance_errors": 0,
                         "batches_visible": 0, "events_visible": 0}
        self._commit_to_queryable: list[float] = []
        self._ingest_to_queryable: list[float] = []
        self.last_error: Optional[str] = None

    def submit(self, records: list[CommitRecord]) -> None:
        if not records:
            return
        with self._lock:
            self._pending.extend(records)
        self._wake.set()

    def kick(self) -> None:
        self._wake.set()

    def idle(self) -> bool:
        with self._lock:
            return not self._pending and not self._busy

    def start(self) -> None:
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="ingest-epoch-driver")
        self._thread.start()

    def stop(self, timeout: float = 5.0) -> None:
        self._stop.set()
        self._wake.set()
        if self._thread is not None:
            self._thread.join(timeout)

    def _loop(self) -> None:
        while not self._stop.is_set():
            self._wake.wait(self.interval_s)
            self._wake.clear()
            self._advance_once()
        self._advance_once()        # final drain on shutdown

    def _advance_once(self) -> None:
        with self._lock:
            batch = self._pending
            self._pending = []
            self._busy = bool(batch)
        if not batch:
            return
        try:
            self.engine.advance()
        except Exception as e:
            with self._lock:
                self.counters["advance_errors"] += 1
                self.last_error = f"{type(e).__name__}: {e}"
                self._pending = batch + self._pending    # retry next wake
                self._busy = False
            self._wake.set()
            return
        t_vis = time.monotonic()
        with self._lock:
            self._busy = False
            self.counters["advances"] += 1
            self.counters["batches_visible"] += len(batch)
            for rec in batch:
                self.counters["events_visible"] += rec.n_events
                self._commit_to_queryable.append(t_vis - rec.t_commit)
                self._ingest_to_queryable.append(t_vis - rec.oldest_t_offer)
            del self._commit_to_queryable[:-_MAX_SAMPLES]
            del self._ingest_to_queryable[:-_MAX_SAMPLES]

    def freshness(self) -> dict:
        """Recent-window freshness percentiles, in seconds."""
        with self._lock:
            c2q = list(self._commit_to_queryable)
            i2q = list(self._ingest_to_queryable)
        return {
            "samples": len(c2q),
            "commit_to_queryable_p50_s": _pct(c2q, 0.50),
            "commit_to_queryable_p99_s": _pct(c2q, 0.99),
            "ingest_to_queryable_p50_s": _pct(i2q, 0.50),
            "ingest_to_queryable_p99_s": _pct(i2q, 0.99),
        }

    def snapshot_counters(self) -> dict:
        with self._lock:
            out = dict(self.counters)
            out["last_error"] = self.last_error
            return out


def _pct(samples: list, q: float) -> Optional[float]:
    if not samples:
        return None
    s = sorted(samples)
    return s[min(len(s) - 1, int(q * len(s)))]


class IngestPipeline:
    """The running ingestion plane for one engine.

    Usually obtained via ``session.ingest()`` (which starts it and ties its
    lifetime to the session).  ``submit()`` is the producer edge — it
    validates the event against the graph schema, derives the dedup key
    from the row for upserts, stamps the arrival ``seq``, and offers to the
    bounded queue (raising :class:`IngestBackpressureError` when full).
    """

    def __init__(self, engine, config: Optional[IngestConfig] = None):
        self.engine = engine
        self.config = config or IngestConfig()
        self._flush_interval = self.config.resolved_flush_interval()
        self.queue = IngestQueue(self.config.resolved_max_queue(),
                                 high_watermark=self.config.high_watermark,
                                 low_watermark=self.config.low_watermark)
        self.committer = MicroBatchCommitter(
            engine, row_group_rows=self.config.row_group_rows)
        self.driver = EpochDriver(
            engine, self.config.advance_interval_s
            if self.config.advance_interval_s is not None
            else self._flush_interval)
        self._tables = {vt.table for vt in engine.schema.vertex_types.values()} \
            | {et.table for et in engine.schema.edge_types.values()}
        self._seq = 0
        self._seq_lock = threading.Lock()
        self._flush_lock = threading.Lock()     # serializes flush passes
        self._stop = threading.Event()
        self._committer_thread: Optional[threading.Thread] = None
        self._pumps: list[threading.Thread] = []
        self._pump_idle: list[bool] = []    # per pump: empty backlog + dry poll
        self._pump_polls: list[int] = []    # per pump: completed poll cycles
        self._sources: list = []
        self._started = False
        self._stalled = False       # last flush failed; queue must back up
        self.counters = {"submitted": 0, "rejected": 0, "flushes": 0,
                         "flush_errors": 0, "source_stalls": 0}
        self._counters_lock = threading.Lock()
        self.last_flush_error: Optional[str] = None

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> "IngestPipeline":
        if self._started:
            return self
        self._started = True
        self.engine.ingest = self
        if self.config.auto_advance:
            self.driver.start()
        self._committer_thread = threading.Thread(
            target=self._committer_loop, daemon=True, name="ingest-committer")
        self._committer_thread.start()
        for t in self._pumps:
            t.start()
        return self

    def close(self, drain_timeout: float = 5.0) -> None:
        """Stop the plane: drain what can be drained within the timeout,
        then stop the threads.  Events stuck behind a persistently failing
        lake are abandoned (counted in ``flush_errors``)."""
        if not self._started:
            return
        self.drain(timeout=drain_timeout)
        self._stop.set()
        if self._committer_thread is not None:
            self._committer_thread.join(5.0)
        for t in self._pumps:
            t.join(1.0)
        self.driver.stop()
        if getattr(self.engine, "ingest", None) is self:
            self.engine.ingest = None
        self._started = False

    # -- producer edge -------------------------------------------------------

    def submit(self, event: ChangeEvent) -> ChangeEvent:
        """Admit one change event.  Returns the admitted event (with the
        pipeline-assigned ``seq`` and derived key); raises
        :class:`IngestBackpressureError` when the queue is full."""
        if event.table not in self._tables:
            raise ValueError(
                f"unknown table {event.table!r} — graph tables: "
                f"{sorted(self._tables)}")
        if event.op == "upsert":
            # reject malformed rows at admission: a poison event inside a
            # micro-batch would fail every flush of its table forever
            meta = self.committer.table_meta(event.table)
            if sorted(event.row) != sorted(meta.columns):
                raise ValueError(
                    f"upsert row for {event.table!r} must carry exactly the "
                    f"table columns {meta.columns}, got {sorted(event.row)}")
            key = self.committer.derive_key(event.table, event.row)
            # dangling-edge admission check: an edge endpoint must exist —
            # committed, pending, or admitted earlier this burst (typed
            # DanglingEdgeError to the producer, DESIGN.md §12)
            self.committer.check_edge_endpoints(event)
        else:
            key = event.key
        with self._seq_lock:
            seq = self._seq
            self._seq += 1
        admitted = dataclasses.replace(event, key=key, seq=seq)
        try:
            self.queue.offer(admitted)
        except IngestBackpressureError:
            with self._counters_lock:
                self.counters["rejected"] += 1
            raise
        self.committer.note_admitted(admitted)
        with self._counters_lock:
            self.counters["submitted"] += 1
        return admitted

    def upsert(self, table: str, row: dict,
               event_time: float = -1.0) -> ChangeEvent:
        return self.submit(ChangeEvent(table=table, op="upsert", row=row,
                                       event_time=event_time))

    def delete(self, table: str, key, event_time: float = -1.0) -> ChangeEvent:
        return self.submit(ChangeEvent(table=table, op="delete", key=key,
                                       event_time=event_time))

    def attach_source(self, source) -> None:
        """Pump a source (``poll(max_events) -> list[ChangeEvent]``) into
        the pipeline on a dedicated thread.  Backpressure pauses the pump
        (the un-admitted event is retried) — nothing is dropped."""
        self._sources.append(source)
        idx = len(self._pumps)
        self._pump_idle.append(False)
        self._pump_polls.append(0)
        t = threading.Thread(target=self._pump, args=(source, idx),
                             daemon=True, name=f"ingest-pump-{idx}")
        self._pumps.append(t)
        if self._started:
            t.start()

    # -- flush / drain -------------------------------------------------------

    def flush_now(self) -> list[CommitRecord]:
        """Synchronously drain the queue and flush pending batches (the
        cadence loop keeps running; flush passes are serialized)."""
        items = self.queue.drain(self.queue.max_events, timeout=0.0)
        if items:
            self.committer.ingest(items)
        return self._do_flush()

    def drain(self, timeout: float = 30.0) -> bool:
        """Push everything produced so far through commit *and* epoch
        publish.  True if fully drained within the timeout."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            if self._quiet():
                # a pump's idle flag may predate the producer's last append:
                # require every pump to complete two fresh poll cycles (the
                # second necessarily *starts* after quiet was observed, so
                # it sees everything on disk at drain time) and quiet to
                # still hold before declaring the stream drained
                marks = list(self._pump_polls)
                settled = False
                while time.monotonic() < deadline:
                    if all(p >= m + 2
                           for p, m in zip(self._pump_polls, marks)):
                        settled = True
                        break
                    time.sleep(0.002)
                if settled and self._quiet():
                    return True
                continue
            self.flush_now()
            if self.config.auto_advance:
                self.driver.kick()
            time.sleep(0.005)
        return False

    def _quiet(self) -> bool:
        return (all(self._pump_idle) and len(self.queue) == 0
                and self.committer.pending_events() == 0
                and (not self.config.auto_advance or self.driver.idle()))

    def _do_flush(self) -> list[CommitRecord]:
        with self._flush_lock:
            if self.committer.pending_events() == 0:
                self._stalled = False
                return []
            records, errors = self.committer.flush()
            self._stalled = bool(errors)
        with self._counters_lock:
            self.counters["flushes"] += 1
            if errors:
                self.counters["flush_errors"] += len(errors)
                self.last_flush_error = errors[-1]
        if records:
            self.driver.submit(records)
        return records

    def _committer_loop(self) -> None:
        next_flush = time.monotonic() + self._flush_interval
        while not self._stop.is_set():
            if self._stalled:
                # a failing lake must surface as backpressure: keep the
                # retained batch, stop draining, and let the bounded queue
                # fill so offer() sheds typed to producers
                self._stop.wait(min(0.05, self._flush_interval))
                items = []
            else:
                items = self.queue.drain(
                    self.config.max_batch_events,
                    timeout=min(0.05, self._flush_interval))
            if items:
                self.committer.ingest(items)
            now = time.monotonic()
            if (now >= next_flush
                    or self.committer.pending_events()
                    >= self.config.max_batch_events):
                self._do_flush()
                next_flush = time.monotonic() + self._flush_interval
        # shutdown: one final sweep so a clean close commits everything
        items = self.queue.drain(self.queue.max_events, timeout=0.0)
        if items:
            self.committer.ingest(items)
        if self.committer.pending_events():
            self._do_flush()

    def _pump(self, source, idx: int) -> None:
        backlog: list[ChangeEvent] = []
        while not self._stop.is_set():
            if not backlog:
                backlog = list(source.poll(256))
                self._pump_polls[idx] += 1
                if not backlog:
                    # only now is this pump drained: an un-submitted backlog
                    # must keep drain() waiting even while the source is empty
                    self._pump_idle[idx] = True
                    if self._stop.wait(self.config.source_poll_interval_s):
                        return
                    continue
                self._pump_idle[idx] = False
            try:
                self.submit(backlog[0])
            except IngestBackpressureError:
                with self._counters_lock:
                    self.counters["source_stalls"] += 1
                if self._stop.wait(self.config.source_poll_interval_s):
                    return
            else:
                backlog.pop(0)

    # -- observability -------------------------------------------------------

    def stats(self) -> dict:
        with self._counters_lock:
            out = dict(self.counters)
        out["last_flush_error"] = self.last_flush_error
        out["stalled"] = self._stalled
        out["queue_depth"] = len(self.queue)
        out["queue_max"] = self.queue.max_events
        out["queue_saturated"] = self.queue.saturated
        out.update(self.queue.counters)
        out["pending_events"] = self.committer.pending_events()
        out["committer"] = self.committer.snapshot_counters()
        out["driver"] = self.driver.snapshot_counters()
        out["freshness"] = self.driver.freshness()
        return out


__all__ = ["EpochDriver", "IngestConfig", "IngestPipeline"]
