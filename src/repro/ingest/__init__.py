"""Streaming ingestion plane: CDC-to-epoch pipeline (DESIGN.md §12).

Change events (upsert/delete) flow through a bounded queue with typed
backpressure into a micro-batch committer that coalesces last-write-wins
per (table, key) and lands CAS-fenced lake commits; an epoch driver turns
each committed batch into a queryable epoch and measures the
commit->queryable freshness SLO.  Entry point: ``session.ingest()``.
"""

from repro.ingest.committer import CommitRecord, IngestQueue, MicroBatchCommitter
from repro.ingest.events import (
    OPS,
    ChangeEvent,
    ChangeLog,
    FileTailSource,
    append_jsonl,
    event_from_json,
    event_to_json,
)
from repro.ingest.pipeline import EpochDriver, IngestConfig, IngestPipeline

__all__ = [
    "OPS",
    "ChangeEvent",
    "ChangeLog",
    "CommitRecord",
    "EpochDriver",
    "FileTailSource",
    "IngestConfig",
    "IngestPipeline",
    "IngestQueue",
    "MicroBatchCommitter",
    "append_jsonl",
    "event_from_json",
    "event_to_json",
]
