"""Micro-batch committer: bounded queue, coalescing, upsert resolution
(DESIGN.md §12).

Two pieces, both synchronous (the pipeline owns the threads):

- :class:`IngestQueue` — the bounded admission edge.  ``offer()`` on a full
  queue raises the typed :class:`~repro.errors.IngestBackpressureError`
  instead of blocking or buffering without bound, so a stalled committer
  (lake outage, fault injection) surfaces to the producer as backpressure
  it can act on — pause the tail, retry with backoff — never as silent
  memory growth.  High/low watermarks give producers an early-warning
  ``saturated`` signal with hysteresis: it latches on crossing the high
  mark and clears only once the queue drains below the low mark.

- :class:`MicroBatchCommitter` — per-table event coalescing plus the
  flush that turns a coalesced batch into lake commits.  Coalescing is
  last-write-wins per ``(table, key)`` on ``(event_time, seq)``; a flush
  resolves each table's survivors against the table's known key set into
  *inserts* (plain ``append_files`` — the cheap path that keeps
  ``advance()`` incremental), *updates*/*deletes* (the copy-on-write
  :meth:`~repro.lakehouse.table.LakeTable.upsert_rows` single-snapshot
  commit), and *ignored deletes* (keys the lake never had).  Every commit
  rides the existing CAS-fenced retry loop; a flush failure leaves the
  batch coalesced in place (newer events keep winning their slots) and is
  retried on the next cadence tick.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Optional

import numpy as np

from repro.errors import DanglingEdgeError, IngestBackpressureError
from repro.ingest.events import ChangeEvent
from repro.lakehouse.columnfile import read_columns, read_footer


class IngestQueue:
    """Bounded change-event queue with typed overflow + watermark hysteresis."""

    def __init__(self, max_events: int, high_watermark: float = 0.75,
                 low_watermark: float = 0.25):
        self.max_events = max(1, int(max_events))
        self._high = max(1, int(self.max_events * high_watermark))
        self._low = int(self.max_events * low_watermark)
        self._items: list = []          # (event, t_offer) pairs, FIFO
        self._cond = threading.Condition()
        self._saturated = False
        self.counters = {"offered": 0, "backpressure_trips": 0,
                         "watermark_trips": 0}

    def offer(self, event: ChangeEvent, t_offer: Optional[float] = None) -> None:
        with self._cond:
            if len(self._items) >= self.max_events:
                self.counters["backpressure_trips"] += 1
                raise IngestBackpressureError(
                    f"ingest queue full ({self.max_events} events pending); "
                    f"shed {event.op} on {event.table!r} key={event.key}")
            self._items.append((event, t_offer if t_offer is not None
                                else time.monotonic()))
            self.counters["offered"] += 1
            if not self._saturated and len(self._items) >= self._high:
                self._saturated = True
                self.counters["watermark_trips"] += 1
            self._cond.notify()

    def drain(self, max_events: int, timeout: float = 0.0) -> list:
        """Up to ``max_events`` queued items, waiting at most ``timeout``
        for the first one."""
        with self._cond:
            if not self._items and timeout > 0:
                self._cond.wait(timeout)
            out = self._items[:max_events]
            del self._items[:len(out)]
            if self._saturated and len(self._items) <= self._low:
                self._saturated = False
            return out

    @property
    def saturated(self) -> bool:
        with self._cond:
            return self._saturated

    def __len__(self) -> int:
        with self._cond:
            return len(self._items)


@dataclasses.dataclass
class CommitRecord:
    """One committed micro-batch on one table — the unit the epoch driver
    tracks from commit to queryable."""

    table: str
    kind: str                   # "append" | "upsert"
    snapshot_id: int
    n_events: int
    t_commit: float             # monotonic instant the commit landed
    oldest_t_offer: float       # monotonic admission time of the oldest event
    commit_s: float             # wall time the lake commit took


@dataclasses.dataclass
class _TableMeta:
    key_columns: list
    columns: list               # schema order
    dtypes: dict                # column -> numpy dtype (object for str)


class MicroBatchCommitter:
    """Coalesces change events per table and flushes them as lake commits."""

    def __init__(self, engine, row_group_rows: int = 4096):
        self.engine = engine
        self.row_group_rows = row_group_rows
        self._lock = threading.Lock()
        # table -> key -> (winning event, earliest admission time)
        self._pending: dict[str, dict[tuple, tuple]] = {}
        self._meta: dict[str, _TableMeta] = {}
        self._known: dict[str, set] = {}    # table -> committed key set
        # vertex-table keys *ever admitted as upserts*, recorded the instant
        # submit() offers the event — the admission-order truth
        # check_edge_endpoints() consults first.  The bounded queue means an
        # admitted vertex may not be in _pending yet (not drained), so
        # checking _pending/_known alone would spuriously reject an edge
        # that rides the same producer burst as its endpoint.  Deletes are
        # deliberately NOT recorded: an edge referencing a vertex that
        # existed and was later deleted is the stream's last-write-wins
        # ordering (the batch oracle replays the same dangling row), not a
        # producer error — only never-existed endpoints reject.  Entries are
        # never evicted: the set is bounded by distinct upserted keys, and a
        # stale entry is exactly what _known would say post-commit.
        self._admitted: dict[str, set[tuple]] = {}
        self._vertex_tables = {vt.table: vt.name
                               for vt in engine.schema.vertex_types.values()}
        self._edge_info = {et.table: et
                          for et in engine.schema.edge_types.values()}
        self.counters = {
            "events_coalesced": 0, "events_committed": 0,
            "rows_inserted": 0, "rows_updated": 0, "rows_deleted": 0,
            "deletes_ignored": 0, "append_commits": 0, "upsert_commits": 0,
            "files_rewritten": 0, "dangling_edges_rejected": 0,
        }

    # -- schema resolution ---------------------------------------------------

    def table_meta(self, table: str) -> _TableMeta:
        """Key columns + column order/dtypes for one lake table (cached —
        table schemas are immutable in this lake)."""
        meta = self._meta.get(table)
        if meta is None:
            ts = self.engine.lake.table(table).schema()
            pk = ts.primary_key
            key_cols = [pk] if pk else [c.name for c in ts.foreign_keys]
            if not key_cols:
                raise ValueError(
                    f"table {table!r} has neither a primary key nor foreign "
                    f"keys — no dedup identity for ingestion")
            dtypes = {c.name: (np.dtype(object) if c.dtype == "str"
                               else np.dtype(c.dtype)) for c in ts.columns}
            meta = _TableMeta(key_columns=key_cols,
                              columns=[c.name for c in ts.columns],
                              dtypes=dtypes)
            self._meta[table] = meta
        return meta

    def derive_key(self, table: str, row: dict) -> tuple:
        return tuple(row[c] for c in self.table_meta(table).key_columns)

    def _known_keys(self, table: str) -> set:
        """The table's committed key set, seeded once from the lake (key
        columns of every data file) and maintained across flushes."""
        known = self._known.get(table)
        if known is None:
            known = set()
            t = self.engine.lake.table(table)
            key_cols = self.table_meta(table).key_columns
            if t.exists() and t.snapshots():
                for fkey in t.data_files():
                    fm = read_footer(self.engine.store, fkey)
                    cols = read_columns(self.engine.store, fm, key_cols)
                    known.update(zip(*[cols[c].tolist() for c in key_cols]))
            self._known[table] = known
        return known

    # -- admission checks ----------------------------------------------------

    def note_admitted(self, event: ChangeEvent) -> None:
        """Record a vertex-table upsert the pipeline just admitted, so edge
        admission sees endpoints that are still queued (not yet drained)."""
        if event.table not in self._vertex_tables or event.op != "upsert":
            return
        with self._lock:
            self._admitted.setdefault(event.table, set()).add(event.key)

    def _endpoint_present(self, vtable: str, key: tuple) -> bool:
        """Has the vertex key *ever existed* as of admission order — upserted
        earlier in the stream, upsert-pending for the next flush, or
        committed in the lake?  A pending/later delete does not un-exist it:
        last-write-wins ordering is the stream's business, and the resulting
        dangling row is exactly what a batch replay of the same history
        produces."""
        if key in self._admitted.get(vtable, ()):
            return True
        slot = self._pending.get(vtable)
        if slot is not None and key in slot and slot[key][0].op == "upsert":
            return True
        return key in self._known_keys(vtable)

    def check_edge_endpoints(self, event: ChangeEvent) -> None:
        """Reject an edge upsert whose endpoint vertex does not exist
        (committed, pending, or admitted ahead of it) with the typed
        :class:`~repro.errors.DanglingEdgeError` — admitting it would either
        poison the table's micro-batch or force ``advance()`` onto the
        dangling-edge rebuild path (DESIGN.md §7)."""
        et = self._edge_info.get(event.table)
        if et is None or event.op != "upsert":
            return
        for column, vtype in ((et.src_column, et.src_type),
                              (et.dst_column, et.dst_type)):
            vtable = self.engine.schema.vertex_types[vtype].table
            key = (event.row[column],)
            # seed the committed key set outside the lock (first call reads
            # key columns from the lake)
            self._known_keys(vtable)
            with self._lock:
                present = self._endpoint_present(vtable, key)
            if not present:
                with self._lock:
                    self.counters["dangling_edges_rejected"] += 1
                raise DanglingEdgeError(
                    f"edge upsert on {event.table!r}: endpoint "
                    f"{column}={key[0]!r} has no {vtype!r} vertex "
                    f"(table {vtable!r}) committed, pending, or admitted",
                    table=event.table, column=column, key=key)

    # -- coalescing ----------------------------------------------------------

    def ingest(self, items: list) -> None:
        """Coalesce drained ``(event, t_offer)`` items into the pending map:
        last-write-wins per (table, key), earliest admission time kept so
        freshness measures the longest-waiting change to a slot."""
        with self._lock:
            for event, t_offer in items:
                slot = self._pending.setdefault(event.table, {})
                cur = slot.get(event.key)
                if cur is None:
                    slot[event.key] = (event, t_offer)
                else:
                    keep = event if event.ordering() >= cur[0].ordering() \
                        else cur[0]
                    slot[event.key] = (keep, min(t_offer, cur[1]))
                    self.counters["events_coalesced"] += 1

    def pending_events(self) -> int:
        with self._lock:
            return sum(len(m) for m in self._pending.values())

    # -- flush ---------------------------------------------------------------

    def flush(self) -> tuple[list[CommitRecord], list[str]]:
        """Commit every table's pending batch; returns (records, errors).

        A failed table keeps its batch pending (retried next tick); a
        succeeded table's slots are removed *only if unchanged* since the
        snapshot, so events that arrived mid-commit are never lost."""
        with self._lock:
            snapshot = {t: dict(m) for t, m in self._pending.items() if m}
        records: list[CommitRecord] = []
        errors: list[str] = []
        for table, slot in snapshot.items():
            try:
                rec = self._commit_table(table, slot)
            except Exception as e:
                errors.append(f"{table}: {type(e).__name__}: {e}")
                continue
            if rec is not None:
                records.append(rec)
            with self._lock:
                pend = self._pending.get(table, {})
                for key, item in slot.items():
                    if pend.get(key) is item:
                        del pend[key]
        return records, errors

    def _columns_for(self, table: str, events: list[ChangeEvent]) -> dict:
        meta = self.table_meta(table)
        cols = {}
        for c in meta.columns:
            vals = [e.row[c] for e in events]
            cols[c] = np.array(vals, dtype=meta.dtypes[c])
        return cols

    def _commit_table(self, table: str,
                      slot: dict) -> Optional[CommitRecord]:
        meta = self.table_meta(table)
        known = self._known_keys(table)
        # deterministic commit order: admission sequence
        items = sorted(slot.values(), key=lambda it: it[0].seq)
        upserts = [e for e, _ in items if e.op == "upsert"]
        delete_keys = []
        ignored = 0
        for e, _ in items:
            if e.op == "delete":
                if e.key in known:
                    delete_keys.append(e.key)
                else:
                    ignored += 1
        updates = [e for e in upserts if e.key in known]
        t = self.engine.lake.table(table)
        t0 = time.perf_counter()
        if updates or delete_keys:
            result = t.upsert_rows(
                self._columns_for(table, upserts) if upserts else None,
                meta.key_columns, delete_keys=delete_keys,
                row_group_rows=self.row_group_rows)
            snap = result.snapshot
            kind = "upsert"
            self.counters["upsert_commits"] += 1
            self.counters["rows_inserted"] += result.rows_inserted
            self.counters["rows_updated"] += result.rows_updated
            self.counters["rows_deleted"] += result.rows_deleted
            self.counters["files_rewritten"] += result.files_rewritten
        elif upserts:
            snap = t.append_files([self._columns_for(table, upserts)],
                                  row_group_rows=self.row_group_rows)
            kind = "append"
            self.counters["append_commits"] += 1
            self.counters["rows_inserted"] += len(upserts)
        else:
            snap = None     # every event was a delete of an unknown key
        self.counters["deletes_ignored"] += ignored
        self.counters["events_committed"] += len(slot)
        known.update(e.key for e in upserts)
        known.difference_update(delete_keys)
        if snap is None:
            return None
        return CommitRecord(
            table=table, kind=kind, snapshot_id=snap.snapshot_id,
            n_events=len(slot), t_commit=time.monotonic(),
            oldest_t_offer=min(t_offer for _, t_offer in items),
            commit_s=time.perf_counter() - t0,
        )

    def snapshot_counters(self) -> dict:
        with self._lock:
            return dict(self.counters)


__all__ = ["IngestQueue", "MicroBatchCommitter", "CommitRecord"]
