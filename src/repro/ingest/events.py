"""The typed change-event model of the streaming ingestion plane
(DESIGN.md §12).

A :class:`ChangeEvent` is one row-level change against a named lake table —
an **upsert** (insert-or-replace, resolved against the table's key columns)
or a **delete** — carrying the event-time of the upstream change and a
dedup key.  The pipeline coalesces events per ``(table, key)`` with
last-write-wins ordering on ``(event_time, seq)``: ``seq`` is the
pipeline-assigned monotonic arrival number, so same-timestamp duplicates
resolve deterministically by arrival order.

Two pluggable sources ship with the model:

- :class:`ChangeLog` — an in-process, replayable buffer: producers
  ``append()`` (or use the ``upsert``/``delete`` sugar), the pipeline
  ``poll()``s, and tests ``rewind()`` to replay the identical history into
  a second lake (the batch-committed oracle the freshness benchmark
  compares against);
- :class:`FileTailSource` — tails a JSONL file of serialized events (one
  per line, :func:`event_to_json`), the file-drop CDC shape: an upstream
  process appends lines, the pipeline picks up complete lines on each
  poll, and ``rewind()`` replays from the top.

A *source* is anything with ``poll(max_events) -> list[ChangeEvent]``
returning at most ``max_events`` new events per call (empty list = nothing
new yet).
"""

from __future__ import annotations

import dataclasses
import json
import threading
import time
from typing import Optional

OPS = ("upsert", "delete")


@dataclasses.dataclass(frozen=True)
class ChangeEvent:
    """One row-level change against a named lake table.

    ``key`` is the dedup identity: the table's primary-key value for vertex
    tables, the ``(src, dst)`` pair for edge tables — always normalized to
    a tuple.  For upserts the pipeline re-derives the key from ``row`` at
    admission (the row is authoritative); deletes must carry it explicitly.
    ``seq`` is assigned by the pipeline at admission (producers leave the
    default)."""

    table: str
    op: str                      # "upsert" | "delete"
    key: tuple = ()
    row: Optional[dict] = None   # column -> scalar (upsert only)
    event_time: float = -1.0     # source timestamp; -1 = stamp at creation
    seq: int = -1                # pipeline-assigned arrival number

    def __post_init__(self):
        if self.op not in OPS:
            raise ValueError(f"unknown change op {self.op!r} (one of {OPS})")
        if self.op == "upsert" and self.row is None:
            raise ValueError("upsert events require a row")
        if not isinstance(self.key, tuple):
            object.__setattr__(
                self, "key",
                tuple(self.key) if isinstance(self.key, (list, set))
                else (self.key,) if self.key is not None else ())
        if self.op == "delete" and not self.key:
            raise ValueError("delete events require a key")
        if self.event_time < 0:
            object.__setattr__(self, "event_time", time.time())

    def ordering(self) -> tuple:
        """Last-write-wins ordering: greater wins a (table, key) slot."""
        return (self.event_time, self.seq)


def _plain(v):
    """JSON-encodable scalar (numpy ints/floats -> python)."""
    return v.item() if hasattr(v, "item") else v


def event_to_json(e: ChangeEvent) -> dict:
    d = {"table": e.table, "op": e.op, "key": [_plain(k) for k in e.key],
         "event_time": e.event_time}
    if e.row is not None:
        d["row"] = {c: _plain(v) for c, v in e.row.items()}
    return d


def event_from_json(d: dict) -> ChangeEvent:
    return ChangeEvent(
        table=d["table"], op=d["op"], key=tuple(d.get("key") or ()),
        row=d.get("row"), event_time=float(d.get("event_time", -1.0)),
    )


class ChangeLog:
    """In-process replayable change buffer (source + producer sugar).

    Keeps the full history: ``poll()`` advances a cursor, ``rewind()``
    resets it, ``history()`` returns everything ever appended — which is
    what lets a test replay the identical (duplicate-laden) stream into a
    batch-committed oracle lake and assert the pipeline's dedup/upsert
    resolution dropped nothing and duplicated nothing."""

    def __init__(self):
        self._events: list[ChangeEvent] = []
        self._cursor = 0
        self._lock = threading.Lock()

    def append(self, event: ChangeEvent) -> None:
        with self._lock:
            self._events.append(event)

    def upsert(self, table: str, row: dict,
               event_time: float = -1.0) -> ChangeEvent:
        e = ChangeEvent(table=table, op="upsert", key=(), row=row,
                        event_time=event_time)
        self.append(e)
        return e

    def delete(self, table: str, key, event_time: float = -1.0) -> ChangeEvent:
        e = ChangeEvent(table=table, op="delete", key=key,
                        event_time=event_time)
        self.append(e)
        return e

    def poll(self, max_events: int = 1024) -> list[ChangeEvent]:
        with self._lock:
            out = self._events[self._cursor:self._cursor + max_events]
            self._cursor += len(out)
            return out

    def rewind(self) -> None:
        with self._lock:
            self._cursor = 0

    def history(self) -> list[ChangeEvent]:
        with self._lock:
            return list(self._events)

    def __len__(self) -> int:
        with self._lock:
            return len(self._events) - self._cursor


class FileTailSource:
    """Tail a JSONL change-log file (one :func:`event_to_json` per line).

    ``poll()`` reads complete lines appended since the last call — a
    partial trailing line (a writer mid-append) is left for the next poll,
    so a torn tail never yields a malformed event.  Missing file = no
    events yet.  ``rewind()`` replays from the top."""

    def __init__(self, path: str):
        self.path = path
        self._offset = 0

    def poll(self, max_events: int = 1024) -> list[ChangeEvent]:
        out: list[ChangeEvent] = []
        try:
            f = open(self.path, "r", encoding="utf-8")
        except FileNotFoundError:
            return out
        with f:
            f.seek(self._offset)
            while len(out) < max_events:
                line = f.readline()
                if not line.endswith("\n"):
                    break               # EOF or partial write: retry later
                self._offset = f.tell()
                line = line.strip()
                if line:
                    out.append(event_from_json(json.loads(line)))
        return out

    def rewind(self) -> None:
        self._offset = 0


def append_jsonl(path: str, events) -> None:
    """Producer-side helper: append events to a JSONL change-log file
    (what :class:`FileTailSource` tails)."""
    with open(path, "a", encoding="utf-8") as f:
        for e in events:
            f.write(json.dumps(event_to_json(e)) + "\n")


__all__ = ["ChangeEvent", "ChangeLog", "FileTailSource", "OPS",
           "append_jsonl", "event_from_json", "event_to_json"]
