"""Perf-optimization toggles (EXPERIMENTS.md §Perf).

Beyond-paper optimizations are individually switchable so the perf loop can
record exact before/after deltas:

- ``tri``        — triangular causal blockwise attention: skip kv blocks
                   above the diagonal at schedule time (2x on causal
                   attention compute; matches the Pallas kernel's @pl.when
                   block skip so the CPU dry-run costs reflect TPU behavior),
- ``chunkloss``  — chunked LM loss: never materialize the (B, S, V) f32
                   logits; compute log-softmax/NLL per sequence chunk,
- ``pushdown``   — GNN projection pushdown: apply the first linear layer
                   before the remote gather so the all_gather moves d_hidden
                   wide rows instead of d_in (the paper's filter/projection
                   pushdown lifted to feature space),
- ``bf16gather`` — cast FSDP-sharded weights to bf16 *before* the per-layer
                   all-gather (half the weight-gather collective bytes;
                   f32 master weights stay sharded),
- ``gnnbf16``    — ship GNN pass-1 feature gathers in bf16 (half the
                   all_gather bytes; pass-2 partial sums stay f32),
- ``moe_ep``     — explicit shard_map expert-parallel MoE dispatch: local
                   scatter per (data, model) device + (T_local, D) psum,
                   replacing GSPMD's (E*C, D) all-reduce per scatter
                   (deepseek train: 94% of collective bytes),
- ``kv_int8``    — int8 KV caches with per-vector scales (OFF by default:
                   a capacity trade; halves decode cache memory — closes the
                   two single-pod decode cells that exceed 16 GB/chip).

- ``csr``        — adaptive CSR dispatch in EdgeScan: serve low-selectivity
                   scans from the per-edge-type CSR index instead of the
                   edge-list scan (the Fig. 15 crossover, DESIGN.md §3).

- ``pipe``       — parallel chunk-pipelined read path (DESIGN.md §5): batch
                   each gather's surviving chunk fetches+decodes through the
                   engine's shared IOPool instead of one-at-a-time on the
                   caller thread.  ``pipe=<depth>`` overrides the bounded
                   in-flight chunk budget (default 16).  Off = the
                   sequential parity path.

- ``refresh``    — background epoch refresh in the query server
                   (DESIGN.md §7): a refresher thread calls the engine's
                   ``advance()`` on an interval so serving picks up lake
                   commits without a restart.  ``refresh=<seconds>``
                   overrides the interval (default 30); an explicit
                   ``ServerConfig.refresh_interval_s`` wins over the flag.

- ``batch``      — shared-scan multi-query batching in the query server
                   (DESIGN.md §9): concurrent requests for the same
                   installed template group within a short window and
                   execute as one pass — one gather, one union chunk-fetch
                   plan, per-rider masks.  ``batch=<window_ms>`` overrides
                   the batching window (default 2 ms); an explicit
                   ``ServerConfig.batch_window_ms`` wins over the flag.
                   Off = the per-request parity path.

- ``retry``      — typed retry with backoff on every lake read
                   (DESIGN.md §11): transient store faults (throttles,
                   torn/short reads) retry with exponential backoff +
                   decorrelated jitter instead of failing the query.
                   ``retry=<attempts>`` overrides the attempt budget
                   (default 5).  Off = fail-fast single attempt.

- ``ingest``     — streaming-ingestion micro-batch cadence (DESIGN.md §12):
                   the CDC-to-epoch pipeline flushes its coalesced change
                   events into a lake commit every ``ingest=<cadence_ms>``
                   milliseconds (default 50) when
                   ``IngestConfig.flush_interval_s`` is unset.  The flag is
                   a tunable, not an on/off path — a pipeline only exists
                   when a caller constructs one.

- ``ingest_queue`` — bounded ingest-queue depth (default 4096 events) when
                   ``IngestConfig.max_queue`` is unset.  A full queue sheds
                   typed ``IngestBackpressureError`` to the producer.  Not
                   an optimization toggle, so it lives in the recognized-
                   but-not-default-on set.

- ``shards``     — shard-fabric width (DESIGN.md §13): ``shards=<n>``
                   partitions the graph into *n* vertex-hash shards and
                   runs every GSQL query as coordinator-merged
                   scatter-gather across per-shard workers, bit-identical
                   to the single-engine run.  A width, not an on/off path —
                   a fabric only exists when ``connect(..., shards=n)`` or
                   ``ShardFabric.attach`` builds one; the flag supplies the
                   default width for ``shards`` left unset.

- ``chaos``      — seeded fault injection on the object store (OFF by
                   default: a test/benchmark mode, not an optimization).
                   ``chaos=<rate>`` injects transient faults at the given
                   rate (default 0.05) on lake-table reads, plus torn reads
                   at rate/2 and latency spikes at 2x rate, from seed 0
                   (``StoreConfig.fault_seed``/``faults`` override in code).

Default: all on.  ``REPRO_OPTS=""`` disables all (baseline);
``REPRO_OPTS="tri,chunkloss"`` enables a subset.

A flag can carry a numeric tunable: ``REPRO_OPTS="csr=0.02"`` enables
``csr`` *and* overrides its selectivity threshold — one entry, so tuning a
flag can never accidentally change which flags are on.  ``value(name,
default)`` reads the numeric part (default when absent or bare).

Unrecognized names in ``REPRO_OPTS`` warn once per distinct setting: a typo
(``REPRO_OPTS=pip``) silently disabling every other optimization is exactly
the kind of misconfiguration a perf loop must not chase for a day.
"""

from __future__ import annotations

import os
import warnings

_ALL = ("tri", "chunkloss", "pushdown", "bf16gather", "gnnbf16", "moe_ep", "csr",
        "pipe", "refresh", "batch", "retry", "ingest")

# recognized but not default-on (capacity trades, chaos modes, bare
# tunables) — never warned
_KNOWN_OFF = ("kv_int8", "chaos", "ingest_queue", "shards")

# REPRO_OPTS strings already checked for typos (warn once per distinct value)
_checked: set = set()


def _check_names(raw: str) -> None:
    if raw in _checked:
        return
    _checked.add(raw)
    names = {x.strip().split("=", 1)[0] for x in raw.split(",") if x.strip()}
    unknown = names - set(_ALL) - set(_KNOWN_OFF)
    if unknown:
        warnings.warn(
            f"REPRO_OPTS names unrecognized flag(s) {sorted(unknown)} — known "
            f"flags: {', '.join(_ALL + _KNOWN_OFF)}.  Listed flags still "
            f"apply, but everything not listed is OFF; check for typos.",
            UserWarning, stacklevel=3)


def enabled(flag: str) -> bool:
    raw = os.environ.get("REPRO_OPTS")
    if raw is None:
        return flag in _ALL
    _check_names(raw)
    chosen = {x.strip().split("=", 1)[0] for x in raw.split(",") if x.strip()}
    return flag in chosen


def value(name: str, default: float) -> float:
    """Numeric tunable attached to a flag (``name=<float>`` entries)."""
    raw = os.environ.get("REPRO_OPTS") or ""
    if raw:
        _check_names(raw)
    for part in raw.split(","):
        if "=" in part:
            k, v = part.split("=", 1)
            if k.strip() == name:
                try:
                    return float(v)
                except ValueError:
                    return default
    return default
