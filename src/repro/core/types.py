"""Core types for the GraphLake engine.

Transformed vertex IDs (paper §4.1): 64-bit integers whose upper 32 bits hold
a globally unique *file ID* and whose lower 32 bits hold the row index inside
that file.  They make vertex-attribute lookup a direct (file, row) address —
no scan over vertex files — and they are what edge lists store.

The *dense index space* is a derived convenience this implementation adds:
each vertex type lays its files out contiguously (file registration order), so
``dense = file_offset[file] + row``.  Dense indices are what accumulators,
frontier bitmaps and the JAX kernels use (TPU-friendly contiguous addressing);
transformed IDs remain the on-disk / in-edge-list representation exactly as in
the paper.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

# reserved file ID for dangling raw IDs (paper §4.3)
DANGLING_FILE_ID = 0
ROW_BITS = 32
ROW_MASK = (1 << ROW_BITS) - 1


def make_transformed(file_id, row_index):
    """(file_id, row) -> transformed 64-bit ID.  Vectorized over numpy inputs."""
    return (np.asarray(file_id, dtype=np.int64) << ROW_BITS) | np.asarray(
        row_index, dtype=np.int64
    )


def split_transformed(tid):
    """transformed ID -> (file_id, row).  Vectorized."""
    tid = np.asarray(tid, dtype=np.int64)
    return (tid >> ROW_BITS).astype(np.int64), (tid & ROW_MASK).astype(np.int64)


@dataclasses.dataclass
class VertexFileInfo:
    """Registry entry for one vertex data file."""

    file_id: int           # globally unique (upper 32 bits of transformed IDs)
    vertex_type: str
    key: str               # object-store key of the data file
    ordinal: int           # position within the vertex type's file list
    n_rows: int
    dense_offset: int      # first dense index of this file within the type


@dataclasses.dataclass
class VertexTypeInfo:
    name: str
    table: str
    primary_key: str
    files: list[VertexFileInfo] = dataclasses.field(default_factory=list)
    n_vertices: int = 0     # includes implicit (dangling) vertices

    def file_by_id(self, file_id: int) -> VertexFileInfo:
        for f in self.files:
            if f.file_id == file_id:
                return f
        raise KeyError(file_id)


@dataclasses.dataclass
class EdgeTypeInfo:
    name: str
    table: str
    src_type: str
    dst_type: str
    src_column: str         # FK column holding raw source-vertex IDs
    dst_column: str         # FK column holding raw target-vertex IDs


@dataclasses.dataclass
class GraphSchema:
    """Mapping of Lakehouse tables to a labeled property graph (paper §3)."""

    vertex_types: dict[str, EdgeTypeInfo | VertexTypeInfo] | dict
    edge_types: dict[str, EdgeTypeInfo]

    def __init__(
        self,
        vertex_types: Optional[dict[str, VertexTypeInfo]] = None,
        edge_types: Optional[dict[str, EdgeTypeInfo]] = None,
    ):
        self.vertex_types = vertex_types or {}
        self.edge_types = edge_types or {}

    def add_vertex_type(self, name: str, table: str, primary_key: str) -> VertexTypeInfo:
        info = VertexTypeInfo(name=name, table=table, primary_key=primary_key)
        self.vertex_types[name] = info
        return info

    def add_edge_type(
        self,
        name: str,
        table: str,
        src_type: str,
        dst_type: str,
        src_column: str,
        dst_column: str,
    ) -> EdgeTypeInfo:
        info = EdgeTypeInfo(
            name=name,
            table=table,
            src_type=src_type,
            dst_type=dst_type,
            src_column=src_column,
            dst_column=dst_column,
        )
        self.edge_types[name] = info
        return info


class VSet:
    """An active vertex set: per-type dense bitmap, segmented by vertex file.

    The paper stores these as compressed per-file bitmaps; we hold one boolean
    array per vertex type over the dense index space (files are contiguous
    slices of it, so per-file segmentation is a view, not a copy).
    """

    def __init__(self, vertex_type: str, mask: np.ndarray):
        self.vertex_type = vertex_type
        self.mask = np.asarray(mask, dtype=bool)

    @staticmethod
    def empty(vertex_type: str, n: int) -> "VSet":
        return VSet(vertex_type, np.zeros(n, dtype=bool))

    @staticmethod
    def full(vertex_type: str, n: int) -> "VSet":
        return VSet(vertex_type, np.ones(n, dtype=bool))

    @staticmethod
    def from_dense_ids(vertex_type: str, n: int, ids: np.ndarray) -> "VSet":
        m = np.zeros(n, dtype=bool)
        m[np.asarray(ids, dtype=np.int64)] = True
        return VSet(vertex_type, m)

    # -- set algebra (GSQL UNION / INTERSECT / MINUS) -------------------------

    def union(self, other: "VSet") -> "VSet":
        self._check(other)
        return VSet(self.vertex_type, self.mask | other.mask)

    def intersect(self, other: "VSet") -> "VSet":
        self._check(other)
        return VSet(self.vertex_type, self.mask & other.mask)

    def minus(self, other: "VSet") -> "VSet":
        self._check(other)
        return VSet(self.vertex_type, self.mask & ~other.mask)

    def _check(self, other: "VSet") -> None:
        if other.vertex_type != self.vertex_type:
            raise ValueError(
                f"vertex set type mismatch: {self.vertex_type} vs {other.vertex_type}"
            )

    # -- helpers ---------------------------------------------------------------

    def ids(self) -> np.ndarray:
        return np.flatnonzero(self.mask)

    def size(self) -> int:
        return int(self.mask.sum())

    def __len__(self) -> int:
        return self.size()

    def min_max(self) -> tuple[int, int]:
        """Dense-index Min-Max of the frontier (drives prefetch pruning)."""
        ids = self.ids()
        if len(ids) == 0:
            return (0, -1)
        return int(ids[0]), int(ids[-1])
