"""Frontier-driven prefetching (paper §5.3).

Two signals drive asynchronous cache-unit loads ahead of traversal:

1. **Vertex frontier Min-Max**: for every vertex file we intersect the
   frontier's dense Min-Max envelope with each row group's dense row range;
   overlapping groups get their (query-required) column chunks prefetched.

2. **Edge-list portion statistics**: each edge-list portion carries Min/Max
   source (and target) dense IDs computed at build time; portions whose range
   misses the frontier envelope are pruned, the rest get their edge-attribute
   chunks prefetched.  Most effective when edge tables are sorted by source
   FK, as the paper notes.

3. **Predicate zone maps** (DESIGN.md §4): when the caller passes ``bounds``
   (column -> ``ColumnBounds`` from the query planner), each surviving row
   group is additionally checked against its chunks' Min/Max value
   statistics.  A row group some bound rejects is *definitively* dead — no
   column of it is prefetched, so pruned chunks are never fetched from the
   lake at all (the read path will skip them identically).

Prefetching is mechanically just ``CacheManager.get_unit`` on I/O threads:
units land in the memory tier before EdgeScan/VertexMap ask for them.
"""

from __future__ import annotations

from typing import Optional, Sequence

from repro.core.cache.manager import CacheManager
from repro.core.cache.units import ChunkRef
from repro.core.plan import new_pruning_counters, zone_map_rejects
from repro.core.types import VSet
from repro.lakehouse.io_pool import IOPool


class Prefetcher:
    def __init__(self, cache: CacheManager, topology, pool: Optional[IOPool] = None):
        self.cache = cache
        self.topology = topology
        self.pool = pool
        self.stats = {"vertex_chunks": 0, "edge_chunks": 0, "pruned_portions": 0,
                      "pruned_chunks": 0}
        # standard pruning-counter schema fed by the shared zone-map helper
        # (plan.zone_map_rejects) — the very same test+bookkeeping the read
        # path applies, so prefetch never fetches a chunk the read will skip
        self.counters = new_pruning_counters()
        self._sync_batch: list = []  # poolless mode: bulk-admitted per call

    def _issue(self, ref: ChunkRef, meta, kind: str) -> None:
        if self.pool is not None:
            # fire-and-forget: units land in the memory tier ahead of the
            # traversal's reads (which coalesce with in-flight admissions
            # through the cache's single-flight loading)
            self.pool.submit(self.cache.get_unit, ref, meta, kind)
        else:
            self._sync_batch.append((ref, meta, kind))

    def _flush_sync(self) -> None:
        if self._sync_batch:
            self.cache.get_units_batch(self._sync_batch)
            self._sync_batch = []

    def _rejected(self, meta, row_group: int, bounds, columns) -> bool:
        if zone_map_rejects(meta, row_group, bounds, columns, 0, self.counters):
            self.stats["pruned_chunks"] = self.counters["chunks_skipped"]
            return True
        return False

    # ---------------------------------------------------------------- vertices

    def prefetch_vertices(
        self, frontier: VSet, columns: Sequence[str], bounds=None, topo=None
    ) -> int:
        """Prefetch vertex column chunks overlapping the frontier envelope.

        ``topo`` pins the file registry to read from — the primitives pass
        their snapshot-pinned epoch here so prefetch and the read path
        resolve the exact same file set (core/epochs.py)."""
        if not columns or frontier.size() == 0:
            return 0
        topo = topo if topo is not None else self.topology
        lo, hi = frontier.min_max()
        issued = 0
        vt = topo.vertex_info[frontier.vertex_type]
        for finfo in vt.files:
            meta = topo.vertex_file_metas[finfo.key]
            for g in meta.row_groups:
                g_lo = finfo.dense_offset + g.first_row
                g_hi = g_lo + g.n_rows - 1
                if g_hi < lo or g_lo > hi:
                    continue
                if self._rejected(meta, g.index, bounds, columns):
                    continue
                for col in columns:
                    self._issue(ChunkRef(finfo.key, col, g.index), meta, "vertex")
                    issued += 1
        self._flush_sync()
        self.stats["vertex_chunks"] += issued
        return issued

    # ------------------------------------------------------------------- edges

    def prefetch_edges(
        self,
        frontier: VSet,
        edge_type: str,
        columns: Sequence[str],
        direction: str = "out",
        bounds=None,
        topo=None,
    ) -> int:
        """Prefetch edge-attribute chunks for portions the frontier can hit."""
        if not columns or frontier.size() == 0:
            return 0
        topo = topo if topo is not None else self.topology
        lo, hi = frontier.min_max()
        issued = 0
        for el in topo.all_edge_lists(edge_type):
            meta = topo.edge_file_metas[el.file_key]
            live = el.portions_overlapping(lo, hi, direction=direction)
            self.stats["pruned_portions"] += len(el.portions) - len(live)
            for p in live:
                if self._rejected(meta, p.row_group, bounds, columns):
                    continue
                for col in columns:
                    self._issue(ChunkRef(el.file_key, col, p.row_group), meta, "edge")
                    issued += 1
        self._flush_sync()
        self.stats["edge_chunks"] += issued
        return issued
