"""Two-tier cache with priority sweep-clock replacement (paper §5.2).

Memory tier holds live cache units; the disk tier holds (a) raw encoded
chunks and (b) decoded vertex value arrays flushed on eviction.  Eviction
policy is the paper's priority-aware sweep clock (PostgreSQL-style):

- on access, a unit's usage count resets to its priority (vertex 3, edge 1),
- the clock hand decrements counts and evicts the first unpinned unit at 0,
- evicted **edge** units are discarded (raw chunk persists on disk),
- evicted **vertex** units flush their decoded arrays to the disk tier so a
  later re-admission skips re-decoding,
- disk-tier entries are deleted outright when the disk budget is exceeded
  (never written back to the data lake — §5.2).
"""

from __future__ import annotations

import dataclasses
import os
import pickle
import threading
from typing import Optional

import numpy as np

from repro.core.cache.units import ChunkRef, EdgeCacheUnit, NaiveChunkReader, VertexCacheUnit
from repro.lakehouse.columnfile import ColumnFileMeta
from repro.lakehouse.objectstore import ObjectStore


@dataclasses.dataclass
class CacheConfig:
    memory_budget_bytes: int = 256 * 1024 * 1024
    disk_budget_bytes: int = 2 * 1024 * 1024 * 1024
    disk_dir: Optional[str] = None          # None -> memory-backed "disk" dict
    edge_window: int = 4096
    naive_mode: bool = False                # Fig. 16 baseline: no decoded caching


class CacheManager:
    def __init__(self, store: ObjectStore, config: Optional[CacheConfig] = None):
        self.store = store
        self.config = config or CacheConfig()
        self._units: dict[str, object] = {}       # cache key -> unit (memory tier)
        self._clock_keys: list[str] = []           # circular buffer of keys
        self._clock_counts: dict[str, int] = {}
        self._hand = 0
        self._mem_bytes = 0
        self._lock = threading.RLock()
        # disk tier: raw chunks and spilled decoded arrays
        self._disk_raw: dict[str, bytes] = {}
        self._disk_decoded: dict[str, tuple[np.ndarray, int]] = {}
        self._disk_bytes = 0
        self._disk_order: list[str] = []
        if self.config.disk_dir:
            os.makedirs(self.config.disk_dir, exist_ok=True)
        self.stats = {
            "hits": 0, "misses": 0, "evictions": 0,
            "vertex_flushes": 0, "disk_hits": 0, "lake_fetches": 0,
        }

    # ------------------------------------------------------------------ fetch

    def get_unit(
        self,
        ref: ChunkRef,
        meta: ColumnFileMeta,
        kind: str,
        pin: bool = False,
    ):
        """Return the cache unit for a chunk, admitting it if necessary."""
        key = ref.cache_key()
        with self._lock:
            unit = self._units.get(key)
            if unit is not None:
                self.stats["hits"] += 1
                self._clock_counts[key] = unit.priority
                if pin:
                    unit.pinned += 1
                return unit
            self.stats["misses"] += 1
            raw = self._load_raw(ref, meta)
            chunk_meta = meta.chunk(ref.column, ref.row_group)
            if self.config.naive_mode:
                unit = NaiveChunkReader(ref, raw, chunk_meta.n_rows)
            elif kind == "vertex":
                unit = VertexCacheUnit(ref, raw, chunk_meta.n_rows)
                spilled = self._disk_decoded.pop(key, None)
                if spilled is not None:
                    values, upto, nbytes = spilled
                    unit.import_decoded(values, upto)
                    # reclaim the disk-tier budget the spilled entry held;
                    # leaving the bytes/order entry behind makes _disk_bytes
                    # drift upward across evict/re-admit cycles and triggers
                    # premature trims
                    self._disk_bytes -= nbytes
                    try:
                        self._disk_order.remove("D:" + key)
                    except ValueError:
                        pass
                    self.stats["disk_hits"] += 1
            else:
                unit = EdgeCacheUnit(ref, raw, chunk_meta.n_rows, window=self.config.edge_window)
            self._admit(key, unit)
            if pin:
                unit.pinned += 1
            return unit

    def unpin(self, unit) -> None:
        with self._lock:
            unit.pinned = max(0, unit.pinned - 1)

    def _load_raw(self, ref: ChunkRef, meta: ColumnFileMeta) -> bytes:
        key = ref.cache_key()
        raw = self._disk_raw.get(key)
        if raw is not None:
            self.stats["disk_hits"] += 1
            return raw
        chunk = meta.chunk(ref.column, ref.row_group)
        raw = self.store.get(meta.key, offset=chunk.offset, length=chunk.length)
        self.stats["lake_fetches"] += 1
        self._disk_put_raw(key, raw)
        return raw

    # ----------------------------------------------------------------- memory tier

    def _admit(self, key: str, unit) -> None:
        self._units[key] = unit
        self._clock_keys.append(key)
        self._clock_counts[key] = unit.priority
        self._mem_bytes += unit.nbytes()
        self._maybe_evict()

    def _maybe_evict(self) -> None:
        # refresh byte accounting lazily: decoded arrays grow after admission
        budget = self.config.memory_budget_bytes
        if self.mem_bytes() <= budget:
            return
        sweeps = 0
        max_sweeps = 8 * max(1, len(self._clock_keys))
        while self.mem_bytes() > budget and self._clock_keys and sweeps < max_sweeps:
            sweeps += 1
            self._hand %= len(self._clock_keys)
            key = self._clock_keys[self._hand]
            unit = self._units[key]
            count = self._clock_counts.get(key, 0)
            if unit.pinned > 0:
                self._hand += 1
                continue
            if count > 0:
                self._clock_counts[key] = count - 1
                self._hand += 1
                continue
            self._evict(key)
            # hand stays: list shrank at this position

    def _evict(self, key: str) -> None:
        unit = self._units.pop(key)
        self._clock_keys.remove(key)
        self._clock_counts.pop(key, None)
        self.stats["evictions"] += 1
        if unit.kind == "vertex":
            values, upto = unit.export_decoded()
            if values is not None and upto > 0:
                self._disk_put_decoded(key, values, upto)
                self.stats["vertex_flushes"] += 1
        # edge units: discard (raw chunk already lives on the disk tier)

    def mem_bytes(self) -> int:
        return sum(u.nbytes() for u in self._units.values())

    # ----------------------------------------------------------------- disk tier

    def _disk_put_raw(self, key: str, raw: bytes) -> None:
        if key in self._disk_raw:
            return
        self._disk_raw[key] = raw
        self._disk_bytes += len(raw)
        self._disk_order.append(key)
        self._disk_trim()

    def _disk_put_decoded(self, key: str, values: np.ndarray, upto: int) -> None:
        old = self._disk_decoded.pop(key, None)
        if old is not None:
            # duplicate admission (evict raced with a stale entry): replace
            # the entry instead of double counting its bytes
            self._disk_bytes -= old[2]
            try:
                self._disk_order.remove("D:" + key)
            except ValueError:
                pass
        nbytes = values.nbytes if values.dtype != object else len(pickle.dumps(values[:upto]))
        self._disk_decoded[key] = (values, upto, nbytes)
        self._disk_bytes += nbytes
        self._disk_order.append("D:" + key)
        self._disk_trim()

    def _disk_trim(self) -> None:
        while self._disk_bytes > self.config.disk_budget_bytes and self._disk_order:
            victim = self._disk_order.pop(0)
            if victim.startswith("D:"):
                entry = self._disk_decoded.pop(victim[2:], None)
                if entry is not None:
                    self._disk_bytes -= entry[2]
            else:
                raw = self._disk_raw.pop(victim, b"")
                self._disk_bytes -= len(raw)

    # ----------------------------------------------------------------- misc

    def drop_memory(self) -> None:
        """Simulate a cold restart: clear the memory tier, keep disk tier."""
        with self._lock:
            self._units.clear()
            self._clock_keys.clear()
            self._clock_counts.clear()
            self._hand = 0
            self._mem_bytes = 0

    def drop_all(self) -> None:
        with self._lock:
            self.drop_memory()
            self._disk_raw.clear()
            self._disk_decoded.clear()
            self._disk_bytes = 0
            self._disk_order.clear()

    def resident_keys(self) -> list[str]:
        return list(self._units.keys())
