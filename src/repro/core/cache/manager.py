"""Two-tier cache with priority sweep-clock replacement (paper §5.2).

Memory tier holds live cache units; the disk tier holds (a) raw encoded
chunks and (b) decoded vertex value arrays flushed on eviction.  Eviction
policy is the paper's priority-aware sweep clock (PostgreSQL-style):

- on access, a unit's usage count resets to its priority (vertex 3, edge 1),
- the clock hand decrements counts and evicts the first unpinned unit at 0,
- evicted **edge** units are discarded (raw chunk persists on disk),
- evicted **vertex** units flush their decoded arrays to the disk tier so a
  later re-admission skips re-decoding,
- disk-tier entries are deleted outright when the disk budget is exceeded
  (never written back to the data lake — §5.2).

**Concurrency (DESIGN.md §5).**  The manager is the shared hot path of the
pipelined read pipeline and of concurrent serving queries, so its internals
are built for parallel callers:

- the hit path is O(1) under one short critical section (dict probe + clock
  count reset);
- chunk loading is **single-flight**: a miss registers a per-key loading
  event and performs the lake fetch *outside* the global lock, concurrent
  requests for the same chunk wait on the event instead of fetching again —
  the structural "never fetch the same chunk twice" guarantee the per-gather
  dedup in ``core/read_pipeline.py`` builds on;
- byte accounting is **incremental**: admission charges ``unit.nbytes()``
  once, decoded growth is reported as deltas through :meth:`note_growth`
  (units track their ``accounted_nbytes`` watermark), and the eviction sweep
  consults the O(1) ``_mem_bytes`` counter instead of re-summing every unit
  per iteration (the old sweep was O(n²));
- the clock ring and the disk-tier order are ordered dicts (rotate =
  ``popitem(last=False)`` + reinsert; arbitrary removal = ``del``) — no
  ``list.remove`` O(n) scans;
- decode happens under **per-unit locks**, never under the global lock.
  Deadlock-freedom argument: a unit-lock holder *may* block on the global
  lock (``on_growth`` fires mid-decode and ``note_growth`` takes it), but a
  global-lock holder never blocks on a unit lock — the eviction sweep's
  unit-lock probe is strictly non-blocking (``acquire(blocking=False)``,
  skipping units mid-decode).  Blocking edges therefore only ever point
  unit-lock → global-lock; a one-directional blocking order cannot cycle.
  Never add a blocking ``unit.lock.acquire()`` anywhere the global lock is
  held — that creates the cycle this design rules out.
"""

from __future__ import annotations

import dataclasses
import os
import pickle
import threading
from collections import OrderedDict
from typing import Optional, Sequence

import numpy as np

from repro.core.cache.units import ChunkRef, EdgeCacheUnit, NaiveChunkReader, VertexCacheUnit
from repro.lakehouse.columnfile import ColumnFileMeta
from repro.lakehouse.objectstore import ObjectStore
from repro.lakehouse.retry import lake_get


@dataclasses.dataclass
class CacheConfig:
    memory_budget_bytes: int = 256 * 1024 * 1024
    disk_budget_bytes: int = 2 * 1024 * 1024 * 1024
    disk_dir: Optional[str] = None          # None -> memory-backed "disk" dict
    edge_window: int = 4096
    naive_mode: bool = False                # Fig. 16 baseline: no decoded caching


class CacheManager:
    def __init__(self, store: ObjectStore, config: Optional[CacheConfig] = None):
        self.store = store
        self.config = config or CacheConfig()
        self._units: dict[str, object] = {}       # cache key -> unit (memory tier)
        # clock ring: key -> usage count, rotated FIFO (second-chance clock)
        self._clock: OrderedDict[str, int] = OrderedDict()
        self._mem_bytes = 0
        self._lock = threading.RLock()
        self._loading: dict[str, threading.Event] = {}  # single-flight admissions
        # disk tier: raw chunks and spilled decoded arrays
        self._disk_raw: dict[str, bytes] = {}
        self._disk_decoded: dict[str, tuple[np.ndarray, int, int]] = {}
        self._disk_bytes = 0
        self._disk_order: OrderedDict[str, None] = OrderedDict()
        if self.config.disk_dir:
            os.makedirs(self.config.disk_dir, exist_ok=True)
        self.stats = {
            "hits": 0, "misses": 0, "evictions": 0,
            "vertex_flushes": 0, "disk_hits": 0, "lake_fetches": 0,
            "load_waits": 0, "sweep_steps": 0, "invalidated_units": 0,
        }

    # ------------------------------------------------------------------ fetch

    def get_unit(
        self,
        ref: ChunkRef,
        meta: ColumnFileMeta,
        kind: str,
        pin: bool = False,
    ):
        """Return the cache unit for a chunk, admitting it if necessary.

        Hits resolve in one O(1) critical section.  Misses are single-flight:
        the winning thread fetches and decodes-restores *outside* the global
        lock while racing threads wait on the per-key loading event — the
        modeled ~30 ms lake latency is never paid under the lock and never
        paid twice for one chunk.
        """
        key = ref.cache_key()
        while True:
            with self._lock:
                unit = self._units.get(key)
                if unit is not None:
                    self.stats["hits"] += 1
                    self._clock[key] = unit.priority
                    if pin:
                        unit.pinned += 1
                    return unit
                event = self._loading.get(key)
                if event is None:
                    event = threading.Event()
                    self._loading[key] = event
                    self.stats["misses"] += 1
                    break
                self.stats["load_waits"] += 1
            event.wait()  # another thread is admitting this chunk

        try:
            raw = self._load_raw(ref, meta)
            chunk_meta = meta.chunk(ref.column, ref.row_group)
            if self.config.naive_mode:
                unit = NaiveChunkReader(ref, raw, chunk_meta.n_rows)
            elif kind == "vertex":
                unit = VertexCacheUnit(ref, raw, chunk_meta.n_rows)
                with self._lock:
                    spilled = self._disk_decoded.pop(key, None)
                    if spilled is not None:
                        values, upto, nbytes = spilled
                        # reclaim the disk-tier budget the spilled entry held;
                        # leaving the bytes/order entry behind makes
                        # _disk_bytes drift upward across evict/re-admit
                        # cycles and triggers premature trims
                        self._disk_bytes -= nbytes
                        self._disk_order.pop("D:" + key, None)
                        self.stats["disk_hits"] += 1
                if spilled is not None:
                    unit.import_decoded(values, upto)
            else:
                unit = EdgeCacheUnit(ref, raw, chunk_meta.n_rows,
                                     window=self.config.edge_window)
            with self._lock:
                self._admit(key, unit)
                if pin:
                    unit.pinned += 1
            return unit
        finally:
            with self._lock:
                self._loading.pop(key, None)
            event.set()

    def get_units_batch(
        self,
        requests: Sequence[tuple[ChunkRef, ColumnFileMeta, str]],
        pool=None,
    ) -> dict[str, object]:
        """Admit a batch of chunks, in parallel when a pool is given.

        Returns ``cache key -> unit`` with duplicate refs deduplicated —
        the synchronous bulk-admission entry (poolless prefetching, warm-up
        loads, tests).  The read pipeline's executor streams per-chunk jobs
        instead, to overlap each chunk's decode with later fetches; both
        paths meet in single-flight ``get_unit`` admission, so batches
        racing the pipeline (or each other) still fetch each chunk once.
        Call it from a caller thread, not from a pool worker — with
        ``pool`` given it blocks on futures of that same bounded pool.
        """
        dedup: dict[str, tuple[ChunkRef, ColumnFileMeta, str]] = {}
        for ref, meta, kind in requests:
            dedup.setdefault(ref.cache_key(), (ref, meta, kind))
        if pool is None:
            return {k: self.get_unit(*req) for k, req in dedup.items()}
        futures = {k: pool.submit(self.get_unit, *req) for k, req in dedup.items()}
        return {k: f.result() for k, f in futures.items()}

    def read_unit(self, unit, rows: np.ndarray) -> tuple[np.ndarray, int]:
        """Decode-safe read: per-unit lock around ``read``.  Growth is
        accounted by the unit's ``on_growth`` callback the moment the decode
        happens.  Returns ``(values, decode_ops delta)``."""
        with unit.lock:
            before = unit.decode_ops
            vals = unit.read(rows)
            delta = unit.decode_ops - before
        return vals, delta

    def unpin(self, unit) -> None:
        with self._lock:
            unit.pinned = max(0, unit.pinned - 1)

    def _load_raw(self, ref: ChunkRef, meta: ColumnFileMeta) -> bytes:
        key = ref.cache_key()
        with self._lock:
            raw = self._disk_raw.get(key)
            if raw is not None:
                self.stats["disk_hits"] += 1
                return raw
        chunk = meta.chunk(ref.column, ref.row_group)
        # lake_get retries transient faults and rejects short (torn) reads
        # against the chunk length, so truncated bytes never enter the cache
        raw = lake_get(self.store, meta.key,
                       offset=chunk.offset, length=chunk.length)
        with self._lock:
            self.stats["lake_fetches"] += 1
            self._disk_put_raw(key, raw)
        return raw

    # ----------------------------------------------------------------- memory tier

    def _admit(self, key: str, unit) -> None:
        # caller holds self._lock
        unit.accounted_nbytes = unit.nbytes()
        unit.on_growth = self.note_growth
        self._units[key] = unit
        # new admissions enter at the ring's front — the next sweep position —
        # so a fresh low-priority unit is inspected before long-resident ones
        # whose counts earlier sweeps already ground down (hand continuation,
        # same placement the list-based clock converged to)
        self._clock[key] = unit.priority
        self._clock.move_to_end(key, last=False)
        self._mem_bytes += unit.accounted_nbytes
        self._maybe_evict()

    def note_growth(self, unit) -> None:
        """Charge a unit's decoded-state growth against the memory budget.

        Units report growth as deltas against their ``accounted_nbytes``
        watermark — the sweep never re-sums live units.  Growth on a unit
        that was already evicted (its holder keeps reading the object) is
        not charged: it left the tier with its watermark's worth of bytes.
        """
        with self._lock:
            nbytes = unit.nbytes()
            delta = nbytes - unit.accounted_nbytes
            if delta == 0:
                return
            unit.accounted_nbytes = nbytes
            if self._units.get(unit.ref.cache_key()) is unit:
                self._mem_bytes += delta
                self._maybe_evict()

    def _maybe_evict(self) -> None:
        # caller holds self._lock; _mem_bytes is maintained incrementally so
        # each sweep step is O(1) — no per-iteration re-sum of unit sizes
        budget = self.config.memory_budget_bytes
        if self._mem_bytes <= budget:
            return
        sweeps = 0
        max_sweeps = 8 * max(1, len(self._clock))
        while self._mem_bytes > budget and self._clock and sweeps < max_sweeps:
            sweeps += 1
            self.stats["sweep_steps"] += 1
            key, count = self._clock.popitem(last=False)
            unit = self._units[key]
            if unit.pinned > 0:
                self._clock[key] = count        # second chance, hand advances
                continue
            if count > 0:
                self._clock[key] = count - 1
                continue
            if not unit.lock.acquire(blocking=False):
                self._clock[key] = count        # mid-decode: skip this round
                continue
            try:
                self._evict(key, unit)
            finally:
                unit.lock.release()

    def _evict(self, key: str, unit) -> None:
        # caller holds self._lock and unit.lock (clock entry already popped)
        self._units.pop(key)
        self._mem_bytes -= unit.accounted_nbytes
        self.stats["evictions"] += 1
        if unit.kind == "vertex":
            values, upto = unit.export_decoded()
            if values is not None and upto > 0:
                self._disk_put_decoded(key, values, upto)
                self.stats["vertex_flushes"] += 1
        # edge units: discard (raw chunk already lives on the disk tier)

    def mem_bytes(self) -> int:
        """Accounted memory-tier bytes — O(1), maintained incrementally."""
        return self._mem_bytes

    def mem_bytes_recomputed(self) -> int:
        """Ground truth: re-sum every live unit (tests assert it matches the
        incremental counter after concurrent storms)."""
        with self._lock:
            return sum(u.nbytes() for u in self._units.values())

    # ----------------------------------------------------------------- disk tier

    def _disk_put_raw(self, key: str, raw: bytes) -> None:
        if key in self._disk_raw:
            return
        self._disk_raw[key] = raw
        self._disk_bytes += len(raw)
        self._disk_order[key] = None
        self._disk_trim()

    def _disk_put_decoded(self, key: str, values: np.ndarray, upto: int) -> None:
        old = self._disk_decoded.pop(key, None)
        if old is not None:
            # duplicate admission (evict raced with a stale entry): replace
            # the entry instead of double counting its bytes
            self._disk_bytes -= old[2]
            self._disk_order.pop("D:" + key, None)
        nbytes = values.nbytes if values.dtype != object else len(pickle.dumps(values[:upto]))
        self._disk_decoded[key] = (values, upto, nbytes)
        self._disk_bytes += nbytes
        self._disk_order["D:" + key] = None
        self._disk_trim()

    def _disk_trim(self) -> None:
        while self._disk_bytes > self.config.disk_budget_bytes and self._disk_order:
            victim, _ = self._disk_order.popitem(last=False)
            if victim.startswith("D:"):
                entry = self._disk_decoded.pop(victim[2:], None)
                if entry is not None:
                    self._disk_bytes -= entry[2]
            else:
                raw = self._disk_raw.pop(victim, b"")
                self._disk_bytes -= len(raw)

    # ------------------------------------------------------- file invalidation

    def invalidate_file(self, file_key: str) -> int:
        """Evict exactly the ``(file, row-group)`` units of one data file —
        every tier: memory units, disk raw chunks, disk decoded spills.

        The epoch manager calls this when a lake commit removes or replaces
        a data file (DESIGN.md §7): nothing else is touched, so the rest of
        the working set stays warm.  Cache keys are
        ``"{file_key}::{column}::{row_group}"``, so prefix matching is
        exact per file.  Readers still holding an affected unit object keep
        a valid self-contained handle (units own their raw bytes), and old
        epochs re-reading a logically deleted file fall through to the lake,
        where the immutable physical object still exists.  Returns the
        number of memory-tier units evicted.
        """
        prefix = file_key + "::"
        n = 0
        with self._lock:
            for key in [k for k in self._units if k.startswith(prefix)]:
                unit = self._units.pop(key)
                self._clock.pop(key, None)
                self._mem_bytes -= unit.accounted_nbytes
                n += 1
            for key in [k for k in self._disk_raw if k.startswith(prefix)]:
                raw = self._disk_raw.pop(key)
                self._disk_bytes -= len(raw)
                self._disk_order.pop(key, None)
            for key in [k for k in self._disk_decoded if k.startswith(prefix)]:
                entry = self._disk_decoded.pop(key)
                self._disk_bytes -= entry[2]
                self._disk_order.pop("D:" + key, None)
            self.stats["invalidated_units"] += n
        return n

    # ----------------------------------------------------------------- misc

    def drop_memory(self) -> None:
        """Simulate a cold restart: clear the memory tier, keep disk tier."""
        with self._lock:
            self._units.clear()
            self._clock.clear()
            self._mem_bytes = 0

    def drop_all(self) -> None:
        with self._lock:
            self.drop_memory()
            self._disk_raw.clear()
            self._disk_decoded.clear()
            self._disk_bytes = 0
            self._disk_order.clear()

    def resident_keys(self) -> list[str]:
        with self._lock:
            return list(self._units.keys())
