from repro.core.cache.units import VertexCacheUnit, EdgeCacheUnit, ChunkRef
from repro.core.cache.manager import CacheManager, CacheConfig
from repro.core.cache.prefetch import Prefetcher

__all__ = [
    "VertexCacheUnit",
    "EdgeCacheUnit",
    "ChunkRef",
    "CacheManager",
    "CacheConfig",
    "Prefetcher",
]
