"""Graph-aware cache units (paper §5.1).

Both units wrap one encoded column chunk and expose *value readers* that
retrieve attribute values by row index.  They differ in decode strategy,
matching the paper exactly:

- ``VertexCacheUnit`` — irregular (random) access pattern.  A decoded value
  array is pre-allocated for the whole chunk and populated **as a contiguous
  prefix**: a request for row 300 when only 100 rows are decoded extends the
  prefix through row 300.  Point lookups after that are plain array indexing.
  The invariant "decoded entries form a contiguous prefix" keeps status
  management a single integer (``_decoded_upto``) — the paper's rationale.

- ``EdgeCacheUnit`` — scan-oriented access with row-level evaluation for
  cross-entity predicates.  A sliding window buffer decodes values in batches
  around the requested index; re-requests inside the window are free; a
  request past the window advances it.  No full decoded array is kept because
  edges are too numerous (paper §7.6.2 shows the decoded-array design is not
  worth it for edges).

Decode-cost accounting (``decode_ops``) lets benchmarks reproduce Fig. 16
(graph-aware units vs naive re-decoding).

**Concurrency contract (DESIGN.md §5).**  Every unit carries its own
``lock``; callers that may run concurrently (the pipelined read path, the
prefetcher's I/O threads, concurrent serving queries) hold it around
``read``/``read_all`` so decode state mutates under exactly one thread.
A unit-lock holder may block on the manager's global lock (``on_growth``
fires mid-decode), but the manager never *blocks* on a unit lock while
holding its global lock — its eviction probe is non-blocking — so blocking
edges only point unit-lock → global-lock and cannot cycle.
``accounted_nbytes`` is the manager's
incremental byte-accounting watermark: the last ``nbytes()`` the manager has
charged against its memory budget.  Units report decoded-growth deltas
upward through the ``on_growth`` callback (installed at admission, wired to
``CacheManager.note_growth``) the moment their decoded state changes size —
the manager never re-sums live units to learn their footprint.
"""

from __future__ import annotations

import dataclasses
import threading

import numpy as np

from repro.lakehouse.encoding import decode_column


@dataclasses.dataclass(frozen=True)
class ChunkRef:
    """Identity of one column chunk: (table file, column, row group)."""

    file_key: str
    column: str
    row_group: int

    def cache_key(self) -> str:
        return f"{self.file_key}::{self.column}::{self.row_group}"


class VertexCacheUnit:
    """Decoded value array with a contiguous decoded prefix."""

    kind = "vertex"
    # sweep-clock priority (paper §5.2): vertex units are favored for retention
    priority = 3

    def __init__(self, ref: ChunkRef, raw_chunk: bytes, n_rows: int):
        self.ref = ref
        self._raw = raw_chunk
        self.n_rows = n_rows
        self._values: np.ndarray | None = None  # allocated lazily on first touch
        self._decoded_upto = 0
        self.decode_ops = 0
        self.pinned = 0
        self.lock = threading.Lock()
        self.accounted_nbytes = 0
        self.on_growth = None

    # -- decoded-state management ------------------------------------------------

    def _ensure_prefix(self, upto: int) -> None:
        """Extend the contiguous decoded prefix through row ``upto`` (exclusive)."""
        upto = min(int(upto), self.n_rows)
        if upto <= self._decoded_upto:
            return
        # the substrate decoder decodes prefixes natively (see encoding.py), so
        # extending the prefix costs only the *new* rows' decode work but one
        # pass over the stream; we count decoded rows as the work unit.
        decoded = decode_column(self._raw, row_limit=upto)
        if self._values is None:
            # pre-allocate full capacity once: avoids resize/copy churn (§5.1)
            if decoded.dtype == object:
                self._values = np.empty(self.n_rows, dtype=object)
            else:
                self._values = np.empty(self.n_rows, dtype=decoded.dtype)
        self._values[self._decoded_upto: upto] = decoded[self._decoded_upto: upto]
        self.decode_ops += upto - self._decoded_upto
        self._decoded_upto = upto
        if self.on_growth is not None:
            self.on_growth(self)

    @property
    def decoded_prefix(self) -> int:
        return self._decoded_upto

    # -- value reader -------------------------------------------------------------

    def read(self, row_indices: np.ndarray) -> np.ndarray:
        """Point lookups by row index (vectorized)."""
        rows = np.asarray(row_indices, dtype=np.int64)
        if len(rows) == 0:
            dtype = self._values.dtype if self._values is not None else np.float64
            return np.empty(0, dtype=dtype)
        self._ensure_prefix(int(rows.max()) + 1)
        return self._values[rows]

    def read_all(self) -> np.ndarray:
        self._ensure_prefix(self.n_rows)
        return self._values

    # -- spill / restore (two-tier cache, §5.2) -----------------------------------

    def export_decoded(self) -> tuple[np.ndarray | None, int]:
        """Decoded state to flush to disk on eviction (vertex units only)."""
        return self._values, self._decoded_upto

    def import_decoded(self, values: np.ndarray, upto: int) -> None:
        self._values = values
        self._decoded_upto = upto

    def nbytes(self) -> int:
        n = len(self._raw)
        if self._values is not None and self._values.dtype != object:
            n += self._values.nbytes
        elif self._values is not None:
            n += sum(len(str(v)) for v in self._values[: self._decoded_upto])
        return n


class EdgeCacheUnit:
    """Sliding-window batch decoder for scan-oriented edge attributes."""

    kind = "edge"
    priority = 1

    def __init__(self, ref: ChunkRef, raw_chunk: bytes, n_rows: int, window: int = 4096):
        self.ref = ref
        self._raw = raw_chunk
        self.n_rows = n_rows
        self.window = window
        self._buf: np.ndarray | None = None
        self._buf_start = 0
        self.decode_ops = 0
        self.pinned = 0
        self.lock = threading.Lock()
        self.accounted_nbytes = 0
        self.on_growth = None

    def _advance(self, start: int, stop: int) -> None:
        stop = min(max(stop, start + self.window), self.n_rows)
        # the encoded stream decodes prefixes; a window [start, stop) costs a
        # prefix decode to `stop` (streams are not backward-seekable), but we
        # only *retain* the window — bounded memory, amortized batch decode.
        decoded = decode_column(self._raw, row_limit=stop)
        self._buf = decoded[start:stop]
        self._buf_start = start
        self.decode_ops += stop - start
        if self.on_growth is not None:
            self.on_growth(self)

    def read(self, row_indices: np.ndarray) -> np.ndarray:
        """Batch row-level reads; indices are typically ascending during scans."""
        rows = np.asarray(row_indices, dtype=np.int64)
        if len(rows) == 0:
            dtype = self._buf.dtype if self._buf is not None else np.float64
            return np.empty(0, dtype=dtype)
        lo, hi = int(rows.min()), int(rows.max())
        if self._buf is None or lo < self._buf_start or hi >= self._buf_start + len(self._buf):
            # widen to cover the whole batch (scans hand us ascending batches)
            self._advance(lo, hi + 1)
        return self._buf[rows - self._buf_start]

    def read_all(self) -> np.ndarray:
        self._advance(0, self.n_rows)
        return self._buf

    def nbytes(self) -> int:
        n = len(self._raw)
        if self._buf is not None and self._buf.dtype != object:
            n += self._buf.nbytes
        return n


class NaiveChunkReader:
    """Baseline for Fig. 16: re-decodes the chunk on every batch request."""

    kind = "naive"
    priority = 1

    def __init__(self, ref: ChunkRef, raw_chunk: bytes, n_rows: int):
        self.ref = ref
        self._raw = raw_chunk
        self.n_rows = n_rows
        self.decode_ops = 0
        self.pinned = 0
        self.lock = threading.Lock()
        self.accounted_nbytes = 0
        self.on_growth = None  # naive readers retain nothing: never fires

    def read(self, row_indices: np.ndarray) -> np.ndarray:
        rows = np.asarray(row_indices, dtype=np.int64)
        if len(rows) == 0:
            return np.empty(0, dtype=np.float64)
        decoded = decode_column(self._raw, row_limit=int(rows.max()) + 1)
        self.decode_ops += int(rows.max()) + 1
        return decoded[rows]

    def read_all(self) -> np.ndarray:
        self.decode_ops += self.n_rows
        return decode_column(self._raw)

    def nbytes(self) -> int:
        return len(self._raw)
