"""Graph algorithms over the engine (paper Table 2: PR, WCC, CDLP, LCC, BFS).

All five run on the *topology only* (no property access) in the edge-centric
style: a contiguous (src, dst) edge array is scanned per superstep and
per-vertex state is combined with segment reductions.  The numeric inner
loops are jitted JAX (dispatching to the Pallas ``edge_scan`` kernel path on
TPU via ``repro.kernels.ops``); convergence control stays in Python exactly
like GSQL's WHILE drives supersteps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops


# ---------------------------------------------------------------------------
# PageRank
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("n",))
def _pagerank_step(rank, src, dst, out_deg, n: int, damping: float):
    contrib = rank[src] / jnp.maximum(out_deg[src], 1.0)
    agg = kops.segment_sum(contrib, dst, n)
    # dangling mass (vertices with no out-edges) redistributes uniformly
    dangling = jnp.where(out_deg > 0, 0.0, rank).sum()
    return (1.0 - damping) / n + damping * (agg + dangling / n)


def pagerank(engine, edge_type: str, n: int | None = None, damping: float = 0.85,
             max_iters: int = 20, tol: float = 1e-7) -> np.ndarray:
    src, dst = engine.concat_edges(edge_type)
    et = engine.schema.edge_types[edge_type]
    n = n or engine.topology.n_vertices(et.src_type)
    src_j = jnp.asarray(src, dtype=jnp.int32)
    dst_j = jnp.asarray(dst, dtype=jnp.int32)
    out_deg = kops.segment_sum(jnp.ones_like(src_j, dtype=jnp.float32), src_j, n)
    rank = jnp.full(n, 1.0 / n, dtype=jnp.float32)
    for _ in range(max_iters):
        new = _pagerank_step(rank, src_j, dst_j, out_deg, n, damping)
        if float(jnp.abs(new - rank).sum()) < tol:
            rank = new
            break
        rank = new
    return np.asarray(rank)


# ---------------------------------------------------------------------------
# Weakly Connected Components (label propagation to minimum)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("n",))
def _wcc_step(labels, src, dst, n: int):
    fwd = kops.segment_min(labels[src], dst, n)
    bwd = kops.segment_min(labels[dst], src, n)
    return jnp.minimum(labels, jnp.minimum(fwd, bwd))


def wcc(engine, edge_type: str, n: int | None = None, max_iters: int = 200) -> np.ndarray:
    src, dst = engine.concat_edges(edge_type)
    et = engine.schema.edge_types[edge_type]
    n = n or engine.topology.n_vertices(et.src_type)
    src_j = jnp.asarray(src, dtype=jnp.int32)
    dst_j = jnp.asarray(dst, dtype=jnp.int32)
    labels = jnp.arange(n, dtype=jnp.int32)
    for _ in range(max_iters):
        new = _wcc_step(labels, src_j, dst_j, n)
        if bool(jnp.array_equal(new, labels)):
            break
        labels = new
    return np.asarray(labels)


# ---------------------------------------------------------------------------
# Community Detection via Label Propagation (CDLP)
# ---------------------------------------------------------------------------

def cdlp(engine, edge_type: str, n: int | None = None, iterations: int = 10) -> np.ndarray:
    """Synchronous LPA, Graphalytics semantics: each vertex adopts the most
    frequent neighbor label; ties break to the smallest label.

    Mode-per-vertex is a sort-and-count host-side pass (argmax over ragged
    groups); the scan itself stays edge-centric.
    """
    src, dst = engine.concat_edges(edge_type)
    et = engine.schema.edge_types[edge_type]
    n = n or engine.topology.n_vertices(et.src_type)
    # undirected neighborhood: both edge directions contribute
    nbr_dst = np.concatenate([dst, src])
    nbr_src = np.concatenate([src, dst])
    labels = np.arange(n, dtype=np.int64)
    for _ in range(iterations):
        lab = labels[nbr_src]
        order = np.lexsort((lab, nbr_dst))
        v_sorted = nbr_dst[order]
        l_sorted = lab[order]
        # run-length encode (vertex, label) pairs
        boundary = np.empty(len(v_sorted), dtype=bool)
        if len(v_sorted):
            boundary[0] = True
            boundary[1:] = (v_sorted[1:] != v_sorted[:-1]) | (l_sorted[1:] != l_sorted[:-1])
        starts = np.flatnonzero(boundary)
        counts = np.diff(np.append(starts, len(v_sorted)))
        grp_v = v_sorted[starts]
        grp_l = l_sorted[starts]
        # per-vertex argmax count, ties -> smallest label: sort by
        # (vertex, -count, label) and take the first entry per vertex
        sel = np.lexsort((grp_l, -counts, grp_v))
        first = np.flatnonzero(
            np.concatenate(([True], grp_v[sel][1:] != grp_v[sel][:-1]))
        )
        winners_v = grp_v[sel][first]
        winners_l = grp_l[sel][first]
        new = labels.copy()
        new[winners_v] = winners_l
        if np.array_equal(new, labels):
            break
        labels = new
    return labels


# ---------------------------------------------------------------------------
# Local Clustering Coefficient
# ---------------------------------------------------------------------------

def lcc(engine, edge_type: str, n: int | None = None, block: int = 1024) -> np.ndarray:
    """LCC via blocked dense adjacency products (wedge-closure counting).

    Fine for benchmark-scale graphs (n <= ~32k); the Graphalytics semantics
    treat the graph as directed-ignored (undirected), no self-loops.
    """
    src, dst = engine.concat_edges(edge_type)
    et = engine.schema.edge_types[edge_type]
    n = n or engine.topology.n_vertices(et.src_type)
    u = np.concatenate([src, dst])
    v = np.concatenate([dst, src])
    keep = u != v
    u, v = u[keep], v[keep]
    adj = np.zeros((n, n), dtype=np.float32)
    adj[u, v] = 1.0
    adj_j = jnp.asarray(adj)
    tri = np.zeros(n, dtype=np.float64)
    for lo in range(0, n, block):
        hi = min(n, lo + block)
        # triangles through i = sum_j sum_k A[i,j] A[j,k] A[k,i] / 2
        paths2 = adj_j[lo:hi] @ adj_j                      # (b, n) 2-paths
        tri[lo:hi] = np.asarray((paths2 * adj_j[lo:hi]).sum(axis=1), dtype=np.float64) / 2.0
    deg = np.asarray(adj.sum(axis=1), dtype=np.float64)
    wedges = deg * (deg - 1) / 2.0
    with np.errstate(divide="ignore", invalid="ignore"):
        out = np.where(wedges > 0, tri / wedges, 0.0)
    return out


# ---------------------------------------------------------------------------
# BFS
# ---------------------------------------------------------------------------

def bfs(engine, edge_type: str, source_dense: int, n: int | None = None,
        directed: bool = True, max_depth: int = 10_000) -> np.ndarray:
    """Edge-centric frontier BFS; returns int64 depths (-1 = unreached)."""
    src, dst = engine.concat_edges(edge_type)
    et = engine.schema.edge_types[edge_type]
    n = n or engine.topology.n_vertices(et.src_type)
    if not directed:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
    depth = np.full(n, -1, dtype=np.int64)
    depth[source_dense] = 0
    frontier = np.zeros(n, dtype=bool)
    frontier[source_dense] = True
    for level in range(1, max_depth):
        hit = frontier[src]
        if not hit.any():
            break
        cand = dst[hit]
        new = cand[depth[cand] < 0]
        if len(new) == 0:
            break
        depth[new] = level
        frontier = np.zeros(n, dtype=bool)
        frontier[new] = True
    return depth
