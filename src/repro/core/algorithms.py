"""Graph algorithms over the engine (paper Table 2: PR, WCC, CDLP, LCC, BFS).

All five run on the *topology only* (no property access), consuming the
**topology plane** (DESIGN.md §3) directly:

- whole-graph scans (PR, WCC, CDLP, LCC) take the plane's **dst-sorted CSR
  edge order** — segment ids arrive non-decreasing, so the Pallas segment
  kernels see tight per-block ranges and skip every non-overlapping
  (edge-block, output-block) pair;
- PageRank's inner reduction is the CSR offset-range segment sum
  (``kops.csr_segment_sum``), fed by the reverse-CSR index — no per-edge
  destination ids at all.  Its 1-D rank column takes the searchsorted
  reference path; the Pallas offset-range kernel serves the 2-D
  (multi-channel) form of the same op;
- BFS dispatches adaptively per level, exactly like EdgeScan: small
  frontiers expand through CSR adjacency ranges, large frontiers fall back
  to the edge-centric masked scan.

The numeric inner loops are jitted JAX (dispatching to the Pallas kernels on
TPU via ``repro.kernels.ops``); convergence control stays in Python exactly
like GSQL's WHILE drives supersteps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops
from repro.perf_flags import enabled as perf_enabled


def _csr_for(engine, edge_type: str, n: int):
    """The edge type's CSR when the ``csr`` perf flag is on (the baseline
    ``REPRO_OPTS=""`` run must not build or consume CSR at all) and its
    vertex spaces match ``n`` (callers may override ``n`` for truncated
    runs — then fall back to edge arrays)."""
    if not perf_enabled("csr"):
        return None
    et = engine.schema.edge_types[edge_type]
    topo = engine.topology
    # dimension check BEFORE building: a truncated run must not pay the
    # grouping cost of an index it cannot use
    if topo.n_vertices(et.src_type) != n or topo.n_vertices(et.dst_type) != n:
        return None
    return engine.plane.csr(edge_type)


def _edges_dst_sorted(engine, edge_type: str, n: int):
    """(src, dst) in dst-sorted order when CSR dims match, else raw concat."""
    csr = _csr_for(engine, edge_type, n)
    if csr is not None:
        return engine.plane.edges_by_dst(edge_type)
    return engine.concat_edges(edge_type)


# ---------------------------------------------------------------------------
# PageRank
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("n",))
def _pagerank_step_csr(rank, rev_src, rev_indptr, out_deg, n: int, damping: float):
    contrib = rank[rev_src] / jnp.maximum(out_deg[rev_src], 1.0)
    agg = kops.csr_segment_sum(contrib, rev_indptr, n)
    # dangling mass (vertices with no out-edges) redistributes uniformly
    dangling = jnp.where(out_deg > 0, 0.0, rank).sum()
    return (1.0 - damping) / n + damping * (agg + dangling / n)


@functools.partial(jax.jit, static_argnames=("n",))
def _pagerank_step(rank, src, dst, out_deg, n: int, damping: float):
    contrib = rank[src] / jnp.maximum(out_deg[src], 1.0)
    agg = kops.segment_sum(contrib, dst, n)
    dangling = jnp.where(out_deg > 0, 0.0, rank).sum()
    return (1.0 - damping) / n + damping * (agg + dangling / n)


def pagerank(engine, edge_type: str, n: int | None = None, damping: float = 0.85,
             max_iters: int = 20, tol: float = 1e-7) -> np.ndarray:
    et = engine.schema.edge_types[edge_type]
    n = n or engine.topology.n_vertices(et.src_type)
    csr = _csr_for(engine, edge_type, n)
    if csr is not None:
        rev_src = jnp.asarray(csr.rev_src, dtype=jnp.int32)
        rev_indptr = jnp.asarray(csr.rev_indptr, dtype=jnp.int32)
        out_deg = jnp.asarray(csr.degrees("out"), dtype=jnp.float32)
        step = lambda r: _pagerank_step_csr(r, rev_src, rev_indptr, out_deg, n, damping)
    else:
        src, dst = engine.concat_edges(edge_type)
        src_j = jnp.asarray(src, dtype=jnp.int32)
        dst_j = jnp.asarray(dst, dtype=jnp.int32)
        out_deg = kops.segment_sum(jnp.ones_like(src_j, dtype=jnp.float32), src_j, n)
        step = lambda r: _pagerank_step(r, src_j, dst_j, out_deg, n, damping)
    rank = jnp.full(n, 1.0 / n, dtype=jnp.float32)
    for _ in range(max_iters):
        new = step(rank)
        if float(jnp.abs(new - rank).sum()) < tol:
            rank = new
            break
        rank = new
    return np.asarray(rank)


# ---------------------------------------------------------------------------
# Weakly Connected Components (label propagation to minimum)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("n",))
def _wcc_step(labels, src, dst, n: int):
    fwd = kops.segment_min(labels[src], dst, n)
    bwd = kops.segment_min(labels[dst], src, n)
    return jnp.minimum(labels, jnp.minimum(fwd, bwd))


def wcc(engine, edge_type: str, n: int | None = None, max_iters: int = 200) -> np.ndarray:
    et = engine.schema.edge_types[edge_type]
    n = n or engine.topology.n_vertices(et.src_type)
    src, dst = _edges_dst_sorted(engine, edge_type, n)
    src_j = jnp.asarray(src, dtype=jnp.int32)
    dst_j = jnp.asarray(dst, dtype=jnp.int32)
    labels = jnp.arange(n, dtype=jnp.int32)
    for _ in range(max_iters):
        new = _wcc_step(labels, src_j, dst_j, n)
        if bool(jnp.array_equal(new, labels)):
            break
        labels = new
    return np.asarray(labels)


# ---------------------------------------------------------------------------
# Community Detection via Label Propagation (CDLP)
# ---------------------------------------------------------------------------

def cdlp(engine, edge_type: str, n: int | None = None, iterations: int = 10) -> np.ndarray:
    """Synchronous LPA, Graphalytics semantics: each vertex adopts the most
    frequent neighbor label; ties break to the smallest label.

    Mode-per-vertex is a sort-and-count host-side pass (argmax over ragged
    groups).  The neighbor pairs come from the plane's dst-sorted CSR order,
    so each half of the undirected concatenation arrives pre-grouped by
    vertex and the per-iteration lexsort runs on nearly-sorted keys.
    """
    et = engine.schema.edge_types[edge_type]
    n = n or engine.topology.n_vertices(et.src_type)
    src, dst = _edges_dst_sorted(engine, edge_type, n)
    # undirected neighborhood: both edge directions contribute
    nbr_dst = np.concatenate([dst, src])
    nbr_src = np.concatenate([src, dst])
    labels = np.arange(n, dtype=np.int64)
    for _ in range(iterations):
        lab = labels[nbr_src]
        order = np.lexsort((lab, nbr_dst))
        v_sorted = nbr_dst[order]
        l_sorted = lab[order]
        # run-length encode (vertex, label) pairs
        boundary = np.empty(len(v_sorted), dtype=bool)
        if len(v_sorted):
            boundary[0] = True
            boundary[1:] = (v_sorted[1:] != v_sorted[:-1]) | (l_sorted[1:] != l_sorted[:-1])
        starts = np.flatnonzero(boundary)
        counts = np.diff(np.append(starts, len(v_sorted)))
        grp_v = v_sorted[starts]
        grp_l = l_sorted[starts]
        # per-vertex argmax count, ties -> smallest label: sort by
        # (vertex, -count, label) and take the first entry per vertex
        sel = np.lexsort((grp_l, -counts, grp_v))
        first = np.flatnonzero(
            np.concatenate(([True], grp_v[sel][1:] != grp_v[sel][:-1]))
        )
        winners_v = grp_v[sel][first]
        winners_l = grp_l[sel][first]
        new = labels.copy()
        new[winners_v] = winners_l
        if np.array_equal(new, labels):
            break
        labels = new
    return labels


# ---------------------------------------------------------------------------
# Local Clustering Coefficient
# ---------------------------------------------------------------------------

def lcc(engine, edge_type: str, n: int | None = None, block: int = 1024) -> np.ndarray:
    """LCC via blocked dense adjacency products (wedge-closure counting).

    Fine for benchmark-scale graphs (n <= ~32k); the Graphalytics semantics
    treat the graph as directed-ignored (undirected), no self-loops.
    """
    et = engine.schema.edge_types[edge_type]
    n = n or engine.topology.n_vertices(et.src_type)
    src, dst = _edges_dst_sorted(engine, edge_type, n)
    u = np.concatenate([src, dst])
    v = np.concatenate([dst, src])
    keep = u != v
    u, v = u[keep], v[keep]
    adj = np.zeros((n, n), dtype=np.float32)
    adj[u, v] = 1.0
    adj_j = jnp.asarray(adj)
    tri = np.zeros(n, dtype=np.float64)
    for lo in range(0, n, block):
        hi = min(n, lo + block)
        # triangles through i = sum_j sum_k A[i,j] A[j,k] A[k,i] / 2
        paths2 = adj_j[lo:hi] @ adj_j                      # (b, n) 2-paths
        tri[lo:hi] = np.asarray((paths2 * adj_j[lo:hi]).sum(axis=1), dtype=np.float64) / 2.0
    deg = np.asarray(adj.sum(axis=1), dtype=np.float64)
    wedges = deg * (deg - 1) / 2.0
    with np.errstate(divide="ignore", invalid="ignore"):
        out = np.where(wedges > 0, tri / wedges, 0.0)
    return out


# ---------------------------------------------------------------------------
# BFS
# ---------------------------------------------------------------------------

def bfs(engine, edge_type: str, source_dense: int, n: int | None = None,
        directed: bool = True, max_depth: int = 10_000) -> np.ndarray:
    """Frontier BFS with per-level adaptive dispatch (DESIGN.md §3): small
    frontiers expand through CSR adjacency ranges (touch only incident
    edges), large frontiers use the edge-centric masked scan (sequential
    locality).  Returns int64 depths (-1 = unreached)."""
    et = engine.schema.edge_types[edge_type]
    n = n or engine.topology.n_vertices(et.src_type)
    csr = _csr_for(engine, edge_type, n)
    src, dst = engine.concat_edges(edge_type)
    if not directed:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
    threshold = engine.plane.threshold()
    depth = np.full(n, -1, dtype=np.int64)
    depth[source_dense] = 0
    frontier_ids = np.array([source_dense], dtype=np.int64)
    for level in range(1, max_depth):
        if csr is not None and len(frontier_ids) <= threshold * n:
            _, cand, _ = csr.expand(frontier_ids, direction="out")
            if not directed:
                _, cand_in, _ = csr.expand(frontier_ids, direction="in")
                cand = np.concatenate([cand, cand_in])
        else:
            mask = np.zeros(n, dtype=bool)
            mask[frontier_ids] = True
            cand = dst[mask[src]]
        if len(cand) == 0:
            break
        new = np.unique(cand[depth[cand] < 0])
        if len(new) == 0:
            break
        depth[new] = level
        frontier_ids = new
    return depth
