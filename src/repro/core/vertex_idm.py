"""Vertex ID Mapping (IDM): raw vertex IDs -> transformed IDs (paper §4.1/§4.3).

The paper uses a sharded hash map populated in batches to limit lock
contention.  A vectorized CPU (and TPU-host) equivalent is a sorted-key map:
we concatenate (raw, transformed) pairs from all vertex files, sort once by
raw ID, and translate FK columns with ``np.searchsorted`` — O(E log V) fully
vectorized, no per-edge Python.  Batched inserts land in per-thread buffers
first (same contention-avoidance idea as the paper's batched hashmap insert).

Dangling raw IDs (edge endpoints that match no vertex row) are assigned rows
in the reserved file DANGLING_FILE_ID from an atomic counter, exactly as in
§4.3, so topology coverage stays complete.
"""

from __future__ import annotations

import threading

import numpy as np

from repro.core.types import DANGLING_FILE_ID, make_transformed


class VertexIDM:
    """Immutable-after-freeze sorted map raw ID -> transformed ID, per type."""

    def __init__(self):
        self._buffers: dict[str, list[tuple[np.ndarray, np.ndarray]]] = {}
        self._sorted_raw: dict[str, np.ndarray] = {}
        self._sorted_tid: dict[str, np.ndarray] = {}
        self._frozen = False
        self._lock = threading.Lock()
        # dangling allocation state (shared across types on purpose: file 0 is
        # one reserved file; the counter is global like the paper's)
        self._dangling_counter = 0
        self._dangling: dict[str, dict[int, int]] = {}

    # -- build phase -----------------------------------------------------------

    def insert_batch(self, vertex_type: str, raw_ids: np.ndarray, file_id: int) -> None:
        """Register one vertex file's PK column (compute-thread batch insert)."""
        if self._frozen:
            raise RuntimeError("IDM is frozen")
        raw = np.asarray(raw_ids, dtype=np.int64)
        tids = make_transformed(file_id, np.arange(len(raw), dtype=np.int64))
        with self._lock:
            self._buffers.setdefault(vertex_type, []).append((raw, tids))

    def freeze(self) -> None:
        """Sort all buffers; after this, lookups are lock-free and vectorized."""
        for vtype, pairs in self._buffers.items():
            raw = np.concatenate([p[0] for p in pairs])
            tid = np.concatenate([p[1] for p in pairs])
            order = np.argsort(raw, kind="stable")
            raw, tid = raw[order], tid[order]
            if len(raw) > 1 and np.any(raw[1:] == raw[:-1]):
                dup = raw[1:][raw[1:] == raw[:-1]][0]
                raise ValueError(
                    f"duplicate primary key {dup} in vertex type {vtype!r}"
                )
            self._sorted_raw[vtype] = raw
            self._sorted_tid[vtype] = tid
            self._dangling.setdefault(vtype, {})
        self._buffers.clear()
        self._frozen = True

    def extend_batch(self, vertex_type: str, raw_ids: np.ndarray, file_id: int) -> None:
        """Merge one *new* vertex file's PK column into a frozen IDM.

        The incremental-epoch path (``EpochManager.advance``, DESIGN.md §7):
        append-only vertex commits extend the dense space at the end, so the
        sorted lookup arrays absorb the new (raw, transformed) pairs with one
        O(V + B) vectorized merge — no re-sort, no full rebuild.  Readers are
        lock-free: the sorted arrays are replaced atomically (attribute
        rebind), so a concurrent ``translate`` sees either the old or the new
        arrays, both correct for every pre-existing raw ID.
        """
        if not self._frozen:
            raise RuntimeError("extend_batch requires a frozen IDM (use insert_batch)")
        raw = np.asarray(raw_ids, dtype=np.int64)
        tids = make_transformed(file_id, np.arange(len(raw), dtype=np.int64))
        order = np.argsort(raw, kind="stable")
        raw, tids = raw[order], tids[order]
        if len(raw) > 1 and np.any(raw[1:] == raw[:-1]):
            dup = raw[1:][raw[1:] == raw[:-1]][0]
            raise ValueError(f"duplicate primary key {dup} in vertex type {vertex_type!r}")
        with self._lock:
            keys = self._sorted_raw.get(vertex_type, np.empty(0, dtype=np.int64))
            vals = self._sorted_tid.get(vertex_type, np.empty(0, dtype=np.int64))
            if len(keys) and len(raw):
                pos_c = np.minimum(np.searchsorted(keys, raw), len(keys) - 1)
                clash = keys[pos_c] == raw
                if clash.any():
                    raise ValueError(
                        f"primary key {raw[clash][0]} already mapped in {vertex_type!r}"
                    )
            pos = np.searchsorted(keys, raw)
            self._sorted_raw[vertex_type] = np.insert(keys, pos, raw)
            self._sorted_tid[vertex_type] = np.insert(vals, pos, tids)
            self._dangling.setdefault(vertex_type, {})

    # -- lookup phase ------------------------------------------------------------

    def n_mapped(self, vertex_type: str) -> int:
        return len(self._sorted_raw.get(vertex_type, ()))

    def translate(
        self, vertex_type: str, raw_ids: np.ndarray, allow_dangling: bool = True
    ) -> np.ndarray:
        """Vectorized raw -> transformed translation for an FK column."""
        if not self._frozen:
            raise RuntimeError("freeze() the IDM before lookups")
        raw = np.asarray(raw_ids, dtype=np.int64)
        keys = self._sorted_raw.get(vertex_type)
        if keys is None or len(keys) == 0:
            pos = np.zeros(len(raw), dtype=np.int64)
            found = np.zeros(len(raw), dtype=bool)
            tids = np.zeros(len(raw), dtype=np.int64)
        else:
            pos = np.searchsorted(keys, raw)
            pos_c = np.minimum(pos, len(keys) - 1)
            found = keys[pos_c] == raw
            tids = self._sorted_tid[vertex_type][pos_c]

        if found.all():
            return tids
        if not allow_dangling:
            missing = raw[~found][0]
            raise KeyError(f"raw vertex id {missing} not in IDM[{vertex_type}]")

        # dangling path (rare): reserved file 0 + atomic counter
        out = tids.copy()
        missing_idx = np.flatnonzero(~found)
        with self._lock:
            table = self._dangling.setdefault(vertex_type, {})
            for i in missing_idx:
                r = int(raw[i])
                if r not in table:
                    table[r] = self._dangling_counter
                    self._dangling_counter += 1
                out[i] = int(make_transformed(DANGLING_FILE_ID, table[r]))
        return out

    def n_dangling(self) -> int:
        return self._dangling_counter

    def dangling_rows(self, vertex_type: str) -> dict[int, int]:
        return dict(self._dangling.get(vertex_type, {}))

    def raw_ids(self, vertex_type: str) -> np.ndarray:
        """All mapped raw IDs (sorted). Used by tests/tools."""
        return self._sorted_raw[vertex_type].copy()

    def memory_bytes(self) -> int:
        total = 0
        for vtype in self._sorted_raw:
            total += self._sorted_raw[vtype].nbytes + self._sorted_tid[vtype].nbytes
        return total

    def deallocate(self) -> None:
        """Free lookup arrays after edge-list building (paper §4.3)."""
        self._sorted_raw.clear()
        self._sorted_tid.clear()
