"""Distributed query processing: file-based sharding + two-pass EdgeScan
(paper §6.2).

``DistributedGraphLake`` runs P partition engines (threads stand in for
compute nodes; each owns the edge *files* assigned by round-robin file-based
sharding, plus the vertex rows of its assigned vertex files).  The semantics
reproduced exactly:

- **Vertex ownership**: a vertex belongs to the node owning its file; its
  accumulators live there ("co-located with their corresponding vertex files").
- **VertexMap** is embarrassingly parallel: every node maps its own vertices.
- **EdgeScan two-pass**: pass 1 scans local edge lists against the frontier,
  collects the remote endpoints whose rows must materialize, and sends one
  batched request per remote node; owners apply vertex predicates before
  replying (**filter pushdown** — non-qualifying vertices never cross the
  network). Pass 2 evaluates UDFs on fully materialized rows; accumulator
  partials are pushed back to the owners and combined.

The per-device `shard_map` realization of this same pattern (all_gather of
projected columns + psum_scatter of partials) lives in
``repro.models.gnn.common`` and is what the multi-pod dry-run compiles.
"""

from __future__ import annotations

import dataclasses
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Optional, Sequence

import numpy as np

from repro.core.accumulators import AccumSpec, Accumulators
from repro.core.engine import GraphLakeEngine
from repro.core.primitives import read_vertex_values
from repro.core.types import GraphSchema, VSet
from repro.lakehouse.objectstore import ObjectStore


@dataclasses.dataclass
class NetworkStats:
    requests: int = 0
    vertex_rows_shipped: int = 0
    accum_updates_shipped: int = 0
    bytes_shipped: int = 0


class DistributedGraphLake:
    """P-way partitioned GraphLake over one lakehouse."""

    def __init__(
        self,
        store: ObjectStore,
        schema: GraphSchema,
        n_partitions: int = 2,
        **engine_kwargs,
    ):
        self.store = store
        self.schema = schema
        self.P = n_partitions
        self.engines = [
            GraphLakeEngine(store, schema, materialize_topology=False, **engine_kwargs)
            for _ in range(n_partitions)
        ]
        self.net = NetworkStats()
        self._pool = ThreadPoolExecutor(max_workers=n_partitions)
        self.startup_seconds = 0.0

    # -------------------------------------------------------------- startup

    def startup(self) -> float:
        """Distributed topology build: node p builds edge lists only for its
        own files (file-based sharding); the Vertex IDM is replicated —
        every node builds the full registry (paper §4.1)."""
        import time

        t0 = time.perf_counter()

        def _start(p: int):
            self.engines[p].startup(
                file_filter=lambda key, idx, p=p: idx % self.P == p
            )

        futs = [self._pool.submit(_start, p) for p in range(self.P)]
        for f in futs:
            f.result()
        self.startup_seconds = time.perf_counter() - t0
        return self.startup_seconds

    def close(self) -> None:
        for e in self.engines:
            e.close()
        self._pool.shutdown(wait=True)

    # -------------------------------------------------------------- ownership

    def owner_of(self, vertex_type: str, dense_ids: np.ndarray) -> np.ndarray:
        """Vertex owner = owner of its file (files round-robin over nodes)."""
        topo = self.engines[0].topology
        file_ids, _ = topo.dense_to_file_row(vertex_type, dense_ids)
        ordinals = np.zeros_like(file_ids)
        for f in topo.vertex_info[vertex_type].files:
            ordinals[file_ids == f.file_id] = f.ordinal
        return (ordinals % self.P).astype(np.int64)

    # -------------------------------------------------------------- primitives

    def vertex_map(self, vset: VSet, columns=(), filter_fn=None):
        """Distributed VertexMap: each node maps its owned vertices."""
        owner = self.owner_of(vset.vertex_type, np.arange(len(vset.mask)))

        def _run(p: int) -> np.ndarray:
            local = VSet(vset.vertex_type, vset.mask & (owner == p))
            out, _ = self.engines[p].vertex_map(local, columns, filter_fn=filter_fn)
            return out.mask

        masks = list(self._pool.map(_run, range(self.P)))
        return VSet(vset.vertex_type, np.logical_or.reduce(masks))

    def edge_scan_accumulate(
        self,
        frontier: VSet,
        edge_type: str,
        direction: str = "out",
        edge_columns: Sequence[str] = (),
        v_columns: Sequence[str] = (),
        edge_filter: Optional[Callable[[dict], np.ndarray]] = None,
        v_filter: Optional[Callable[[dict], np.ndarray]] = None,
        accum_name: str = "acc",
        accum_op: str = "sum",
        accum_value=1.0,
    ) -> tuple[VSet, np.ndarray]:
        """Two-pass distributed EdgeScan with accumulator push-back (§6.2).

        Returns (next frontier over far-side endpoints, combined accumulator
        array over the far-side vertex type).
        """
        et = self.schema.edge_types[edge_type]
        v_type = et.dst_type if direction == "out" else et.src_type
        topo0 = self.engines[0].topology
        n_v = topo0.n_vertices(v_type)
        owner_all = self.owner_of(v_type, np.arange(n_v))

        # ---- PASS 1: local scans find remote endpoints to materialize -------
        def _pass1(p: int):
            eng = self.engines[p]
            frame = eng.edge_scan(
                frontier, edge_type, direction,
                edge_columns=edge_columns, edge_filter=edge_filter,
            )
            return frame

        frames = list(self._pool.map(_pass1, range(self.P)))

        # batched remote requests: node p needs v-rows it does not own
        requests: list[list[np.ndarray]] = [[] for _ in range(self.P)]
        for p, frame in enumerate(frames):
            if len(frame.v) == 0:
                continue
            need = np.unique(frame.v)
            owners = owner_all[need]
            for q in range(self.P):
                ids_q = need[owners == q]
                if len(ids_q):
                    requests[p].append(ids_q)
                    if q != p:
                        self.net.requests += 1

        # owners materialize + FILTER PUSHDOWN before replying
        def _serve(q: int):
            eng = self.engines[q]
            served: dict[int, tuple[np.ndarray, dict[str, np.ndarray], np.ndarray]] = {}
            for p, frame in enumerate(frames):
                asked = [ids for ids in requests[p] if len(ids) and owner_all[ids[0]] == q]
                if not asked:
                    continue
                ids = np.concatenate(asked)
                cols = {
                    c: read_vertex_values(eng.topology, eng.cache, v_type, ids, c)
                    for c in v_columns
                }
                if v_filter is not None and v_columns:
                    fr = {f"v.{c}": a for c, a in cols.items()}
                    fr["v"] = ids
                    keep = np.asarray(v_filter(fr), dtype=bool)
                else:
                    keep = np.ones(len(ids), dtype=bool)
                served[p] = (ids[keep], {c: a[keep] for c, a in cols.items()}, keep)
                if p != q:
                    self.net.vertex_rows_shipped += int(keep.sum())
                    self.net.bytes_shipped += int(keep.sum()) * (8 * (1 + len(v_columns)))
            return served

        replies = list(self._pool.map(_serve, range(self.P)))

        # ---- PASS 2: evaluate on materialized rows; accumulate locally ------
        partials: list[tuple[np.ndarray, np.ndarray]] = []
        next_mask = np.zeros(n_v, dtype=bool)
        for p, frame in enumerate(frames):
            if len(frame.v) == 0:
                continue
            qualified_parts = [r[p][0] for r in replies if p in r]
            qualified = (
                np.concatenate(qualified_parts) if qualified_parts
                else np.empty(0, dtype=np.int64)
            )
            qual_mask = np.zeros(n_v, dtype=bool)
            qual_mask[qualified] = True
            keep = qual_mask[frame.v]
            v_kept = frame.v[keep]
            next_mask[v_kept] = True
            if isinstance(accum_value, str):
                pfx, col = accum_value.split(".", 1)
                vals = frame.columns[f"{pfx}.{col}"][keep]
            else:
                vals = np.broadcast_to(accum_value, v_kept.shape)
            # local partial accumulation (per-node combine before the network)
            ids_u, inv = np.unique(v_kept, return_inverse=True)
            if accum_op == "sum":
                part = np.bincount(inv, weights=vals.astype(np.float64))
            elif accum_op == "max":
                part = np.full(len(ids_u), -np.inf)
                np.maximum.at(part, inv, vals)
            elif accum_op == "min":
                part = np.full(len(ids_u), np.inf)
                np.minimum.at(part, inv, vals)
            else:
                raise ValueError(accum_op)
            partials.append((ids_u, part))
            self.net.accum_updates_shipped += len(ids_u)

        # push partials back to owners and combine into the final array
        combined = Accumulators(topo0)
        combined.register(AccumSpec(v_type, accum_name, op=accum_op))
        for ids_u, part in partials:
            combined.combine_delta(v_type, accum_name, ids_u, part)

        return VSet(v_type, next_mask), combined.array(v_type, accum_name)
