"""Snapshot-pinned epochs: consistent reads + incremental delta sync
(DESIGN.md §7).

GraphLake computes *directly over* evolving lake tables, so the engine needs
a first-class answer to "which version of the lake is this query reading?".
Before this subsystem nothing was pinned: a ``commit()`` landing mid-query
tore reads (the planner, prefetcher and pipelined readers each consulted the
live, mutating topology), any vertex-table change forced a full topology
rebuild, and the cache could not invalidate per-file.

A :class:`GraphEpoch` is an immutable view of the whole graph: for every
vertex/edge table it pins the ``(snapshot_id, data-file set)``, plus the
topology-plane version, the frozen per-edge-type edge-list tuples, the
frozen vertex file registry and the dangling tail.  It exposes the same
read surface as :class:`~repro.core.topology.GraphTopology` (duck-typed:
``all_edge_lists`` / ``tid_to_dense`` / ``plane`` / file metas / ...), so
``Query.run``, the ``read_pipeline`` planners, the staged ``edge_scan``
evaluators and the prefetcher simply *receive an epoch where they used to
receive the topology* — every file they resolve comes from the pinned sets,
and results are bit-identical no matter what commits land mid-query.

The :class:`EpochManager` owns refcounted epochs:

- ``acquire()`` / ``release()`` pin an epoch for a query's lifetime;
  in-flight queries drain on their pinned epoch while new queries pick up
  the latest one;
- ``advance()`` (the promotion of ``GraphCatalog.sync``) diffs the lake
  against the current epoch and applies **incremental deltas** to the
  mutable builder topology: append-only edge commits build edge lists for
  the *new files only* and merge them into the per-type CSR via
  :meth:`~repro.core.csr.CSRIndex.extended`; append-only vertex commits
  extend the Vertex IDM's dense offsets (``VertexIDM.extend_batch``) —
  replacing the old "any vertex change ⇒ full rebuild" flag; removed or
  replaced files trigger **file-scoped cache invalidation**
  (``CacheManager.invalidate_file`` evicts exactly the affected
  ``(file, row-group)`` units, nothing else).  Only vertex-file *removals*
  (dense offsets of later files shift) — or vertex appends while dangling
  vertices exist (the dangling tail sits right after the real rows, so the
  tail's dense ids would shift) — fall back to a full rebuild;
- the new epoch then publishes atomically; a superseded epoch whose
  refcount has drained is *retired*: its pinned edge-list tuples and
  derived plane state (CSR, concat caches) are dropped so delta buffers
  only ever live as long as some query needs them.

Concurrency contract: ``advance()`` mutates the builder topology only by
*rebinding* or *appending* (epochs pin tuples and insert-only dicts), so
readers on any pinned epoch never observe intermediate state; one advancer
runs at a time (``_advance_lock``); publish/acquire/release share one short
mutex.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Optional

import numpy as np

from repro.core.topology import (
    GraphTopology,
    dense_to_file_row_for,
    tid_to_dense_for,
)
from repro.core.topology_plane import TopologyPlane
from repro.lakehouse.columnfile import read_column_chunk, read_footer


@dataclasses.dataclass(frozen=True)
class TablePin:
    """One lake table as an epoch sees it: snapshot + exact data-file set."""

    table: str
    snapshot_id: int
    data_files: tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class EpochVertexType:
    """Frozen registry slice of one vertex type (same shape the prefetcher
    and the dense translators consume on the mutable topology)."""

    name: str
    table: str
    primary_key: str
    files: tuple  # tuple[VertexFileInfo, ...] — entries are write-once

    @property
    def n_real(self) -> int:
        return sum(f.n_rows for f in self.files)


@dataclasses.dataclass
class AdvanceReport:
    """What one ``EpochManager.advance()`` observed and did."""

    changed: bool = False
    mode: str = "noop"              # "noop" | "incremental" | "rebuild"
    from_epoch: int = -1
    to_epoch: int = -1
    vertex_files_added: int = 0
    vertex_files_removed: int = 0
    edge_files_added: int = 0
    edge_files_removed: int = 0
    vertices_added: int = 0
    edges_added: int = 0
    csr_extended: list = dataclasses.field(default_factory=list)
    cache_units_evicted: int = 0
    # whether the persisted topology blobs + MANIFEST were refreshed to the
    # new epoch ("delta" | "full" | ""), so second connections stay on the
    # fast load_materialized path instead of a stale blob
    rematerialized: str = ""
    wall_s: float = 0.0


class GraphEpoch:
    """An immutable, refcounted view of the graph at one lake state.

    Exposes the read-path surface of :class:`GraphTopology` (duck-typed), so
    the primitives, planners and prefetcher resolve every file through the
    pinned state.  File-meta dicts and the file registry are *shared* with
    the builder topology — they are insert-only, and entries are never
    mutated, so sharing is safe; the file *sets* that decide what a query
    touches are pinned as tuples here.
    """

    def __init__(
        self,
        epoch_id: int,
        schema,
        vertex_pins: dict[str, TablePin],
        edge_pins: dict[str, TablePin],
        vertex_info: dict[str, EpochVertexType],
        file_registry: dict,
        vertex_file_metas: dict,
        edge_file_metas: dict,
        edge_lists: dict[str, tuple],
        n_dangling: int,
        topology_version: int,
        idm=None,
    ):
        self.epoch_id = epoch_id
        self.schema = schema
        self.vertex_pins = vertex_pins
        self.edge_pins = edge_pins
        self.vertex_info = vertex_info
        self.file_registry = file_registry
        self.vertex_file_metas = vertex_file_metas
        self.edge_file_metas = edge_file_metas
        self._edge_lists = edge_lists
        self._n_real = {name: vt.n_real for name, vt in vertex_info.items()}
        self.n_dangling = n_dangling
        self.topology_version = topology_version
        # the IDM whose file-id assignments match this epoch's registry.
        # Incremental advances extend the same object in place (safe: old raw
        # ids keep their translations), but a full rebuild re-assigns file
        # ids — raw-id seeds on an old pinned epoch must translate through
        # the IDM it was frozen with, never the rebuilt one.
        self.idm = idm
        self.created_at = time.time()
        self.retired = False
        self._refs = 0
        # per-epoch derived representations: CSR / concat / eid offsets are
        # built (or carried forward) against the pinned edge lists, never
        # against the mutating builder topology
        self.plane = TopologyPlane(self)
        # armed lookup plans (core/lookup.py), keyed by template name: the
        # fast path's epoch-bound state lives *on* the epoch, so advance()
        # invalidates by publishing a new (empty-cached) epoch, and retire
        # drops the CSR/IDM references along with the plane
        self.lookup_plans: dict = {}
        self.lookup_lock = threading.Lock()

    # -- the GraphTopology read surface (duck-typed) -------------------------

    def all_edge_lists(self, edge_type: str):
        return self._edge_lists[edge_type]

    def n_real_vertices(self, vertex_type: str) -> int:
        return self._n_real[vertex_type]

    def n_vertices(self, vertex_type: str) -> int:
        return self._n_real[vertex_type] + self.n_dangling

    def n_edges(self, edge_type: Optional[str] = None) -> int:
        if edge_type is not None:
            return sum(el.n_edges for el in self._edge_lists[edge_type])
        return sum(self.n_edges(e) for e in self._edge_lists)

    def tid_to_dense(self, vertex_type: str, tids: np.ndarray) -> np.ndarray:
        return tid_to_dense_for(
            self.vertex_info[vertex_type].files,
            self._n_real[vertex_type], vertex_type, tids,
        )

    def dense_to_file_row(self, vertex_type: str, dense: np.ndarray):
        return dense_to_file_row_for(
            self.vertex_info[vertex_type].files,
            self._n_real[vertex_type], dense,
        )

    # -- lifecycle ------------------------------------------------------------

    def staleness_s(self) -> float:
        """Seconds since this view of the lake was pinned."""
        return max(0.0, time.time() - self.created_at)

    def refs(self) -> int:
        return self._refs


class EpochManager:
    """Owns the epoch sequence: bootstrap, acquire/release, advance, retire."""

    def __init__(self, engine):
        self.engine = engine
        self._lock = threading.Lock()           # publish / acquire / release
        self._advance_lock = threading.Lock()   # one advancer at a time
        self._current: Optional[GraphEpoch] = None
        self._next_id = 1
        self.stats = {"published": 0, "retired": 0, "advances": 0,
                      "noop_advances": 0, "rebuilds": 0}

    # -- pinning ---------------------------------------------------------------

    def current(self) -> GraphEpoch:
        with self._lock:
            return self._current

    def current_id(self) -> int:
        return self.current().epoch_id

    def acquire(self) -> GraphEpoch:
        """Pin the current epoch for a query's lifetime (refcounted)."""
        with self._lock:
            e = self._current
            e._refs += 1
            return e

    def release(self, epoch: GraphEpoch) -> None:
        with self._lock:
            epoch._refs = max(0, epoch._refs - 1)
            if epoch._refs == 0 and epoch is not self._current:
                self._retire(epoch)

    def _publish(self, epoch: GraphEpoch) -> None:
        with self._lock:
            old = self._current
            self._current = epoch
            self.stats["published"] += 1
            if old is not None and old._refs == 0:
                self._retire(old)

    def _retire(self, epoch: GraphEpoch) -> None:
        # caller holds self._lock; nobody references the epoch anymore, so
        # drop its delta buffers: the pinned edge-list tuples and every
        # derived plane representation (CSR / concat) it owned
        epoch.retired = True
        epoch._edge_lists = {}
        epoch.plane.invalidate()
        with epoch.lookup_lock:
            epoch.lookup_plans.clear()
        # shard fabric (DESIGN.md §13): a retiring epoch also drops its
        # per-shard views — their planes hold sliced CSRs, and a worker that
        # disconnected mid-advance must not keep them (or its routed delta
        # buffers) alive through a dead epoch
        views = getattr(epoch, "shard_views", None)
        if views:
            for view in views.values():
                view.plane.invalidate()
            epoch.shard_views = {}
        self.stats["retired"] += 1

    # -- bootstrap ---------------------------------------------------------------

    def bootstrap(self) -> GraphEpoch:
        """Pin the freshly-started topology as epoch 1."""
        eng = self.engine
        topo = eng.topology
        vertex_pins = {}
        for name, vt in topo.vertex_info.items():
            files = tuple(f.key for f in vt.files)
            vertex_pins[name] = TablePin(
                table=vt.table,
                snapshot_id=self._match_snapshot(vt.table, files),
                data_files=files,
            )
        edge_pins = {}
        for ename, et in topo.schema.edge_types.items():
            files = tuple(el.file_key for el in topo.edge_lists[ename])
            edge_pins[ename] = TablePin(
                table=et.table,
                snapshot_id=topo._edge_snapshot_ids.get(ename, -1),
                data_files=files,
            )
        epoch = self._freeze(topo, vertex_pins, edge_pins)
        # adopt derived state the startup path already built — notably CSR
        # indexes restored from the materialized topology blob (the
        # second-connection fast path must reach epoch-pinned queries too)
        for ename, csr in topo.plane.built_csrs().items():
            epoch.plane.adopt(ename, csr=csr)
        for ename in topo.schema.edge_types:
            epoch.plane.adopt(
                ename,
                concat=topo.plane.cached_concat(ename),
                eid_offsets=topo.plane.cached_eid_offsets(ename),
            )
        self._publish(epoch)
        return epoch

    def _match_snapshot(self, table: str, files: tuple[str, ...]) -> int:
        """Find the snapshot whose file set the topology actually loaded.

        A materialized topology can lag the table HEAD; pinning the matching
        snapshot (newest first) makes the first ``advance()`` diff correctly.
        ``-1`` when nothing matches — the next advance reconciles by file set.
        """
        try:
            t = self.engine.lake.table(table)
            want = set(files)
            for snap in reversed(t.snapshots()):
                if set(t.data_files(snap.snapshot_id)) == want:
                    return snap.snapshot_id
        except Exception:
            pass
        return -1

    def _freeze(self, topo: GraphTopology, vertex_pins, edge_pins) -> GraphEpoch:
        vertex_info = {
            name: EpochVertexType(
                name=name, table=vt.table, primary_key=vt.primary_key,
                files=tuple(vt.files),
            )
            for name, vt in topo.vertex_info.items()
        }
        # id allocation under the publish mutex: _advance_lock already
        # serializes advancers, but bootstrap and any future caller must
        # never be able to mint the same epoch_id twice
        with self._lock:
            epoch_id = self._next_id
            self._next_id += 1
        epoch = GraphEpoch(
            epoch_id=epoch_id,
            schema=topo.schema,
            vertex_pins=vertex_pins,
            edge_pins=edge_pins,
            vertex_info=vertex_info,
            file_registry=topo.file_registry,
            vertex_file_metas=topo.vertex_file_metas,
            edge_file_metas=topo.edge_file_metas,
            edge_lists={e: tuple(els) for e, els in topo.edge_lists.items()},
            n_dangling=topo._n_dangling,
            topology_version=topo.version,
            idm=topo.idm,
        )
        return epoch

    # -- advance ---------------------------------------------------------------

    def advance(self) -> AdvanceReport:
        """Diff the lake against the current epoch; publish a new epoch.

        Append-only commits apply as deltas (new edge lists, CSR merge
        extension, IDM dense-offset extension); removed/replaced files evict
        exactly their cache units; vertex-file removal (or a vertex append
        while dangling vertices exist) falls back to a full rebuild.  No-op
        when nothing changed — the current epoch stays published.
        """
        eng = self.engine
        if getattr(eng, "_file_filter", None) is not None:
            raise RuntimeError(
                "advance() is unsupported on a file-filtered engine (a static "
                "slice of the lake cannot diff against the whole); for "
                "multi-worker freshness use the shard fabric "
                "(repro.shard.ShardFabric / connect(..., shards=n)), whose "
                "workers share the coordinator's epochs")
        with self._advance_lock:
            t0 = time.perf_counter()
            cur = self.current()
            topo = eng.topology
            lake, store = eng.lake, eng.store
            report = AdvanceReport(from_epoch=cur.epoch_id, to_epoch=cur.epoch_id)
            self.stats["advances"] += 1

            # diff every pinned table against the lake — one job per table
            # through the engine's IOPool, so the modeled metadata latency
            # is paid once across tables, not once per table
            def resolve(pin: TablePin):
                t = lake.table(pin.table)
                snap = t.current_snapshot()
                if snap.snapshot_id == pin.snapshot_id:
                    return None
                return (snap.snapshot_id, tuple(t.data_files(snap.snapshot_id)))

            items = (
                [("v", name, pin) for name, pin in cur.vertex_pins.items()]
                + [("e", ename, pin) for ename, pin in cur.edge_pins.items()]
            )
            pool = getattr(eng, "pool", None)
            if pool is not None:
                futs = [(kind, name, pool.submit(resolve, pin))
                        for kind, name, pin in items]
                states = [(kind, name, f.result()) for kind, name, f in futs]
            else:
                states = [(kind, name, resolve(pin)) for kind, name, pin in items]
            vdiffs: dict[str, tuple[int, tuple[str, ...]]] = {}
            ediffs: dict[str, tuple[int, tuple[str, ...]]] = {}
            for kind, name, state in states:
                if state is not None:
                    (vdiffs if kind == "v" else ediffs)[name] = state

            if not vdiffs and not ediffs:
                self.stats["noop_advances"] += 1
                report.wall_s = time.perf_counter() - t0
                return report

            removed_keys: list[str] = []
            v_added: dict[str, list[str]] = {}
            rebuild = False
            for name, (_sid, files) in vdiffs.items():
                old = set(cur.vertex_pins[name].data_files)
                added = [k for k in files if k not in old]
                removed = [k for k in old if k not in set(files)]
                removed_keys += removed
                report.vertex_files_added += len(added)
                report.vertex_files_removed += len(removed)
                v_added[name] = added
                if removed:
                    rebuild = True   # dense offsets of every later file shift
                elif added and topo._n_dangling > 0:
                    rebuild = True   # the dangling dense tail would shift
            for ename, (_sid, files) in ediffs.items():
                old = set(cur.edge_pins[ename].data_files)
                report.edge_files_added += len([k for k in files if k not in old])
                removed = [k for k in old if k not in set(files)]
                report.edge_files_removed += len(removed)
                removed_keys += removed

            report.changed = True
            if rebuild:
                report.mode = "rebuild"
                self.stats["rebuilds"] += 1
                topo = self._full_rebuild()
            else:
                report.mode = "incremental"
                # vertices first: the IDM must cover appended vertices before
                # delta edge files translate their FK columns
                for name, added in v_added.items():
                    if added:
                        report.vertices_added += self._apply_vertex_append(
                            topo, name, added)
                e_before = topo.n_edges()
                for ename in ediffs:
                    topo.refresh_edges(store, lake, ename)
                report.edges_added = max(0, topo.n_edges() - e_before)

            for key in removed_keys:
                report.cache_units_evicted += eng.cache.invalidate_file(key)

            new_epoch = self._freeze(
                topo,
                vertex_pins=self._new_vertex_pins(topo, cur, vdiffs),
                edge_pins=self._new_edge_pins(topo, cur),
            )
            if not rebuild:
                self._carry_plane(cur, new_epoch, ediffs, report)
            self._publish(new_epoch)
            # shard fabric (DESIGN.md §13): route the delta to owning
            # shards, re-arm per-worker views/sliced CSRs (delta re-shard on
            # rebuild) — after publish, so fabric epochs only ever wrap a
            # published coordinator epoch
            fabric = getattr(eng, "_shard_fabric", None)
            if fabric is not None:
                fabric.sync_to(new_epoch, report)
            # keep the persisted topology in lockstep with the published
            # epoch: a second connection must never pay a first-connection
            # build (or load a stale blob) just because this engine advanced
            if eng.materialize_topology and eng._file_filter is None:
                if rebuild:
                    topo.materialize(store, pool=pool)
                    report.rematerialized = "full"
                else:
                    # csr_source: the new epoch's carried/extended CSRs are
                    # the fresh ones — persisting them under this version's
                    # keys keeps the CSR fast path for shard workers and
                    # second connections instead of dropping the refs stale
                    report.rematerialized = topo.rematerialize_delta(
                        store, pool=pool, csr_source=new_epoch.plane)["mode"]
            report.to_epoch = new_epoch.epoch_id
            report.wall_s = time.perf_counter() - t0
            return report

    # -- delta application -------------------------------------------------------

    def _apply_vertex_append(self, topo: GraphTopology, name: str,
                             added_keys: list[str]) -> int:
        """Register appended vertex files + extend the IDM's dense offsets."""
        store = self.engine.store
        vt = topo.schema.vertex_types[name]
        idm = topo.idm
        can_extend = (
            idm is not None and idm._frozen
            and sum(idm.n_mapped(t) for t in topo.vertex_info) > 0
        )
        n_rows = 0
        for key in added_keys:   # manifest order — matches a cold rebuild
            meta = read_footer(store, key)
            topo.vertex_file_metas[key] = meta
            finfo = topo.register_vertex_file(name, key, meta.n_rows)
            n_rows += meta.n_rows
            if can_extend:
                parts = [
                    read_column_chunk(store, meta, vt.primary_key, g.index)
                    for g in meta.row_groups
                ]
                idm.extend_batch(
                    name,
                    np.concatenate(parts) if len(parts) > 1 else parts[0],
                    finfo.file_id,
                )
            # else: the IDM is absent/deallocated; the next lazy
            # _rebuild_idm walks the registry and picks the new file up
        topo.version += 1
        return n_rows

    def _full_rebuild(self) -> GraphTopology:
        """Non-incremental fallback: rebuild from the lake HEAD and swap the
        engine's builder topology.  Old epochs keep serving from their pinned
        (now-orphaned) structures until they drain."""
        eng = self.engine
        new_topo = GraphTopology(eng.schema)
        new_topo.build(eng.store, eng.lake, pool=eng.pool)
        # stay monotonic across the swap: materialized blob keys carry the
        # version, so a rebuilt topology restarting at v1 would overwrite
        # blobs the published manifest still references (torn loads)
        new_topo.version = max(new_topo.version, eng.topology.version + 1)
        eng.adopt_topology(new_topo)
        return new_topo

    def _carry_plane(self, prev: GraphEpoch, nxt: GraphEpoch,
                     ediffs: dict, report: AdvanceReport) -> None:
        """Carry derived representations across an incremental advance.

        Unchanged edge types share the previous epoch's CSR/concat outright
        (indptrs padded if the vertex space grew); append-only deltas merge
        into the CSR via ``CSRIndex.extended``.  Anything with removals is
        left to rebuild lazily on first demand.
        """
        for ename, et in nxt.schema.edge_types.items():
            old_lists = prev.all_edge_lists(ename)
            new_lists = nxt.all_edge_lists(ename)
            shared_prefix = len(new_lists) >= len(old_lists) and all(
                a is b for a, b in zip(old_lists, new_lists)
            )
            if not shared_prefix:
                continue  # removals/replacements: lazy rebuild
            n_src = nxt.n_vertices(et.src_type)
            n_dst = nxt.n_vertices(et.dst_type)
            old_csr = prev.plane.csr(ename, build=False)
            if len(new_lists) == len(old_lists):
                # topologically unchanged: share everything, pad dims
                if old_csr is not None:
                    nxt.plane.adopt(ename, csr=old_csr.padded(n_src, n_dst))
                nxt.plane.adopt(
                    ename,
                    concat=prev.plane.cached_concat(ename),
                    eid_offsets=prev.plane.cached_eid_offsets(ename),
                )
                continue
            delta = new_lists[len(old_lists):]
            if old_csr is not None:
                delta_src = np.concatenate([el.src_dense for el in delta])
                delta_dst = np.concatenate([el.dst_dense for el in delta])
                nxt.plane.adopt(ename, csr=old_csr.extended(
                    delta_src, delta_dst, n_src, n_dst,
                    eid_base=old_csr.n_edges,
                ))
                report.csr_extended.append(ename)

    def _new_vertex_pins(self, topo, prev: GraphEpoch, vdiffs) -> dict:
        pins = {}
        for name, vt in topo.vertex_info.items():
            sid = vdiffs[name][0] if name in vdiffs \
                else prev.vertex_pins[name].snapshot_id
            pins[name] = TablePin(
                table=vt.table, snapshot_id=sid,
                data_files=tuple(f.key for f in vt.files),
            )
        return pins

    def _new_edge_pins(self, topo, prev: GraphEpoch) -> dict:
        pins = {}
        for ename, et in topo.schema.edge_types.items():
            pins[ename] = TablePin(
                table=et.table,
                snapshot_id=topo._edge_snapshot_ids.get(
                    ename, prev.edge_pins[ename].snapshot_id),
                data_files=tuple(el.file_key for el in topo.edge_lists[ename]),
            )
        return pins
