"""The topology plane: one logical topology, multiple physical layouts
(DESIGN.md §3).

A ``TopologyView`` is a physical representation of one edge type that can
``gather`` the edges incident to a frontier.  Two first-class views exist:

- ``EdgeListView`` — the paper's per-file edge lists (§4.1): sequential scan
  with Min-Max portion pruning.  Wins at high frontier selectivity (scan
  locality, no indirection) and is the only representation that supports
  cheap incremental maintenance, so it is always present.
- ``CSRView`` — a per-edge-type :class:`~repro.core.csr.CSRIndex`:
  adjacency-range gather.  Wins at low selectivity (prunes whole vertices),
  the vertex-centric side of the paper's Fig. 15 crossover.

Both views return ``(u, v, eid)`` in **global edge-id order** — edge lists in
registration order, rows in file order — so downstream attribute
materialization and the scan output are bit-identical regardless of which
representation served the scan.

``TopologyPlane`` owns the views per edge type, the lazily-built CSR indexes
(invalidated on incremental edge refresh), the concatenated edge-array cache
the analytics algorithms use, and the **adaptive dispatcher**: per scan it
estimates frontier selectivity and picks the representation, with the
crossover threshold calibrated by ``benchmarks/bench_edgelist_vs_csr.py`` and
overridable via ``REPRO_OPTS="csr=0.02"`` (the ``csr`` perf flag with an
attached threshold value).
"""

from __future__ import annotations

import abc
from typing import Optional

import numpy as np

from repro.core.csr import CSRIndex
from repro.core.types import VSet
from repro.perf_flags import enabled, value

# Fig. 15 reproduction on this substrate (graph500 scale 14, edge factor 16):
# the raw-gather crossover lands between 10% and 50% frontier selectivity and
# the full edge_scan path crosses even later, so 20% is the calibrated
# default — conservative toward the general-purpose edge-list scan (see
# DESIGN.md §3.3; recalibrate with benchmarks/bench_edgelist_vs_csr.py).
# Override: REPRO_OPTS="csr=<threshold>".
DEFAULT_CSR_THRESHOLD = 0.2


def _empty_gather():
    z = np.empty(0, dtype=np.int64)
    return z, z.copy(), z.copy()


class TopologyView(abc.ABC):
    """A physical representation of one edge type's topology."""

    kind: str = "abstract"

    @abc.abstractmethod
    def gather(self, frontier: VSet, direction: str = "out"):
        """Edges incident to ``frontier``: ``(u, v, eid)`` int64 arrays in
        global edge-id order.  ``u`` is the frontier-side endpoint,
        ``v`` the far side, ``eid`` the global edge id (attribute row)."""

    @property
    @abc.abstractmethod
    def n_edges(self) -> int: ...


class EdgeListView(TopologyView):
    """Edge-centric scan over the per-file edge lists (paper §6.1)."""

    kind = "edgelist"

    def __init__(self, edge_type: str, edge_lists, eid_offsets: np.ndarray):
        self.edge_type = edge_type
        self.edge_lists = edge_lists
        self.eid_offsets = eid_offsets  # cumulative edge counts per list

    @property
    def n_edges(self) -> int:
        return int(self.eid_offsets[-1]) if len(self.eid_offsets) else 0

    def gather(self, frontier: VSet, direction: str = "out"):
        lo, hi = frontier.min_max()
        mask = frontier.mask
        parts_u, parts_v, parts_e = [], [], []
        for li, el in enumerate(self.edge_lists):
            u_all = el.src_dense if direction == "out" else el.dst_dense
            v_all = el.dst_dense if direction == "out" else el.src_dense
            base = self.eid_offsets[li]
            # Min-Max portion pruning (paper §5.3): skip portions whose
            # frontier-side ID range misses the frontier envelope.
            for p in el.portions_overlapping(lo, hi, direction=direction):
                sl = slice(p.first_row, p.first_row + p.n_rows)
                u = u_all[sl]
                hit = mask[u]
                if not hit.any():
                    continue
                rows = np.flatnonzero(hit)
                parts_u.append(u[hit])
                parts_v.append(v_all[sl][hit])
                parts_e.append(base + p.first_row + rows)
        if not parts_u:
            return _empty_gather()
        return (
            np.concatenate(parts_u),
            np.concatenate(parts_v),
            np.concatenate(parts_e),
        )


class CSRView(TopologyView):
    """Vertex-centric adjacency-range gather over a ``CSRIndex``."""

    kind = "csr"

    def __init__(self, csr: CSRIndex):
        self.csr = csr

    @property
    def n_edges(self) -> int:
        return self.csr.n_edges

    def gather(self, frontier: VSet, direction: str = "out"):
        u, v, eid = self.csr.expand(frontier.ids(), direction=direction)
        if len(eid) == 0:
            return _empty_gather()
        # canonical global edge-id order: bit-identical to the edge-list scan
        # (cheap — the CSR path only runs on small gathered sets)
        order = np.argsort(eid, kind="stable")
        return u[order], v[order], eid[order]


class TopologyPlane:
    """Per-edge-type physical representations + adaptive per-scan dispatch."""

    def __init__(self, topology):
        self._topology = topology
        self._csr: dict[str, CSRIndex] = {}
        self._concat: dict[str, tuple[np.ndarray, np.ndarray]] = {}
        self._eid_offsets: dict[str, np.ndarray] = {}
        self.auto_build_csr = True
        self.csr_build_seconds: dict[str, float] = {}
        self.last_strategy: dict[str, str] = {}  # edge_type -> kind (introspection)

    # ------------------------------------------------------------ invalidation

    def invalidate(self, edge_type: Optional[str] = None) -> None:
        """Drop derived state after the underlying edge lists changed
        (topology rebuild or incremental refresh)."""
        if edge_type is None:
            self._csr.clear()
            self._concat.clear()
            self._eid_offsets.clear()
        else:
            self._csr.pop(edge_type, None)
            self._concat.pop(edge_type, None)
            self._eid_offsets.pop(edge_type, None)

    # ------------------------------------------------------------ constituents

    def eid_offsets(self, edge_type: str) -> np.ndarray:
        """Cumulative edge counts per edge list: global eid = offsets[list] + row."""
        if edge_type not in self._eid_offsets:
            counts = [el.n_edges for el in self._topology.all_edge_lists(edge_type)]
            self._eid_offsets[edge_type] = np.concatenate(
                ([0], np.cumsum(counts, dtype=np.int64))
            ) if counts else np.zeros(1, dtype=np.int64)
        return self._eid_offsets[edge_type]

    def edge_list_view(self, edge_type: str) -> EdgeListView:
        return EdgeListView(
            edge_type,
            self._topology.all_edge_lists(edge_type),
            self.eid_offsets(edge_type),
        )

    def csr(self, edge_type: str, build: bool = True) -> Optional[CSRIndex]:
        """The edge type's CSR index; built (and cached) on first demand."""
        if edge_type not in self._csr:
            if not build:
                return None
            et = self._topology.schema.edge_types[edge_type]
            src, dst = self.concat_edges(edge_type)  # shares the concat cache
            idx = CSRIndex.from_arrays(
                edge_type, src, dst,
                n_src=self._topology.n_vertices(et.src_type),
                n_dst=self._topology.n_vertices(et.dst_type),
            )
            self._csr[edge_type] = idx
            self.csr_build_seconds[edge_type] = idx.build_seconds
        return self._csr[edge_type]

    def csr_ready(self, edge_type: str) -> bool:
        return edge_type in self._csr

    def cached_concat(self, edge_type: str):
        """The concat cache entry if built (epoch carry-forward), else None."""
        return self._concat.get(edge_type)

    def cached_eid_offsets(self, edge_type: str):
        return self._eid_offsets.get(edge_type)

    def attach_csr(self, edge_type: str, csr: CSRIndex) -> None:
        """Adopt a deserialized CSR (topology materialization restore)."""
        self._csr[edge_type] = csr

    def adopt(self, edge_type: str, csr: Optional[CSRIndex] = None,
              concat=None, eid_offsets=None) -> None:
        """Seed derived state carried forward from a previous epoch's plane
        (unchanged edge types share it outright; append-only deltas pass an
        incrementally-extended CSR) — see core/epochs.py, DESIGN.md §7."""
        if csr is not None:
            self._csr[edge_type] = csr
        if concat is not None:
            self._concat[edge_type] = concat
        if eid_offsets is not None:
            self._eid_offsets[edge_type] = eid_offsets

    def built_csrs(self) -> dict[str, CSRIndex]:
        return dict(self._csr)

    # --------------------------------------------------------------- dispatch

    @staticmethod
    def threshold() -> float:
        return value("csr", DEFAULT_CSR_THRESHOLD)

    def choose(self, edge_type: str, frontier: VSet, direction: str = "out") -> str:
        """Pick the physical representation for one scan.

        CSR serves the scan when (a) the ``csr`` perf flag is on, (b) frontier
        selectivity is below the crossover threshold, and (c) a CSR index is
        either already built or allowed to build lazily.
        """
        if not enabled("csr"):
            return "edgelist"
        k = frontier.size()
        if k == 0:
            # nothing to gather — never worth triggering a lazy CSR build
            return "edgelist"
        n = max(1, len(frontier.mask))
        if k / n > self.threshold():
            return "edgelist"
        if not self.csr_ready(edge_type) and not self.auto_build_csr:
            return "edgelist"
        return "csr"

    def view(
        self,
        edge_type: str,
        strategy: str = "auto",
        frontier: Optional[VSet] = None,
        direction: str = "out",
    ) -> TopologyView:
        """Resolve a strategy name ("auto" | "edgelist" | "csr") to a view."""
        if strategy == "auto":
            if frontier is None:
                strategy = "edgelist"
            else:
                strategy = self.choose(edge_type, frontier, direction)
        if strategy == "csr":
            self.last_strategy[edge_type] = "csr"
            return CSRView(self.csr(edge_type))
        if strategy == "edgelist":
            self.last_strategy[edge_type] = "edgelist"
            return self.edge_list_view(edge_type)
        raise ValueError(f"unknown edge_scan strategy: {strategy!r}")

    # ------------------------------------------------------- analytics arrays

    def concat_edges(self, edge_type: str) -> tuple[np.ndarray, np.ndarray]:
        """All (src_dense, dst_dense) pairs in global edge-id order, cached."""
        if edge_type not in self._concat:
            els = self._topology.all_edge_lists(edge_type)
            if els:
                src = np.concatenate([el.src_dense for el in els])
                dst = np.concatenate([el.dst_dense for el in els])
            else:
                src = np.empty(0, dtype=np.int64)
                dst = np.empty(0, dtype=np.int64)
            self._concat[edge_type] = (src, dst)
        return self._concat[edge_type]

    def edges_by_dst(self, edge_type: str) -> tuple[np.ndarray, np.ndarray]:
        """(src, dst) sorted by dst — the Pallas-kernel-friendly edge order."""
        src, dst, _ = self.csr(edge_type).edges_by_dst()
        return src, dst

    def edges_by_src(self, edge_type: str) -> tuple[np.ndarray, np.ndarray]:
        """(src, dst) sorted by src."""
        src, dst, _ = self.csr(edge_type).edges_by_src()
        return src, dst
