"""Graph catalog: element-type <-> Lakehouse-table mapping + change monitor
(paper §3, "Graph Catalog").

Maintains the mapping metadata linking vertex/edge types to tables and polls
the lake catalog for snapshot changes (added/deleted data files), triggering
incremental topology maintenance (``GraphTopology.refresh_edges``).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.topology import GraphTopology
from repro.core.types import GraphSchema
from repro.lakehouse.objectstore import ObjectStore
from repro.lakehouse.table import LakeCatalog


@dataclasses.dataclass
class SyncReport:
    edge_lists_added: int = 0
    edge_lists_removed: int = 0
    vertex_changes_detected: bool = False


# MissingTableError now lives in repro.errors (the consolidated typed-error
# surface, common ReproError base); re-exported here for one release.
from repro.errors import MissingTableError  # noqa: F401


class GraphCatalog:
    def __init__(self, store: ObjectStore, schema: GraphSchema,
                 topology: GraphTopology, epochs=None):
        self.store = store
        self.lake = LakeCatalog(store)
        self.schema = schema
        self.topology = topology
        # when an EpochManager is attached (core/epochs.py), sync() promotes
        # to its epoch-publishing advance(); the legacy in-place refresh
        # remains for catalogs watching a bare topology
        self.epochs = epochs
        self._vertex_snapshots: dict[str, int] = {}
        for name, vt in schema.vertex_types.items():
            table = self.lake.table(vt.table)
            if not table.exists():
                raise MissingTableError(
                    f"vertex type {name!r} maps to table {vt.table!r}, "
                    f"which does not exist in the lake"
                )
            try:
                self._vertex_snapshots[name] = table.current_snapshot().snapshot_id
            except RuntimeError:
                # the table exists but has no snapshots yet (created, never
                # committed) — a legitimate empty state, not a misconfiguration
                self._vertex_snapshots[name] = -1

    def mapping(self) -> dict[str, dict]:
        """The catalog's mapping metadata, element type -> table binding."""
        return {
            "vertices": {
                name: {"table": vt.table, "primary_key": vt.primary_key}
                for name, vt in self.schema.vertex_types.items()
            },
            "edges": {
                name: {
                    "table": et.table,
                    "src": f"{et.src_type}.{et.src_column}",
                    "dst": f"{et.dst_type}.{et.dst_column}",
                }
                for name, et in self.schema.edge_types.items()
            },
        }

    def sync(self) -> SyncReport:
        """Poll the lake for table changes; update topology incrementally.

        With an attached :class:`~repro.core.epochs.EpochManager` this is
        the epoch-publishing ``advance()`` — consistent snapshot diffing,
        incremental delta merges and file-scoped cache invalidation — and
        the report is translated back to the legacy shape.
        """
        if self.epochs is not None:
            r = self.epochs.advance()
            return SyncReport(
                edge_lists_added=r.edge_files_added,
                edge_lists_removed=r.edge_files_removed,
                vertex_changes_detected=bool(
                    r.vertex_files_added or r.vertex_files_removed
                    or r.mode == "rebuild"
                ),
            )
        report = SyncReport()
        for ename in self.schema.edge_types:
            added, removed = self.topology.refresh_edges(self.store, self.lake, ename)
            report.edge_lists_added += added
            report.edge_lists_removed += removed
        for name, vt in self.schema.vertex_types.items():
            snap = self.lake.table(vt.table).current_snapshot().snapshot_id
            if snap != self._vertex_snapshots.get(name):
                # vertex-file changes shift dense offsets -> full rebuild path;
                # flagged to the caller (the engine restarts topology build).
                report.vertex_changes_detected = True
                self._vertex_snapshots[name] = snap
        return report
