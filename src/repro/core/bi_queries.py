"""Five LDBC_SNB-BI-style graph-aggregation queries (paper §7.3).

Expressed in the declarative Query layer (GSQL-block analogue).  Each returns
a small summary dict so the serving layer can ship results cheaply.  BI1 is
the paper's §6 running example verbatim.
"""

from __future__ import annotations

import numpy as np

from repro.core.query import Query, accum_max, accum_sum, eq, ge, gt, le


def bi1_music_women(engine, tag_name: str = "Music", date: int = 20100101):
    """Women who created comments tagged `tag_name` after `date`; count per
    person (the paper's running example)."""
    res = (
        Query(engine)
        .vertices("Tag", where=eq("name", tag_name))
        .hop("HasTag", direction="in")
        .hop("HasCreator", direction="out",
             edge_where=gt("creationDate", date),
             target_where=eq("gender", "Female"),
             accum=accum_sum("cnt", 1.0))
        .run()
    )
    counts = res.accumulators.get("cnt", np.zeros(1))
    return {
        "n_persons": int(res.vset.size()),
        "total_comments": float(counts.sum()),
        "max_per_person": float(counts.max()) if len(counts) else 0.0,
        "edges_scanned": res.n_edges_scanned,
    }


def bi2_tag_activity(engine, date_lo: int = 20120101, date_hi: int = 20151231):
    """Comment volume per tag inside a date window."""
    res = (
        Query(engine)
        .vertices("Comment")
        .hop("HasCreator", direction="out",
             edge_where=ge("creationDate", date_lo) & le("creationDate", date_hi))
        .run()
    )
    active = res.frames[0].u_set(engine.topology.n_vertices("Comment"))
    # count tags only over the date-active comments
    frame = engine.edge_scan(active, "HasTag", "out")
    engine.register_accum("Tag", "tag_cnt", op="sum")
    engine.accums.update("Tag", "tag_cnt", frame.v, 1.0)
    counts = engine.accums.array("Tag", "tag_cnt")
    out = {
        "n_active_comments": int(active.size()),
        "n_tags_touched": int((counts > 0).sum()),
        "top_tag_count": float(counts.max()) if len(counts) else 0.0,
    }
    engine.accums.reset("Tag", "tag_cnt")
    return out


def bi3_person_engagement(engine, min_len: int = 500):
    """Per-person total length of their long comments (cross-entity ACCUM)."""
    res = (
        Query(engine)
        .vertices("Comment")
        .hop("HasCreator", direction="out",
             source_where=gt("length", min_len),
             accum=accum_sum("tot_len", "u.length"))
        .run()
    )
    tot = res.accumulators["tot_len"]
    return {
        "n_persons": int((tot > 0).sum()),
        "total_length": float(tot.sum()),
    }


def bi4_city_social(engine, city: str = "city_1"):
    """Friend counts of persons in one city (1-hop Knows aggregation)."""
    res = (
        Query(engine)
        .vertices("Person", where=eq("locationCity", city))
        .hop("Knows", direction="out", accum=accum_sum("deg", 1.0, target="u"))
        .run()
    )
    deg = res.accumulators["deg"]
    return {
        "n_friend_edges": float(deg.sum()),
        "max_degree": float(deg.max()) if len(deg) else 0.0,
    }


def bi5_influencer_tags(engine, min_degree: int = 10, date: int = 20140101):
    """Tags used by comments of well-connected persons (3 hops with
    accumulator-driven filtering)."""
    # hop 1: find high-out-degree persons via Knows aggregation
    res = (
        Query(engine)
        .vertices("Person")
        .hop("Knows", direction="out", accum=accum_sum("deg", 1.0, target="u"))
        .run()
    )
    deg = res.accumulators["deg"]
    n_p = engine.topology.n_vertices("Person")
    from repro.core.types import VSet
    influencers = VSet.from_dense_ids("Person", n_p, np.flatnonzero(deg >= min_degree))
    # hop 2: their recent comments
    frame = engine.edge_scan(
        influencers, "HasCreator", "in",
        edge_columns=["creationDate"],
        edge_filter=lambda fr: fr["e.creationDate"] > date,
    )
    comments = frame.v_set(engine.topology.n_vertices("Comment"))
    # hop 3: tags of those comments
    frame2 = engine.edge_scan(comments, "HasTag", "out")
    engine.register_accum("Tag", "inf_cnt", op="sum")
    engine.accums.update("Tag", "inf_cnt", frame2.v, 1.0)
    counts = engine.accums.array("Tag", "inf_cnt")
    out = {
        "n_influencers": int(influencers.size()),
        "n_comments": int(comments.size()),
        "n_tags": int((counts > 0).sum()),
    }
    engine.accums.reset("Tag", "inf_cnt")
    return out


BI_QUERIES = {
    "bi1": bi1_music_women,
    "bi2": bi2_tag_activity,
    "bi3": bi3_person_engagement,
    "bi4": bi4_city_social,
    "bi5": bi5_influencer_tags,
}
