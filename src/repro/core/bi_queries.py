"""Five LDBC_SNB-BI-style graph-aggregation queries (paper §7.3), expressed
as *installed GSQL text* (DESIGN.md §8).

Each query is a named GSQL program in :data:`BI_GSQL`, installed once per
session (parse + schema validation up front) and executed with bound
parameters through :class:`~repro.gsql.session.GraphSession` — there is no
imperative traversal code left here.  BI1 is the paper's §6 running example
verbatim; BI2's second aggregation (tag counts over the date-active
comments) is the POST-ACCUM block; BI5's accumulator-driven influencer
filter is a two-statement program whose second seed filters on ``@deg``.

The ``bi*`` callables keep their historical signatures — they accept either
an engine (a cached session is created for it) or a session — and shape the
:class:`~repro.core.query.QueryResult` into the small summary dicts the
serving layer ships.  Results are bit-identical to the pre-GSQL builder
implementations (pinned by ``tests/test_gsql_exec.py``).
"""

from __future__ import annotations

import numpy as np

from repro.gsql.session import GraphSession

BI_GSQL: dict[str, str] = {
    # women who created comments tagged $tag after $date; count per person
    # (the paper's running example)
    "bi1": """
        SELECT p
        FROM Tag:t -(HasTag:e1)- Comment:c -(HasCreator:e2)- Person:p
        WHERE t.name == $tag AND e2.creationDate > $date
          AND p.gender == 'Female'
        ACCUM p.@cnt += 1
    """,
    # comment volume per tag inside a date window: the main SELECT matches
    # the date-active comments, POST-ACCUM aggregates their tags
    "bi2": """
        SELECT c
        FROM Comment:c -(HasCreator:e)- Person:p
        WHERE e.creationDate >= $lo AND e.creationDate <= $hi
        POST-ACCUM c -(HasTag:e2)- Tag:t ACCUM t.@tag_cnt += 1
    """,
    # per-person total length of their long comments (cross-entity ACCUM)
    "bi3": """
        SELECT p
        FROM Comment:c -(HasCreator:e)- Person:p
        WHERE c.length > $min_len
        ACCUM p.@tot_len += c.length
    """,
    # friend counts of persons in one city (1-hop Knows aggregation)
    "bi4": """
        SELECT s
        FROM Person:s -(Knows:k)-> Person:q
        WHERE s.locationCity == $city
        ACCUM s.@deg += 1
    """,
    # tags used by recent comments of well-connected persons: statement 1
    # computes out-degrees, statement 2 seeds on the @deg filter
    "bi5": """
        SELECT q FROM Person:a -(Knows:k)-> Person:q ACCUM a.@deg += 1;

        SELECT t
        FROM Person:s -(HasCreator:e)- Comment:c -(HasTag:e2)- Tag:t
        WHERE s.@deg >= $min_degree AND e.creationDate > $date
        ACCUM t.@inf_cnt += 1
    """,
}


def install_bi_queries(session: GraphSession) -> None:
    """Install (parse + validate) the whole BI suite on a session."""
    for name, text in BI_GSQL.items():
        session.install(name, text)


def _session(engine_or_session) -> GraphSession:
    """Resolve the session the BI suite runs on, installing it on first use."""
    if isinstance(engine_or_session, GraphSession):
        session = engine_or_session
    else:
        session = GraphSession.for_engine(engine_or_session)
    if not session.is_installed("bi1"):
        install_bi_queries(session)
    return session


def bi1_music_women(engine, tag_name: str = "Music", date: int = 20100101):
    res = _session(engine).query("bi1", tag=tag_name, date=date)
    counts = res.accumulators.get("cnt", np.zeros(1))
    return {
        "n_persons": int(res.vset.size()),
        "total_comments": float(counts.sum()),
        "max_per_person": float(counts.max()) if len(counts) else 0.0,
        "edges_scanned": res.n_edges_scanned,
    }


def bi2_tag_activity(engine, date_lo: int = 20120101, date_hi: int = 20151231):
    res = _session(engine).query("bi2", lo=date_lo, hi=date_hi)
    counts = res.accumulators["tag_cnt"]
    return {
        # SELECT c projects the date-active comments (forward-matched seed)
        "n_active_comments": int(res.vset.size()),
        "n_tags_touched": int((counts > 0).sum()),
        "top_tag_count": float(counts.max()) if len(counts) else 0.0,
    }


def bi3_person_engagement(engine, min_len: int = 500):
    res = _session(engine).query("bi3", min_len=min_len)
    tot = res.accumulators["tot_len"]
    return {
        "n_persons": int((tot > 0).sum()),
        "total_length": float(tot.sum()),
    }


def bi4_city_social(engine, city: str = "city_1"):
    res = _session(engine).query("bi4", city=city)
    deg = res.accumulators["deg"]
    return {
        "n_friend_edges": float(deg.sum()),
        "max_degree": float(deg.max()) if len(deg) else 0.0,
    }


def bi5_influencer_tags(engine, min_degree: int = 10, date: int = 20140101):
    res = _session(engine).query("bi5", min_degree=min_degree, date=date)
    counts = res.accumulators["inf_cnt"]
    return {
        "n_influencers": int(res.alias_sets["s"].size()),
        "n_comments": int(res.alias_sets["c"].size()),
        "n_tags": int((counts > 0).sum()),
    }


BI_QUERIES = {
    "bi1": bi1_music_women,
    "bi2": bi2_tag_activity,
    "bi3": bi3_person_engagement,
    "bi4": bi4_city_social,
    "bi5": bi5_influencer_tags,
}
