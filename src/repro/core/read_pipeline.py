"""Parallel chunk-pipelined read path (DESIGN.md §5).

The query-path readers used to fetch and decode one column chunk at a time
on the caller thread — every surviving chunk serially paid the object
store's modeled ~30 ms first-byte latency while the ``IOPool`` that already
pipelines startup loading sat idle.  This module splits each gather into
two phases, mirroring the paper's §4.2 fetch/decode/compute overlap:

1. **Planning** (:func:`plan_vertex_read` / :func:`plan_edge_read`): walk
   the (file, row-group) partition of the request, apply zone-map pruning
   up front (shared :func:`~repro.core.plan.zone_map_rejects` test, so the
   plan and the prefetcher agree chunk-for-chunk), and emit one
   :class:`ChunkFetchPlan` covering *all* surviving (column, row group)
   chunks — each with its group-local rows and output scatter positions.

2. **Execution** (:func:`execute_plan`): issue the plan as a batch of
   streamed per-chunk jobs through the engine's shared ``IOPool`` — each
   job runs lake fetch *and* raw→decoded on a worker thread
   (``CacheManager.get_unit`` + per-unit-locked ``read``), with at most
   ``pipe=<depth>`` jobs in flight so one chunk's decode overlaps another's
   fetch wait — and stream results into the caller's scatter buffers in
   deterministic plan order as they complete.  Without a pool the same plan
   executes sequentially on the caller thread: bit-identical output, the
   parity baseline.  (Whether a pool is passed is decided upstream: the
   engine's ``_query_pool`` consults the ``pipe`` perf flag unless the
   caller pins an explicit override.)

A :class:`ReadContext` scopes deduplication to one gather: the E/U/V/ACCUM
stages of ``_edge_scan_staged`` share it, so a chunk two stages touch (e.g.
``u.``/``v.`` columns of the same vertex file when an edge type is a
self-loop) is fetched and pool-dispatched once; later stages read it
directly from the context.  Across gathers the cache manager's single-flight
admission provides the same never-fetch-twice guarantee globally.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core.cache.manager import CacheManager
from repro.core.cache.units import ChunkRef
from repro.core.plan import zone_map_rejects_multi
from repro import perf_flags


@dataclasses.dataclass
class ChunkRequest:
    """One surviving (column, row group) chunk of a gather: which rows of it
    to decode and where their values scatter in the output frame."""

    ref: ChunkRef
    meta: object                # ColumnFileMeta of the owning file
    kind: str                   # "vertex" | "edge"
    rows: np.ndarray            # chunk-local row indices to read
    pos: np.ndarray             # positions in the length-n output arrays


@dataclasses.dataclass
class ChunkFetchPlan:
    """Every chunk one gather must read, zone-map pruning already applied.

    ``reject`` flags request rows whose row group a bound definitively
    rejected — their output values are filler and must not be consulted
    (identical contract to the pre-pipeline readers).
    """

    n: int                      # request length (output array length)
    columns: list[str]
    requests: list[ChunkRequest]
    reject: np.ndarray


class ReadContext:
    """Per-gather dedup scope: cache key -> unit already materialized by an
    earlier stage of the same gather.  Not thread-safe by design — stages of
    one gather run from one caller thread; only the chunk jobs fan out.

    Holding unit references pins their memory for the gather's lifetime
    (eviction may drop them from the cache, but the context keeps them
    alive), so peak memory is bounded by one gather's surviving chunk set —
    the price of never re-entering the cache across E/U/V/ACCUM stages.
    Executors only retain units when a context asks for cross-stage reuse;
    context-free reads drop each unit as soon as its values are scattered.
    """

    def __init__(self):
        self.units: dict[str, object] = {}


# ---------------------------------------------------------------------------
# planning
# ---------------------------------------------------------------------------

def plan_vertex_read_multi(
    topology, vertex_type: str, dense_ids: np.ndarray, columns: Sequence[str],
    bounds_list: Sequence[Optional[dict]], counters: Optional[dict] = None,
) -> tuple[ChunkFetchPlan, np.ndarray]:
    """Multi-rider variant of :func:`plan_vertex_read` (DESIGN.md §9).

    One shared request row set, one bounds map per rider.  A row group is
    dropped from the plan only when *every* rider's bounds reject it; the
    returned ``(R, n)`` reject matrix flags each rider's rows whenever that
    rider's own bounds reject the owning group — fetched for another rider
    or not — so restricting the shared output by rider *r*'s row of the
    matrix reproduces rider *r*'s solo read verdicts exactly.  The plan's
    own ``reject`` is the all-rider AND (rows of truly skipped chunks)."""
    bounds_list = [b or {} for b in bounds_list]
    n_riders = len(bounds_list)
    dense_ids = np.asarray(dense_ids, dtype=np.int64)
    n = len(dense_ids)
    rejects = np.zeros((n_riders, n), dtype=bool)
    requests: list[ChunkRequest] = []
    if n == 0 or not columns:
        return ChunkFetchPlan(n, list(columns), requests,
                              rejects.all(axis=0)), rejects
    any_bounds = any(bounds_list)
    file_ids, rows = topology.dense_to_file_row(vertex_type, dense_ids)
    for fid in np.unique(file_ids):
        finfo = topology.file_registry.get(int(fid))
        if finfo is None:  # dangling vertices have no attributes
            continue
        meta = topology.vertex_file_metas[finfo.key]
        sel_f = file_ids == fid
        rows_f = rows[sel_f]
        idx_f = np.flatnonzero(sel_f)
        for g in meta.row_groups:
            in_g = (rows_f >= g.first_row) & (rows_f < g.first_row + g.n_rows)
            if not in_g.any():
                continue
            pos = idx_f[in_g]
            if any_bounds:
                skip, per_rider = zone_map_rejects_multi(
                    meta, g.index, bounds_list, columns, int(in_g.sum()),
                    counters)
                for r, rej in enumerate(per_rider):
                    if rej:
                        rejects[r, pos] = True
                if skip:
                    continue
            local = rows_f[in_g] - g.first_row
            for c in columns:
                requests.append(ChunkRequest(
                    ChunkRef(finfo.key, c, g.index), meta, "vertex", local, pos))
    return ChunkFetchPlan(n, list(columns), requests,
                          rejects.all(axis=0)), rejects


def plan_vertex_read(
    topology, vertex_type: str, dense_ids: np.ndarray, columns: Sequence[str],
    bounds: Optional[dict] = None, counters: Optional[dict] = None,
) -> ChunkFetchPlan:
    """Partition a dense-id point-lookup request into per-chunk requests."""
    plan, rejects = plan_vertex_read_multi(
        topology, vertex_type, dense_ids, columns, [bounds], counters=counters)
    plan.reject = rejects[0]
    return plan


def plan_edge_read_multi(
    topology, edge_type: str, eids: np.ndarray, columns: Sequence[str],
    bounds_list: Sequence[Optional[dict]], counters: Optional[dict] = None,
) -> tuple[ChunkFetchPlan, np.ndarray]:
    """Multi-rider variant of :func:`plan_edge_read` — same union-skip /
    per-rider-reject contract as :func:`plan_vertex_read_multi`."""
    bounds_list = [b or {} for b in bounds_list]
    n_riders = len(bounds_list)
    eids = np.asarray(eids, dtype=np.int64)
    n = len(eids)
    rejects = np.zeros((n_riders, n), dtype=bool)
    requests: list[ChunkRequest] = []
    if n == 0 or not columns:
        return ChunkFetchPlan(n, list(columns), requests,
                              rejects.all(axis=0)), rejects
    any_bounds = any(bounds_list)
    offsets = topology.plane.eid_offsets(edge_type)
    lists = topology.all_edge_lists(edge_type)
    list_idx = np.searchsorted(offsets, eids, side="right") - 1
    for li in np.unique(list_idx):
        sel = list_idx == li
        local_rows = eids[sel] - offsets[li]
        pos = np.flatnonzero(sel)
        el = lists[li]
        meta = topology.edge_file_metas[el.file_key]
        for g in meta.row_groups:
            in_g = (local_rows >= g.first_row) & (local_rows < g.first_row + g.n_rows)
            if not in_g.any():
                continue
            gpos = pos[in_g]
            if any_bounds:
                skip, per_rider = zone_map_rejects_multi(
                    meta, g.index, bounds_list, columns, int(in_g.sum()),
                    counters)
                for r, rej in enumerate(per_rider):
                    if rej:
                        rejects[r, gpos] = True
                if skip:
                    continue
            local = local_rows[in_g] - g.first_row
            for c in columns:
                requests.append(ChunkRequest(
                    ChunkRef(el.file_key, c, g.index), meta, "edge", local, gpos))
    return ChunkFetchPlan(n, list(columns), requests,
                          rejects.all(axis=0)), rejects


def plan_edge_read(
    topology, edge_type: str, eids: np.ndarray, columns: Sequence[str],
    bounds: Optional[dict] = None, counters: Optional[dict] = None,
) -> ChunkFetchPlan:
    """Partition a global-edge-id request into per-chunk requests."""
    plan, rejects = plan_edge_read_multi(
        topology, edge_type, eids, columns, [bounds], counters=counters)
    plan.reject = rejects[0]
    return plan


# ---------------------------------------------------------------------------
# execution
# ---------------------------------------------------------------------------

def _scatter(out: dict, column: str, n: int, pos: np.ndarray, vals: np.ndarray) -> None:
    if out[column] is None:
        out[column] = np.empty(n, dtype=vals.dtype)
        if vals.dtype == object:
            out[column][:] = ""
        else:
            out[column][:] = 0
    out[column][pos] = vals


def _count_read(counters: Optional[dict], req: ChunkRequest, decode_delta: int) -> None:
    if counters is None:
        return
    counters["chunks_read"] += 1
    counters["rows_decoded"] += decode_delta
    try:
        counters["bytes_read"] += req.meta.chunk(req.ref.column, req.ref.row_group).length
    except KeyError:
        pass


def pipeline_depth() -> int:
    """In-flight chunk budget of the pipelined executor (``pipe=<depth>``)."""
    return max(1, int(perf_flags.value("pipe", 16)))


def execute_plan(
    plan: ChunkFetchPlan,
    cache: CacheManager,
    counters: Optional[dict] = None,
    pool=None,
    ctx: Optional[ReadContext] = None,
) -> dict[str, Optional[np.ndarray]]:
    """Materialize a fetch plan into per-column scatter buffers.

    With a pool, each fresh chunk becomes one worker job — cache admission
    (single-flight lake fetch) plus per-unit-locked decode — with at most
    :func:`pipeline_depth` jobs in flight; the caller consumes results in
    deterministic plan order (scatter targets are disjoint, so ordering
    only fixes counter/decode determinism, not values).  Without a pool the
    same jobs run inline: the sequential parity path.
    """
    out: dict[str, Optional[np.ndarray]] = {c: None for c in plan.columns}
    if not plan.requests:
        return out
    units = ctx.units if ctx is not None else {}

    def _job(req: ChunkRequest):
        unit = units.get(req.ref.cache_key())
        if unit is None:
            unit = cache.get_unit(req.ref, req.meta, req.kind)
        return unit, *cache.read_unit(unit, req.rows)

    # whether to pipeline is decided where ``pool`` is resolved (the engine's
    # _query_pool consults the ``pipe`` flag unless the caller pinned an
    # explicit override); a non-None pool here *is* the decision
    if pool is None:
        for req in plan.requests:
            unit, vals, delta = _job(req)
            if ctx is not None:
                units[req.ref.cache_key()] = unit
            _count_read(counters, req, delta)
            _scatter(out, req.ref.column, plan.n, req.pos, vals)
        return out

    # split by dedup state: chunks an earlier stage of this gather already
    # materialized are read inline (O(1) cache hit, no pool round-trip)
    fresh = [r for r in plan.requests if r.ref.cache_key() not in units]
    for req in plan.requests:
        if req.ref.cache_key() in units:
            unit, vals, delta = _job(req)
            _count_read(counters, req, delta)
            _scatter(out, req.ref.column, plan.n, req.pos, vals)

    # one streamed fetch+decode job per fresh chunk: at most pipeline_depth()
    # jobs in flight, so `pipe=<depth>` bounds concurrent lake requests, and
    # chunk N's decode overlaps chunk N+k's fetch wait on the worker pool.
    # Units are retained only while a ReadContext needs them for cross-stage
    # dedup; otherwise each unit is dropped once its values are scattered,
    # so cache eviction can actually free memory mid-gather.
    def _consume(req: ChunkRequest, result) -> None:
        unit, vals, delta = result
        if ctx is not None:
            units[req.ref.cache_key()] = unit
        _count_read(counters, req, delta)
        _scatter(out, req.ref.column, plan.n, req.pos, vals)

    pool.map_pipelined(fresh, _job, lambda req, res: _consume(req, res),
                       depth=pipeline_depth())
    return out
