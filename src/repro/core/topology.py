"""Graph topology: registry + edge lists + build/materialize (paper §4).

``GraphTopology`` owns:

- the **vertex file registry** (global file IDs, per-type dense offsets),
- the **Vertex IDM** during builds (deallocated afterwards, §4.3),
- one **edge list per edge file** (§4.1), built in parallel and pipelined with
  lake I/O (§4.2),
- **materialization**: edge lists persist to the lake as binary blobs so a
  second connection skips the build entirely (§4.2),
- **incremental maintenance**: added/deleted edge files only touch their own
  edge lists (the reason the paper chose edge lists over CSR).

Startup phase timings are recorded in ``self.timings`` — the startup-breakdown
benchmark (paper Fig. 9) reads them.
"""

from __future__ import annotations

import json
import time
from typing import Callable, Optional

import numpy as np

from repro.core.csr import CSRIndex
from repro.core.edge_list import EdgeList, build_edge_list
from repro.core.topology_plane import TopologyPlane
from repro.perf_flags import enabled as perf_enabled
from repro.core.types import (
    DANGLING_FILE_ID,
    GraphSchema,
    VertexFileInfo,
    VertexTypeInfo,
    split_transformed,
)
from repro.core.vertex_idm import VertexIDM
from repro.lakehouse.columnfile import ColumnFileMeta, read_column_chunk, read_footer
from repro.lakehouse.io_pool import IOPool, prefetch_iter
from repro.lakehouse.objectstore import ObjectStore
from repro.lakehouse.retry import lake_get, lake_get_json
from repro.lakehouse.table import LakeCatalog


def tid_to_dense_for(
    files, n_real: int, vertex_type: str, tids: np.ndarray
) -> np.ndarray:
    """transformed IDs -> dense indices over a pinned file registry.

    Shared by the mutable :class:`GraphTopology` and the immutable
    :class:`~repro.core.epochs.GraphEpoch`, which pin different ``files``
    tuples of the same vertex type (DESIGN.md §7)."""
    file_ids, rows = split_transformed(tids)
    max_fid = int(file_ids.max()) if len(file_ids) else 0
    lut = np.full(max(max_fid + 1, 1), -1, dtype=np.int64)
    for f in files:
        if f.file_id <= max_fid:
            lut[f.file_id] = f.dense_offset
    dense = np.where(
        file_ids == DANGLING_FILE_ID,
        n_real + rows,
        lut[np.minimum(file_ids, max_fid)] + rows,
    )
    if np.any((file_ids != DANGLING_FILE_ID) & (lut[np.minimum(file_ids, max_fid)] < 0)):
        bad = file_ids[(file_ids != DANGLING_FILE_ID) & (lut[np.minimum(file_ids, max_fid)] < 0)][0]
        raise KeyError(f"file id {bad} is not a {vertex_type} file")
    return dense.astype(np.int64)


def dense_to_file_row_for(files, n_real: int, dense: np.ndarray):
    """dense indices -> (file_id, row) pairs over a pinned file registry."""
    offsets = np.array([f.dense_offset for f in files], dtype=np.int64)
    fids = np.array([f.file_id for f in files], dtype=np.int64)
    dense = np.asarray(dense, dtype=np.int64)
    idx = np.searchsorted(offsets, dense, side="right") - 1
    idx = np.clip(idx, 0, max(len(offsets) - 1, 0))
    if len(offsets):
        file_ids = fids[idx]
        rows = dense - offsets[idx]
    else:
        file_ids = np.zeros_like(dense)
        rows = dense
    dangling = dense >= n_real
    file_ids = np.where(dangling, DANGLING_FILE_ID, file_ids)
    rows = np.where(dangling, dense - n_real, rows)
    return file_ids, rows


class GraphTopology:
    def __init__(self, schema: GraphSchema):
        self.schema = schema
        self.vertex_info: dict[str, VertexTypeInfo] = {}
        self.file_registry: dict[int, VertexFileInfo] = {}
        self.edge_lists: dict[str, list[EdgeList]] = {e: [] for e in schema.edge_types}
        self.edge_file_metas: dict[str, ColumnFileMeta] = {}   # edge file key -> meta
        self.vertex_file_metas: dict[str, ColumnFileMeta] = {}  # vertex file key -> meta
        self.idm: Optional[VertexIDM] = None
        self.timings: dict[str, float] = {}
        self._next_file_id = DANGLING_FILE_ID + 1
        self._n_dangling = 0
        self._edge_snapshot_ids: dict[str, int] = {}
        # monotonic mutation counter: bumped on build/load/refresh so epochs
        # (core/epochs.py) can pin exactly which topology state they froze
        self.version = 0
        # the topology plane: physical representations (edge lists + CSR) and
        # the adaptive per-scan dispatch over them (DESIGN.md §3)
        self.plane = TopologyPlane(self)

    # ------------------------------------------------------------------ registry

    def register_vertex_file(
        self, vertex_type: str, key: str, n_rows: int
    ) -> VertexFileInfo:
        vt = self.vertex_info[vertex_type]
        info = VertexFileInfo(
            file_id=self._next_file_id,
            vertex_type=vertex_type,
            key=key,
            ordinal=len(vt.files),
            n_rows=n_rows,
            dense_offset=sum(f.n_rows for f in vt.files),
        )
        self._next_file_id += 1
        vt.files.append(info)
        self.file_registry[info.file_id] = info
        return info

    def n_real_vertices(self, vertex_type: str) -> int:
        return sum(f.n_rows for f in self.vertex_info[vertex_type].files)

    def n_vertices(self, vertex_type: str) -> int:
        """Dense-space size incl. the dangling tail (upper bound, see types.py)."""
        return self.n_real_vertices(vertex_type) + self._n_dangling

    def tid_to_dense(self, vertex_type: str, tids: np.ndarray) -> np.ndarray:
        """transformed IDs -> dense indices for ``vertex_type``. Vectorized."""
        return tid_to_dense_for(
            self.vertex_info[vertex_type].files,
            self.n_real_vertices(vertex_type), vertex_type, tids,
        )

    def dense_to_file_row(self, vertex_type: str, dense: np.ndarray):
        """dense indices -> (file_id, row) pairs. Vectorized over sorted offsets."""
        return dense_to_file_row_for(
            self.vertex_info[vertex_type].files,
            self.n_real_vertices(vertex_type), dense,
        )

    def all_edge_lists(self, edge_type: str) -> list[EdgeList]:
        return self.edge_lists[edge_type]

    def n_edges(self, edge_type: Optional[str] = None) -> int:
        if edge_type is not None:
            return sum(el.n_edges for el in self.edge_lists[edge_type])
        return sum(self.n_edges(e) for e in self.edge_lists)

    def topology_bytes(self) -> int:
        return sum(el.nbytes() for els in self.edge_lists.values() for el in els)

    # ------------------------------------------------------------------ building

    def build(
        self,
        store: ObjectStore,
        lake: LakeCatalog,
        pool: Optional[IOPool] = None,
        file_filter: Optional[Callable[[str, int], bool]] = None,
        deallocate_idm: bool = False,
    ) -> None:
        """Topology-only startup load (paper §4.3).

        ``file_filter(file_key, index)`` restricts which *edge* files this
        node owns — the file-based sharding used by the distributed engine.
        """
        own_pool = pool is None
        pool = pool or IOPool(n_threads=8)
        try:
            t0 = time.perf_counter()
            # 1. connect: resolve data files + footers for every mapped table
            for name, vt in self.schema.vertex_types.items():
                self.vertex_info[name] = VertexTypeInfo(
                    name=name, table=vt.table, primary_key=vt.primary_key
                )
            vertex_jobs = []
            for name, vt in self.schema.vertex_types.items():
                table = lake.table(vt.table)
                for key in table.data_files():
                    vertex_jobs.append((name, key))
            edge_jobs = []
            for ename, et in self.schema.edge_types.items():
                table = lake.table(et.table)
                self._edge_snapshot_ids[ename] = table.current_snapshot().snapshot_id
                for i, key in enumerate(table.data_files()):
                    if file_filter is None or file_filter(key, i):
                        edge_jobs.append((ename, key))

            for (name, key), meta in prefetch_iter(
                pool, vertex_jobs, lambda jk: read_footer(store, jk[1]), depth=8
            ):
                self.vertex_file_metas[key] = meta
                self.register_vertex_file(name, key, meta.n_rows)
            for (ename, key), meta in prefetch_iter(
                pool, edge_jobs, lambda jk: read_footer(store, jk[1]), depth=8
            ):
                self.edge_file_metas[key] = meta
            self.timings["connect_s"] = time.perf_counter() - t0

            # 2. Vertex IDM building: pipelined PK-chunk fetch -> batch insert
            t1 = time.perf_counter()
            self.idm = VertexIDM()

            def _fetch_pk(job):
                vtype, finfo = job
                meta = self.vertex_file_metas[finfo.key]
                pk = self.vertex_info[vtype].primary_key
                parts = [
                    read_column_chunk(store, meta, pk, g.index)
                    for g in meta.row_groups
                ]
                return np.concatenate(parts) if len(parts) > 1 else parts[0]

            idm_jobs = [
                (name, f)
                for name, vt in self.vertex_info.items()
                for f in vt.files
            ]
            for (name, finfo), pk_col in prefetch_iter(pool, idm_jobs, _fetch_pk, depth=8):
                self.idm.insert_batch(name, pk_col, finfo.file_id)
            self.idm.freeze()
            self.timings["idm_build_s"] = time.perf_counter() - t1

            # 3. Edge list building: pipelined FK fetch -> translate -> stats
            t2 = time.perf_counter()

            def _fetch_fk(job):
                ename, key = job
                et = self.schema.edge_types[ename]
                meta = self.edge_file_metas[key]
                src_parts, dst_parts, rows = [], [], []
                for g in meta.row_groups:
                    src_parts.append(read_column_chunk(store, meta, et.src_column, g.index))
                    dst_parts.append(read_column_chunk(store, meta, et.dst_column, g.index))
                    rows.append(g.n_rows)
                return (
                    np.concatenate(src_parts) if len(src_parts) > 1 else src_parts[0],
                    np.concatenate(dst_parts) if len(dst_parts) > 1 else dst_parts[0],
                    rows,
                )

            for (ename, key), (src_raw, dst_raw, rows) in prefetch_iter(
                pool, edge_jobs, _fetch_fk, depth=8
            ):
                et = self.schema.edge_types[ename]
                el = build_edge_list(
                    ename, key, src_raw, dst_raw, rows,
                    self.idm, et.src_type, et.dst_type, self.tid_to_dense,
                )
                self.edge_lists[ename].append(el)
            self._n_dangling = self.idm.n_dangling()
            self.timings["edge_list_build_s"] = time.perf_counter() - t2
            self.version += 1
            self.plane.invalidate()

            if deallocate_idm:
                self.idm.deallocate()
        finally:
            if own_pool:
                pool.close()

    # ---------------------------------------------------------- materialization

    def _blob_key(self, ename: str, i: int) -> str:
        # blob keys carry the topology version: a re-materialization never
        # overwrites a blob an already-published MANIFEST references, so a
        # concurrently-loading second connection can't read a torn mix of
        # old manifest + new blobs (superseded blobs are simply orphaned)
        return f"topology/{ename}/{i:05d}-v{self.version}.el"

    def _csr_key(self, ename: str) -> str:
        return f"topology/csr/{ename}-v{self.version}.csr"

    def _manifest(self, edge_list_keys: Optional[dict] = None) -> dict:
        return {
            "n_dangling": self._n_dangling,
            "next_file_id": self._next_file_id,
            "edge_snapshot_ids": self._edge_snapshot_ids,
            # which topology state these blobs serialize; lets the delta
            # re-materialization after an epoch advance (DESIGN.md §7) diff
            # what is already persisted instead of re-uploading everything
            "topology_version": self.version,
            "edge_sources": {
                ename: [el.file_key for el in els]
                for ename, els in self.edge_lists.items()
            },
            "vertex_types": {
                name: {
                    "table": vt.table,
                    "primary_key": vt.primary_key,
                    "files": [
                        {
                            "file_id": f.file_id,
                            "key": f.key,
                            "ordinal": f.ordinal,
                            "n_rows": f.n_rows,
                            "dense_offset": f.dense_offset,
                        }
                        for f in vt.files
                    ],
                }
                for name, vt in self.vertex_info.items()
            },
            "edge_lists": edge_list_keys if edge_list_keys is not None else {
                ename: [self._blob_key(ename, i) for i in range(len(els))]
                for ename, els in self.edge_lists.items()
            },
            # mirrors the materialize() upload guard: with the csr flag off
            # no blobs are written, so none may be referenced
            "csr": {
                ename: self._csr_key(ename)
                for ename in (self.plane.built_csrs() if perf_enabled("csr") else ())
            },
        }

    def materialize(self, store: ObjectStore, pool: Optional[IOPool] = None) -> None:
        """Persist edge lists + CSR indexes + registry to the lake (§4.2).

        CSR indexes are built eagerly here (once per edge type) so the fast
        "second connection" path restores *both* physical representations and
        never pays the grouping cost again.
        """
        t0 = time.perf_counter()
        own = pool is None
        pool = pool or IOPool(n_threads=8)
        try:
            futs = []
            for ename, els in self.edge_lists.items():
                for i, el in enumerate(els):
                    futs.append(
                        pool.submit(store.put, self._blob_key(ename, i), el.to_bytes())
                    )
            for f in futs:
                f.result()
            # CSR build + upload is an *extra* representation the paper's
            # startup path doesn't have — timed separately (csr_build_s) so
            # the Fig. 8/9 materialize phase stays comparable.
            t_csr = time.perf_counter()
            if perf_enabled("csr"):
                csr_futs = []
                for ename in self.edge_lists:
                    csr = self.plane.csr(ename)
                    csr_futs.append(
                        pool.submit(store.put, self._csr_key(ename), csr.to_bytes())
                    )
                for f in csr_futs:
                    f.result()
            csr_s = time.perf_counter() - t_csr
            store.put("topology/MANIFEST.json", json.dumps(self._manifest()).encode())
        finally:
            if own:
                pool.close()
        self.timings["csr_build_s"] = csr_s
        self.timings["materialize_s"] = time.perf_counter() - t0 - csr_s

    def rematerialize_delta(self, store: ObjectStore,
                            pool: Optional[IOPool] = None,
                            csr_source=None) -> dict:
        """Refresh the persisted topology after an incremental epoch advance
        (ROADMAP: stale-manifest gap) — so a second connection pays the fast
        ``load_materialized`` path against the *current* lake state instead
        of a stale blob (or, worse, a full first-connection build).

        Append-only deltas upload only the new tail blobs of each changed
        edge type — the manifest keeps referencing the already-persisted
        prefix blobs, which stay valid because per-file edge lists are
        immutable.  Removals serialize that edge type's whole run under
        fresh version-suffixed keys (never overwriting blobs the published
        manifest references — a concurrently-loading second connection
        reads either the old consistent set or, after the final manifest
        swap, the new one).  The manifest is always rewritten — it is tiny.

        ``csr_source`` is the per-epoch CSR blob scheme (DESIGN.md §13): a
        plane whose built CSRs are *current* for this version — the
        advance's new epoch plane, holding the carried/extended indexes
        (this builder's own plane was invalidated by ``refresh_edges``, so
        it cannot serve).  Its CSRs upload under this version's
        version-suffixed keys and the manifest references them, keeping the
        CSR fast path for shard workers and second connections.  Without a
        source the CSR refs are dropped (stale for this version; a second
        connection rebuilds lazily).

        Returns upload stats.  Falls back to a full :meth:`materialize` when
        no (new-format) manifest exists yet.
        """
        t0 = time.perf_counter()
        if not self.is_materialized(store):
            self.materialize(store, pool=pool)
            return {"mode": "full", "blobs_uploaded": -1,
                    "wall_s": time.perf_counter() - t0}
        man = lake_get_json(store, "topology/MANIFEST.json")
        old_sources = man.get("edge_sources")
        own = pool is None
        pool = pool or IOPool(n_threads=8)
        uploaded = 0
        try:
            if old_sources is None:
                self.materialize(store, pool=pool)
                return {"mode": "full", "blobs_uploaded": -1,
                        "wall_s": time.perf_counter() - t0}
            futs = []
            keys_by_type: dict[str, list[str]] = {}
            for ename, els in self.edge_lists.items():
                cur = [el.file_key for el in els]
                old = old_sources.get(ename, [])
                old_keys = man["edge_lists"].get(ename, [])
                # append-only: the persisted prefix blobs stay referenced,
                # only the tail uploads; anything else (removal/reorder):
                # serialize the whole run fresh
                if cur[:len(old)] == old and len(old_keys) == len(old):
                    keys, start = list(old_keys), len(old)
                else:
                    keys, start = [], 0
                for i in range(start, len(els)):
                    key = self._blob_key(ename, i)
                    keys.append(key)
                    futs.append(pool.submit(store.put, key, els[i].to_bytes()))
                keys_by_type[ename] = keys
            for f in futs:
                f.result()
            uploaded = len(futs)
            new_man = self._manifest(edge_list_keys=keys_by_type)
            if csr_source is not None and perf_enabled("csr"):
                csr_refs = {}
                csr_futs = []
                for ename, csr in csr_source.built_csrs().items():
                    key = self._csr_key(ename)
                    if not store.exists(key):
                        csr_futs.append(
                            pool.submit(store.put, key, csr.to_bytes()))
                    csr_refs[ename] = key
                for f in csr_futs:
                    f.result()
                uploaded += len(csr_futs)
                new_man["csr"] = csr_refs
            else:
                new_man["csr"] = {}   # stale for this version; rebuilt lazily
            store.put("topology/MANIFEST.json", json.dumps(new_man).encode())
        finally:
            if own:
                pool.close()
        return {"mode": "delta", "blobs_uploaded": uploaded,
                "wall_s": time.perf_counter() - t0}

    @staticmethod
    def is_materialized(store: ObjectStore) -> bool:
        return store.exists("topology/MANIFEST.json")

    def load_materialized(
        self,
        store: ObjectStore,
        lake: LakeCatalog,
        pool: Optional[IOPool] = None,
    ) -> None:
        """Second-connection startup: load persisted topology, skip rebuild."""
        t0 = time.perf_counter()
        man = lake_get_json(store, "topology/MANIFEST.json")
        self._n_dangling = man["n_dangling"]
        self._next_file_id = man["next_file_id"]
        self._edge_snapshot_ids = dict(man["edge_snapshot_ids"])
        for name, vt_json in man["vertex_types"].items():
            vt = VertexTypeInfo(
                name=name, table=vt_json["table"], primary_key=vt_json["primary_key"]
            )
            for fj in vt_json["files"]:
                info = VertexFileInfo(
                    file_id=fj["file_id"],
                    vertex_type=name,
                    key=fj["key"],
                    ordinal=fj["ordinal"],
                    n_rows=fj["n_rows"],
                    dense_offset=fj["dense_offset"],
                )
                vt.files.append(info)
                self.file_registry[info.file_id] = info
            self.vertex_info[name] = vt
        self.timings["connect_s"] = time.perf_counter() - t0

        t1 = time.perf_counter()
        own = pool is None
        pool = pool or IOPool(n_threads=8)
        try:
            for ename, keys in man["edge_lists"].items():
                blobs = [pool.submit(lake_get, store, k) for k in keys]
                self.edge_lists[ename] = [EdgeList.from_bytes(b.result()) for b in blobs]
            self.plane.invalidate()
            # restore CSR indexes persisted alongside the edge lists — the
            # second connection gets both physical representations for free.
            # The baseline (csr flag off) must not pay the download either.
            if perf_enabled("csr"):
                for ename, key in man.get("csr", {}).items():
                    if store.exists(key):
                        self.plane.attach_csr(ename, CSRIndex.from_bytes(lake_get(store, key)))
            # footers for vertex files are still needed for attribute access
            all_keys = [f.key for vt in self.vertex_info.values() for f in vt.files]
            for key, meta in prefetch_iter(pool, all_keys, lambda k: read_footer(store, k), depth=8):
                self.vertex_file_metas[key] = meta
            for ename in self.schema.edge_types:
                et_keys = {el.file_key for el in self.edge_lists[ename]}
                for key, meta in prefetch_iter(pool, sorted(et_keys), lambda k: read_footer(store, k), depth=8):
                    self.edge_file_metas[key] = meta
        finally:
            if own:
                pool.close()
        self.version += 1
        self.timings["load_topology_s"] = time.perf_counter() - t1

    # ------------------------------------------------------ incremental updates

    def refresh_edges(
        self, store: ObjectStore, lake: LakeCatalog, edge_type: str
    ) -> tuple[int, int]:
        """Incrementally sync one edge type with its table (paper §4.1).

        Returns (n_added, n_removed) edge lists.  Added files build fresh edge
        lists; removed files just drop theirs — no global rebuild, which is
        the point of the per-file edge-list design.
        """
        et = self.schema.edge_types[edge_type]
        table = lake.table(et.table)
        snap = table.current_snapshot()
        if snap.snapshot_id == self._edge_snapshot_ids.get(edge_type):
            return (0, 0)
        current_files = table.data_files(snap.snapshot_id)
        current = set(current_files)
        have = {el.file_key for el in self.edge_lists[edge_type]}

        removed = have - current
        if removed:
            # rebind, never mutate in place: epochs pin the old list object
            self.edge_lists[edge_type] = [
                el for el in self.edge_lists[edge_type] if el.file_key not in removed
            ]
        # manifest order, not lexicographic: appended lists then land in the
        # same global-edge-id order a cold rebuild would produce, which is
        # what keeps incremental epochs bit-identical to a fresh engine
        added = [k for k in current_files if k not in have]
        if added and (self.idm is None or self.idm.n_mapped(et.src_type) == 0):
            self._rebuild_idm(store)
        for key in added:
            el = self.build_edge_list_for_file(store, edge_type, key)
            self.edge_lists[edge_type] = self.edge_lists[edge_type] + [el]
            self._n_dangling = max(self._n_dangling, self.idm.n_dangling())
        self._edge_snapshot_ids[edge_type] = snap.snapshot_id
        if added or removed:
            # derived representations (CSR, concat cache) are stale now;
            # they rebuild lazily on next demand
            self.version += 1
            self.plane.invalidate(edge_type)
        return (len(added), len(removed))

    def build_edge_list_for_file(self, store: ObjectStore, edge_type: str, key: str):
        """Fetch + translate one edge file into an EdgeList (delta builds)."""
        et = self.schema.edge_types[edge_type]
        meta = read_footer(store, key)
        self.edge_file_metas[key] = meta
        src_parts, dst_parts, rows = [], [], []
        for g in meta.row_groups:
            src_parts.append(read_column_chunk(store, meta, et.src_column, g.index))
            dst_parts.append(read_column_chunk(store, meta, et.dst_column, g.index))
            rows.append(g.n_rows)
        return build_edge_list(
            edge_type, key,
            np.concatenate(src_parts) if len(src_parts) > 1 else src_parts[0],
            np.concatenate(dst_parts) if len(dst_parts) > 1 else dst_parts[0],
            rows, self.idm, et.src_type, et.dst_type, self.tid_to_dense,
        )

    def _rebuild_idm(self, store: ObjectStore) -> None:
        self.idm = VertexIDM()
        for name, vt in self.vertex_info.items():
            for f in vt.files:
                meta = self.vertex_file_metas[f.key]
                parts = [
                    read_column_chunk(store, meta, vt.primary_key, g.index)
                    for g in meta.row_groups
                ]
                self.idm.insert_batch(
                    name, np.concatenate(parts) if len(parts) > 1 else parts[0], f.file_id
                )
        self.idm.freeze()
