"""The point-lookup serving tier: plan-cached fast path for installed
point / single-hop templates (DESIGN.md §10).

The full engine pays lex -> parse -> compile -> staged-scan for every
request, which is the right trade for analytics and exactly the wrong one
for the dominant production traffic shape — "get this vertex", "get its
neighbors, maybe filtered, maybe counted".  This module executes those
shapes directly against what the engine already holds decoded in memory:

- the pinned epoch's per-edge-type CSR (``core/csr.py``) — point adjacency
  is an array-offset slice, never a scan;
- the epoch's frozen Vertex IDM — the ``vertex_id -> dense-id`` probe is
  one binary search;
- already-decoded cached columns, read through the zone-map-guided
  single-chunk path of ``core/read_pipeline.py`` on a cache miss (the
  requested dense ids resolve to exactly the (file, row-group) chunks they
  live in — nothing else is fetched).

Templates are classified at ``install()`` time (``gsql/compiler.py``):

- **green** — point lookup or single-hop whose predicates all sit on the
  primary key and whose accumulator (if any) adds a constant: executes
  with *no lake column access at all* (IDM probe + CSR slice + result
  buffer);
- **yellow** — the same shapes needing a column fetch (non-key predicates,
  column-valued ACCUM): executes through the single-chunk read path, warm
  cache hits stay sub-millisecond, a miss pays one chunk fetch;
- **red** — everything else: routed to the existing full engine unchanged.

Green/yellow templates compile once into a :class:`LookupPlan` (pure data,
no engine references).  Execution *arms* the plan against one pinned epoch
— resolving the CSR, the IDM and the dense-space sizes — and caches the
armed form on the epoch itself (``GraphEpoch.lookup_plans``), so the cache
is invalidated by construction when ``advance()`` publishes a new epoch,
and lazily when a re-install swaps the plan object.  Results are
bit-identical to the full engine on the same epoch: same vset, same
accumulator arrays, same ``n_edges_scanned``, same alias sets.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Optional

import numpy as np

from repro.core.plan import ColumnBounds, merge_bounds, new_pruning_counters
from repro.core.query import QueryResult
from repro.core.types import VSet
from repro.errors import GSQLCompileError


# ---------------------------------------------------------------------------
# plan (pure data — what install-time classification produces)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ParamRef:
    """A ``$name`` placeholder inside a :class:`LookupPlan`, bound per call."""

    name: str


@dataclasses.dataclass(frozen=True)
class Conjunct:
    """One pushable WHERE conjunct: ``column op value``.

    ``op`` is one of ``== != > >= < <= in``; for ``in``, ``value`` is a
    tuple of candidates.  Values (or candidates) may be :class:`ParamRef`.
    """

    column: str
    op: str
    value: object


@dataclasses.dataclass(frozen=True)
class AccumPlan:
    """The single ``sum`` accumulator a lookup template may carry."""

    name: str
    target: str                 # "u" (seed side) | "v" (far side)
    # constant / ParamRef, or a ("e"|"u"|"v", column) reference
    value: object


@dataclasses.dataclass(frozen=True)
class RouteDecision:
    """Install-time traffic-light verdict for one template."""

    tier: str                   # "green" | "yellow" | "red"
    reason: str


@dataclasses.dataclass(frozen=True)
class LookupPlan:
    """A green/yellow template compiled for the fast path (install-time)."""

    name: str
    tier: str                   # "green" | "yellow"
    kind: str                   # "point" | "hop"
    vertex_type: str            # seed vertex type
    pk_value: object            # seed primary-key equality (literal/ParamRef)
    seed_where: tuple = ()      # extra Conjuncts over seed vertex columns
    edge_type: Optional[str] = None
    direction: str = "out"      # resolved frontier orientation of the hop
    target_type: Optional[str] = None
    edge_where: tuple = ()      # Conjuncts over edge columns
    target_where: tuple = ()    # Conjuncts over far-side vertex columns
    accum: Optional[AccumPlan] = None
    select: int = 0             # vertex position of the result set (0|1)
    aliases: tuple = ()         # vertex alias per position
    param_names: frozenset = frozenset()


# ---------------------------------------------------------------------------
# binding + evaluation (mirrors core/query.py Predicate semantics exactly —
# the fast path must be bit-identical to the staged scan)
# ---------------------------------------------------------------------------

def _bind(value, params: dict):
    if isinstance(value, ParamRef):
        try:
            return params[value.name]
        except KeyError:
            raise GSQLCompileError(f"unbound parameter ${value.name}") from None
    return value


_NUMPY_CMP = {
    "==": np.equal, "!=": np.not_equal, ">": np.greater,
    ">=": np.greater_equal, "<": np.less, "<=": np.less_equal,
}


def _eval_conjunct(col: np.ndarray, op: str, value) -> np.ndarray:
    if op == "in":
        values = set(value)
        test = np.asarray(sorted(values, key=repr))
        if col.dtype != object and test.dtype.kind in "biuf":
            return np.isin(col, test)
        return np.asarray([x in values for x in col.tolist()], dtype=bool)
    fn = _NUMPY_CMP[op]
    if col.dtype == object:
        col = np.asarray([str(x) for x in col])
        return fn(col, str(value))
    return fn(col, value)


def _conjunct_bounds(op: str, value) -> Optional[ColumnBounds]:
    if op == "==":
        return ColumnBounds(values=frozenset([value]))
    if op == "in":
        return ColumnBounds(values=frozenset(value))
    if op == ">":
        return ColumnBounds(lo=value, lo_strict=True)
    if op == ">=":
        return ColumnBounds(lo=value)
    if op == "<":
        return ColumnBounds(hi=value, hi_strict=True)
    if op == "<=":
        return ColumnBounds(hi=value)
    return None                  # "!=" degrades to no-prune, like ne()


def _bind_conjuncts(conjuncts: tuple, params: dict) -> list:
    """(column, op, bound value) triples with parameters substituted."""
    out = []
    for c in conjuncts:
        if c.op == "in":
            value = tuple(_bind(v, params) for v in c.value)
        else:
            value = _bind(c.value, params)
        out.append((c.column, c.op, value))
    return out


def _bounds_map(bound_conjuncts: list) -> dict:
    """Per-column zone-map bounds of a conjunction (AND = intersect)."""
    out: dict = {}
    for column, op, value in bound_conjuncts:
        b = _conjunct_bounds(op, value)
        if b is not None:
            out = merge_bounds(out, {column: b})
    return out


def _apply_conjuncts(columns: dict, reject: np.ndarray,
                     bound_conjuncts: list) -> np.ndarray:
    """Survivor mask: zone-map-rejected rows definitively fail; the rest
    evaluate against the fetched values (same protocol as the staged scan)."""
    mask = ~np.asarray(reject, dtype=bool)
    for column, op, value in bound_conjuncts:
        mask &= _eval_conjunct(columns[column], op, value)
    return mask


# ---------------------------------------------------------------------------
# arming — plan + epoch -> directly executable state, cached on the epoch
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ArmedLookup:
    """A LookupPlan resolved against one pinned epoch."""

    plan: LookupPlan
    idm: object                      # the IDM matching the epoch's registry
    csr: object                      # CSRIndex (hop plans) | None (point)
    n_seed: int                      # seed type's dense-space size
    n_target: int                    # far side's dense-space size (hop) | 0
    # the probe table: sorted raw pk values and their dense ids under THIS
    # epoch's file registry (-1 = the raw id's file is not pinned here).
    # Precomputed once at arm time so a probe is a single binary search —
    # the per-call LUT rebuild of ``tid_to_dense_for`` is the difference
    # between ~5us and ~50us per lookup.
    probe_raw: np.ndarray = None
    probe_dense: np.ndarray = None


def _resolve_idm(engine, epoch, vertex_type: str):
    """The IDM whose file-id assignments match the epoch — the same
    resolution ``engine.vset_from_raw_ids`` uses."""
    idm = getattr(epoch, "idm", None) if epoch is not None else None
    if idm is None or idm.n_mapped(vertex_type) == 0:
        topo = engine.topology
        if topo.idm is None or topo.idm.n_mapped(vertex_type) == 0:
            topo._rebuild_idm(engine.store)
        idm = topo.idm
    return idm


def arm_lookup(engine, plan: LookupPlan, epoch) -> ArmedLookup:
    """Resolve (and cache) a plan's epoch-bound execution state.

    The armed form lives on the epoch itself (``epoch.lookup_plans``), so
    ``advance()`` invalidates it by publishing a fresh epoch, and a
    re-install invalidates it lazily — a cached entry is only reused when
    it was armed from the *same* plan object."""
    cache = getattr(epoch, "lookup_plans", None)
    lock = getattr(epoch, "lookup_lock", None)
    if cache is not None:
        with lock:
            entry = cache.get(plan.name)
        if entry is not None and entry.plan is plan:
            return entry
    topo = epoch if epoch is not None else engine.topology
    csr = None
    n_target = 0
    if plan.kind == "hop":
        plane = topo.plane
        csr = plane.csr(plan.edge_type)           # built once, then cached
        n_target = topo.n_vertices(plan.target_type)
    idm = _resolve_idm(engine, epoch, plan.vertex_type)
    probe_raw, probe_dense = _build_probe_table(idm, topo, plan.vertex_type)
    armed = ArmedLookup(
        plan=plan,
        idm=idm,
        csr=csr,
        n_seed=topo.n_vertices(plan.vertex_type),
        n_target=n_target,
        probe_raw=probe_raw,
        probe_dense=probe_dense,
    )
    if cache is not None:
        with lock:
            cache[plan.name] = armed
    return armed


# ---------------------------------------------------------------------------
# execution
# ---------------------------------------------------------------------------

def _build_probe_table(idm, topo, vertex_type: str):
    """Sorted ``(raw pk, dense id)`` arrays for one epoch's registry.

    Raw ids whose file is not pinned by this epoch (the shared IDM was
    extended by a later incremental advance) map to -1: unknown here,
    exactly like the full engine seeding through this epoch's own files."""
    from repro.core.types import split_transformed

    if idm.n_mapped(vertex_type) == 0:
        e = np.empty(0, dtype=np.int64)
        return e, e
    raw = idm.raw_ids(vertex_type)                # sorted ascending (a copy)
    file_ids, rows = split_transformed(idm.translate(vertex_type, raw))
    max_fid = int(file_ids.max()) if len(file_ids) else 0
    lut = np.full(max_fid + 1, -1, dtype=np.int64)
    for f in topo.vertex_info[vertex_type].files:
        if f.file_id <= max_fid:
            lut[f.file_id] = f.dense_offset
    offs = lut[np.minimum(file_ids, max_fid)]
    dense = np.where(offs >= 0, offs + rows, -1)
    return raw, dense.astype(np.int64)


def _probe(armed: ArmedLookup, pk) -> Optional[int]:
    """``vertex_id -> dense-id`` probe — one binary search over the armed
    table; None when the id is unknown to this epoch (the full engine's
    seed filter matches nothing either)."""
    try:
        pk = int(pk)
    except (TypeError, ValueError, OverflowError):
        return None
    raw = armed.probe_raw
    pos = int(raw.searchsorted(pk))
    if pos >= len(raw) or int(raw[pos]) != pk:
        return None
    dense = int(armed.probe_dense[pos])
    return dense if dense >= 0 else None


def execute_lookup(engine, plan: LookupPlan, params: Optional[dict] = None,
                   epoch=None) -> QueryResult:
    """Run one green/yellow template through the fast path.

    Pins one epoch for the whole lookup (pass ``epoch`` to time-travel onto
    an explicitly acquired one), arms the plan against it, and produces a
    :class:`~repro.core.query.QueryResult` bit-identical to
    ``session.query()`` on the same epoch — stamped ``route="lookup"`` and
    the plan's tier.
    """
    params = params or {}
    unknown = set(params) - set(plan.param_names)
    if unknown:
        raise GSQLCompileError(
            f"unknown parameter(s): {', '.join('$' + p for p in sorted(unknown))}")
    mgr = getattr(engine, "epochs", None)
    acquired = None
    if epoch is None and mgr is not None:
        epoch = acquired = mgr.acquire()
    try:
        return _execute_pinned(engine, plan, params, epoch)
    finally:
        if acquired is not None:
            mgr.release(acquired)


def _execute_pinned(engine, plan: LookupPlan, params: dict, epoch) -> QueryResult:
    from repro.core.primitives import (
        EdgeFrame,
        read_edge_columns_pruned,
        read_vertex_columns_pruned,
    )

    armed = arm_lookup(engine, plan, epoch)
    topo = epoch if epoch is not None else engine.topology
    counters = new_pruning_counters()

    def result(vset, accums, n_scanned, frames, alias_sets):
        return QueryResult(
            vset=vset, accumulators=accums, n_edges_scanned=n_scanned,
            frames=frames, pruning=counters,
            epoch_id=epoch.epoch_id if epoch is not None else -1,
            staleness_s=epoch.staleness_s() if epoch is not None else 0.0,
            alias_sets=alias_sets, route="lookup", tier=plan.tier,
        )

    accums: dict = {}
    if plan.accum is not None:
        n_acc = armed.n_target if plan.accum.target == "v" else armed.n_seed
        accums[plan.accum.name] = np.zeros(n_acc, dtype=np.float64)

    def empty():
        # the full engine still runs the hop over an empty frontier when the
        # seed misses: both aliases land in alias_sets (empty), the frame is
        # present (empty), accumulator arrays sit at the identity
        seed_set = VSet.empty(plan.vertex_type, armed.n_seed)
        alias_sets = {plan.aliases[0]: seed_set} if plan.aliases else {}
        vset, frames = seed_set, []
        if plan.kind == "hop":
            empty_ids = np.empty(0, dtype=np.int64)
            frames = [EdgeFrame(u=empty_ids, v=empty_ids,
                                u_type=plan.vertex_type,
                                v_type=plan.target_type, columns={})]
            far_set = VSet.empty(plan.target_type, armed.n_target)
            if len(plan.aliases) > 1 and plan.aliases[1] is not None:
                alias_sets[plan.aliases[1]] = far_set
            if plan.select == 1:
                vset = far_set
            else:
                vset = VSet.empty(plan.vertex_type, armed.n_seed)
        return result(vset, accums, 0, frames, alias_sets)

    # -- seed: IDM probe + (yellow) single-chunk predicate fetch --------------
    dense = _probe(armed, _bind(plan.pk_value, params))
    if dense is None:
        return empty()
    if plan.seed_where:
        conj = _bind_conjuncts(plan.seed_where, params)
        cols, reject = read_vertex_columns_pruned(
            topo, engine.cache, plan.vertex_type,
            np.asarray([dense], dtype=np.int64),
            [c for c, _, _ in conj], bounds=_bounds_map(conj),
            counters=counters)
        if not _apply_conjuncts(cols, reject, conj)[0]:
            return empty()

    seed_set = VSet.from_dense_ids(plan.vertex_type, armed.n_seed, [dense])
    alias_sets: dict = {}
    if plan.aliases:
        alias_sets[plan.aliases[0]] = seed_set

    if plan.kind == "point":
        return result(seed_set, accums, 0, [], alias_sets)

    # -- hop: CSR adjacency slice + (yellow) edge/far-side predicate fetch ----
    # single-seed special case of CSRIndex.expand: one contiguous indptr
    # range, same (u, v, eid) ordering, none of the ragged-gather machinery
    csr = armed.csr
    if plan.direction == "out":
        indptr, far, eids = csr.fwd_indptr, csr.fwd_dst, csr.fwd_eid
    else:
        indptr, far, eids = csr.rev_indptr, csr.rev_src, csr.rev_eid
    lo, hi = int(indptr[dense]), int(indptr[dense + 1])
    v, eid = far[lo:hi], eids[lo:hi]
    u = np.full(hi - lo, dense, dtype=np.int64)
    frame_cols: dict = {}
    if plan.edge_where or plan.target_where:   # yellow: predicate fetch+filter
        alive = np.ones(len(v), dtype=bool)
        if plan.edge_where and len(eid):
            conj = _bind_conjuncts(plan.edge_where, params)
            cols, reject = read_edge_columns_pruned(
                topo, engine.cache, plan.edge_type, eid,
                [c for c, _, _ in conj], bounds=_bounds_map(conj),
                counters=counters)
            alive &= _apply_conjuncts(cols, reject, conj)
            for c, arr in cols.items():
                frame_cols[f"e.{c}"] = arr
        if plan.target_where and alive.any():
            conj = _bind_conjuncts(plan.target_where, params)
            cols, reject = read_vertex_columns_pruned(
                topo, engine.cache, plan.target_type, v,
                [c for c, _, _ in conj], bounds=_bounds_map(conj),
                counters=counters)
            alive &= _apply_conjuncts(cols, reject, conj)
            for c, arr in cols.items():
                frame_cols[f"v.{c}"] = arr
        elif plan.target_where:
            alive[:] = False
        u, v, eid = u[alive], v[alive], eid[alive]
        frame_cols = {k: arr[alive] for k, arr in frame_cols.items()}

    # -- accumulate (late materialization: value columns for survivors only) --
    if plan.accum is not None:
        a = plan.accum
        arr = accums[a.name]
        tgt_ids = v if a.target == "v" else u
        if len(tgt_ids):
            if isinstance(a.value, tuple):
                pfx, col = a.value
                key = f"{pfx}.{col}"
                if key not in frame_cols:
                    if pfx == "e":
                        cols, _ = read_edge_columns_pruned(
                            topo, engine.cache, plan.edge_type, eid, [col],
                            counters=counters)
                    else:
                        vtype = plan.target_type if pfx == "v" else plan.vertex_type
                        ids = v if pfx == "v" else u
                        cols, _ = read_vertex_columns_pruned(
                            topo, engine.cache, vtype, ids, [col],
                            counters=counters)
                    frame_cols[key] = cols[col]
                vals = np.asarray(frame_cols[key], dtype=np.float64)
            else:
                vals = float(_bind(a.value, params))
            np.add.at(arr, tgt_ids, vals)

    u_type, v_type = plan.vertex_type, plan.target_type
    frame = EdgeFrame(u=u, v=v, u_type=u_type, v_type=v_type,
                      columns=frame_cols)
    # same masks as frame.v_set()/u_set(), minus the redundant np.unique
    # (from_dense_ids scatters into a bitmap, so duplicates are free)
    v_set = VSet.from_dense_ids(v_type, armed.n_target, v)
    if len(plan.aliases) > 1 and plan.aliases[1] is not None:
        alias_sets[plan.aliases[1]] = v_set

    if plan.select == 1:
        vset = v_set
    else:
        # seed vertices with at least one surviving edge (matched_set(0))
        vset = VSet.from_dense_ids(u_type, armed.n_seed, u)
    return result(vset, accums, len(frame), [frame], alias_sets)


# ---------------------------------------------------------------------------
# the primitive lookup surface (GraphSession.get_vertex / .neighbors and the
# GNN sampler draw from here — no template required)
# ---------------------------------------------------------------------------

def point_get(engine, vertex_type: str, vertex_id, columns=(),
              epoch=None) -> Optional[dict]:
    """Fetch one vertex by primary key: IDM probe + single-chunk column
    reads.  Returns ``{"dense_id": ..., <column>: value, ...}`` or ``None``
    when the id is unknown to the pinned epoch."""
    from repro.core.primitives import read_vertex_columns_pruned

    mgr = getattr(engine, "epochs", None)
    acquired = None
    if epoch is None and mgr is not None:
        epoch = acquired = mgr.acquire()
    try:
        topo = epoch if epoch is not None else engine.topology
        idm = _resolve_idm(engine, epoch, vertex_type)
        try:
            tids = idm.translate(
                vertex_type, np.asarray([vertex_id], dtype=np.int64),
                allow_dangling=False)
        except (KeyError, ValueError, OverflowError, TypeError):
            return None
        dense = int(topo.tid_to_dense(vertex_type, tids)[0])
        out = {"dense_id": dense}
        if columns:
            cols, _ = read_vertex_columns_pruned(
                topo, engine.cache, vertex_type,
                np.asarray([dense], dtype=np.int64), list(columns))
            for c in columns:
                out[c] = cols[c][0] if hasattr(cols[c], "__len__") else cols[c]
        return out
    finally:
        if acquired is not None:
            mgr.release(acquired)


def neighbor_ids(engine, edge_type: str, vertex_id, direction: str = "out",
                 epoch=None) -> np.ndarray:
    """Dense ids of one vertex's neighbors — a CSR adjacency slice.

    ``direction="out"`` treats ``vertex_id`` as the edge type's source side
    and returns destinations; ``"in"`` the reverse.  Unknown ids yield an
    empty array (parity with an empty seed match)."""
    mgr = getattr(engine, "epochs", None)
    acquired = None
    if epoch is None and mgr is not None:
        epoch = acquired = mgr.acquire()
    try:
        topo = epoch if epoch is not None else engine.topology
        et = engine.schema.edge_types[edge_type]
        seed_type = et.src_type if direction == "out" else et.dst_type
        idm = _resolve_idm(engine, epoch, seed_type)
        try:
            tids = idm.translate(
                seed_type, np.asarray([vertex_id], dtype=np.int64),
                allow_dangling=False)
        except (KeyError, ValueError, OverflowError, TypeError):
            return np.empty(0, dtype=np.int64)
        dense = int(topo.tid_to_dense(seed_type, tids)[0])
        return topo.plane.csr(edge_type).neighbors(dense, direction).copy()
    finally:
        if acquired is not None:
            mgr.release(acquired)


def csr_adjacency(engine, edge_type: str, direction: str = "out",
                  epoch=None) -> tuple[np.ndarray, np.ndarray]:
    """The epoch CSR's ``(indptr, neighbors)`` arrays for one direction —
    the zero-copy adjacency the GNN sampler draws from (``data/sampler.py``)
    instead of re-sorting raw topology arrays."""
    mgr = getattr(engine, "epochs", None)
    acquired = None
    if epoch is None and mgr is not None:
        epoch = acquired = mgr.acquire()
    try:
        topo = epoch if epoch is not None else engine.topology
        csr = topo.plane.csr(edge_type)
        if direction == "out":
            return csr.fwd_indptr, csr.fwd_dst
        return csr.rev_indptr, csr.rev_src
    finally:
        if acquired is not None:
            mgr.release(acquired)
