"""Edge lists — GraphLake's topology representation (paper §4.1).

One ``EdgeList`` per edge *file*: a pair of int64 arrays holding transformed
(source, target) vertex IDs in the file's original row order.  Row-level
alignment with the underlying edge table is the load-bearing property — edge
attribute chunk row ``i`` describes edge-list entry ``i`` — so OLAP scans walk
the list and the attribute chunks in tandem.

Per-portion statistics: the list is logically split by the edge file's row
groups; for every portion we record Min/Max of the source and target IDs
(in dense index space).  These drive the §5.3 frontier pruning: a portion (and
its attribute chunks) is skipped when its ID range misses the frontier's
Min-Max envelope.

Edge lists serialize to a compact binary blob and persist to the data lake
(topology materialization, §4.2): restarted engines load blobs instead of
rebuilding, which is the paper's fast "second connection" path.
"""

from __future__ import annotations

import dataclasses
import io
import struct

import numpy as np

_MAGIC = b"REL1"


@dataclasses.dataclass
class PortionStats:
    row_group: int
    first_row: int
    n_rows: int
    src_min: int
    src_max: int
    dst_min: int
    dst_max: int


class EdgeList:
    """Topology of one edge file: transformed-ID pairs + portion statistics."""

    def __init__(
        self,
        edge_type: str,
        file_key: str,
        src_tids: np.ndarray,
        dst_tids: np.ndarray,
        src_dense: np.ndarray,
        dst_dense: np.ndarray,
        row_group_rows: list[int],
    ):
        assert len(src_tids) == len(dst_tids) == len(src_dense) == len(dst_dense)
        self.edge_type = edge_type
        self.file_key = file_key
        self.src_tids = np.asarray(src_tids, dtype=np.int64)
        self.dst_tids = np.asarray(dst_tids, dtype=np.int64)
        # dense indices are a derived, cache-friendly addressing of the same
        # endpoints (see core.types); kept alongside so hot scans avoid the
        # shift/mask + file-offset translation per query.
        self.src_dense = np.asarray(src_dense, dtype=np.int64)
        self.dst_dense = np.asarray(dst_dense, dtype=np.int64)
        self.row_group_rows = list(row_group_rows)
        self.portions = self._compute_portions()

    # -- stats -------------------------------------------------------------------

    def _compute_portions(self) -> list[PortionStats]:
        portions = []
        first = 0
        for g, rows in enumerate(self.row_group_rows):
            if rows == 0:
                portions.append(PortionStats(g, first, 0, 0, -1, 0, -1))
                continue
            s = self.src_dense[first : first + rows]
            d = self.dst_dense[first : first + rows]
            portions.append(
                PortionStats(
                    row_group=g,
                    first_row=first,
                    n_rows=rows,
                    src_min=int(s.min()),
                    src_max=int(s.max()),
                    dst_min=int(d.min()),
                    dst_max=int(d.max()),
                )
            )
            first += rows
        return portions

    @property
    def n_edges(self) -> int:
        return len(self.src_tids)

    def nbytes(self) -> int:
        return (
            self.src_tids.nbytes
            + self.dst_tids.nbytes
            + self.src_dense.nbytes
            + self.dst_dense.nbytes
        )

    def portions_overlapping(
        self, lo: int, hi: int, direction: str = "out"
    ) -> list[PortionStats]:
        """Portions whose source (out) / target (in) range hits [lo, hi]."""
        out = []
        for p in self.portions:
            if p.n_rows == 0:
                continue
            pmin, pmax = (p.src_min, p.src_max) if direction == "out" else (p.dst_min, p.dst_max)
            if pmax >= lo and pmin <= hi:
                out.append(p)
        return out

    # -- serialization (topology materialization) ---------------------------------

    def to_bytes(self) -> bytes:
        buf = io.BytesIO()
        ft = self.file_key.encode()
        et = self.edge_type.encode()
        buf.write(_MAGIC)
        buf.write(struct.pack("<iiq", len(et), len(ft), self.n_edges))
        buf.write(struct.pack("<i", len(self.row_group_rows)))
        buf.write(et)
        buf.write(ft)
        buf.write(np.asarray(self.row_group_rows, dtype=np.int64).tobytes())
        for arr in (self.src_tids, self.dst_tids, self.src_dense, self.dst_dense):
            buf.write(arr.tobytes())
        return buf.getvalue()

    @staticmethod
    def from_bytes(blob: bytes) -> "EdgeList":
        if blob[:4] != _MAGIC:
            raise ValueError("bad edge list magic")
        et_len, ft_len, n_edges = struct.unpack_from("<iiq", blob, 4)
        (n_groups,) = struct.unpack_from("<i", blob, 20)
        off = 24
        edge_type = blob[off : off + et_len].decode(); off += et_len
        file_key = blob[off : off + ft_len].decode(); off += ft_len
        rows = np.frombuffer(blob, dtype=np.int64, count=n_groups, offset=off)
        off += n_groups * 8
        arrays = []
        for _ in range(4):
            arrays.append(np.frombuffer(blob, dtype=np.int64, count=n_edges, offset=off).copy())
            off += n_edges * 8
        return EdgeList(edge_type, file_key, arrays[0], arrays[1], arrays[2], arrays[3], rows.tolist())


def build_edge_list(
    edge_type: str,
    file_key: str,
    src_raw: np.ndarray,
    dst_raw: np.ndarray,
    row_group_rows: list[int],
    idm,
    src_type: str,
    dst_type: str,
    tid_to_dense,
) -> EdgeList:
    """Translate one edge file's FK columns into an EdgeList (paper §4.3).

    ``idm`` is the (frozen) VertexIDM; ``tid_to_dense(vertex_type, tids)``
    converts transformed IDs to dense indices (provided by the topology, which
    owns the file registry).  Each call is independent -> edge files build in
    parallel, lock-free on the primary path.
    """
    src_tids = idm.translate(src_type, src_raw)
    dst_tids = idm.translate(dst_type, dst_raw)
    return EdgeList(
        edge_type=edge_type,
        file_key=file_key,
        src_tids=src_tids,
        dst_tids=dst_tids,
        src_dense=tid_to_dense(src_type, src_tids),
        dst_dense=tid_to_dense(dst_type, dst_tids),
        row_group_rows=row_group_rows,
    )
