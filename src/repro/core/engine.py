"""GraphLakeEngine — the compute engine tying topology, cache and primitives
together (paper §3).

Startup modes reproduce the paper's two connection paths:

- **first connection**: topology-only load (Vertex IDM + edge lists) straight
  from the Lakehouse tables, then (optionally) materialize topology to the
  lake;
- **second connection**: detect materialized topology and load it directly,
  skipping the build — the 6.9x-26.3x faster path of Fig. 8.

The engine evaluates queries with the BSP accumulator model: supersteps apply
``VertexMap`` / ``EdgeScan`` to an active vertex set and strictly synchronize
between steps.
"""

from __future__ import annotations

import time
from typing import Callable, Optional, Sequence

import numpy as np

from repro import perf_flags
from repro.core.accumulators import Accumulators, AccumSpec
from repro.core.cache.manager import CacheConfig, CacheManager
from repro.core.cache.prefetch import Prefetcher
from repro.core.epochs import AdvanceReport, EpochManager
from repro.core.primitives import EdgeFrame, edge_scan, read_vertex_values, vertex_map
from repro.core.topology import GraphTopology
from repro.core.types import GraphSchema, VSet
from repro.lakehouse.io_pool import IOPool
from repro.lakehouse.objectstore import ObjectStore
from repro.lakehouse.table import LakeCatalog


class GraphLakeEngine:
    def __init__(
        self,
        store: ObjectStore,
        schema: GraphSchema,
        cache_config: Optional[CacheConfig] = None,
        n_io_threads: int = 8,
        enable_prefetch: bool = True,
        materialize_topology: bool = True,
    ):
        self.store = store
        self.schema = schema
        self.lake = LakeCatalog(store)
        self.cache = CacheManager(store, cache_config)
        self.pool = IOPool(n_threads=n_io_threads)
        self.topology = GraphTopology(schema)
        self.enable_prefetch = enable_prefetch
        self.materialize_topology = materialize_topology
        self.prefetcher: Optional[Prefetcher] = None
        self.accums = None
        self.epochs: Optional[EpochManager] = None
        self.ingest = None      # set by IngestPipeline.start() (repro/ingest)
        self.startup_seconds: float = 0.0
        self.startup_mode: str = "unstarted"
        self._started = False
        self._file_filter = None
        # set by ShardFabric.attach (repro/shard, DESIGN.md §13): the seam
        # GraphSession/serving route through for scatter-gather execution
        self._shard_fabric = None

    # ------------------------------------------------------------------ startup

    def startup(self, file_filter=None) -> dict[str, float]:
        """Connect + topology-only load (paper §4.3). Returns phase timings."""
        t0 = time.perf_counter()
        if GraphTopology.is_materialized(self.store) and file_filter is None:
            self.startup_mode = "second_connection"
            self.topology.load_materialized(self.store, self.lake, pool=self.pool)
        else:
            self.startup_mode = "first_connection"
            self.topology.build(self.store, self.lake, pool=self.pool, file_filter=file_filter)
            if self.materialize_topology and file_filter is None:
                self.topology.materialize(self.store, pool=self.pool)
        self.prefetcher = (
            Prefetcher(self.cache, self.topology, pool=self.pool)
            if self.enable_prefetch
            else None
        )
        self.accums = Accumulators(self.topology)
        # pin the loaded lake state as epoch 1 (DESIGN.md §7); queries
        # acquire/release epochs so mid-query commits can never tear reads
        self._file_filter = file_filter
        self.epochs = EpochManager(self)
        self.epochs.bootstrap()
        self.startup_seconds = time.perf_counter() - t0
        self._started = True
        return dict(self.topology.timings)

    # ------------------------------------------------------------------ epochs

    def advance(self) -> AdvanceReport:
        """Sync with the lake: diff tables against the current epoch, apply
        incremental deltas, publish a new epoch (core/epochs.py)."""
        return self.epochs.advance()

    def current_epoch(self):
        return self.epochs.current()

    def session(self, options=None):
        """This engine's cached :class:`~repro.gsql.session.GraphSession` —
        the GSQL front end (DESIGN.md §8).  ``options`` only applies on the
        first call (it seeds the session's defaults)."""
        from repro.gsql.session import GraphSession

        return GraphSession.for_engine(self, options)

    def adopt_topology(self, topology: GraphTopology) -> None:
        """Swap in a freshly rebuilt builder topology (the epoch manager's
        non-incremental fallback).  Accumulator state is dropped — a rebuild
        renumbers the dense space, so old accumulator slots are meaningless."""
        self.topology = topology
        if self.prefetcher is not None:
            self.prefetcher = Prefetcher(self.cache, topology, pool=self.pool)
        self.accums = Accumulators(topology)

    def _topo(self, epoch=None):
        """Resolve the topology surface a read should use: an explicitly
        pinned epoch, else the live builder topology (analytics paths)."""
        return epoch if epoch is not None else self.topology

    def close(self) -> None:
        if self._shard_fabric is not None:
            self._shard_fabric.close()
        self.pool.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # ------------------------------------------------------------------ vsets

    def all_vertices(self, vertex_type: str, epoch=None) -> VSet:
        topo = self._topo(epoch)
        n = topo.n_vertices(vertex_type)
        mask = np.zeros(n, dtype=bool)
        mask[: topo.n_real_vertices(vertex_type)] = True
        return VSet(vertex_type, mask)

    def empty_vset(self, vertex_type: str, epoch=None) -> VSet:
        return VSet.empty(vertex_type, self._topo(epoch).n_vertices(vertex_type))

    def vset_from_raw_ids(self, vertex_type: str, raw_ids, epoch=None) -> VSet:
        """Seed a vertex set from raw (lakehouse) primary-key values.

        With a pinned epoch, translation uses the IDM the epoch was frozen
        with — its file-id assignments match the epoch's registry even after
        a full rebuild re-assigned them — and the set size comes from the
        epoch, so an ID committed after the epoch raises instead of silently
        leaking future data in."""
        topo = self._topo(epoch)
        idm = getattr(epoch, "idm", None) if epoch is not None else None
        if idm is None or idm.n_mapped(vertex_type) == 0:
            if self.topology.idm is None or self.topology.idm.n_mapped(vertex_type) == 0:
                self.topology._rebuild_idm(self.store)
            idm = self.topology.idm
        tids = idm.translate(
            vertex_type, np.asarray(raw_ids, dtype=np.int64), allow_dangling=False
        )
        dense = topo.tid_to_dense(vertex_type, tids)
        return VSet.from_dense_ids(vertex_type, topo.n_vertices(vertex_type), dense)

    # ------------------------------------------------------------------ primitives

    def _query_pool(self, pipeline: Optional[bool]):
        """The shared query-time IOPool, or None for the sequential path.

        ``pipeline=None`` defers to the ``pipe`` perf flag; an explicit
        True/False overrides it (benchmarks pin each arm).  Concurrent
        queries (``serving/server.py`` workers) all flow through this one
        pool, so the store's modeled parallel-stream budget is shared, not
        multiplied, under load.
        """
        if pipeline is None:
            pipeline = perf_flags.enabled("pipe")
        return self.pool if pipeline else None

    def vertex_map(self, vset: VSet, columns=(), filter_fn=None, map_fn=None,
                   bounds=None, counters=None, pipeline: Optional[bool] = None,
                   epoch=None, deadline: Optional[float] = None):
        return vertex_map(
            self._topo(epoch), self.cache, vset, columns,
            filter_fn=filter_fn, map_fn=map_fn, prefetcher=self.prefetcher,
            bounds=bounds, counters=counters, pool=self._query_pool(pipeline),
            deadline=deadline,
        )

    def edge_scan(
        self,
        frontier: VSet,
        edge_type: str,
        direction: str = "out",
        edge_columns: Sequence[str] = (),
        u_columns: Sequence[str] = (),
        v_columns: Sequence[str] = (),
        edge_filter=None,
        strategy: str = "auto",
        plan=None,
        counters=None,
        pipeline: Optional[bool] = None,
        epoch=None,
        deadline: Optional[float] = None,
    ) -> EdgeFrame:
        return edge_scan(
            self._topo(epoch), self.cache, frontier, edge_type, direction,
            edge_columns=edge_columns, u_columns=u_columns, v_columns=v_columns,
            edge_filter=edge_filter, prefetcher=self.prefetcher,
            strategy=strategy, plan=plan, counters=counters,
            pool=self._query_pool(pipeline), deadline=deadline,
        )

    def read_vertex_column(self, vertex_type: str, dense_ids, column: str,
                           epoch=None) -> np.ndarray:
        return read_vertex_values(self._topo(epoch), self.cache, vertex_type,
                                  dense_ids, column)

    # ------------------------------------------------------------------ accums

    def register_accum(self, vertex_type: str, name: str, op: str = "sum",
                       dtype: str = "float64", init=None) -> np.ndarray:
        return self.accums.register(AccumSpec(vertex_type, name, op, dtype, init))

    # ------------------------------------------------------------------ BSP loop

    def bsp_run(
        self,
        initial: VSet,
        superstep: Callable[[int, VSet, "GraphLakeEngine"], Optional[VSet]],
        max_steps: int = 100,
    ) -> VSet:
        """Run supersteps until the active set empties or ``superstep`` returns
        None.  Strict synchronization between steps (BSP, paper §3)."""
        active = initial
        for step in range(max_steps):
            if active.size() == 0:
                break
            nxt = superstep(step, active, self)
            if nxt is None:
                break
            active = nxt
        return active

    # ------------------------------------------------------------------ topology plane (for algorithms)

    @property
    def plane(self):
        """The topology plane: physical representations + adaptive dispatch."""
        return self.topology.plane

    def concat_edges(self, edge_type: str) -> tuple[np.ndarray, np.ndarray]:
        """All (src_dense, dst_dense) pairs of an edge type, concatenated.

        The iterative graph algorithms consume the whole topology every
        superstep; the plane concatenates once, caches, and invalidates the
        cache whenever the topology is (re)built or incrementally refreshed.
        """
        return self.topology.plane.concat_edges(edge_type)

    def edges_by_dst(self, edge_type: str) -> tuple[np.ndarray, np.ndarray]:
        """(src, dst) sorted by dst — tight segment ranges for the Pallas
        kernels (DESIGN.md §2); served from the plane's CSR index."""
        return self.topology.plane.edges_by_dst(edge_type)
