"""Declarative multi-hop pattern queries — the GSQL-block analogue (paper §6).

A query is a sequence of blocks; each block takes an input vertex set,
traverses one edge type (VertexMap + EdgeScan underneath), applies WHERE
predicates over edge/endpoint columns, optionally updates ACCUM state on an
endpoint, and yields the next vertex set.  The paper's running example

    SELECT p FROM (t:Tag) <-[e1:HasTag]- (c:Comment) -[e2:HasCreator]-> (p:Person)
    WHERE t.name == "Music" AND e2.date > ... AND p.gender == "Female"
    ACCUM p.@sum += 1

is expressed as::

    q = (Query(engine)
         .vertices("Tag", where=eq("name", "Music"))
         .hop("HasTag", direction="in")
         .hop("HasCreator", direction="out",
              edge_where=gt("date", d), target_where=eq("gender", "Female"),
              accum=accum_sum("cnt", 1.0)))
    result = q.run()

Predicates compose with ``&`` / ``|``; they compile to vectorized masks over
materialized frames.

**Predicate pushdown (DESIGN.md §4).**  ``run()`` plans every hop before
executing it: the WHERE conjuncts are already split by prefix (``e.`` /
``u.`` / ``v.``) at the API level, so the planner's job is staging — pred
columns vs ACCUM-only columns per prefix — plus compiling each boundable
conjunct to :class:`~repro.core.plan.ColumnBounds` via ``Predicate.bounds()``.
``eq``/``gt``/``ge``/``lt``/``le``/``isin`` and their ``&``-compositions
produce usable bounds; ``|``-compositions, ``ne`` and opaque UDF predicates
degrade safely to no-prune (empty bounds).  The staged plan drives
``edge_scan``'s late materialization and the zone-map chunk skipping in the
read/prefetch path; ``run(pushdown=False)`` forces the legacy
full-materialization path (the parity baseline).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

from repro.core.accumulators import AccumSpec
from repro.core.plan import ColumnBounds, ScanPlan, merge_bounds, new_pruning_counters
from repro.core.types import VSet


# ---------------------------------------------------------------------------
# predicates
# ---------------------------------------------------------------------------

class Predicate:
    """Vectorized predicate over a named column of a materialized frame."""

    def __init__(
        self,
        fn: Callable[[dict, str], np.ndarray],
        columns: tuple[str, ...],
        bounds: Optional[dict] = None,
    ):
        self._fn = fn
        self.columns = columns  # bare column names this predicate touches
        self._bounds = dict(bounds) if bounds else {}

    def bounds(self) -> dict[str, ColumnBounds]:
        """Column -> zone-map bounds implied by this predicate.

        Conservative protocol: every returned bound is a *necessary*
        condition of the whole predicate, so chunk pruning against it can
        only drop rows that would fail anyway.  Unboundable predicates
        (``|``-composition, ``ne``, raw UDFs) return ``{}`` — no pruning.
        """
        return dict(self._bounds)

    def evaluate(self, frame: dict, prefix: str) -> np.ndarray:
        return self._fn(frame, prefix)

    def __and__(self, other: "Predicate") -> "Predicate":
        # AND is at least as restrictive as each side: bounds intersect, and
        # a one-sided bound stays usable even if the other side is opaque.
        return Predicate(
            lambda f, p: self.evaluate(f, p) & other.evaluate(f, p),
            self.columns + other.columns,
            bounds=merge_bounds(self._bounds, other.bounds()),
        )

    def __or__(self, other: "Predicate") -> "Predicate":
        # OR weakens both sides; degrade to no-prune rather than widen.
        return Predicate(
            lambda f, p: self.evaluate(f, p) | other.evaluate(f, p),
            self.columns + other.columns,
        )


def _col(frame: dict, prefix: str, column: str) -> np.ndarray:
    key = f"{prefix}.{column}" if prefix else column
    if key in frame:
        return frame[key]
    return frame[column]


def _cmp(column: str, op: Callable, bounds_of: Optional[Callable] = None) -> Callable[..., Predicate]:
    def make(value) -> Predicate:
        def fn(frame, prefix):
            col = _col(frame, prefix, column)
            if col.dtype == object:
                col = np.asarray([str(x) for x in col])
                return op(col, str(value))
            return op(col, value)
        b = {column: bounds_of(value)} if bounds_of is not None else None
        return Predicate(fn, (column,), bounds=b)
    return make


def eq(column: str, value) -> Predicate:
    return _cmp(column, np.equal,
                lambda v: ColumnBounds(values=frozenset([v])))(value)


def ne(column: str, value) -> Predicate:
    return _cmp(column, np.not_equal)(value)


def gt(column: str, value) -> Predicate:
    return _cmp(column, np.greater,
                lambda v: ColumnBounds(lo=v, lo_strict=True))(value)


def ge(column: str, value) -> Predicate:
    return _cmp(column, np.greater_equal, lambda v: ColumnBounds(lo=v))(value)


def lt(column: str, value) -> Predicate:
    return _cmp(column, np.less,
                lambda v: ColumnBounds(hi=v, hi_strict=True))(value)


def le(column: str, value) -> Predicate:
    return _cmp(column, np.less_equal, lambda v: ColumnBounds(hi=v))(value)


def isin(column: str, values) -> Predicate:
    values = set(values)
    test = np.asarray(sorted(values, key=repr))

    def fn(frame, prefix):
        col = _col(frame, prefix, column)
        if col.dtype != object and test.dtype.kind in "biuf":
            # vectorized membership — only when the candidates are uniformly
            # numeric (a mixed list coerces to strings and would mismatch)
            return np.isin(col, test)
        return np.asarray([x in values for x in col.tolist()], dtype=bool)

    return Predicate(fn, (column,),
                     bounds={column: ColumnBounds(values=frozenset(values))})


# ---------------------------------------------------------------------------
# accumulate specs
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class AccumUpdate:
    name: str
    op: str                     # sum | max | min | or
    value: object               # constant, or "e.col"/"u.col"/"v.col" reference
    target: str = "v"           # which endpoint receives the update ("u"|"v")
    dtype: str = "float64"


def accum_sum(name: str, value=1.0, target: str = "v") -> AccumUpdate:
    return AccumUpdate(name=name, op="sum", value=value, target=target)


def accum_max(name: str, value, target: str = "v") -> AccumUpdate:
    return AccumUpdate(name=name, op="max", value=value, target=target)


def accum_min(name: str, value, target: str = "v") -> AccumUpdate:
    return AccumUpdate(name=name, op="min", value=value, target=target)


# ---------------------------------------------------------------------------
# query blocks
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _SeedBlock:
    vertex_type: str
    where: Optional[Predicate]
    raw_ids: Optional[np.ndarray]


@dataclasses.dataclass
class _HopBlock:
    edge_type: str
    direction: str
    edge_where: Optional[Predicate]
    source_where: Optional[Predicate]
    target_where: Optional[Predicate]
    accum: Optional[AccumUpdate]


@dataclasses.dataclass
class QueryResult:
    vset: VSet
    accumulators: dict[str, np.ndarray]
    n_edges_scanned: int
    frames: list
    # zone-map pruning counters accumulated over every read the query issued
    # (seed VertexMap + all hops); see plan.new_pruning_counters for keys
    pruning: dict = dataclasses.field(default_factory=new_pruning_counters)
    # which snapshot-pinned epoch served the query and how stale its view of
    # the lake was when the query finished (core/epochs.py); -1 = no epoch
    # subsystem (query ran straight against the mutable topology)
    epoch_id: int = -1
    staleness_s: float = 0.0


def plan_hop(hop: "_HopBlock") -> ScanPlan:
    """Compile one hop block into a staged :class:`ScanPlan`.

    The WHERE is already split per prefix at the builder level; planning
    stages the columns (predicate columns materialize in their stage,
    ACCUM-only columns for final survivors) and compiles each conjunct's
    zone-map bounds.
    """
    e_cols = list(dict.fromkeys(hop.edge_where.columns)) if hop.edge_where else []
    u_cols = list(dict.fromkeys(hop.source_where.columns)) if hop.source_where else []
    v_cols = list(dict.fromkeys(hop.target_where.columns)) if hop.target_where else []
    acc: dict[str, list[str]] = {"e": [], "u": [], "v": []}
    if hop.accum is not None and isinstance(hop.accum.value, str):
        pfx, col = hop.accum.value.split(".", 1)
        if col not in {"e": e_cols, "u": u_cols, "v": v_cols}[pfx]:
            acc[pfx].append(col)
    return ScanPlan(
        edge_pred=hop.edge_where,
        source_pred=hop.source_where,
        target_pred=hop.target_where,
        edge_columns=tuple(sorted(e_cols)),
        u_columns=tuple(sorted(u_cols)),
        v_columns=tuple(sorted(v_cols)),
        accum_edge_columns=tuple(acc["e"]),
        accum_u_columns=tuple(acc["u"]),
        accum_v_columns=tuple(acc["v"]),
        edge_bounds=hop.edge_where.bounds() if hop.edge_where else {},
        u_bounds=hop.source_where.bounds() if hop.source_where else {},
        v_bounds=hop.target_where.bounds() if hop.target_where else {},
    )


class Query:
    def __init__(self, engine):
        self.engine = engine
        self._seed: Optional[_SeedBlock] = None
        self._hops: list[_HopBlock] = []

    # -- builders ---------------------------------------------------------------

    def vertices(self, vertex_type: str, where: Optional[Predicate] = None,
                 raw_ids=None) -> "Query":
        self._seed = _SeedBlock(vertex_type, where,
                                None if raw_ids is None else np.asarray(raw_ids))
        return self

    def hop(
        self,
        edge_type: str,
        direction: str = "out",
        edge_where: Optional[Predicate] = None,
        source_where: Optional[Predicate] = None,
        target_where: Optional[Predicate] = None,
        accum: Optional[AccumUpdate] = None,
    ) -> "Query":
        self._hops.append(
            _HopBlock(edge_type, direction, edge_where, source_where, target_where, accum)
        )
        return self

    # -- execution ----------------------------------------------------------------

    def run(self, pushdown: bool = True,
            pipeline: Optional[bool] = None, epoch=None) -> QueryResult:
        """Execute the query.  ``pushdown=False`` forces the legacy
        full-materialization scan path (no staging, no zone-map pruning) —
        the baseline the pushdown parity tests and benchmarks compare
        against.  ``pipeline`` pins the parallel chunk-pipelined read path
        on/off per run (``None`` defers to the ``pipe`` perf flag; the
        sequential path is the pipelining parity baseline, DESIGN.md §5).
        All paths return bit-identical results.

        Every run executes against one snapshot-pinned epoch (DESIGN.md §7):
        by default the engine's current epoch is acquired for the whole run
        and released afterwards, so commits (and ``advance()``) landing
        mid-query can never tear the result — the next run simply picks up
        the newer epoch.  Pass ``epoch`` (an explicitly acquired
        ``GraphEpoch``) to time-travel onto an older pinned view; the caller
        then owns its release."""
        eng = self.engine
        seed = self._seed
        if seed is None:
            raise ValueError("query has no seed block")
        counters = new_pruning_counters()

        mgr = getattr(eng, "epochs", None)
        acquired = None
        if epoch is None and mgr is not None:
            epoch = acquired = mgr.acquire()
        try:
            return self._run_pinned(eng, seed, counters, pushdown, pipeline, epoch)
        finally:
            if acquired is not None:
                mgr.release(acquired)

    def _run_pinned(self, eng, seed, counters, pushdown, pipeline, epoch) -> QueryResult:
        topo = epoch if epoch is not None else eng.topology
        # pin the accumulator store too: a full-rebuild advance() swaps
        # eng.accums (renumbered dense space), and this query's dense ids
        # only mean anything in the store that matches its pinned epoch
        accums = eng.accums
        if seed.raw_ids is not None:
            vset = eng.vset_from_raw_ids(seed.vertex_type, seed.raw_ids, epoch=epoch)
        else:
            vset = eng.all_vertices(seed.vertex_type, epoch=epoch)
        if seed.where is not None:
            vset, _ = eng.vertex_map(
                vset,
                columns=list(dict.fromkeys(seed.where.columns)),
                filter_fn=lambda fr: seed.where.evaluate(fr, ""),
                bounds=seed.where.bounds() if pushdown else None,
                counters=counters, pipeline=pipeline, epoch=epoch,
            )

        accum_out: dict[str, np.ndarray] = {}
        frames = []
        n_scanned = 0
        for hop_i, hop in enumerate(self._hops):
            et = eng.schema.edge_types[hop.edge_type]
            u_type = et.src_type if hop.direction == "out" else et.dst_type
            v_type = et.dst_type if hop.direction == "out" else et.src_type

            if pushdown:
                frame = eng.edge_scan(
                    vset, hop.edge_type, hop.direction,
                    plan=plan_hop(hop), counters=counters, pipeline=pipeline,
                    epoch=epoch,
                )
            else:
                edge_cols, u_cols, v_cols = set(), set(), set()
                if hop.edge_where is not None:
                    edge_cols.update(hop.edge_where.columns)
                if hop.source_where is not None:
                    u_cols.update(hop.source_where.columns)
                if hop.target_where is not None:
                    v_cols.update(hop.target_where.columns)
                if hop.accum is not None and isinstance(hop.accum.value, str):
                    pfx, col = hop.accum.value.split(".", 1)
                    {"e": edge_cols, "u": u_cols, "v": v_cols}[pfx].add(col)

                def _filter(frame, hop=hop):
                    n = len(frame["u"])
                    keep = np.ones(n, dtype=bool)
                    if hop.edge_where is not None:
                        keep &= hop.edge_where.evaluate(frame, "e")
                    if hop.source_where is not None:
                        keep &= hop.source_where.evaluate(frame, "u")
                    if hop.target_where is not None:
                        keep &= hop.target_where.evaluate(frame, "v")
                    return keep

                frame = eng.edge_scan(
                    vset, hop.edge_type, hop.direction,
                    edge_columns=sorted(edge_cols),
                    u_columns=sorted(u_cols),
                    v_columns=sorted(v_cols),
                    edge_filter=_filter,
                    counters=counters, pipeline=pipeline,
                    epoch=epoch,
                )
            n_scanned += len(frame)
            frames.append(frame)

            if hop.accum is not None:
                a = hop.accum
                if a.target == "v":
                    tgt_type, tgt_ids = v_type, frame.v
                else:
                    tgt_type, tgt_ids = u_type, frame.u
                if (tgt_type, a.name) not in accums._arrays:
                    accums.register(AccumSpec(tgt_type, a.name, op=a.op, dtype=a.dtype))
                if isinstance(a.value, str):
                    pfx, col = a.value.split(".", 1)
                    vals = frame.columns[f"{pfx}.{col}"]
                else:
                    vals = a.value
                accums.update(tgt_type, a.name, tgt_ids, vals)
                # the result view is sized to *this* epoch's dense space, so
                # it always aligns with the result vset's mask even when a
                # later epoch has already grown the shared array
                n_tgt = topo.n_vertices(tgt_type)
                accums.ensure_capacity(tgt_type, a.name, n_tgt)
                accum_out[a.name] = accums.array(tgt_type, a.name)[:n_tgt]

            n_v = topo.n_vertices(v_type)
            vset = frame.v_set(n_v)

        return QueryResult(
            vset=vset, accumulators=accum_out, n_edges_scanned=n_scanned,
            frames=frames, pruning=counters,
            epoch_id=epoch.epoch_id if epoch is not None else -1,
            staleness_s=epoch.staleness_s() if epoch is not None else 0.0,
        )
