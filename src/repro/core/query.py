"""Declarative multi-hop pattern queries — the execution core behind both
query front ends (paper §6).

Two front ends construct the same :class:`~repro.gsql.ir.LogicalQuery` IR
and compile to the same execution blocks (DESIGN.md §8):

- **GSQL text** (the paper's headline interface), via
  ``repro.gsql``::

      session = repro.connect(store, schema)
      session.query('''
          SELECT p FROM Tag:t -(HasTag:e1)- Comment:c -(HasCreator:e2)- Person:p
          WHERE t.name == $tag AND e2.creationDate > $date
            AND p.gender == "Female"
          ACCUM p.@cnt += 1
      ''', tag="Music", date=20100101)

- the **fluent builder** (this module), a thin constructor over the same
  blocks::

      q = (Query(engine)
           .vertices("Tag", where=eq("name", "Music"))
           .hop("HasTag", direction="in")
           .hop("HasCreator", direction="out",
                edge_where=gt("creationDate", d), target_where=eq("gender", "Female"),
                accum=accum_sum("cnt", 1.0)))
      result = q.run()

Either way execution flows through :func:`execute_compiled` over
``_SeedBlock`` / ``_HopBlock`` sequences — one execution path, two front
ends — so text queries are bit-identical to their builder equivalents.

Predicates compose with ``&`` / ``|``; they compile to vectorized masks over
materialized frames.  The standard comparison builders additionally carry a
declarative ``spec`` so builder chains can round-trip through the IR
(``Query.to_ir()`` -> ``LogicalQuery.render()`` -> ``parse()``).

**Predicate pushdown (DESIGN.md §4).**  Every hop is planned before it
executes: the WHERE conjuncts are already split by prefix (``e.`` / ``u.`` /
``v.``), so the planner's job is staging — pred columns vs ACCUM-only
columns per prefix — plus compiling each boundable conjunct to
:class:`~repro.core.plan.ColumnBounds` via ``Predicate.bounds()``.
``eq``/``gt``/``ge``/``lt``/``le``/``isin`` and their ``&``-compositions
produce usable bounds; ``|``-compositions, ``ne`` and opaque UDF predicates
degrade safely to no-prune (empty bounds).  The staged plan drives
``edge_scan``'s late materialization and the zone-map chunk skipping in the
read/prefetch path; ``ExecOptions(pushdown=False)`` forces the legacy
full-materialization path (the parity baseline).

**Execution knobs** live in :class:`ExecOptions` (per-session defaults on
:class:`~repro.gsql.session.GraphSession`, overridable per call) — the one
place they travel; ``Query.run`` takes an ``ExecOptions``, nothing else.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import numpy as np

from repro.core.accumulators import AccumSpec
from repro.core.plan import (
    ColumnBounds,
    ScanPlan,
    check_deadline,
    merge_bounds,
    new_pruning_counters,
    union_bounds_maps,
)
from repro.core.types import VSet


# ---------------------------------------------------------------------------
# predicates
# ---------------------------------------------------------------------------

class Predicate:
    """Vectorized predicate over a named column of a materialized frame."""

    def __init__(
        self,
        fn: Callable[[dict, str], np.ndarray],
        columns: tuple[str, ...],
        bounds: Optional[dict] = None,
        spec=None,
    ):
        self._fn = fn
        self.columns = columns  # bare column names this predicate touches
        self._bounds = dict(bounds) if bounds else {}
        # declarative shape for IR round-tripping: ("cmp", col, op, value) |
        # ("in", col, values) | ("and"|"or", left, right); None for opaque
        # UDFs — those execute fine but cannot render as GSQL text
        self.spec = spec

    def bounds(self) -> dict[str, ColumnBounds]:
        """Column -> zone-map bounds implied by this predicate.

        Conservative protocol: every returned bound is a *necessary*
        condition of the whole predicate, so chunk pruning against it can
        only drop rows that would fail anyway.  Unboundable predicates
        (``|``-composition, ``ne``, raw UDFs) return ``{}`` — no pruning.
        """
        return dict(self._bounds)

    def evaluate(self, frame: dict, prefix: str) -> np.ndarray:
        return self._fn(frame, prefix)

    def _compose_spec(self, kind: str, other: "Predicate"):
        if self.spec is None or other.spec is None:
            return None
        return (kind, self.spec, other.spec)

    def __and__(self, other: "Predicate") -> "Predicate":
        # AND is at least as restrictive as each side: bounds intersect, and
        # a one-sided bound stays usable even if the other side is opaque.
        return Predicate(
            lambda f, p: self.evaluate(f, p) & other.evaluate(f, p),
            self.columns + other.columns,
            bounds=merge_bounds(self._bounds, other.bounds()),
            spec=self._compose_spec("and", other),
        )

    def __or__(self, other: "Predicate") -> "Predicate":
        # OR weakens both sides; degrade to no-prune rather than widen.
        return Predicate(
            lambda f, p: self.evaluate(f, p) | other.evaluate(f, p),
            self.columns + other.columns,
            spec=self._compose_spec("or", other),
        )


def _col(frame: dict, prefix: str, column: str) -> np.ndarray:
    key = f"{prefix}.{column}" if prefix else column
    if key in frame:
        return frame[key]
    return frame[column]


def _cmp(column: str, op: Callable, op_text: str,
         bounds_of: Optional[Callable] = None) -> Callable[..., Predicate]:
    def make(value) -> Predicate:
        def fn(frame, prefix):
            col = _col(frame, prefix, column)
            if col.dtype == object:
                col = np.asarray([str(x) for x in col])
                return op(col, str(value))
            return op(col, value)
        b = {column: bounds_of(value)} if bounds_of is not None else None
        return Predicate(fn, (column,), bounds=b,
                         spec=("cmp", column, op_text, value))
    return make


def eq(column: str, value) -> Predicate:
    return _cmp(column, np.equal, "==",
                lambda v: ColumnBounds(values=frozenset([v])))(value)


def ne(column: str, value) -> Predicate:
    return _cmp(column, np.not_equal, "!=")(value)


def gt(column: str, value) -> Predicate:
    return _cmp(column, np.greater, ">",
                lambda v: ColumnBounds(lo=v, lo_strict=True))(value)


def ge(column: str, value) -> Predicate:
    return _cmp(column, np.greater_equal, ">=", lambda v: ColumnBounds(lo=v))(value)


def lt(column: str, value) -> Predicate:
    return _cmp(column, np.less, "<",
                lambda v: ColumnBounds(hi=v, hi_strict=True))(value)


def le(column: str, value) -> Predicate:
    return _cmp(column, np.less_equal, "<=", lambda v: ColumnBounds(hi=v))(value)


def isin(column: str, values) -> Predicate:
    values = set(values)
    test = np.asarray(sorted(values, key=repr))

    def fn(frame, prefix):
        col = _col(frame, prefix, column)
        if col.dtype != object and test.dtype.kind in "biuf":
            # vectorized membership — only when the candidates are uniformly
            # numeric (a mixed list coerces to strings and would mismatch)
            return np.isin(col, test)
        return np.asarray([x in values for x in col.tolist()], dtype=bool)

    return Predicate(fn, (column,),
                     bounds={column: ColumnBounds(values=frozenset(values))},
                     spec=("in", column, tuple(sorted(values, key=repr))))


# ---------------------------------------------------------------------------
# accumulate specs
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class AccumUpdate:
    name: str
    op: str                     # sum | max | min | or
    value: object               # constant, or "e.col"/"u.col"/"v.col" reference
    target: str = "v"           # which endpoint receives the update ("u"|"v")
    dtype: str = "float64"


def accum_sum(name: str, value=1.0, target: str = "v") -> AccumUpdate:
    return AccumUpdate(name=name, op="sum", value=value, target=target)


def accum_max(name: str, value, target: str = "v") -> AccumUpdate:
    return AccumUpdate(name=name, op="max", value=value, target=target)


def accum_min(name: str, value, target: str = "v") -> AccumUpdate:
    return AccumUpdate(name=name, op="min", value=value, target=target)


# ---------------------------------------------------------------------------
# execution blocks — what the GSQL compiler and the fluent builder both
# lower to (the IR's execution targets, DESIGN.md §8)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _SeedBlock:
    vertex_type: str
    where: Optional[Predicate]
    raw_ids: Optional[np.ndarray]
    # accumulator conjuncts (name, cmp-op text, value): filter the seed set
    # against runtime @accum state without touching the lake (BI5's
    # "high-degree persons" stage)
    accum_where: Optional[list] = None


@dataclasses.dataclass
class _HopBlock:
    edge_type: str
    direction: str
    edge_where: Optional[Predicate]
    source_where: Optional[Predicate]
    target_where: Optional[Predicate]
    accum: Optional[AccumUpdate]


@dataclasses.dataclass
class _PostAccumBlock:
    """POST-ACCUM: one aggregation hop seeded from an already-matched alias
    (vertex position ``source`` of the statement's path) — it updates
    accumulators and appends its frame, but never moves the result set."""

    source: int
    hop: _HopBlock
    target_alias: Optional[str] = None


@dataclasses.dataclass
class CompiledStatement:
    """One SELECT statement lowered to execution blocks."""

    seed: _SeedBlock
    hops: list[_HopBlock] = dataclasses.field(default_factory=list)
    # vertex position (0 = seed) whose forward-matched set becomes the
    # statement's result vset; -1 = last position (builder default)
    select: int = -1
    # alias name per vertex position (None = unnamed, builder chains)
    vertex_aliases: list = dataclasses.field(default_factory=list)
    post: list = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class CompiledQuery:
    """A full query: statements sharing one accumulator space."""

    statements: list
    # (vertex_type, accum name) pairs the query writes — what a session
    # resets before running so repeated queries are deterministic
    accum_targets: list = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class ExecOptions:
    """Per-execution knobs, owned by the session (DESIGN.md §8).

    ``pushdown=False`` forces the legacy full-materialization scan path (no
    staging, no zone-map pruning) — the pushdown parity baseline.
    ``pipeline`` pins the parallel chunk-pipelined read path on/off
    (``None`` defers to the ``pipe`` perf flag; ``False`` is the pipelining
    parity baseline, DESIGN.md §5).  All paths return bit-identical
    results.  ``timeout_s`` bounds wall time: exceeded deadlines raise
    :class:`~repro.core.plan.QueryTimeoutError` at the next stage boundary
    (E/U/V/ACCUM stage reads in ``edge_scan``, hop and statement edges in
    the executor)."""

    pushdown: bool = True
    pipeline: Optional[bool] = None
    timeout_s: Optional[float] = None

    def deadline(self) -> Optional[float]:
        if self.timeout_s is None:
            return None
        return time.monotonic() + self.timeout_s


@dataclasses.dataclass
class QueryResult:
    vset: VSet
    accumulators: dict[str, np.ndarray]
    n_edges_scanned: int
    frames: list
    # zone-map pruning counters accumulated over every read the query issued
    # (seed VertexMap + all hops); see plan.new_pruning_counters for keys
    pruning: dict = dataclasses.field(default_factory=new_pruning_counters)
    # which snapshot-pinned epoch served the query and how stale its view of
    # the lake was when the query finished (core/epochs.py); -1 = no epoch
    # subsystem (query ran straight against the mutable topology)
    epoch_id: int = -1
    staleness_s: float = 0.0
    # named vertex aliases -> vertex sets (GSQL front end): the seed alias
    # maps to the filtered seed set, every other alias to the set that
    # reached it (its hop's surviving far side)
    alias_sets: dict = dataclasses.field(default_factory=dict)
    # which execution path produced this result ("full" engine vs the
    # plan-cached "lookup" fast path) and the template's traffic-light tier
    # at install time ("green"/"yellow"/"red", "" = ad-hoc). Observability
    # stamps only — result contents are bit-identical across routes.
    route: str = "full"
    tier: str = ""
    # True when the serving layer's refresh breaker was open and the result
    # was served from the last good pinned epoch (staleness_s stays honest —
    # it keeps growing while degraded); DESIGN.md §11
    degraded: bool = False


def plan_hop(hop: "_HopBlock") -> ScanPlan:
    """Compile one hop block into a staged :class:`ScanPlan`.

    The WHERE is already split per prefix at the front end; planning stages
    the columns (predicate columns materialize in their stage, ACCUM-only
    columns for final survivors) and compiles each conjunct's zone-map
    bounds.
    """
    e_cols = list(dict.fromkeys(hop.edge_where.columns)) if hop.edge_where else []
    u_cols = list(dict.fromkeys(hop.source_where.columns)) if hop.source_where else []
    v_cols = list(dict.fromkeys(hop.target_where.columns)) if hop.target_where else []
    acc: dict[str, list[str]] = {"e": [], "u": [], "v": []}
    if hop.accum is not None and isinstance(hop.accum.value, str):
        pfx, col = hop.accum.value.split(".", 1)
        if col not in {"e": e_cols, "u": u_cols, "v": v_cols}[pfx]:
            acc[pfx].append(col)
    return ScanPlan(
        edge_pred=hop.edge_where,
        source_pred=hop.source_where,
        target_pred=hop.target_where,
        edge_columns=tuple(sorted(e_cols)),
        u_columns=tuple(sorted(u_cols)),
        v_columns=tuple(sorted(v_cols)),
        accum_edge_columns=tuple(acc["e"]),
        accum_u_columns=tuple(acc["u"]),
        accum_v_columns=tuple(acc["v"]),
        edge_bounds=hop.edge_where.bounds() if hop.edge_where else {},
        u_bounds=hop.source_where.bounds() if hop.source_where else {},
        v_bounds=hop.target_where.bounds() if hop.target_where else {},
    )


# ---------------------------------------------------------------------------
# the executor — one path under both front ends
# ---------------------------------------------------------------------------

_ACC_CMP = {
    "==": np.equal, "!=": np.not_equal, ">": np.greater, ">=": np.greater_equal,
    "<": np.less, "<=": np.less_equal,
}


def execute_compiled(engine, compiled: CompiledQuery,
                     options: Optional[ExecOptions] = None,
                     epoch=None, private_accums: bool = False) -> QueryResult:
    """Run a compiled query against the engine.

    Every run executes against one snapshot-pinned epoch (DESIGN.md §7): by
    default the engine's current epoch is acquired for the whole run —
    covering *all* statements of a multi-statement query — and released
    afterwards, so commits (and ``advance()``) landing mid-query can never
    tear the result.  Pass ``epoch`` (an explicitly acquired
    :class:`~repro.core.epochs.GraphEpoch`) to time-travel onto an older
    pinned view; the caller then owns its release.

    ``private_accums=True`` (the session path) runs the query against a
    fresh accumulator store sized to the pinned epoch: results are a pure
    function of (query, params, epoch), concurrent queries can never
    observe each other's partial accumulator state, and the returned arrays
    are never mutated by later queries.  The default shares the engine's
    store — the legacy builder semantics (cumulative across runs), which
    ``engine.register_accum`` consumers rely on.  Either store is captured
    *once* here: a full-rebuild ``advance()`` swapping ``engine.accums``
    mid-query cannot hand later hops a renumbered dense space.
    """
    options = options or ExecOptions()
    deadline = options.deadline()
    counters = new_pruning_counters()
    mgr = getattr(engine, "epochs", None)
    acquired = None
    if epoch is None and mgr is not None:
        epoch = acquired = mgr.acquire()
    try:
        from repro.core.accumulators import Accumulators

        accums = Accumulators(epoch if epoch is not None else engine.topology) \
            if private_accums else engine.accums
        accum_out: dict[str, np.ndarray] = {}
        frames: list = []
        alias_sets: dict = {}
        n_scanned = 0
        vset = None
        for stmt in compiled.statements:
            check_deadline(deadline)
            vset, n = _run_statement(
                engine, stmt, accums, counters, options, epoch, deadline,
                accum_out, frames, alias_sets,
            )
            n_scanned += n
        return QueryResult(
            vset=vset, accumulators=accum_out, n_edges_scanned=n_scanned,
            frames=frames, pruning=counters,
            epoch_id=epoch.epoch_id if epoch is not None else -1,
            staleness_s=epoch.staleness_s() if epoch is not None else 0.0,
            alias_sets=alias_sets,
        )
    finally:
        if acquired is not None:
            mgr.release(acquired)


def _run_statement(eng, stmt: CompiledStatement, accums, counters, options,
                   epoch, deadline, accum_out, frames, alias_sets):
    # ``accums`` is the store execute_compiled pinned for the whole query: a
    # full-rebuild advance() swaps eng.accums (renumbered dense space), and
    # this query's dense ids only mean anything in the store that matches
    # its pinned epoch
    seed = stmt.seed
    topo = epoch if epoch is not None else eng.topology
    pushdown, pipeline = options.pushdown, options.pipeline

    if seed.raw_ids is not None:
        vset = eng.vset_from_raw_ids(seed.vertex_type, seed.raw_ids, epoch=epoch)
    else:
        vset = eng.all_vertices(seed.vertex_type, epoch=epoch)
    if seed.where is not None:
        vset, _ = eng.vertex_map(
            vset,
            columns=list(dict.fromkeys(seed.where.columns)),
            filter_fn=lambda fr: seed.where.evaluate(fr, ""),
            bounds=seed.where.bounds() if pushdown else None,
            counters=counters, pipeline=pipeline, epoch=epoch,
            deadline=deadline,
        )
    if seed.accum_where:
        n = topo.n_vertices(seed.vertex_type)
        mask = vset.mask.copy()
        for name, op, value in seed.accum_where:
            if accums.has(seed.vertex_type, name):
                arr = accums.ensure_capacity(seed.vertex_type, name, n)[:n]
            else:  # never written -> every slot sits at the sum identity
                arr = np.zeros(n)
            mask &= _ACC_CMP[op](arr, value)
        vset = VSet(seed.vertex_type, mask)
    seed_set = vset

    aliases = stmt.vertex_aliases or [None] * (len(stmt.hops) + 1)
    if aliases[0] is not None:
        alias_sets[aliases[0]] = seed_set

    # forward-matched set per vertex position: position i>0 is the set its
    # hop reached; position 0 (computed lazily — it costs a np.unique) is
    # the seed vertices with at least one edge surviving hop 1
    matched: list = [None] * (len(stmt.hops) + 1)
    matched[0] = seed_set
    n_scanned = 0
    first_frame = None
    for hop_i, hop in enumerate(stmt.hops):
        check_deadline(deadline)
        frame, u_type, v_type = _exec_hop(
            eng, vset, hop, counters, options, epoch, deadline)
        if hop_i == 0:
            first_frame = frame
        n_scanned += len(frame)
        frames.append(frame)
        _apply_accum(accums, topo, hop, frame, u_type, v_type, accum_out)
        n_v = topo.n_vertices(v_type)
        vset = frame.v_set(n_v)
        matched[hop_i + 1] = vset
        if aliases[hop_i + 1] is not None:
            alias_sets[aliases[hop_i + 1]] = vset

    def matched_set(pos: int) -> VSet:
        if pos == 0 and stmt.hops:
            # lazily refine: seed vertices that kept an edge through hop 1
            return first_frame.u_set(topo.n_vertices(seed.vertex_type))
        return matched[pos]

    for pb in stmt.post:
        check_deadline(deadline)
        src = matched_set(pb.source)
        frame, u_type, v_type = _exec_hop(
            eng, src, pb.hop, counters, options, epoch, deadline)
        n_scanned += len(frame)
        frames.append(frame)
        _apply_accum(accums, topo, pb.hop, frame, u_type, v_type, accum_out)
        if pb.target_alias is not None:
            alias_sets[pb.target_alias] = frame.v_set(topo.n_vertices(v_type))

    select = stmt.select if stmt.select >= 0 else len(stmt.hops)
    return matched_set(select), n_scanned


def _exec_hop(eng, vset, hop: _HopBlock, counters, options, epoch, deadline):
    """One EdgeScan hop: staged pushdown plan, or the legacy
    full-materialization path when ``options.pushdown`` is off."""
    et = eng.schema.edge_types[hop.edge_type]
    u_type = et.src_type if hop.direction == "out" else et.dst_type
    v_type = et.dst_type if hop.direction == "out" else et.src_type

    if options.pushdown:
        frame = eng.edge_scan(
            vset, hop.edge_type, hop.direction,
            plan=plan_hop(hop), counters=counters, pipeline=options.pipeline,
            epoch=epoch, deadline=deadline,
        )
        return frame, u_type, v_type

    edge_cols, u_cols, v_cols = set(), set(), set()
    if hop.edge_where is not None:
        edge_cols.update(hop.edge_where.columns)
    if hop.source_where is not None:
        u_cols.update(hop.source_where.columns)
    if hop.target_where is not None:
        v_cols.update(hop.target_where.columns)
    if hop.accum is not None and isinstance(hop.accum.value, str):
        pfx, col = hop.accum.value.split(".", 1)
        {"e": edge_cols, "u": u_cols, "v": v_cols}[pfx].add(col)

    def _filter(frame, hop=hop):
        n = len(frame["u"])
        keep = np.ones(n, dtype=bool)
        if hop.edge_where is not None:
            keep &= hop.edge_where.evaluate(frame, "e")
        if hop.source_where is not None:
            keep &= hop.source_where.evaluate(frame, "u")
        if hop.target_where is not None:
            keep &= hop.target_where.evaluate(frame, "v")
        return keep

    frame = eng.edge_scan(
        vset, hop.edge_type, hop.direction,
        edge_columns=sorted(edge_cols),
        u_columns=sorted(u_cols),
        v_columns=sorted(v_cols),
        edge_filter=_filter,
        counters=counters, pipeline=options.pipeline,
        epoch=epoch, deadline=deadline,
    )
    return frame, u_type, v_type


def _apply_accum(accums, topo, hop: _HopBlock, frame, u_type, v_type, accum_out):
    if hop.accum is None:
        return
    a = hop.accum
    if a.target == "v":
        tgt_type, tgt_ids = v_type, frame.v
    else:
        tgt_type, tgt_ids = u_type, frame.u
    if not accums.has(tgt_type, a.name):
        accums.register(AccumSpec(tgt_type, a.name, op=a.op, dtype=a.dtype))
    if isinstance(a.value, str):
        pfx, col = a.value.split(".", 1)
        vals = frame.columns[f"{pfx}.{col}"]
    else:
        vals = a.value
    accums.update(tgt_type, a.name, tgt_ids, vals)
    # the result view is sized to *this* epoch's dense space, so it always
    # aligns with the result vset's mask even when a later epoch has
    # already grown the shared array
    n_tgt = topo.n_vertices(tgt_type)
    accums.ensure_capacity(tgt_type, a.name, n_tgt)
    accum_out[a.name] = accums.array(tgt_type, a.name)[:n_tgt]


# ---------------------------------------------------------------------------
# the shared-scan batched executor (DESIGN.md §9)
# ---------------------------------------------------------------------------

def _batch_shape(cq: CompiledQuery) -> tuple:
    """The structural skeleton riders must share to execute as one pass:
    everything about a compiled query *except* its bound parameter values."""
    def hop_shape(h: _HopBlock):
        a = h.accum
        return (h.edge_type, h.direction,
                h.edge_where is not None, h.source_where is not None,
                h.target_where is not None,
                None if a is None else
                (a.name, a.op, a.target, a.dtype,
                 a.value if isinstance(a.value, str) else "<const>"))

    def stmt_shape(s: CompiledStatement):
        return (s.seed.vertex_type, s.seed.where is not None,
                s.seed.raw_ids is not None,
                tuple((n, op) for n, op, _ in (s.seed.accum_where or ())),
                tuple(hop_shape(h) for h in s.hops), s.select,
                tuple(s.vertex_aliases),
                tuple((p.source, p.target_alias, hop_shape(p.hop))
                      for p in s.post))

    return tuple(stmt_shape(s) for s in cq.statements)


def _assert_batchable(compiled_list: list) -> None:
    ref = _batch_shape(compiled_list[0])
    for i, cq in enumerate(compiled_list[1:], start=1):
        if _batch_shape(cq) != ref:
            raise ValueError(
                "shared-scan batch requires riders compiled from one query "
                f"template (rider {i} differs structurally from rider 0); "
                "riders may only differ in bound parameter values")
    for cq in compiled_list:
        for s in cq.statements:
            if s.seed.raw_ids is not None:
                raise ValueError(
                    "raw_ids seeds cannot ride a shared-scan batch")


def execute_compiled_batch(engine, compiled_list: list,
                           options: Optional[ExecOptions] = None,
                           epoch=None) -> list[QueryResult]:
    """Run R compiled riders of one query template as a single shared pass
    (DESIGN.md §9).

    All riders pin the *same* epoch — acquired once here — and each gets a
    private accumulator store, so per-rider results match
    ``session.query()`` run solo on that epoch bit-for-bit: one gather over
    the union frontier, one chunk fetch/decode pass per stage (a chunk is
    skipped only when every rider's zone-map bounds reject it), per-rider
    masks over the shared decoded columns, and a stacked accumulator update.

    Riders must share the template's structure (:func:`_assert_batchable`);
    only bound parameter values may differ.  A single rider, or
    ``pushdown=False`` (the batched path is staged-scan-only), degenerates
    to sequential solo execution on one pinned epoch.  Pruning counters are
    the *batch's* — each rider's ``QueryResult.pruning`` is a copy of the
    shared pass's counters, which is exactly what "one pass served N
    riders" looks like (the serving benchmark asserts on it).
    """
    options = options or ExecOptions()
    if not compiled_list:
        return []
    mgr = getattr(engine, "epochs", None)
    acquired = None
    if epoch is None and mgr is not None:
        epoch = acquired = mgr.acquire()
    try:
        if len(compiled_list) == 1 or not options.pushdown:
            return [execute_compiled(engine, cq, options=options, epoch=epoch,
                                     private_accums=True)
                    for cq in compiled_list]
        _assert_batchable(compiled_list)
        from repro.core.accumulators import Accumulators

        deadline = options.deadline()
        counters = new_pruning_counters()
        n_riders = len(compiled_list)
        accums_list = [Accumulators(epoch if epoch is not None
                                    else engine.topology)
                       for _ in range(n_riders)]
        accum_outs: list[dict] = [{} for _ in range(n_riders)]
        frames_list: list[list] = [[] for _ in range(n_riders)]
        alias_sets_list: list[dict] = [{} for _ in range(n_riders)]
        n_scanned = [0] * n_riders
        vsets: list = [None] * n_riders
        for si in range(len(compiled_list[0].statements)):
            check_deadline(deadline)
            stmts = [cq.statements[si] for cq in compiled_list]
            vsets = _run_statement_batched(
                engine, stmts, accums_list, counters, options, epoch,
                deadline, accum_outs, frames_list, alias_sets_list, n_scanned,
            )
        return [
            QueryResult(
                vset=vsets[r], accumulators=accum_outs[r],
                n_edges_scanned=n_scanned[r], frames=frames_list[r],
                pruning=dict(counters),
                epoch_id=epoch.epoch_id if epoch is not None else -1,
                staleness_s=epoch.staleness_s() if epoch is not None else 0.0,
                alias_sets=alias_sets_list[r],
            )
            for r in range(n_riders)
        ]
    finally:
        if acquired is not None:
            mgr.release(acquired)


def _run_statement_batched(eng, stmts, accums_list, counters, options, epoch,
                           deadline, accum_outs, frames_list, alias_sets_list,
                           n_scanned):
    """Lockstep batched :func:`_run_statement`: riders advance hop by hop
    through one shared scan per hop, each tracking its own frontier,
    matched sets, aliases and accumulators."""
    from repro.core.primitives import edge_scan_batched, read_vertex_columns_multi

    n_riders = len(stmts)
    topo = epoch if epoch is not None else eng.topology
    pool = eng._query_pool(options.pipeline)
    seed0 = stmts[0].seed
    base = eng.all_vertices(seed0.vertex_type, epoch=epoch)

    # seed stage: one shared column read over the base set, per-rider
    # evaluation — vertex_map's filter path lifted across riders
    wheres = [s.seed.where for s in stmts]
    if any(w is not None for w in wheres):
        check_deadline(deadline)
        columns = list(dict.fromkeys(
            c for w in wheres if w is not None for c in w.columns))
        bounds_list = [w.bounds() if w is not None else {} for w in wheres]
        if eng.prefetcher is not None:
            eng.prefetcher.prefetch_vertices(
                base, columns, bounds=union_bounds_maps(bounds_list),
                topo=eng._topo(epoch))
        ids = base.ids()
        cols, rejects = read_vertex_columns_multi(
            eng._topo(epoch), eng.cache, seed0.vertex_type, ids, columns,
            bounds_list, counters=counters, pool=pool,
        )
        frame = {"id": ids, **cols}
        vsets = []
        for r, w in enumerate(wheres):
            if w is None:
                vsets.append(base)
                continue
            keep = np.asarray(w.evaluate(frame, ""), dtype=bool) & ~rejects[r]
            vsets.append(VSet.from_dense_ids(
                seed0.vertex_type, len(base.mask), ids[keep]))
    else:
        vsets = [base] * n_riders

    for r, s in enumerate(stmts):
        seed = s.seed
        if seed.accum_where:
            n = topo.n_vertices(seed.vertex_type)
            mask = vsets[r].mask.copy()
            for name, op, value in seed.accum_where:
                if accums_list[r].has(seed.vertex_type, name):
                    arr = accums_list[r].ensure_capacity(
                        seed.vertex_type, name, n)[:n]
                else:  # never written -> every slot sits at the sum identity
                    arr = np.zeros(n)
                mask &= _ACC_CMP[op](arr, value)
            vsets[r] = VSet(seed.vertex_type, mask)
    seed_sets = list(vsets)

    n_hops = len(stmts[0].hops)
    rider_aliases = [s.vertex_aliases or [None] * (n_hops + 1) for s in stmts]
    for r in range(n_riders):
        if rider_aliases[r][0] is not None:
            alias_sets_list[r][rider_aliases[r][0]] = seed_sets[r]

    matched = [[None] * (n_hops + 1) for _ in range(n_riders)]
    first_frames: list = [None] * n_riders
    for r in range(n_riders):
        matched[r][0] = seed_sets[r]

    for hop_i in range(n_hops):
        check_deadline(deadline)
        hops = [s.hops[hop_i] for s in stmts]
        scan = edge_scan_batched(
            eng._topo(epoch), eng.cache, vsets, hops[0].edge_type,
            hops[0].direction, [plan_hop(h) for h in hops],
            prefetcher=eng.prefetcher, counters=counters, pool=pool,
            deadline=deadline,
        )
        rider_frames = [scan.frame(r) for r in range(n_riders)]
        _apply_accum_batched(accums_list, topo, hops, scan, accum_outs)
        n_v = topo.n_vertices(scan.v_type)
        for r in range(n_riders):
            if hop_i == 0:
                first_frames[r] = rider_frames[r]
            frames_list[r].append(rider_frames[r])
            n_scanned[r] += len(rider_frames[r])
            vsets[r] = rider_frames[r].v_set(n_v)
            matched[r][hop_i + 1] = vsets[r]
            if rider_aliases[r][hop_i + 1] is not None:
                alias_sets_list[r][rider_aliases[r][hop_i + 1]] = vsets[r]

    def matched_set(r: int, pos: int) -> VSet:
        if pos == 0 and n_hops:
            # lazily refine: seed vertices that kept an edge through hop 1
            return first_frames[r].u_set(topo.n_vertices(seed0.vertex_type))
        return matched[r][pos]

    for pb_i in range(len(stmts[0].post)):
        check_deadline(deadline)
        pbs = [s.post[pb_i] for s in stmts]
        hops = [pb.hop for pb in pbs]
        srcs = [matched_set(r, pbs[r].source) for r in range(n_riders)]
        scan = edge_scan_batched(
            eng._topo(epoch), eng.cache, srcs, hops[0].edge_type,
            hops[0].direction, [plan_hop(h) for h in hops],
            prefetcher=eng.prefetcher, counters=counters, pool=pool,
            deadline=deadline,
        )
        rider_frames = [scan.frame(r) for r in range(n_riders)]
        _apply_accum_batched(accums_list, topo, hops, scan, accum_outs)
        n_v = topo.n_vertices(scan.v_type)
        for r in range(n_riders):
            frames_list[r].append(rider_frames[r])
            n_scanned[r] += len(rider_frames[r])
            if pbs[r].target_alias is not None:
                alias_sets_list[r][pbs[r].target_alias] = \
                    rider_frames[r].v_set(n_v)

    sel = stmts[0].select if stmts[0].select >= 0 else n_hops
    return [matched_set(r, sel) for r in range(n_riders)]


def _apply_accum_batched(accums_list, topo, hops, scan, accum_outs):
    """Stacked accumulator update over one shared scan.

    ``sum`` riders update through a single flattened bincount — the numpy
    mirror of ``kernels.ops.stacked_segment_sum`` (rider r's segments live
    at offset ``r * cap``), with dead rows contributing the identity instead
    of being sliced away (the masking formulation, DESIGN.md §2/§9).  The
    ordered-traversal ops (max/min/or) update per rider on their masked
    slice — same ``np.<op>.at`` path as solo.
    """
    a0 = hops[0].accum
    if a0 is None:    # riders share the template's accum shape (batchable)
        return
    if a0.target == "v":
        tgt_type, tgt_ids = scan.v_type, scan.v
    else:
        tgt_type, tgt_ids = scan.u_type, scan.u
    n_riders, n_rows = scan.alive.shape
    for accums in accums_list:
        if not accums.has(tgt_type, a0.name):
            accums.register(AccumSpec(tgt_type, a0.name, op=a0.op,
                                      dtype=a0.dtype))

    def rider_values(r: int):
        a = hops[r].accum
        if isinstance(a.value, str):
            pfx, col = a.value.split(".", 1)
            return scan.columns[f"{pfx}.{col}"]
        return a.value

    if n_rows:
        if a0.op == "sum":
            vals = np.stack([
                np.broadcast_to(np.asarray(rider_values(r), dtype=np.float64),
                                (n_rows,))
                for r in range(n_riders)
            ])
            contrib = np.where(scan.alive, vals, 0.0)
            cap = int(tgt_ids.max()) + 1
            seg = tgt_ids[None, :] + (np.arange(n_riders) * cap)[:, None]
            stacked = np.bincount(
                seg.ravel(), weights=contrib.ravel(),
                minlength=n_riders * cap).reshape(n_riders, cap)
            for r, accums in enumerate(accums_list):
                arr = accums.ensure_capacity(tgt_type, a0.name, cap)
                arr[:cap] += stacked[r].astype(arr.dtype, copy=False)
        else:
            for r, accums in enumerate(accums_list):
                m = scan.alive[r]
                vals = rider_values(r)
                if isinstance(vals, np.ndarray):
                    vals = vals[m]
                accums.update(tgt_type, a0.name, tgt_ids[m], vals)

    # result views sized to this epoch's dense space (see _apply_accum)
    n_tgt = topo.n_vertices(tgt_type)
    for r, accums in enumerate(accums_list):
        accums.ensure_capacity(tgt_type, a0.name, n_tgt)
        accum_outs[r][a0.name] = accums.array(tgt_type, a0.name)[:n_tgt]


# ---------------------------------------------------------------------------
# the fluent builder front end
# ---------------------------------------------------------------------------

class Query:
    def __init__(self, engine):
        self.engine = engine
        self._seed: Optional[_SeedBlock] = None
        self._hops: list[_HopBlock] = []

    # -- builders ---------------------------------------------------------------

    def vertices(self, vertex_type: str, where: Optional[Predicate] = None,
                 raw_ids=None) -> "Query":
        self._seed = _SeedBlock(vertex_type, where,
                                None if raw_ids is None else np.asarray(raw_ids))
        return self

    def hop(
        self,
        edge_type: str,
        direction: str = "out",
        edge_where: Optional[Predicate] = None,
        source_where: Optional[Predicate] = None,
        target_where: Optional[Predicate] = None,
        accum: Optional[AccumUpdate] = None,
    ) -> "Query":
        self._hops.append(
            _HopBlock(edge_type, direction, edge_where, source_where, target_where, accum)
        )
        return self

    # -- lowering ---------------------------------------------------------------

    def compiled(self) -> CompiledQuery:
        """This chain as a single-statement :class:`CompiledQuery` — the
        exact blocks the GSQL compiler would emit for the equivalent text."""
        if self._seed is None:
            raise ValueError("query has no seed block")
        return CompiledQuery(
            statements=[CompiledStatement(seed=self._seed, hops=list(self._hops))],
        )

    def to_ir(self):
        """This chain as a :class:`~repro.gsql.ir.LogicalQuery`.

        Only declarative chains convert: opaque UDF predicates (no
        ``spec``) and ``raw_ids`` seeds raise ``ValueError``.  The result
        renders to GSQL text that parses back to an equal IR — the
        round-trip property the GSQL tests fuzz.
        """
        from repro.gsql import ir

        if self._seed is None:
            raise ValueError("query has no seed block")
        if self._seed.raw_ids is not None:
            raise ValueError("raw_ids seeds are not representable in GSQL text")

        schema = self.engine.schema
        v_aliases = ["s"] + [f"v{i + 1}" for i in range(len(self._hops))]
        vtypes = [self._seed.vertex_type]
        hop_pats = []
        conds: list = []
        accums: list = []

        def add_pred(pred: Optional[Predicate], alias: str):
            if pred is None:
                return
            conds.extend(_spec_to_conds(pred.spec, alias))

        add_pred(self._seed.where, "s")
        if self._seed.accum_where:
            for name, op, value in self._seed.accum_where:
                conds.append(ir.Cmp(ref=ir.ColRef("s", name, is_accum=True),
                                    op=op, value=value))

        for i, hop in enumerate(self._hops):
            et = schema.edge_types[hop.edge_type]
            if hop.direction not in ("out", "in"):
                raise ValueError(f"direction {hop.direction!r} is not renderable")
            v_type = et.dst_type if hop.direction == "out" else et.src_type
            u_type = et.src_type if hop.direction == "out" else et.dst_type
            if u_type != vtypes[-1]:
                raise ValueError(
                    f"hop {i + 1} ({hop.edge_type}, {hop.direction}) expects a "
                    f"{u_type} frontier, got {vtypes[-1]}")
            vtypes.append(v_type)
            e_alias = f"e{i + 1}"
            hop_pats.append(ir.HopPat(edge_type=hop.edge_type, alias=e_alias,
                                      direction=hop.direction))
            add_pred(hop.edge_where, e_alias)
            add_pred(hop.source_where, v_aliases[i])
            add_pred(hop.target_where, v_aliases[i + 1])
            if hop.accum is not None:
                a = hop.accum
                if a.op not in ir.ACCUM_OPS:
                    raise ValueError(f"accumulator op {a.op!r} is not renderable")
                tgt_alias = v_aliases[i + 1] if a.target == "v" else v_aliases[i]
                if isinstance(a.value, str):
                    pfx, col = a.value.split(".", 1)
                    value = ir.ColRef(
                        {"u": v_aliases[i], "v": v_aliases[i + 1], "e": e_alias}[pfx],
                        col)
                else:
                    value = a.value
                accums.append(ir.AccumStmt(
                    target=ir.ColRef(tgt_alias, a.name, is_accum=True),
                    op=a.op, value=value))

        stmt = ir.StatementIR(
            select_alias=v_aliases[-1],
            vertices=tuple(ir.VertexPat(vtype=t, alias=a)
                           for t, a in zip(vtypes, v_aliases)),
            hops=tuple(hop_pats),
            where=tuple(conds),
            accums=tuple(accums),
        )
        return ir.LogicalQuery(statements=(stmt,))

    # -- execution ----------------------------------------------------------------

    def run(self, options: Optional[ExecOptions] = None, *,
            epoch=None) -> QueryResult:
        """Execute the query via :func:`execute_compiled`.

        Execution knobs travel in :class:`ExecOptions` (or as session
        defaults via ``repro.connect()``).  ``epoch`` time-travels onto an
        explicitly acquired pinned view (the caller owns its release)."""
        return execute_compiled(self.engine, self.compiled(),
                                options=options, epoch=epoch)


def _spec_to_conds(spec, alias: str) -> list:
    """A Predicate's declarative ``spec`` -> IR conjuncts for one alias."""
    from repro.gsql import ir

    if spec is None:
        raise ValueError("opaque (UDF) predicates are not representable in GSQL")
    kind = spec[0]
    if kind == "cmp":
        _, col, op, value = spec
        return [ir.Cmp(ref=ir.ColRef(alias, col), op=op, value=value)]
    if kind == "in":
        _, col, values = spec
        return [ir.InSet(ref=ir.ColRef(alias, col), values=tuple(values))]
    if kind == "and":
        return _spec_to_conds(spec[1], alias) + _spec_to_conds(spec[2], alias)
    if kind == "or":
        items = []
        for side in (spec[1], spec[2]):
            cs = _spec_to_conds(side, alias)
            if len(cs) != 1:
                # (a & b) | c has no GSQL spelling in the subset — the
                # grammar's OR joins simple comparisons only
                raise ValueError("OR over an AND-composition is not "
                                 "representable in GSQL")
            if isinstance(cs[0], ir.OrCond):
                items.extend(cs[0].items)
            else:
                items.append(cs[0])
        return [ir.OrCond(items=tuple(items))]
    raise ValueError(f"unknown predicate spec {spec!r}")
