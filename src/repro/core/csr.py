"""CSR topology representation — the second physical layout of the topology
plane (DESIGN.md §3).

``CSRIndex`` holds one edge type's edges grouped by vertex, both directions:

- **forward** (grouped by source): ``fwd_indptr``/``fwd_dst`` — the classic
  vertex-centric adjacency index.  ``fwd_eid`` maps each CSR slot back to the
  *global edge id* (edge-list order: lists in registration order, rows in file
  order), which is what keeps CSR scans row-aligned with edge-attribute
  chunks and lets the two physical representations produce bit-identical
  scan output.
- **reverse** (grouped by destination): ``rev_indptr``/``rev_src``/``rev_eid``
  — bidirectional traversal with no transpose at query time, and the
  dst-sorted edge order whose tight per-block Min-Max ranges the Pallas
  ``edge_segment_sum`` kernel skips on (DESIGN.md §2).

Unlike the per-file edge lists (cheap incremental maintenance, sequential
scan locality), a CSR is built once per edge type over *all* its files — the
expensive grouping step the paper's Fig. 15 amortizes across low-selectivity
scans.  It serializes to a single lake blob next to the edge-list blobs so
the fast "second connection" path restores both representations.
"""

from __future__ import annotations

import io
import struct
import time
from typing import Optional

import numpy as np

_MAGIC = b"RCSR"


def _ragged_gather(indptr: np.ndarray, active_ids: np.ndarray):
    """Vectorized expansion of the adjacency ranges of ``active_ids``.

    Returns ``(positions, lengths)``: ``positions`` indexes the CSR value
    arrays (neighbors / eids) for every edge incident to an active vertex,
    ``lengths`` is the per-active-vertex range length (for ``np.repeat``).
    """
    starts = indptr[active_ids]
    stops = indptr[active_ids + 1]
    lengths = stops - starts
    total = int(lengths.sum())
    if total == 0:
        return np.empty(0, dtype=np.int64), lengths
    # within-range offsets are arange(total) minus each range's cumulative
    # start, shifted to the range's first CSR slot
    cumstarts = np.concatenate(([0], np.cumsum(lengths)[:-1]))
    pos = np.arange(total) - np.repeat(cumstarts, lengths) + np.repeat(starts, lengths)
    return pos, lengths


class CSRIndex:
    """Forward + reverse CSR of one edge type (all edge files merged)."""

    def __init__(
        self,
        edge_type: str,
        n_src: int,
        n_dst: int,
        fwd_indptr: np.ndarray,
        fwd_dst: np.ndarray,
        fwd_eid: np.ndarray,
        rev_indptr: np.ndarray,
        rev_src: np.ndarray,
        rev_eid: np.ndarray,
        build_seconds: float = 0.0,
    ):
        self.edge_type = edge_type
        self.n_src = n_src
        self.n_dst = n_dst
        self.fwd_indptr = np.asarray(fwd_indptr, dtype=np.int64)
        self.fwd_dst = np.asarray(fwd_dst, dtype=np.int64)
        self.fwd_eid = np.asarray(fwd_eid, dtype=np.int64)
        self.rev_indptr = np.asarray(rev_indptr, dtype=np.int64)
        self.rev_src = np.asarray(rev_src, dtype=np.int64)
        self.rev_eid = np.asarray(rev_eid, dtype=np.int64)
        self.build_seconds = build_seconds

    # ------------------------------------------------------------------ build

    @staticmethod
    def from_arrays(
        edge_type: str, src: np.ndarray, dst: np.ndarray, n_src: int, n_dst: int
    ) -> "CSRIndex":
        """Group (src, dst) dense edge arrays by both endpoints.

        ``src``/``dst`` are in global-edge-id order; the stable argsorts keep
        ``eid`` monotone within each vertex's range, so per-vertex adjacency
        stays in edge-list order too.
        """
        t0 = time.perf_counter()
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        fwd_order = np.argsort(src, kind="stable")
        fwd_indptr = np.zeros(n_src + 1, dtype=np.int64)
        np.cumsum(np.bincount(src, minlength=n_src), out=fwd_indptr[1:])
        rev_order = np.argsort(dst, kind="stable")
        rev_indptr = np.zeros(n_dst + 1, dtype=np.int64)
        np.cumsum(np.bincount(dst, minlength=n_dst), out=rev_indptr[1:])
        return CSRIndex(
            edge_type=edge_type,
            n_src=n_src,
            n_dst=n_dst,
            fwd_indptr=fwd_indptr,
            fwd_dst=dst[fwd_order],
            fwd_eid=fwd_order,
            rev_indptr=rev_indptr,
            rev_src=src[rev_order],
            rev_eid=rev_order,
            build_seconds=time.perf_counter() - t0,
        )

    # ---------------------------------------------------------- incremental

    @staticmethod
    def _pad_indptr(indptr: np.ndarray, n_new: int) -> np.ndarray:
        """Extend an indptr to ``n_new`` vertices (new tail vertices have
        empty adjacency ranges).  Returns the input when nothing grows."""
        n_old = len(indptr) - 1
        if n_new == n_old:
            return indptr
        if n_new < n_old:
            raise ValueError(f"CSR vertex space cannot shrink ({n_old} -> {n_new})")
        out = np.empty(n_new + 1, dtype=np.int64)
        out[: n_old + 1] = indptr
        out[n_old + 1:] = indptr[-1]
        return out

    def padded(self, n_src: int, n_dst: int) -> "CSRIndex":
        """This index re-dimensioned for a grown vertex space (append-only
        vertex commits).  Edge arrays are shared, only indptrs reallocate —
        the O(V) carry-forward the epoch manager uses for edge types whose
        edges did not change (DESIGN.md §7)."""
        if n_src == self.n_src and n_dst == self.n_dst:
            return self
        return CSRIndex(
            edge_type=self.edge_type,
            n_src=n_src,
            n_dst=n_dst,
            fwd_indptr=self._pad_indptr(self.fwd_indptr, n_src),
            fwd_dst=self.fwd_dst,
            fwd_eid=self.fwd_eid,
            rev_indptr=self._pad_indptr(self.rev_indptr, n_dst),
            rev_src=self.rev_src,
            rev_eid=self.rev_eid,
        )

    def extended(
        self,
        src_new: np.ndarray,
        dst_new: np.ndarray,
        n_src: int,
        n_dst: int,
        eid_base: Optional[int] = None,
    ) -> "CSRIndex":
        """A *new* CSRIndex with ``(src_new, dst_new)`` delta edges merged in.

        The incremental-epoch maintenance path (DESIGN.md §7): append-only
        edge commits add edge lists at the end of the global-edge-id space,
        so each vertex's adjacency range grows at its tail — an O(E_old +
        E_new log E_new) positional merge (copies + one delta-sized sort)
        instead of the full rebuild's two O(E_total log E_total) argsorts
        over re-concatenated arrays.  ``self`` is untouched (epochs are
        immutable; the previous epoch keeps serving from the old index), and
        the result is bit-identical to ``from_arrays`` over the concatenated
        edge set: old slots keep their order, delta slots append per vertex
        in delta order, so eids stay monotone within every adjacency range.
        """
        t0 = time.perf_counter()
        src_new = np.asarray(src_new, dtype=np.int64)
        dst_new = np.asarray(dst_new, dtype=np.int64)
        if eid_base is None:
            eid_base = self.n_edges

        def merge(indptr_old, far_old, eid_old, group_new, far_new, n_groups):
            indptr_old = self._pad_indptr(indptr_old, n_groups)
            old_deg = np.diff(indptr_old)
            new_cnt = np.bincount(group_new, minlength=n_groups)
            indptr = np.zeros(n_groups + 1, dtype=np.int64)
            np.cumsum(old_deg + new_cnt, out=indptr[1:])
            # old slots shift by the delta edges inserted before their vertex
            shift = indptr[:-1] - indptr_old[:-1]
            pos_old = np.arange(len(far_old), dtype=np.int64) + np.repeat(shift, old_deg)
            order = np.argsort(group_new, kind="stable")
            g_sorted = group_new[order]
            # rank within each vertex group of the sorted delta
            rank = np.arange(len(g_sorted), dtype=np.int64) - np.searchsorted(
                g_sorted, g_sorted, side="left"
            )
            pos_new = indptr[g_sorted] + old_deg[g_sorted] + rank
            total = len(far_old) + len(far_new)
            far = np.empty(total, dtype=np.int64)
            eid = np.empty(total, dtype=np.int64)
            far[pos_old] = far_old
            far[pos_new] = far_new[order]
            eid[pos_old] = eid_old
            eid[pos_new] = eid_base + order
            return indptr, far, eid

        fwd_indptr, fwd_dst, fwd_eid = merge(
            self.fwd_indptr, self.fwd_dst, self.fwd_eid, src_new, dst_new, n_src)
        rev_indptr, rev_src, rev_eid = merge(
            self.rev_indptr, self.rev_src, self.rev_eid, dst_new, src_new, n_dst)
        return CSRIndex(
            edge_type=self.edge_type,
            n_src=n_src,
            n_dst=n_dst,
            fwd_indptr=fwd_indptr,
            fwd_dst=fwd_dst,
            fwd_eid=fwd_eid,
            rev_indptr=rev_indptr,
            rev_src=rev_src,
            rev_eid=rev_eid,
            build_seconds=time.perf_counter() - t0,
        )

    # ------------------------------------------------------------------ reads

    @property
    def n_edges(self) -> int:
        return len(self.fwd_dst)

    def nbytes(self) -> int:
        return sum(
            a.nbytes
            for a in (
                self.fwd_indptr, self.fwd_dst, self.fwd_eid,
                self.rev_indptr, self.rev_src, self.rev_eid,
            )
        )

    def neighbors(self, v: int, direction: str = "out") -> np.ndarray:
        if direction == "out":
            return self.fwd_dst[self.fwd_indptr[v]: self.fwd_indptr[v + 1]]
        return self.rev_src[self.rev_indptr[v]: self.rev_indptr[v + 1]]

    def degrees(self, direction: str = "out") -> np.ndarray:
        indptr = self.fwd_indptr if direction == "out" else self.rev_indptr
        return np.diff(indptr)

    def expand(self, active_ids: np.ndarray, direction: str = "out"):
        """Vertex-centric EdgeMap: gather the adjacency ranges of the active
        vertices.  Returns ``(u, v, eid)`` — frontier-side endpoints repeated
        per neighbor, far-side endpoints, and global edge ids.
        """
        active_ids = np.asarray(active_ids, dtype=np.int64)
        if direction == "out":
            indptr, far, eids = self.fwd_indptr, self.fwd_dst, self.fwd_eid
        else:
            indptr, far, eids = self.rev_indptr, self.rev_src, self.rev_eid
        pos, lengths = _ragged_gather(indptr, active_ids)
        if len(pos) == 0:
            z = np.empty(0, dtype=np.int64)
            return z, z.copy(), z.copy()
        return np.repeat(active_ids, lengths), far[pos], eids[pos]

    def edges_by_dst(self):
        """(src, dst, eid) with dst non-decreasing — the kernel-friendly edge
        order (tight Pallas block Min-Max ranges, DESIGN.md §2)."""
        dst = np.repeat(np.arange(self.n_dst, dtype=np.int64), np.diff(self.rev_indptr))
        return self.rev_src, dst, self.rev_eid

    def edges_by_src(self):
        """(src, dst, eid) with src non-decreasing."""
        src = np.repeat(np.arange(self.n_src, dtype=np.int64), np.diff(self.fwd_indptr))
        return src, self.fwd_dst, self.fwd_eid

    # ---------------------------------------------------------- serialization

    def to_bytes(self) -> bytes:
        buf = io.BytesIO()
        et = self.edge_type.encode()
        buf.write(_MAGIC)
        buf.write(struct.pack("<iqqq", len(et), self.n_src, self.n_dst, self.n_edges))
        buf.write(et)
        for arr in (
            self.fwd_indptr, self.fwd_dst, self.fwd_eid,
            self.rev_indptr, self.rev_src, self.rev_eid,
        ):
            buf.write(np.ascontiguousarray(arr, dtype=np.int64).tobytes())
        return buf.getvalue()

    @staticmethod
    def from_bytes(blob: bytes) -> "CSRIndex":
        if blob[:4] != _MAGIC:
            raise ValueError("bad CSR magic")
        et_len, n_src, n_dst, n_edges = struct.unpack_from("<iqqq", blob, 4)
        off = 4 + struct.calcsize("<iqqq")
        edge_type = blob[off: off + et_len].decode(); off += et_len

        def take(count):
            nonlocal off
            arr = np.frombuffer(blob, dtype=np.int64, count=count, offset=off).copy()
            off += count * 8
            return arr

        return CSRIndex(
            edge_type=edge_type,
            n_src=n_src,
            n_dst=n_dst,
            fwd_indptr=take(n_src + 1),
            fwd_dst=take(n_edges),
            fwd_eid=take(n_edges),
            rev_indptr=take(n_dst + 1),
            rev_src=take(n_edges),
            rev_eid=take(n_edges),
        )
