"""Lakehouse-optimized parallel primitives: VertexMap and EdgeScan (paper §6.1).

Both primitives materialize rows through graph-aware cache units and run
vectorized UDFs.  The paper's per-thread loops become block-vectorized numpy
over (file x row-group) tasks — the TPU-idiomatic masking formulation of the
same computation (see DESIGN.md §2).

``EdgeScan`` consumes the topology through the **topology plane**
(DESIGN.md §3): per scan it resolves a physical representation — the
edge-centric per-file edge lists (sequential scan, Min-Max portion pruning)
or the vertex-centric CSR index (adjacency-range gather) — via an adaptive
selectivity dispatch.  Either way the gather returns (u, v, global-edge-id)
in canonical order and row-level alignment with edge-attribute chunks is
kept through the global edge ids.

Two materialization paths exist past the gather (DESIGN.md §4):

- the **legacy full-materialization path** (``edge_filter`` callable): every
  requested column is materialized for every gathered row, then the filter
  runs once over the complete frame — the only path that supports opaque
  cross-entity UDF filters;
- the **staged pushdown path** (``plan``: a :class:`~repro.core.plan.ScanPlan`
  from the query planner): per-prefix conjuncts evaluate stage by stage on a
  shrinking row set (edge columns -> frontier-side vertex columns -> far-side
  vertex columns), each stage's reads consult per-chunk Min/Max statistics to
  skip chunks that cannot satisfy the conjunct (zone-map pruning), and
  ACCUM-only columns materialize last, for final survivors only.  Both paths
  produce bit-identical ``EdgeFrame``s.

Every reader accepts an optional ``pool`` (the engine's shared ``IOPool``):
surviving chunks then fetch and decode through the **parallel chunk
pipeline** (``core/read_pipeline.py``, DESIGN.md §5) instead of one at a
time on the caller thread.  The staged path threads one
:class:`~repro.core.read_pipeline.ReadContext` through all of its stages so
E/U/V/ACCUM never fetch the same chunk twice.  ``pool=None`` (or the
``pipe`` flag off) is the sequential parity path — bit-identical output.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import numpy as np

from repro.core.cache.manager import CacheManager
from repro.core.plan import check_deadline, union_bounds_maps
from repro.core.read_pipeline import (
    ReadContext,
    execute_plan,
    plan_edge_read,
    plan_edge_read_multi,
    plan_vertex_read,
    plan_vertex_read_multi,
)
from repro.core.types import VSet


def _finalize(out: dict, n: int) -> dict[str, np.ndarray]:
    for c, arr in out.items():
        if arr is None:
            out[c] = np.zeros(n, dtype=np.float64)
    return out


def read_vertex_columns_pruned(
    topology, cache: CacheManager, vertex_type: str, dense_ids: np.ndarray,
    columns: Sequence[str], bounds: Optional[dict] = None, counters: Optional[dict] = None,
    pool=None, ctx: Optional[ReadContext] = None,
) -> tuple[dict[str, np.ndarray], np.ndarray]:
    """Materialize vertex columns for arbitrary dense IDs (point lookups).

    Groups the request by (file, row group) into a
    :class:`~repro.core.read_pipeline.ChunkFetchPlan` and reads each
    surviving chunk through its VertexCacheUnit — batched through ``pool``
    when given — scattering results back into request order.  When
    ``bounds`` (column -> ``ColumnBounds``) is given, row groups whose chunk
    Min/Max statistics cannot satisfy a bound are skipped outright — no
    column of the group is fetched/decoded — and their rows are flagged in
    the returned reject mask (they definitively fail the conjunct; their
    output values are filler and must not be consulted).
    """
    plan = plan_vertex_read(topology, vertex_type, dense_ids, columns,
                            bounds=bounds, counters=counters)
    out = execute_plan(plan, cache, counters=counters, pool=pool, ctx=ctx)
    return _finalize(out, plan.n), plan.reject


def read_vertex_values(
    topology, cache: CacheManager, vertex_type: str, dense_ids: np.ndarray, column: str
) -> np.ndarray:
    """Single-column, no-pruning convenience over
    :func:`read_vertex_columns_pruned` (the pre-pushdown API)."""
    cols, _ = read_vertex_columns_pruned(topology, cache, vertex_type, dense_ids, [column])
    return cols[column]


def read_edge_columns_pruned(
    topology, cache: CacheManager, edge_type: str, eids: np.ndarray,
    columns: Sequence[str], bounds: Optional[dict] = None, counters: Optional[dict] = None,
    pool=None, ctx: Optional[ReadContext] = None,
) -> tuple[dict[str, np.ndarray], np.ndarray]:
    """Materialize edge columns for *global* edge ids of an edge type.

    Global edge ids address rows across the edge type's files (lists in
    registration order, rows in file order) — the addressing every
    ``TopologyView.gather`` returns.  The per-list/per-row-group grouping
    depends only on the eids, so it is computed once and shared by all
    requested columns.  ``bounds``/``counters``/``pool`` behave exactly as
    in :func:`read_vertex_columns_pruned`: zone-map-rejected row groups are
    never fetched or decoded and their rows come back reject-flagged.
    """
    plan = plan_edge_read(topology, edge_type, eids, columns,
                          bounds=bounds, counters=counters)
    out = execute_plan(plan, cache, counters=counters, pool=pool, ctx=ctx)
    return _finalize(out, plan.n), plan.reject


def read_vertex_columns_multi(
    topology, cache: CacheManager, vertex_type: str, dense_ids: np.ndarray,
    columns: Sequence[str], bounds_list: Sequence[Optional[dict]],
    counters: Optional[dict] = None, pool=None,
    ctx: Optional[ReadContext] = None,
) -> tuple[dict[str, np.ndarray], np.ndarray]:
    """Shared-scan vertex read: one fetch pass, R riders (DESIGN.md §9).

    Identical to :func:`read_vertex_columns_pruned` except pruning takes one
    bounds map *per rider*: a chunk is skipped only when every rider rejects
    it, and the returned ``(R, n)`` reject matrix carries each rider's own
    definitive verdicts (rider *r* must not consult values its row flags)."""
    plan, rejects = plan_vertex_read_multi(
        topology, vertex_type, dense_ids, columns, bounds_list,
        counters=counters)
    out = execute_plan(plan, cache, counters=counters, pool=pool, ctx=ctx)
    return _finalize(out, plan.n), rejects


def read_edge_columns_multi(
    topology, cache: CacheManager, edge_type: str, eids: np.ndarray,
    columns: Sequence[str], bounds_list: Sequence[Optional[dict]],
    counters: Optional[dict] = None, pool=None,
    ctx: Optional[ReadContext] = None,
) -> tuple[dict[str, np.ndarray], np.ndarray]:
    """Shared-scan edge read — :func:`read_vertex_columns_multi` for global
    edge ids."""
    plan, rejects = plan_edge_read_multi(
        topology, edge_type, eids, columns, bounds_list, counters=counters)
    out = execute_plan(plan, cache, counters=counters, pool=pool, ctx=ctx)
    return _finalize(out, plan.n), rejects


def read_edge_columns_by_eid(
    topology, cache: CacheManager, edge_type: str, eids: np.ndarray,
    columns: Sequence[str], pool=None,
) -> dict[str, np.ndarray]:
    """No-pruning convenience over :func:`read_edge_columns_pruned`."""
    return read_edge_columns_pruned(topology, cache, edge_type, eids, columns,
                                    pool=pool)[0]


def read_edge_values_by_eid(
    topology, cache: CacheManager, edge_type: str, eids: np.ndarray, column: str
) -> np.ndarray:
    """Single-column convenience over :func:`read_edge_columns_by_eid`."""
    return read_edge_columns_by_eid(topology, cache, edge_type, eids, [column])[column]


# ---------------------------------------------------------------------------
# VertexMap
# ---------------------------------------------------------------------------

def vertex_map(
    topology,
    cache: CacheManager,
    vset: VSet,
    columns: Sequence[str] = (),
    filter_fn: Optional[Callable[[dict], np.ndarray]] = None,
    map_fn: Optional[Callable[[dict], np.ndarray]] = None,
    prefetcher=None,
    bounds: Optional[dict] = None,
    counters: Optional[dict] = None,
    pool=None,
    deadline: Optional[float] = None,
):
    """Apply a UDF over an active vertex set (paper §6.1).

    Returns ``(VSet, values)``: the filtered subset (if ``filter_fn``) and the
    per-active-vertex ``map_fn`` output (if given).  The UDF receives a dict
    ``{"id": dense ids, <col>: values...}`` — fully materialized vertex rows.

    ``bounds`` (column -> ``ColumnBounds``, only sensible with ``filter_fn``)
    enables zone-map chunk pruning on the column reads: definitively rejected
    rows are dropped from the output without the UDF seeing real values.
    ``deadline`` (monotonic seconds) enforces ``ExecOptions.timeout_s`` at
    the read boundary.
    """
    check_deadline(deadline)
    if prefetcher is not None:
        prefetcher.prefetch_vertices(vset, columns, bounds=bounds, topo=topology)
    ids = vset.ids()
    frame = {"id": ids}
    cols, reject = read_vertex_columns_pruned(
        topology, cache, vset.vertex_type, ids, list(columns),
        bounds=bounds, counters=counters, pool=pool,
    )
    frame.update(cols)
    out_vals = map_fn(frame) if map_fn is not None else None
    if filter_fn is not None:
        keep = np.asarray(filter_fn(frame), dtype=bool) & ~reject
        new = VSet.from_dense_ids(vset.vertex_type, len(vset.mask), ids[keep])
        if out_vals is not None:
            out_vals = out_vals[keep]
        return new, out_vals
    return vset, out_vals


# ---------------------------------------------------------------------------
# EdgeScan
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class EdgeFrame:
    """Materialized, filtered edge rows from one EdgeScan."""

    u: np.ndarray                 # frontier-side dense endpoint IDs
    v: np.ndarray                 # far-side dense endpoint IDs
    u_type: str
    v_type: str
    columns: dict[str, np.ndarray]  # "e.X" / "u.X" / "v.X"
    eid: Optional[np.ndarray] = None  # global edge ids, aligned with u/v

    def __len__(self) -> int:
        return len(self.u)

    def v_set(self, n: int) -> VSet:
        return VSet.from_dense_ids(self.v_type, n, np.unique(self.v))

    def u_set(self, n: int) -> VSet:
        return VSet.from_dense_ids(self.u_type, n, np.unique(self.u))


def edge_scan(
    topology,
    cache: CacheManager,
    frontier: VSet,
    edge_type: str,
    direction: str = "out",
    edge_columns: Sequence[str] = (),
    u_columns: Sequence[str] = (),
    v_columns: Sequence[str] = (),
    edge_filter: Optional[Callable[[dict], np.ndarray]] = None,
    prefetcher=None,
    read_v_values: Optional[Callable[[str, np.ndarray, str], np.ndarray]] = None,
    strategy: str = "auto",
    plan=None,
    counters: Optional[dict] = None,
    pool=None,
    deadline: Optional[float] = None,
) -> EdgeFrame:
    """Scan the edges incident to ``frontier`` (paper §6.1).

    The physical plan is chosen per scan by the topology plane
    (DESIGN.md §3): ``strategy="edgelist"`` forces the edge-centric
    sequential scan with Min-Max portion pruning, ``strategy="csr"`` forces
    the vertex-centric adjacency-range gather, and ``strategy="auto"``
    (default) picks by frontier selectivity — CSR below the calibrated
    crossover threshold, edge lists above it.  Both produce bit-identical
    output (global edge-id order).

    ``direction="out"`` treats stored (first, second) IDs as (u=src, v=dst);
    ``direction="in"`` swaps roles — bidirectional traversal without storing
    reverse edges (edge lists swap endpoint roles; CSR uses its reverse
    index).  ``edge_filter`` sees the full materialized frame and returns a
    keep-mask (cross-entity predicates welcome).

    ``plan`` (a :class:`~repro.core.plan.ScanPlan`, mutually exclusive with
    ``edge_filter``/column args) switches to the staged pushdown path
    (DESIGN.md §4): per-prefix conjuncts evaluate on a shrinking row set with
    zone-map chunk pruning, and far-side/ACCUM columns materialize late.

    ``read_v_values`` overrides far-side attribute reads — the distributed
    engine injects the two-pass remote fetch here (paper §6.2).  ``pool``
    selects the parallel chunk pipeline for every attribute read.
    ``deadline`` (monotonic seconds) enforces ``ExecOptions.timeout_s`` at
    every stage boundary — a timed-out scan stops before its next batch of
    lake reads.
    """
    check_deadline(deadline)
    et = topology.schema.edge_types[edge_type]
    if direction == "out":
        u_type, v_type = et.src_type, et.dst_type
    else:
        u_type, v_type = et.dst_type, et.src_type

    if plan is not None:
        return _edge_scan_staged(
            topology, cache, frontier, edge_type, direction, plan,
            prefetcher, read_v_values, strategy, counters, u_type, v_type, pool,
            deadline=deadline,
        )

    if prefetcher is not None:
        prefetcher.prefetch_edges(frontier, edge_type, edge_columns,
                                  direction=direction, topo=topology)
        prefetcher.prefetch_vertices(frontier, u_columns, topo=topology)

    view = topology.plane.view(
        edge_type, strategy, frontier=frontier, direction=direction
    )
    u, v, eid = view.gather(frontier, direction=direction)
    ctx = ReadContext()
    by_col, _ = read_edge_columns_pruned(
        topology, cache, edge_type, eid, edge_columns, counters=counters,
        pool=pool, ctx=ctx,
    )
    columns = {f"e.{c}": by_col[c] for c in edge_columns}

    # endpoint materialization (vertex rows via graph-aware cache units)
    check_deadline(deadline)
    u_vals, _ = read_vertex_columns_pruned(
        topology, cache, u_type, u, list(u_columns), counters=counters,
        pool=pool, ctx=ctx,
    )
    for c in u_columns:
        columns[f"u.{c}"] = u_vals[c]
    if read_v_values is not None:
        for c in v_columns:
            columns[f"v.{c}"] = read_v_values(v_type, v, c)
    else:
        v_vals, _ = read_vertex_columns_pruned(
            topology, cache, v_type, v, list(v_columns), counters=counters,
            pool=pool, ctx=ctx,
        )
        for c in v_columns:
            columns[f"v.{c}"] = v_vals[c]

    frame = dict(columns)
    frame["u"] = u
    frame["v"] = v
    if edge_filter is not None and len(u):
        keep = np.asarray(edge_filter(frame), dtype=bool)
        u, v, eid = u[keep], v[keep], eid[keep]
        columns = {k: vals[keep] for k, vals in columns.items()}

    return EdgeFrame(u=u, v=v, u_type=u_type, v_type=v_type, columns=columns,
                     eid=eid)


# ---------------------------------------------------------------------------
# shared-scan batched EdgeScan (DESIGN.md §9)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BatchedScan:
    """One shared pass serving R rider queries.

    ``u``/``v``/``columns`` hold the *union* survivors — every row at least
    one rider kept — and ``alive`` is the (R, E) rider mask: row *j* belongs
    to rider *r*'s solo result iff ``alive[r, j]``.  Slicing the shared
    arrays by a rider's mask yields exactly that rider's solo
    :class:`EdgeFrame` (rows stay in canonical global-edge-id order, so the
    restriction preserves solo row order bit-for-bit).  The stacked
    accumulator path consumes the mask form directly — the masking
    formulation of DESIGN.md §2, lifted across queries.
    """

    u: np.ndarray
    v: np.ndarray
    u_type: str
    v_type: str
    columns: dict[str, np.ndarray]
    alive: np.ndarray               # (R, E) per-rider keep masks
    eid: Optional[np.ndarray] = None  # global edge ids, aligned with u/v

    @property
    def n_riders(self) -> int:
        return self.alive.shape[0]

    def frame(self, r: int) -> EdgeFrame:
        m = self.alive[r]
        return EdgeFrame(
            u=self.u[m], v=self.v[m], u_type=self.u_type, v_type=self.v_type,
            columns={k: vals[m] for k, vals in self.columns.items()},
            eid=self.eid[m] if self.eid is not None else None)


def _union_frontier(frontiers: Sequence[VSet]) -> VSet:
    mask = frontiers[0].mask.copy()
    for f in frontiers[1:]:
        mask |= f.mask
    return VSet(frontiers[0].vertex_type, mask)


def _union_cols(col_lists) -> tuple:
    # riders of one installed template request identical column sets; keep
    # first-seen order (the per-plan tuples are already sorted)
    return tuple(dict.fromkeys(c for cols in col_lists for c in cols))


def edge_scan_batched(
    topology,
    cache: CacheManager,
    frontiers: Sequence[VSet],
    edge_type: str,
    direction: str,
    plans: Sequence,
    prefetcher=None,
    strategy: str = "auto",
    counters: Optional[dict] = None,
    pool=None,
    deadline: Optional[float] = None,
) -> BatchedScan:
    """One EdgeScan pass shared by R rider queries (DESIGN.md §9).

    The staged pushdown scan (:func:`_edge_scan_staged`) generalized across
    queries: gather once over the *union* frontier, fetch/decode each stage's
    chunk union once (multi-rider zone maps — a chunk is skipped only when
    every rider's bounds reject it), then evaluate each rider's conjunct
    vectorized over the shared rows and AND it into that rider's ``alive``
    mask together with the rider's own definitive reject row.  Rows dead for
    *every* rider compress away between stages, so each stage's reads cover
    exactly the union of the rows the solo scans would read.

    Parity with R solo scans is structural, not numeric: gathers return rows
    in canonical global-edge-id order, predicates are row-local (the GSQL
    subset guarantees it — no cross-row UDFs reach this path), and rejects
    are per-rider conservative, so restricting the shared pass to one
    rider's mask commutes with running that rider alone.
    """
    check_deadline(deadline)
    union = _union_frontier(frontiers)
    e_cols = _union_cols([p.edge_columns for p in plans])
    u_cols = _union_cols([p.u_columns for p in plans])
    v_cols = _union_cols([p.v_columns for p in plans])
    if prefetcher is not None:
        prefetcher.prefetch_edges(
            union, edge_type,
            e_cols + _union_cols([p.accum_edge_columns for p in plans]),
            direction=direction,
            bounds=union_bounds_maps([p.edge_bounds for p in plans]),
            topo=topology,
        )
        prefetcher.prefetch_vertices(
            union, u_cols + _union_cols([p.accum_u_columns for p in plans]),
            bounds=union_bounds_maps([p.u_bounds for p in plans]),
            topo=topology,
        )

    et = topology.schema.edge_types[edge_type]
    if direction == "out":
        u_type, v_type = et.src_type, et.dst_type
    else:
        u_type, v_type = et.dst_type, et.src_type

    view = topology.plane.view(
        edge_type, strategy, frontier=union, direction=direction
    )
    u, v, eid = view.gather(union, direction=direction)
    alive = np.stack([f.mask[u] for f in frontiers]) if len(u) \
        else np.zeros((len(frontiers), 0), dtype=bool)
    ctx = ReadContext()
    columns: dict[str, np.ndarray] = {}

    def _evaluate(preds, prefix, prefix_cols, rejects):
        """AND each rider's verdict into its alive row, then drop rows no
        rider keeps."""
        nonlocal u, v, eid, alive, columns
        columns.update(prefix_cols)
        if len(u):
            frame = dict(columns)
            frame["u"] = u
            frame["v"] = v
            for r, pred in enumerate(preds):
                if pred is None:
                    continue
                keep = np.asarray(pred.evaluate(frame, prefix), dtype=bool)
                alive[r] &= keep & ~rejects[r]
        keep_any = alive.any(axis=0)
        if keep_any.all():
            return
        u, v, eid = u[keep_any], v[keep_any], eid[keep_any]
        alive = alive[:, keep_any]
        columns = {k: vals[keep_any] for k, vals in columns.items()}

    if e_cols:
        check_deadline(deadline)
        cols, rejects = read_edge_columns_multi(
            topology, cache, edge_type, eid, e_cols,
            [p.edge_bounds for p in plans], counters=counters, pool=pool,
            ctx=ctx,
        )
        _evaluate([p.edge_pred for p in plans], "e",
                  {f"e.{c}": a for c, a in cols.items()}, rejects)

    if u_cols:
        check_deadline(deadline)
        cols, rejects = read_vertex_columns_multi(
            topology, cache, u_type, u, u_cols,
            [p.u_bounds for p in plans], counters=counters, pool=pool, ctx=ctx,
        )
        _evaluate([p.source_pred for p in plans], "u",
                  {f"u.{c}": a for c, a in cols.items()}, rejects)

    if v_cols:
        check_deadline(deadline)
        cols, rejects = read_vertex_columns_multi(
            topology, cache, v_type, v, v_cols,
            [p.v_bounds for p in plans], counters=counters, pool=pool, ctx=ctx,
        )
        _evaluate([p.target_pred for p in plans], "v",
                  {f"v.{c}": a for c, a in cols.items()}, rejects)

    # ACCUM-only columns: union of final survivors (each rider's slice only
    # ever consults rows its own mask kept)
    acc_e = _union_cols([p.accum_edge_columns for p in plans])
    acc_u = _union_cols([p.accum_u_columns for p in plans])
    acc_v = _union_cols([p.accum_v_columns for p in plans])
    if acc_e or acc_u or acc_v:
        check_deadline(deadline)
    if acc_e:
        cols, _ = read_edge_columns_multi(
            topology, cache, edge_type, eid, acc_e, [{}], counters=counters,
            pool=pool, ctx=ctx,
        )
        columns.update({f"e.{c}": a for c, a in cols.items()})
    if acc_u:
        cols, _ = read_vertex_columns_multi(
            topology, cache, u_type, u, acc_u, [{}], counters=counters,
            pool=pool, ctx=ctx,
        )
        columns.update({f"u.{c}": a for c, a in cols.items()})
    if acc_v:
        cols, _ = read_vertex_columns_multi(
            topology, cache, v_type, v, acc_v, [{}], counters=counters,
            pool=pool, ctx=ctx,
        )
        columns.update({f"v.{c}": a for c, a in cols.items()})

    return BatchedScan(u=u, v=v, u_type=u_type, v_type=v_type,
                       columns=columns, alive=alive, eid=eid)


def _edge_scan_staged(
    topology, cache, frontier, edge_type, direction, plan,
    prefetcher, read_v_values, strategy, counters, u_type, v_type, pool=None,
    deadline=None,
) -> EdgeFrame:
    """Staged late-materialization EdgeScan (DESIGN.md §4).

    Stage order E -> U -> V: each predicate stage materializes only its own
    prefix's columns, for only the rows still alive, with zone-map chunk
    pruning folded into the reads (a pruned chunk's rows carry a definitive
    reject, so filler values never reach a predicate's verdict).  Far-side
    (``v.``) reads — the expensive random point lookups — therefore see only
    rows that survived the cheaper stages, and ACCUM-only columns are read
    last, for final survivors.

    All stages share one :class:`ReadContext`, so a chunk materialized by an
    earlier stage (self-loop edge types, predicate columns re-used by ACCUM
    reads) is never fetched or pool-dispatched twice within the gather.
    """
    if prefetcher is not None:
        prefetcher.prefetch_edges(
            frontier, edge_type,
            tuple(plan.edge_columns) + tuple(plan.accum_edge_columns),
            direction=direction, bounds=plan.edge_bounds, topo=topology,
        )
        prefetcher.prefetch_vertices(
            frontier, tuple(plan.u_columns) + tuple(plan.accum_u_columns),
            bounds=plan.u_bounds, topo=topology,
        )

    view = topology.plane.view(
        edge_type, strategy, frontier=frontier, direction=direction
    )
    u, v, eid = view.gather(frontier, direction=direction)
    ctx = ReadContext()
    columns: dict[str, np.ndarray] = {}

    def _evaluate(pred, prefix, prefix_cols, reject):
        """Shrink (u, v, eid, columns) to the conjunct's survivors."""
        nonlocal u, v, eid, columns
        columns.update(prefix_cols)
        if pred is None or not len(u):
            return
        frame = dict(columns)
        frame["u"] = u
        frame["v"] = v
        keep = np.asarray(pred.evaluate(frame, prefix), dtype=bool) & ~reject
        u, v, eid = u[keep], v[keep], eid[keep]
        columns = {k: vals[keep] for k, vals in columns.items()}

    if plan.edge_columns:
        check_deadline(deadline)
        e_cols, rej = read_edge_columns_pruned(
            topology, cache, edge_type, eid, plan.edge_columns,
            bounds=plan.edge_bounds, counters=counters, pool=pool, ctx=ctx,
        )
        _evaluate(plan.edge_pred, "e", {f"e.{c}": a for c, a in e_cols.items()}, rej)

    if plan.u_columns:
        check_deadline(deadline)
        u_cols, rej = read_vertex_columns_pruned(
            topology, cache, u_type, u, plan.u_columns,
            bounds=plan.u_bounds, counters=counters, pool=pool, ctx=ctx,
        )
        _evaluate(plan.source_pred, "u", {f"u.{c}": a for c, a in u_cols.items()}, rej)

    if plan.v_columns:
        check_deadline(deadline)
        if read_v_values is not None:
            v_cols = {c: read_v_values(v_type, v, c) for c in plan.v_columns}
            rej = np.zeros(len(v), dtype=bool)
        else:
            v_cols, rej = read_vertex_columns_pruned(
                topology, cache, v_type, v, plan.v_columns,
                bounds=plan.v_bounds, counters=counters, pool=pool, ctx=ctx,
            )
        _evaluate(plan.target_pred, "v", {f"v.{c}": a for c, a in v_cols.items()}, rej)

    # ACCUM-only columns: needed by no predicate -> final survivors only
    if plan.accum_edge_columns or plan.accum_u_columns or plan.accum_v_columns:
        check_deadline(deadline)
    if plan.accum_edge_columns:
        e_cols, _ = read_edge_columns_pruned(
            topology, cache, edge_type, eid, plan.accum_edge_columns,
            counters=counters, pool=pool, ctx=ctx,
        )
        columns.update({f"e.{c}": a for c, a in e_cols.items()})
    if plan.accum_u_columns:
        u_cols, _ = read_vertex_columns_pruned(
            topology, cache, u_type, u, plan.accum_u_columns,
            counters=counters, pool=pool, ctx=ctx,
        )
        columns.update({f"u.{c}": a for c, a in u_cols.items()})
    if plan.accum_v_columns:
        if read_v_values is not None:
            columns.update(
                {f"v.{c}": read_v_values(v_type, v, c) for c in plan.accum_v_columns}
            )
        else:
            v_cols, _ = read_vertex_columns_pruned(
                topology, cache, v_type, v, plan.accum_v_columns,
                counters=counters, pool=pool, ctx=ctx,
            )
            columns.update({f"v.{c}": a for c, a in v_cols.items()})

    return EdgeFrame(u=u, v=v, u_type=u_type, v_type=v_type, columns=columns,
                     eid=eid)
