"""Lakehouse-optimized parallel primitives: VertexMap and EdgeScan (paper §6.1).

Both primitives materialize rows through graph-aware cache units and run
vectorized UDFs.  The paper's per-thread loops become block-vectorized numpy
over (file x row-group) tasks — the TPU-idiomatic masking formulation of the
same computation (see DESIGN.md §2).

``EdgeScan`` consumes the topology through the **topology plane**
(DESIGN.md §3): per scan it resolves a physical representation — the
edge-centric per-file edge lists (sequential scan, Min-Max portion pruning)
or the vertex-centric CSR index (adjacency-range gather) — via an adaptive
selectivity dispatch.  Either way the gather returns (u, v, global-edge-id)
in canonical order, row-level alignment with edge-attribute chunks is kept
through the global edge ids, and the (u, v, edge) rows that survive the
frontier test are fully materialized before UDFs run.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import numpy as np

from repro.core.cache.manager import CacheManager
from repro.core.cache.units import ChunkRef
from repro.core.types import VSet


# ---------------------------------------------------------------------------
# value-reader helpers
# ---------------------------------------------------------------------------

def read_vertex_values(
    topology, cache: CacheManager, vertex_type: str, dense_ids: np.ndarray, column: str
) -> np.ndarray:
    """Materialize one vertex column for arbitrary dense IDs (point lookups).

    Groups the request by (file, row group) and reads each group through its
    VertexCacheUnit, then scatters results back into request order.
    """
    dense_ids = np.asarray(dense_ids, dtype=np.int64)
    out: Optional[np.ndarray] = None
    if len(dense_ids) == 0:
        return np.empty(0, dtype=np.float64)
    file_ids, rows = topology.dense_to_file_row(vertex_type, dense_ids)
    for fid in np.unique(file_ids):
        finfo = topology.file_registry.get(int(fid))
        if finfo is None:  # dangling vertices have no attributes
            continue
        meta = topology.vertex_file_metas[finfo.key]
        sel_f = file_ids == fid
        rows_f = rows[sel_f]
        idx_f = np.flatnonzero(sel_f)
        for g in meta.row_groups:
            in_g = (rows_f >= g.first_row) & (rows_f < g.first_row + g.n_rows)
            if not in_g.any():
                continue
            unit = cache.get_unit(ChunkRef(finfo.key, column, g.index), meta, "vertex")
            vals = unit.read(rows_f[in_g] - g.first_row)
            if out is None:
                out = np.empty(len(dense_ids), dtype=vals.dtype)
                if vals.dtype == object:
                    out[:] = ""
                else:
                    out[:] = 0
            out[idx_f[in_g]] = vals
    if out is None:
        out = np.zeros(len(dense_ids), dtype=np.float64)
    return out


def read_edge_columns_by_eid(
    topology, cache: CacheManager, edge_type: str, eids: np.ndarray,
    columns: Sequence[str],
) -> dict[str, np.ndarray]:
    """Materialize edge columns for *global* edge ids of an edge type.

    Global edge ids address rows across the edge type's files (lists in
    registration order, rows in file order) — the addressing every
    ``TopologyView.gather`` returns.  The per-list grouping depends only on
    the eids, so it is computed once and shared by all requested columns;
    each group reads through the scan-aligned per-file reader.
    """
    eids = np.asarray(eids, dtype=np.int64)
    if len(eids) == 0 or not columns:
        return {c: np.empty(0, dtype=np.float64) for c in columns}
    offsets = topology.plane.eid_offsets(edge_type)
    lists = topology.all_edge_lists(edge_type)
    list_idx = np.searchsorted(offsets, eids, side="right") - 1
    groups = [
        (li, list_idx == li) for li in np.unique(list_idx)
    ]
    out: dict[str, Optional[np.ndarray]] = {c: None for c in columns}
    for li, sel in groups:
        local_rows = eids[sel] - offsets[li]
        pos = np.flatnonzero(sel)
        for c in columns:
            vals = read_edge_values(topology, cache, lists[li], local_rows, c)
            if out[c] is None:
                out[c] = np.empty(len(eids), dtype=vals.dtype)
                if vals.dtype == object:
                    out[c][:] = ""
                else:
                    out[c][:] = 0
            out[c][pos] = vals
    return out


def read_edge_values_by_eid(
    topology, cache: CacheManager, edge_type: str, eids: np.ndarray, column: str
) -> np.ndarray:
    """Single-column convenience over :func:`read_edge_columns_by_eid`."""
    return read_edge_columns_by_eid(topology, cache, edge_type, eids, [column])[column]


def read_edge_values(
    topology, cache: CacheManager, edge_list, local_rows: np.ndarray, column: str
) -> np.ndarray:
    """Materialize one edge column for rows of one edge file (scan-aligned)."""
    meta = topology.edge_file_metas[edge_list.file_key]
    local_rows = np.asarray(local_rows, dtype=np.int64)
    out: Optional[np.ndarray] = None
    first = 0
    for g in meta.row_groups:
        in_g = (local_rows >= g.first_row) & (local_rows < g.first_row + g.n_rows)
        if in_g.any():
            unit = cache.get_unit(ChunkRef(edge_list.file_key, column, g.index), meta, "edge")
            vals = unit.read(local_rows[in_g] - g.first_row)
            if out is None:
                out = np.empty(len(local_rows), dtype=vals.dtype)
                if vals.dtype == object:
                    out[:] = ""
                else:
                    out[:] = 0
            out[np.flatnonzero(in_g)] = vals
        first += g.n_rows
    if out is None:
        out = np.zeros(len(local_rows), dtype=np.float64)
    return out


# ---------------------------------------------------------------------------
# VertexMap
# ---------------------------------------------------------------------------

def vertex_map(
    topology,
    cache: CacheManager,
    vset: VSet,
    columns: Sequence[str] = (),
    filter_fn: Optional[Callable[[dict], np.ndarray]] = None,
    map_fn: Optional[Callable[[dict], np.ndarray]] = None,
    prefetcher=None,
):
    """Apply a UDF over an active vertex set (paper §6.1).

    Returns ``(VSet, values)``: the filtered subset (if ``filter_fn``) and the
    per-active-vertex ``map_fn`` output (if given).  The UDF receives a dict
    ``{"id": dense ids, <col>: values...}`` — fully materialized vertex rows.
    """
    if prefetcher is not None:
        prefetcher.prefetch_vertices(vset, columns)
    ids = vset.ids()
    frame = {"id": ids}
    for col in columns:
        frame[col] = read_vertex_values(topology, cache, vset.vertex_type, ids, col)
    out_vals = map_fn(frame) if map_fn is not None else None
    if filter_fn is not None:
        keep = np.asarray(filter_fn(frame), dtype=bool)
        new = VSet.from_dense_ids(vset.vertex_type, len(vset.mask), ids[keep])
        if out_vals is not None:
            out_vals = out_vals[keep]
        return new, out_vals
    return vset, out_vals


# ---------------------------------------------------------------------------
# EdgeScan
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class EdgeFrame:
    """Materialized, filtered edge rows from one EdgeScan."""

    u: np.ndarray                 # frontier-side dense endpoint IDs
    v: np.ndarray                 # far-side dense endpoint IDs
    u_type: str
    v_type: str
    columns: dict[str, np.ndarray]  # "e.X" / "u.X" / "v.X"

    def __len__(self) -> int:
        return len(self.u)

    def v_set(self, n: int) -> VSet:
        return VSet.from_dense_ids(self.v_type, n, np.unique(self.v))

    def u_set(self, n: int) -> VSet:
        return VSet.from_dense_ids(self.u_type, n, np.unique(self.u))


def edge_scan(
    topology,
    cache: CacheManager,
    frontier: VSet,
    edge_type: str,
    direction: str = "out",
    edge_columns: Sequence[str] = (),
    u_columns: Sequence[str] = (),
    v_columns: Sequence[str] = (),
    edge_filter: Optional[Callable[[dict], np.ndarray]] = None,
    prefetcher=None,
    read_v_values: Optional[Callable[[str, np.ndarray, str], np.ndarray]] = None,
    strategy: str = "auto",
) -> EdgeFrame:
    """Scan the edges incident to ``frontier`` (paper §6.1).

    The physical plan is chosen per scan by the topology plane
    (DESIGN.md §3): ``strategy="edgelist"`` forces the edge-centric
    sequential scan with Min-Max portion pruning, ``strategy="csr"`` forces
    the vertex-centric adjacency-range gather, and ``strategy="auto"``
    (default) picks by frontier selectivity — CSR below the calibrated
    crossover threshold, edge lists above it.  Both produce bit-identical
    output (global edge-id order).

    ``direction="out"`` treats stored (first, second) IDs as (u=src, v=dst);
    ``direction="in"`` swaps roles — bidirectional traversal without storing
    reverse edges (edge lists swap endpoint roles; CSR uses its reverse
    index).  ``edge_filter`` sees the full materialized frame and returns a
    keep-mask (cross-entity predicates welcome).

    ``read_v_values`` overrides far-side attribute reads — the distributed
    engine injects the two-pass remote fetch here (paper §6.2).
    """
    et = topology.schema.edge_types[edge_type]
    if direction == "out":
        u_type, v_type = et.src_type, et.dst_type
    else:
        u_type, v_type = et.dst_type, et.src_type

    if prefetcher is not None:
        prefetcher.prefetch_edges(frontier, edge_type, edge_columns, direction=direction)
        prefetcher.prefetch_vertices(frontier, u_columns)

    view = topology.plane.view(
        edge_type, strategy, frontier=frontier, direction=direction
    )
    u, v, eid = view.gather(frontier, direction=direction)
    by_col = read_edge_columns_by_eid(topology, cache, edge_type, eid, edge_columns)
    columns = {f"e.{c}": by_col[c] for c in edge_columns}

    # endpoint materialization (vertex rows via graph-aware cache units)
    for c in u_columns:
        columns[f"u.{c}"] = read_vertex_values(topology, cache, u_type, u, c)
    for c in v_columns:
        if read_v_values is not None:
            columns[f"v.{c}"] = read_v_values(v_type, v, c)
        else:
            columns[f"v.{c}"] = read_vertex_values(topology, cache, v_type, v, c)

    frame = dict(columns)
    frame["u"] = u
    frame["v"] = v
    if edge_filter is not None and len(u):
        keep = np.asarray(edge_filter(frame), dtype=bool)
        u, v = u[keep], v[keep]
        columns = {k: vals[keep] for k, vals in columns.items()}

    return EdgeFrame(u=u, v=v, u_type=u_type, v_type=v_type, columns=columns)
