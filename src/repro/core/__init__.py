"""GraphLake core: the paper's primary contribution.

Topology-only startup loading (edge lists + transformed vertex IDs),
graph-aware columnar caching, Lakehouse-optimized parallel primitives
(VertexMap / EdgeScan), the accumulator-based BSP compute framework, the
GSQL-like query layer, and the Table-2 graph algorithms.
"""

from repro.core.types import GraphSchema, VSet, make_transformed, split_transformed
from repro.core.engine import GraphLakeEngine
from repro.core.topology import GraphTopology
from repro.core.vertex_idm import VertexIDM

__all__ = [
    "GraphSchema",
    "VSet",
    "make_transformed",
    "split_transformed",
    "GraphLakeEngine",
    "GraphTopology",
    "VertexIDM",
]
