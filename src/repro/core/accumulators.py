"""Accumulators — GSQL-style per-vertex runtime state (paper §2.2/§6).

Accumulators are mutable containers attached to vertices, updated in parallel
during traversal and combined between BSP supersteps.  We implement the
containers used by the paper's workloads:

- ``SumAccum`` / ``MaxAccum`` / ``MinAccum`` / ``OrAccum`` — combine via the
  obvious monoid, vectorized with ``np.bincount`` / ``np.maximum.at`` etc.
- snapshots + deltas so the distributed engine can ship *partial* updates and
  combine them at the owner (paper §6.2's push-back step).
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Callable

import numpy as np

_COMBINERS: dict[str, Callable] = {}


def _register(name):
    def deco(fn):
        _COMBINERS[name] = fn
        return fn
    return deco


@_register("sum")
def _combine_sum(arr: np.ndarray, ids: np.ndarray, values: np.ndarray) -> None:
    # bincount is the fastest vectorized scatter-add on CPU numpy
    upd = np.bincount(ids, weights=values, minlength=len(arr))
    arr += upd.astype(arr.dtype, copy=False)


@_register("max")
def _combine_max(arr: np.ndarray, ids: np.ndarray, values: np.ndarray) -> None:
    np.maximum.at(arr, ids, values.astype(arr.dtype, copy=False))


@_register("min")
def _combine_min(arr: np.ndarray, ids: np.ndarray, values: np.ndarray) -> None:
    np.minimum.at(arr, ids, values.astype(arr.dtype, copy=False))


@_register("or")
def _combine_or(arr: np.ndarray, ids: np.ndarray, values: np.ndarray) -> None:
    np.logical_or.at(arr, ids, values.astype(bool))


_IDENTITY = {"sum": 0.0, "max": -np.inf, "min": np.inf, "or": False}


@dataclasses.dataclass
class AccumSpec:
    vertex_type: str
    name: str
    op: str = "sum"
    dtype: str = "float64"
    init: float | bool | None = None


class Accumulators:
    """Per-vertex accumulator storage over the dense index space."""

    def __init__(self, topology):
        self.topology = topology
        self._arrays: dict[tuple[str, str], np.ndarray] = {}
        self._specs: dict[tuple[str, str], AccumSpec] = {}
        # updates mutate / may grow-and-rebind arrays; concurrent serving
        # workers share one Accumulators, so both must happen under one lock
        # (reentrant: combine_delta funnels through update)
        self._lock = threading.RLock()

    def register(self, spec: AccumSpec) -> np.ndarray:
        key = (spec.vertex_type, spec.name)
        if spec.op not in _COMBINERS:
            raise ValueError(f"unknown accumulator op {spec.op!r}")
        with self._lock:
            n = self.topology.n_vertices(spec.vertex_type)
            init = spec.init if spec.init is not None else _IDENTITY[spec.op]
            if spec.op == "or":
                arr = np.full(n, bool(init), dtype=bool)
            else:
                arr = np.full(n, init, dtype=np.dtype(spec.dtype))
            self._arrays[key] = arr
            self._specs[key] = spec
            return arr

    def array(self, vertex_type: str, name: str) -> np.ndarray:
        return self._arrays[(vertex_type, name)]

    def has(self, vertex_type: str, name: str) -> bool:
        return (vertex_type, name) in self._arrays

    def ensure_capacity(self, vertex_type: str, name: str, n: int) -> np.ndarray:
        """Grow an accumulator array for a dense space extended by an
        incremental epoch advance (vertex appends land at the tail, so old
        slots keep their meaning; new slots start at the identity)."""
        with self._lock:
            return self._ensure_capacity((vertex_type, name), n)

    def _ensure_capacity(self, key: tuple[str, str], n: int) -> np.ndarray:
        # caller holds self._lock
        arr = self._arrays[key]
        if n <= len(arr):
            return arr
        spec = self._specs[key]
        init = spec.init if spec.init is not None else _IDENTITY[spec.op]
        grown = np.full(n, init, dtype=arr.dtype)
        grown[: len(arr)] = arr
        self._arrays[key] = grown
        return grown

    def update(
        self, vertex_type: str, name: str, dense_ids: np.ndarray, values
    ) -> None:
        """Parallel accumulator update: @name op= values at dense_ids."""
        key = (vertex_type, name)
        ids = np.asarray(dense_ids, dtype=np.int64)
        if len(ids) == 0:
            return
        with self._lock:
            arr = self._ensure_capacity(key, int(ids.max()) + 1)
            vals = np.broadcast_to(np.asarray(values), ids.shape)
            _COMBINERS[self._specs[key].op](arr, ids, vals)

    def reset(self, vertex_type: str, name: str) -> None:
        spec = self._specs[(vertex_type, name)]
        self._arrays[(vertex_type, name)][:] = (
            spec.init if spec.init is not None else _IDENTITY[spec.op]
        )

    # -- distributed combine (paper §6.2) ------------------------------------

    def export_delta(self, vertex_type: str, name: str) -> tuple[np.ndarray, np.ndarray]:
        """(ids, values) of non-identity entries — a shippable partial update."""
        spec = self._specs[(vertex_type, name)]
        arr = self._arrays[(vertex_type, name)]
        identity = spec.init if spec.init is not None else _IDENTITY[spec.op]
        ids = np.flatnonzero(arr != identity)
        return ids, arr[ids]

    def combine_delta(
        self, vertex_type: str, name: str, ids: np.ndarray, values: np.ndarray
    ) -> None:
        self.update(vertex_type, name, ids, values)
