"""Architectural stand-ins for the paper's (closed-source) baselines.

- ``CSRTopology`` + ``csr_edge_map``: TigerGraph-style vertex-centric CSR
  EdgeMap — used by the Fig. 15 selectivity-crossover reproduction.  The
  topology plane promoted CSR to a first-class representation
  (``repro.core.csr.CSRIndex``, DESIGN.md §3); what stays here is the thin
  "always vertex-centric" measurement stand-in: forward-direction grouping
  only (honest build-time numbers), the plane's shared ragged gather, and
  none of the adaptive dispatch or edge-id bookkeeping.
- ``FullLoadEngine``: loads *all* columns of *all* tables at startup into
  dense in-memory arrays (TigerGraph-style proprietary load).  Fast queries,
  slow startup — the left end of the paper's Fig. 1 trade-off.
- The PuppyGraph-style in-situ baseline is a configuration of the real engine
  (``CacheConfig(naive_mode=True)`` + ``materialize_topology=False`` +
  ``enable_prefetch=False``), so the comparison isolates the paper's
  techniques on identical substrate.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.csr import _ragged_gather
from repro.lakehouse.columnfile import read_columns, read_footer
from repro.lakehouse.objectstore import ObjectStore
from repro.lakehouse.table import LakeCatalog
from repro.core.types import GraphSchema


class CSRTopology:
    """Vertex-centric CSR built from (src, dst) dense edge arrays.

    Forward direction only — the baseline engine stores no reverse index and
    no edge-id permutation, so ``build_seconds`` measures exactly the single
    grouping pass the Fig. 15 build-time comparison is about (the plane's
    full ``CSRIndex`` builds both directions plus eid maps).
    """

    def __init__(self, src: np.ndarray, dst: np.ndarray, n: int):
        t0 = time.perf_counter()
        order = np.argsort(src, kind="stable")   # group edges by source vertex
        self.dst_sorted = np.ascontiguousarray(np.asarray(dst)[order])
        counts = np.bincount(src, minlength=n)
        self.indptr = np.zeros(n + 1, dtype=np.int64)
        np.cumsum(counts, out=self.indptr[1:])
        self.n = n
        self.build_seconds = time.perf_counter() - t0

    def neighbors(self, v: int) -> np.ndarray:
        return self.dst_sorted[self.indptr[v]: self.indptr[v + 1]]


def csr_edge_map(csr: CSRTopology, active_ids: np.ndarray):
    """Vertex-centric EdgeMap: visit only edges of active vertices.

    Returns (u_repeated, v) edge endpoints — the CSR engine prunes whole
    adjacency ranges per inactive vertex (why it wins at low selectivity).
    The range expansion is the plane's shared ragged gather.
    """
    active_ids = np.asarray(active_ids, dtype=np.int64)
    pos, lengths = _ragged_gather(csr.indptr, active_ids)
    if len(pos) == 0:
        return np.empty(0, dtype=np.int64), np.empty(0, dtype=np.int64)
    return np.repeat(active_ids, lengths), csr.dst_sorted[pos]


def edge_list_edge_map(src: np.ndarray, dst: np.ndarray, active_mask: np.ndarray):
    """Edge-centric EdgeScan over a contiguous edge list (GraphLake side of
    Fig. 15): sequential scan + membership mask."""
    hit = active_mask[src]
    return src[hit], dst[hit]


class FullLoadEngine:
    """Loads the complete graph (topology + every property column) upfront."""

    def __init__(self, store: ObjectStore, schema: GraphSchema):
        self.store = store
        self.schema = schema
        self.lake = LakeCatalog(store)
        self.vertex_columns: dict[str, dict[str, np.ndarray]] = {}
        self.edge_columns: dict[str, dict[str, np.ndarray]] = {}
        self.startup_seconds = 0.0

    def startup(self) -> float:
        t0 = time.perf_counter()
        for name, vt in self.schema.vertex_types.items():
            table = self.lake.table(vt.table)
            metas = [read_footer(self.store, k) for k in table.data_files()]
            cols: dict[str, list[np.ndarray]] = {}
            for meta in metas:
                got = read_columns(self.store, meta, meta.columns)
                for c, arr in got.items():
                    cols.setdefault(c, []).append(arr)
            self.vertex_columns[name] = {
                c: np.concatenate(parts) for c, parts in cols.items()
            }
        for ename, et in self.schema.edge_types.items():
            table = self.lake.table(et.table)
            metas = [read_footer(self.store, k) for k in table.data_files()]
            cols = {}
            for meta in metas:
                got = read_columns(self.store, meta, meta.columns)
                for c, arr in got.items():
                    cols.setdefault(c, []).append(arr)
            self.edge_columns[ename] = {
                c: np.concatenate(parts) for c, parts in cols.items()
            }
        self.startup_seconds = time.perf_counter() - t0
        return self.startup_seconds
