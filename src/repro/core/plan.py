"""Predicate-pushdown planning types (DESIGN.md §4).

A hop's WHERE clause is split by the planner (``core/query.py``) into
per-prefix conjuncts; each boundable conjunct also compiles to a
:class:`ColumnBounds` — the value-range/value-set constraint the zone-map
pruning in the read path checks against ``ColumnChunkMeta.min_value`` /
``max_value``.  A chunk whose statistics *cannot* satisfy a bound is skipped
entirely: never fetched, never decoded, never admitted to the cache, and its
rows come back with a **definitive reject mask** (they provably fail the
conjunct, so the staged scan drops them without evaluating anything).

Bounds are conservative by construction: ``rejects`` may only return True
when no value inside the chunk's [min, max] envelope can satisfy the
constraint.  Anything it cannot reason about (missing statistics, non-numeric
constants, ``|``-composition, opaque UDFs) degrades to "cannot reject", i.e.
the pre-pushdown full-read behavior.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional


# QueryTimeoutError now lives in repro.errors (the consolidated typed-error
# surface, common ReproError base); re-exported here for one release.
from repro.errors import QueryTimeoutError  # noqa: F401


def check_deadline(deadline: Optional[float]) -> None:
    """Raise :class:`QueryTimeoutError` when ``time.monotonic()`` has passed
    ``deadline`` (``None`` = no timeout)."""
    if deadline is not None and time.monotonic() > deadline:
        raise QueryTimeoutError(
            f"query exceeded its timeout (deadline {deadline:.3f}, "
            f"now {time.monotonic():.3f})")


def _as_float(v) -> Optional[float]:
    try:
        return float(v)
    except (TypeError, ValueError):
        return None


@dataclasses.dataclass(frozen=True)
class ColumnBounds:
    """Satisfiability envelope of one column's conjunct.

    ``lo``/``hi`` express range constraints (``lo_strict`` means ``col > lo``
    rather than ``col >= lo``); ``values`` expresses an exact membership set
    (``eq`` / ``isin``).  ``None`` fields are unconstrained.
    """

    lo: Optional[float] = None
    hi: Optional[float] = None
    lo_strict: bool = False
    hi_strict: bool = False
    values: Optional[frozenset] = None

    # -- zone-map test ---------------------------------------------------------

    def rejects(self, min_value, max_value) -> bool:
        """True iff NO value in a chunk with [min_value, max_value] statistics
        can satisfy this constraint.  Missing statistics never reject."""
        if min_value is None or max_value is None:
            return False
        mn, mx = float(min_value), float(max_value)
        if self.values is not None:
            if len(self.values) > 64:
                # large sets: fall back to their numeric envelope (safe:
                # may fail to reject, never wrongly rejects)
                nums = [f for f in (_as_float(v) for v in self.values) if f is not None]
                if len(nums) < len(self.values):
                    return False  # non-numeric candidate -> cannot reason
                return bool(nums) and (min(nums) > mx or max(nums) < mn)
            for v in self.values:
                fv = _as_float(v)
                if fv is None:
                    return False  # non-numeric candidate -> cannot reason
                if mn <= fv <= mx:
                    return False
            return True  # nothing in the set fits the chunk (incl. empty set)
        if self.lo is not None and (mx < self.lo or (self.lo_strict and mx <= self.lo)):
            return True
        if self.hi is not None and (mn > self.hi or (self.hi_strict and mn >= self.hi)):
            return True
        return False

    # -- conjunction -----------------------------------------------------------

    def intersect(self, other: "ColumnBounds") -> "ColumnBounds":
        """Bounds of the AND of two constraints on the same column."""
        lo, lo_strict = self.lo, self.lo_strict
        if other.lo is not None and (
            lo is None or other.lo > lo or (other.lo == lo and other.lo_strict)
        ):
            lo, lo_strict = other.lo, other.lo_strict
        hi, hi_strict = self.hi, self.hi_strict
        if other.hi is not None and (
            hi is None or other.hi < hi or (other.hi == hi and other.hi_strict)
        ):
            hi, hi_strict = other.hi, other.hi_strict
        if self.values is not None and other.values is not None:
            values = self.values & other.values
        else:
            values = self.values if self.values is not None else other.values
        if values is not None and (lo is not None or hi is not None):
            # fold the range into the membership set (non-numeric survive:
            # the range test cannot speak about them)
            kept = []
            for v in values:
                fv = _as_float(v)
                if fv is None:
                    kept.append(v)
                    continue
                if lo is not None and (fv < lo or (lo_strict and fv == lo)):
                    continue
                if hi is not None and (fv > hi or (hi_strict and fv == hi)):
                    continue
                kept.append(v)
            values = frozenset(kept)
        return ColumnBounds(lo, hi, lo_strict, hi_strict, values)


@dataclasses.dataclass(frozen=True)
class AnyOfBounds:
    """Disjunction of per-rider constraints on one column (DESIGN.md §9).

    A shared-scan batch fetches a chunk when *any* rider could use its rows,
    so the union bound may only reject a chunk every rider's own bound
    rejects.  Still conservative: each member is conservative, and the AND
    of conservative rejects is conservative for the OR of the constraints.
    Duck-typed to :class:`ColumnBounds` for the one method the zone-map test
    calls.
    """

    members: tuple

    def rejects(self, min_value, max_value) -> bool:
        return all(m.rejects(min_value, max_value) for m in self.members)


def union_bounds_maps(bounds_list: list) -> dict:
    """Per-column OR of rider bounds maps — the bounds a shared scan prunes
    with.  A column missing from any rider's map is unconstrained for that
    rider, hence unconstrained in the union and dropped entirely."""
    bounds_list = [b or {} for b in bounds_list]
    if not bounds_list:
        return {}
    if len(bounds_list) == 1:
        return dict(bounds_list[0])
    shared = set(bounds_list[0])
    for b in bounds_list[1:]:
        shared &= set(b)
    return {col: AnyOfBounds(tuple(b[col] for b in bounds_list))
            for col in shared}


def group_rejected(meta, row_group: int, bounds: Optional[dict]) -> bool:
    """The one zone-map test both the read path and the prefetcher apply:
    True iff some bounded column's chunk statistics in this row group prove
    the conjunct unsatisfiable.  A rejected group is *definitive* — its rows
    cannot survive the predicate — so callers skip every column of it.
    Sharing the test keeps the two paths in lockstep: prefetch never fetches
    a chunk the read would skip, and vice versa."""
    if not bounds:
        return False
    for col, b in bounds.items():
        try:
            cm = meta.chunk(col, row_group)
        except KeyError:
            continue
        if b.rejects(cm.min_value, cm.max_value):
            return True
    return False


def zone_map_rejects(meta, row_group: int, bounds, columns, n_req: int,
                     counters: Optional[dict]) -> bool:
    """:func:`group_rejected` plus the pruning-counter bookkeeping every
    consumer of the zone-map test wants (DESIGN.md §4).

    The read path (``core/primitives.py`` / ``core/read_pipeline.py``) and
    the prefetcher (``cache/prefetch.py``) used to carry their own copies of
    this group-reject + counter logic; one shared helper keeps their
    accounting — chunks skipped, rows pruned, encoded bytes never fetched —
    in lockstep with the reject decision itself.  ``counters`` follows the
    :func:`new_pruning_counters` schema; pass ``None`` to skip bookkeeping.
    """
    if not group_rejected(meta, row_group, bounds):
        return False
    _count_skipped(counters, meta, row_group, columns, n_req)
    return True


def _count_skipped(counters: Optional[dict], meta, row_group: int, columns,
                   n_req: int) -> None:
    if counters is None:
        return
    counters["chunks_skipped"] += len(columns)
    counters["rows_pruned"] += n_req
    for c in columns:
        try:
            counters["bytes_skipped"] += meta.chunk(c, row_group).length
        except KeyError:
            pass


def zone_map_rejects_multi(meta, row_group: int, bounds_list: list, columns,
                           n_req: int, counters: Optional[dict],
                           ) -> tuple[bool, list[bool]]:
    """Per-rider zone-map verdicts for one row group of a shared scan.

    Returns ``(skip, per_rider)``: ``per_rider[r]`` is rider *r*'s own
    :func:`group_rejected` verdict — its rows in this group provably fail
    rider *r*'s conjunct, fetched or not — and ``skip`` is their AND: the
    group is fetched for nobody only when *every* rider rejects it.  Only a
    real skip books pruning counters (the batch pays one fetch for the
    group otherwise, however many riders reject it)."""
    per_rider = [group_rejected(meta, row_group, b) for b in bounds_list]
    skip = all(per_rider)
    if skip:
        _count_skipped(counters, meta, row_group, columns, n_req)
    return skip, per_rider


def merge_bounds(a: dict, b: dict) -> dict:
    """Per-column conjunction of two bounds maps (missing key = unconstrained
    on that side; the AND is at least as restrictive as either side)."""
    out = dict(a)
    for col, bnd in b.items():
        out[col] = out[col].intersect(bnd) if col in out else bnd
    return out


@dataclasses.dataclass
class ScanPlan:
    """Staged execution plan for one EdgeScan hop (DESIGN.md §4).

    Stage order is E -> U -> V -> accum: edge-column conjuncts first (their
    chunks are scan-aligned and cheapest), then frontier-side vertex
    conjuncts, then far-side (``v.``) conjuncts — far-side reads are the
    expensive random point lookups, so they only ever see rows that survived
    the earlier stages.  ``accum_*_columns`` are needed for ACCUM values but
    by no predicate; they materialize last, for final survivors only.
    """

    edge_pred: Optional[object] = None      # Predicate over "e." columns
    source_pred: Optional[object] = None    # Predicate over "u." columns
    target_pred: Optional[object] = None    # Predicate over "v." columns
    edge_columns: tuple = ()
    u_columns: tuple = ()
    v_columns: tuple = ()
    accum_edge_columns: tuple = ()
    accum_u_columns: tuple = ()
    accum_v_columns: tuple = ()
    edge_bounds: dict = dataclasses.field(default_factory=dict)
    u_bounds: dict = dataclasses.field(default_factory=dict)
    v_bounds: dict = dataclasses.field(default_factory=dict)


def new_pruning_counters() -> dict:
    """Per-query pruning counters (exposed on ``QueryResult.pruning``)."""
    return {
        "chunks_skipped": 0,   # chunks never fetched/decoded (zone-map reject)
        "chunks_read": 0,      # chunks materialized through the cache
        "rows_pruned": 0,      # requested rows covered by skipped chunks
        "rows_decoded": 0,     # chunk rows actually decoded (decode_ops delta)
        "bytes_skipped": 0,    # encoded bytes of skipped chunks
        "bytes_read": 0,       # encoded bytes of chunks read
    }
