"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the semantics contracts: kernels must match them (allclose) across
shape/dtype sweeps in interpret mode.  They are also the non-TPU execution
path used by the 512-device CPU dry-run, so they are written to compile
efficiently under SPMD (no materialized (S, S) score matrices, etc.).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# edge segment-sum (the EdgeScan aggregation hot path)
# ---------------------------------------------------------------------------

def edge_segment_sum(values: jax.Array, segment_ids: jax.Array, num_segments: int) -> jax.Array:
    """out[s] = sum over edges e with segment_ids[e]==s of values[e].

    values: (E, D) float; segment_ids: (E,) int; returns (N, D).
    """
    return jax.ops.segment_sum(values, segment_ids, num_segments=num_segments)


def masked_edge_segment_sum(
    values: jax.Array, src: jax.Array, dst: jax.Array, frontier: jax.Array, num_segments: int
) -> jax.Array:
    """EdgeScan semantics: accumulate values of edges whose src is active."""
    mask = frontier[src].astype(values.dtype)
    return edge_segment_sum(values * mask[:, None], dst, num_segments)


def csr_segment_sum(values: jax.Array, indptr: jax.Array, num_segments: int) -> jax.Array:
    """out[v] = sum of values[indptr[v]:indptr[v+1]] — segment sum over CSR
    offset ranges (values pre-sorted by owning segment).

    values: (E, D) float; indptr: (N+1,) int; returns (N, D).
    """
    e = values.shape[0]
    # edge e belongs to segment v iff indptr[v] <= e < indptr[v+1]; with a
    # sorted indptr that is searchsorted-right minus one (empty ranges skip)
    seg = jnp.searchsorted(indptr, jnp.arange(e), side="right") - 1
    return jax.ops.segment_sum(values, seg, num_segments=num_segments)


# ---------------------------------------------------------------------------
# embedding bag (gather + segment-sum; recsys lookup)
# ---------------------------------------------------------------------------

def embedding_bag(
    table: jax.Array, indices: jax.Array, weights: jax.Array | None = None,
    mode: str = "sum",
) -> jax.Array:
    """out[b] = reduce_l table[indices[b, l]] * weights[b, l].

    table: (V, D); indices: (B, L) int; weights: (B, L) or None (all ones,
    padding handled by zero weights).  mode: "sum" | "mean".
    """
    gathered = table[indices]                      # (B, L, D)
    if weights is None:
        weights = jnp.ones(indices.shape, dtype=table.dtype)
    w = weights.astype(table.dtype)[..., None]
    summed = (gathered * w).sum(axis=1)
    if mode == "mean":
        denom = jnp.maximum(weights.sum(axis=1, keepdims=True), 1e-9)
        return summed / denom.astype(table.dtype)
    return summed


# ---------------------------------------------------------------------------
# flash attention (streaming softmax; no (S, S) materialization)
# ---------------------------------------------------------------------------

def _attention_naive(q, k, v, causal, scale, kv_len=None):
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    qlen, klen = q.shape[2], k.shape[2]
    kpos = jnp.arange(klen)[None, :]
    mask = jnp.ones((qlen, klen), dtype=bool)
    if causal:
        qpos = jnp.arange(qlen)[:, None] + (klen - qlen)
        mask = mask & (qpos >= kpos)
    if kv_len is not None:
        mask = mask & (kpos < kv_len)
    scores = jnp.where(mask, scores, -jnp.inf)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


def attention(q, k, v, causal: bool = True, scale: float | None = None,
              kv_len=None):
    """Oracle multi-head attention. q,k,v: (B, H, S, Dh). ``kv_len`` masks
    key positions >= kv_len (partially-filled caches)."""
    if scale is None:
        scale = q.shape[-1] ** -0.5
    return _attention_naive(q, k, v, causal, scale, kv_len)


@functools.partial(jax.jit, static_argnames=("causal", "block_kv", "unroll"))
def attention_blockwise(q, k, v, causal: bool = True, block_kv: int = 512,
                        kv_len_mask=None, unroll: bool = False):
    """Streaming-softmax attention in pure lax.scan — flash semantics without
    Pallas.  This is the memory-safe path the dry-run compiles on any backend.
    q,k,v: (B, H, S, Dh); returns (B, H, S, Dh).  ``kv_len_mask`` (traced
    scalar) masks key positions >= it (partially-filled caches).
    """
    scale = q.shape[-1] ** -0.5
    b, h, q_len, dh = q.shape
    kv_len = k.shape[2]
    n_blocks = -(-kv_len // block_kv)
    pad = n_blocks * block_kv - kv_len
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kb = k.reshape(b, h, n_blocks, block_kv, dh).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(b, h, n_blocks, block_kv, dh).transpose(2, 0, 1, 3, 4)
    qpos = jnp.arange(q_len) + (kv_len - q_len)  # align causal offsets
    valid_len = kv_len if kv_len_mask is None else kv_len_mask

    def step(carry, blk):
        m, l, acc = carry
        k_j, v_j, j = blk
        kpos = j * block_kv + jnp.arange(block_kv)
        s = jnp.einsum("bhqd,bhkd->bhqk", q, k_j).astype(jnp.float32) * scale
        valid = kpos[None, :] < valid_len
        if causal:
            valid = valid & (qpos[:, None] >= kpos[None, :])
        s = jnp.where(valid[None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(axis=-1))
        # guard fully-masked rows (m_new = -inf)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(valid[None, None], p, 0.0)
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p.astype(q.dtype), v_j
        ).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, q_len), -jnp.inf, dtype=jnp.float32)
    l0 = jnp.zeros((b, h, q_len), dtype=jnp.float32)
    acc0 = jnp.zeros((b, h, q_len, dh), dtype=jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, acc0), (kb, vb, jnp.arange(n_blocks)),
        unroll=n_blocks if unroll else 1,
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


def attention_triangular(q, k, v, causal: bool = True, block: int = 512,
                         unroll: bool = False):
    """Causal attention with schedule-time triangular block skipping: q block
    i only visits kv blocks 0..i (2x less work than the rectangle for
    q_len == kv_len).  Mirrors the Pallas kernel's @pl.when causal skip so
    compiled-cost numbers reflect the TPU schedule (§Perf 'tri').

    Requires q_len == kv_len and both divisible by ``block``.
    """
    b, h, s, dh = q.shape
    assert causal and k.shape[2] == s and s % block == 0
    n_blocks = s // block
    outs = []
    for i in range(n_blocks):  # static python loop: straight-line schedule
        q_i = q[:, :, i * block:(i + 1) * block, :]
        k_i = k[:, :, : (i + 1) * block, :]
        v_i = v[:, :, : (i + 1) * block, :]
        outs.append(attention_blockwise(q_i, k_i, v_i, causal=True,
                                        block_kv=block, unroll=unroll))
    return jnp.concatenate(outs, axis=2)
