"""Pallas TPU flash attention (streaming softmax, no (S, S) materialization).

The LM-side compute hot spot (prefill_32k shapes).  Standard FlashAttention
tiling adapted to the TPU memory hierarchy: q blocks stay resident in VMEM
with f32 scratch (running max / denominator / accumulator) while kv blocks
stream HBM->VMEM; the causal upper triangle is skipped at block granularity
(never scheduled, not just masked).

Grid: (batch*heads, n_q_blocks, n_kv_blocks), kv innermost.
GQA is handled in ops.py by expanding kv heads before the call.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_KV = 512


def _kernel(
    kv_len_ref, q_ref, k_ref, v_ref, o_ref,
    m_scr, l_scr, acc_scr,
    *, scale: float, causal: bool, block_q: int, block_kv: int,
    n_kv_blocks: int, q_offset: int,
):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal block skip: kv block strictly above the diagonal never runs
    q_hi = q_offset + (qi + 1) * block_q - 1        # max absolute q position
    kv_lo = kj * block_kv
    live = (kv_lo <= q_hi) if causal else True

    @pl.when(live)
    def _accumulate():
        q = q_ref[0].astype(jnp.float32)            # (block_q, dh)
        k = k_ref[0].astype(jnp.float32)            # (block_kv, dh)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                    # (block_q, block_kv)
        kpos_row = kv_lo + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 1
        )
        s = jnp.where(kpos_row < kv_len_ref[0], s, -jnp.inf)
        if causal:
            qpos = q_offset + qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 0
            )
            kpos = kv_lo + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 1
            )
            s = jnp.where(qpos >= kpos, s, -jnp.inf)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[:, None])
        p = jnp.where(jnp.isfinite(s), p, 0.0)
        alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new

    @pl.when(kj == n_kv_blocks - 1)
    def _finalize():
        o_ref[0] = (
            acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[:, None]
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_kv", "interpret")
)
def flash_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    block_q: int = DEFAULT_BLOCK_Q,
    block_kv: int = DEFAULT_BLOCK_KV,
    interpret: bool = False,
    kv_len_mask: jax.Array | None = None,
) -> jax.Array:
    """q,k,v: (B, H, S_q, Dh) / (B, H, S_kv, Dh) with H already expanded
    (GQA handled by the wrapper).  Returns (B, H, S_q, Dh).

    ``kv_len_mask``: optional traced scalar; key positions >= it are masked
    (decode against a partially-filled cache)."""
    b, h, q_len, dh = q.shape
    kv_len = k.shape[2]
    scale = dh ** -0.5
    block_q = min(block_q, q_len)
    block_kv = min(block_kv, kv_len)
    if q_len % block_q or kv_len % block_kv:
        raise ValueError("sequence lengths must divide block sizes")
    qr = q.reshape(b * h, q_len, dh)
    kr = k.reshape(b * h, kv_len, dh)
    vr = v.reshape(b * h, kv_len, dh)
    n_q = q_len // block_q
    n_kv = kv_len // block_kv
    q_offset = kv_len - q_len  # decode-style alignment (q tail of kv)
    if kv_len_mask is None:
        kv_len_arr = jnp.full((1,), kv_len, dtype=jnp.int32)
    else:
        kv_len_arr = jnp.asarray(kv_len_mask, dtype=jnp.int32).reshape(1)

    out = pl.pallas_call(
        functools.partial(
            _kernel, scale=scale, causal=causal, block_q=block_q,
            block_kv=block_kv, n_kv_blocks=n_kv, q_offset=q_offset,
        ),
        grid=(b * h, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1,), lambda g, i, j: (0,)),   # kv length mask
            pl.BlockSpec((1, block_q, dh), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, block_kv, dh), lambda g, i, j: (g, j, 0)),
            pl.BlockSpec((1, block_kv, dh), lambda g, i, j: (g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda g, i, j: (g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, q_len, dh), q.dtype),
        scratch_shapes=[
            # running max / denominator / accumulator, f32 resident in VMEM
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, dh), jnp.float32),
        ],
        interpret=interpret,
    )(kv_len_arr, qr, kr, vr)
    return out.reshape(b, h, q_len, dh)
