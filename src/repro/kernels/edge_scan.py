"""Pallas TPU kernel for the EdgeScan aggregation hot path.

Computes ``out[n] = sum_{e: dst[e]==n} values[e]`` — the segment reduction at
the heart of GraphLake's edge-centric EdgeScan (paper §6.1), of GNN message
passing, and of the accumulator combine step.

TPU adaptation (DESIGN.md §2): the CPU engine's per-edge scatter becomes a
**block one-hot matmul** so the MXU does the scatter: for an edge block ``j``
and an output row block ``i``,

    out[i]  +=  onehot(dst_j - i*BLOCK_N)^T  @  values_j        (MXU matmul)

The paper's Min-Max portion pruning (§5.3) maps to a per-edge-block skip:
each edge block carries min/max(dst); blocks whose range misses the output
block are skipped with ``@pl.when`` — the same "most effective when the edge
table is sorted by the FK" property the paper notes, because sorted edges
make block ranges narrow.

Grid: (n_out_blocks, n_edge_blocks), edge blocks innermost so each output
block stays resident in VMEM while every edge block streams past it once.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_E = 1024   # edges per block  (8*128-aligned)
DEFAULT_BLOCK_N = 512    # output rows per block
_NEG = -1                # padding dst id: matches no output row


def _kernel(blk_min_ref, blk_max_ref, dst_ref, val_ref, out_ref, *, block_n: int):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    row_lo = i * block_n
    overlaps = (blk_max_ref[0] >= row_lo) & (blk_min_ref[0] < row_lo + block_n)

    @pl.when(overlaps)
    def _accumulate():
        dst = dst_ref[...]                                   # (block_e,)
        block_e = dst.shape[0]
        cols = jax.lax.broadcasted_iota(jnp.int32, (block_e, block_n), 1) + row_lo
        onehot = (dst[:, None] == cols).astype(val_ref.dtype)  # (block_e, block_n)
        out_ref[...] += jax.lax.dot_general(
            onehot, val_ref[...],
            dimension_numbers=(((0,), (0,)), ((), ())),       # onehot^T @ values
            preferred_element_type=jnp.float32,
        ).astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("num_segments", "block_e", "block_n", "interpret"),
)
def edge_segment_sum_pallas(
    values: jax.Array,
    dst: jax.Array,
    num_segments: int,
    block_e: int = DEFAULT_BLOCK_E,
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool = False,
) -> jax.Array:
    """Pallas segment-sum. values: (E, D) float; dst: (E,) int32 in [0, N)."""
    e, d = values.shape
    n = num_segments
    block_e = min(block_e, max(8, e))
    block_n = min(block_n, max(8, n))
    e_pad = -(-e // block_e) * block_e
    n_pad = -(-n // block_n) * block_n
    if e_pad != e:
        values = jnp.pad(values, ((0, e_pad - e), (0, 0)))
        dst = jnp.pad(dst, (0, e_pad - e), constant_values=_NEG)
    dst = dst.astype(jnp.int32)

    n_eblk = e_pad // block_e
    n_nblk = n_pad // block_n
    dst_blocks = dst.reshape(n_eblk, block_e)
    # per-edge-block Min-Max statistics (paper §5.3); padding (_NEG) is
    # excluded from min so sorted inputs keep tight ranges.
    blk_min = jnp.where(dst_blocks >= 0, dst_blocks, n_pad).min(axis=1).astype(jnp.int32)
    blk_max = dst_blocks.max(axis=1).astype(jnp.int32)

    out = pl.pallas_call(
        functools.partial(_kernel, block_n=block_n),
        grid=(n_nblk, n_eblk),
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (j,)),            # blk_min
            pl.BlockSpec((1,), lambda i, j: (j,)),            # blk_max
            pl.BlockSpec((block_e,), lambda i, j: (j,)),      # dst ids
            pl.BlockSpec((block_e, d), lambda i, j: (j, 0)),  # edge values
        ],
        out_specs=pl.BlockSpec((block_n, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, d), jnp.float32),
        interpret=interpret,
    )(blk_min, blk_max, dst, values)
    return out[:n].astype(values.dtype)
