"""Pallas TPU kernel for EmbeddingBag (gather + weighted segment-sum).

RecSys lookup = GraphLake vertex-property fetch: ``out[b] = sum_l w[b,l] *
table[idx[b,l]]``.  JAX has no native EmbeddingBag (kernel taxonomy §B.6);
this is the TPU-native one.

TPU adaptation: like ``edge_scan``, the gather becomes an MXU matmul — for a
batch block ``i`` and a vocab block ``j``:

    M[b, v]  = sum_l w[b,l] * (idx[b,l] == j*BLOCK_V + v)     (VPU compares)
    out[i]  +=  M @ table_j                                    (MXU matmul)

with per-batch-block min/max(idx) pruning so only vocab blocks actually
referenced are visited (row-sharded tables keep index ranges narrow — the
same locality GraphLake's transformed IDs create for vertex files).

Grid: (n_batch_blocks, n_vocab_blocks), vocab innermost (out block resident).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_B = 256
DEFAULT_BLOCK_V = 512


def _kernel(blk_min_ref, blk_max_ref, idx_ref, w_ref, table_ref, out_ref, *, block_v: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    v_lo = j * block_v
    overlaps = (blk_max_ref[0] >= v_lo) & (blk_min_ref[0] < v_lo + block_v)

    @pl.when(overlaps)
    def _accumulate():
        idx = idx_ref[...]            # (block_b, L)
        w = w_ref[...]                # (block_b, L)
        block_b, bag = idx.shape

        def body(l, m):
            cols = jax.lax.broadcasted_iota(jnp.int32, (block_b, block_v), 1) + v_lo
            hit = (idx[:, l][:, None] == cols).astype(w.dtype)
            return m + hit * w[:, l][:, None]

        m0 = jnp.zeros((block_b, block_v), dtype=jnp.float32)
        m = jax.lax.fori_loop(0, bag, body, m0)   # (block_b, block_v)
        out_ref[...] += jax.lax.dot_general(
            m, table_ref[...].astype(jnp.float32),
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_b", "block_v", "interpret")
)
def embedding_bag_pallas(
    table: jax.Array,
    indices: jax.Array,
    weights: jax.Array,
    block_b: int = DEFAULT_BLOCK_B,
    block_v: int = DEFAULT_BLOCK_V,
    interpret: bool = False,
) -> jax.Array:
    """table: (V, D); indices: (B, L) int32; weights: (B, L). Returns (B, D).

    Padding entries must carry weight 0 (their index value is then irrelevant
    but should stay in range or -1).
    """
    v, d = table.shape
    b, bag = indices.shape
    block_b = min(block_b, max(8, b))
    block_v = min(block_v, max(8, v))
    b_pad = -(-b // block_b) * block_b
    v_pad = -(-v // block_v) * block_v
    if b_pad != b:
        indices = jnp.pad(indices, ((0, b_pad - b), (0, 0)), constant_values=-1)
        weights = jnp.pad(weights, ((0, b_pad - b), (0, 0)))
    if v_pad != v:
        table = jnp.pad(table, ((0, v_pad - v), (0, 0)))
    indices = indices.astype(jnp.int32)

    n_bblk = b_pad // block_b
    n_vblk = v_pad // block_v
    idx_blocks = indices.reshape(n_bblk, block_b * bag)
    live = (weights.reshape(n_bblk, block_b * bag) != 0) & (idx_blocks >= 0)
    blk_min = jnp.where(live, idx_blocks, v_pad).min(axis=1).astype(jnp.int32)
    blk_max = jnp.where(live, idx_blocks, -1).max(axis=1).astype(jnp.int32)

    out = pl.pallas_call(
        functools.partial(_kernel, block_v=block_v),
        grid=(n_bblk, n_vblk),
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (i,)),                # blk_min
            pl.BlockSpec((1,), lambda i, j: (i,)),                # blk_max
            pl.BlockSpec((block_b, bag), lambda i, j: (i, 0)),    # indices
            pl.BlockSpec((block_b, bag), lambda i, j: (i, 0)),    # weights
            pl.BlockSpec((block_v, d), lambda i, j: (j, 0)),      # table tile
        ],
        out_specs=pl.BlockSpec((block_b, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b_pad, d), jnp.float32),
        interpret=interpret,
    )(blk_min, blk_max, indices, weights.astype(jnp.float32), table)
    return out[:b].astype(table.dtype)
