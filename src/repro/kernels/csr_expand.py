"""Pallas TPU kernel for the CSR frontier-expand aggregation path.

Computes ``out[v] = sum_{e in [indptr[v], indptr[v+1])} values[e]`` — the
vertex-centric counterpart of the edge-centric ``edge_scan`` kernel: edge
values arrive **pre-sorted by destination** (the topology plane's reverse-CSR
order, DESIGN.md §3), so segment membership is an *offset range* instead of a
scattered id array.

TPU adaptation (DESIGN.md §2): like the edge-scan kernel, the per-edge
scatter becomes a block one-hot matmul so the MXU does the segment gather.
For edge block ``j`` and output row block ``i``,

    onehot[e, v] = (start[v] <= e_global < end[v])          (VPU compare)
    out[i]      += onehot^T @ values_j                      (MXU matmul)

where ``start``/``end`` are the vertex block's indptr slices.  Because
offsets are sorted, the block-skip test is **exact** rather than a Min-Max
heuristic: edge block ``j`` intersects output block ``i`` iff the half-open
ranges ``[j*BLOCK_E, (j+1)*BLOCK_E)`` and ``[start[first], end[last])``
overlap — every skipped (i, j) pair provably contributes nothing.  This is
the tight-range property that dst-sorted edge order buys (the same property
that narrows the edge-scan kernel's Min-Max ranges on FK-sorted tables).

Grid: (n_out_blocks, n_edge_blocks), edge blocks innermost so each output
block stays resident in VMEM while its edge range streams past.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_E = 1024   # edges per block  (8*128-aligned)
DEFAULT_BLOCK_N = 512    # output rows per block


def _kernel(blk_lo_ref, blk_hi_ref, starts_ref, ends_ref, val_ref, out_ref,
            *, block_e: int, block_n: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    e_lo = j * block_e
    # exact range-overlap skip: sorted offsets make this provably lossless
    overlaps = (blk_hi_ref[0] > e_lo) & (blk_lo_ref[0] < e_lo + block_e)

    @pl.when(overlaps)
    def _accumulate():
        starts = starts_ref[...]                              # (block_n,)
        ends = ends_ref[...]                                  # (block_n,)
        eidx = jax.lax.broadcasted_iota(jnp.int32, (block_e, block_n), 0) + e_lo
        onehot = ((eidx >= starts[None, :]) & (eidx < ends[None, :])).astype(
            val_ref.dtype
        )                                                     # (block_e, block_n)
        out_ref[...] += jax.lax.dot_general(
            onehot, val_ref[...],
            dimension_numbers=(((0,), (0,)), ((), ())),       # onehot^T @ values
            preferred_element_type=jnp.float32,
        ).astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("num_segments", "block_e", "block_n", "interpret"),
)
def csr_segment_sum_pallas(
    values: jax.Array,
    indptr: jax.Array,
    num_segments: int,
    block_e: int = DEFAULT_BLOCK_E,
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool = False,
) -> jax.Array:
    """Pallas CSR segment-sum.

    values: (E, D) float, sorted by owning segment; indptr: (N+1,) int with
    ``indptr[0] == 0`` and ``indptr[N] == E``; returns (N, D) float.
    """
    e, d = values.shape
    n = num_segments
    block_e = min(block_e, max(8, e))
    block_n = min(block_n, max(8, n))
    e_pad = -(-max(e, 1) // block_e) * block_e
    n_pad = -(-max(n, 1) // block_n) * block_n
    if e_pad != e:
        values = jnp.pad(values, ((0, e_pad - e), (0, 0)))

    indptr = indptr.astype(jnp.int32)
    starts = indptr[:-1]
    ends = indptr[1:]
    if n_pad != n:
        # padded output rows own the empty range [E, E)
        starts = jnp.pad(starts, (0, n_pad - n), constant_values=e)
        ends = jnp.pad(ends, (0, n_pad - n), constant_values=e)

    n_eblk = e_pad // block_e
    n_nblk = n_pad // block_n
    # per-output-block edge range: offsets are sorted, so it is exactly
    # [starts[first], ends[last]) — block min/max without a reduction scan
    starts_blocks = starts.reshape(n_nblk, block_n)
    ends_blocks = ends.reshape(n_nblk, block_n)
    blk_lo = starts_blocks[:, 0].astype(jnp.int32)
    blk_hi = ends_blocks[:, -1].astype(jnp.int32)

    out = pl.pallas_call(
        functools.partial(_kernel, block_e=block_e, block_n=block_n),
        grid=(n_nblk, n_eblk),
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (i,)),            # blk_lo
            pl.BlockSpec((1,), lambda i, j: (i,)),            # blk_hi
            pl.BlockSpec((block_n,), lambda i, j: (i,)),      # range starts
            pl.BlockSpec((block_n,), lambda i, j: (i,)),      # range ends
            pl.BlockSpec((block_e, d), lambda i, j: (j, 0)),  # edge values
        ],
        out_specs=pl.BlockSpec((block_n, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, d), jnp.float32),
        interpret=interpret,
    )(blk_lo, blk_hi, starts, ends, values)
    return out[:n].astype(values.dtype)
