"""Pallas TPU kernels for the compute hot spots, with jnp reference oracles.

- ``edge_scan``       -- EdgeScan segment aggregation (block one-hot matmul
                         with Min-Max block pruning),
- ``embedding_bag``   -- recsys table lookup (gather + weighted segment-sum),
- ``flash_attention`` -- streaming-softmax attention for LM prefill,
- ``ops``             -- public dispatching API (TPU -> Pallas, else jnp ref),
- ``ref``             -- pure-jnp oracles (also the CPU dry-run path).
"""
