"""Public kernel API with backend dispatch.

Every op has a pure-jnp reference path (``ref.py``) — used on CPU/GPU and for
the 512-device SPMD dry-run — and a Pallas TPU kernel selected when running
on TPU (or when forced for testing).  The dispatch contract:

    backend == tpu  and shapes suitable  -> Pallas kernel
    REPRO_PALLAS=interpret                -> Pallas kernel in interpret mode
                                            (CPU execution of the kernel body;
                                            how kernels are validated here)
    otherwise                             -> jnp reference

All ops are shape-polymorphic jit-stable functions safe to call inside
pjit/shard_map-traced code.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.csr_expand import csr_segment_sum_pallas
from repro.kernels.edge_scan import edge_segment_sum_pallas
from repro.kernels.embedding_bag import embedding_bag_pallas
from repro.kernels.flash_attention import flash_attention_pallas


def _mode() -> str:
    forced = os.environ.get("REPRO_PALLAS", "").lower()
    if forced in ("interpret", "force", "off"):
        return forced
    return "tpu" if jax.default_backend() == "tpu" else "off"


def use_pallas() -> bool:
    return _mode() in ("tpu", "force", "interpret")


def _interpret() -> bool:
    return _mode() == "interpret"


# When True, the jnp attention path unrolls its kv-block scan so that
# compiled-cost analysis counts every block (cost_analysis counts loop bodies
# once).  Set by the dry-run's cost-variant compiles only.
_ATTN_UNROLL = False


class attention_unroll:
    """Context manager: unroll attention kv scans for exact cost analysis."""

    def __enter__(self):
        global _ATTN_UNROLL
        self._prev = _ATTN_UNROLL
        _ATTN_UNROLL = True

    def __exit__(self, *exc):
        global _ATTN_UNROLL
        _ATTN_UNROLL = self._prev


# ---------------------------------------------------------------------------
# segment reductions
# ---------------------------------------------------------------------------

def segment_sum(values: jax.Array, segment_ids: jax.Array, num_segments: int) -> jax.Array:
    """1-D or 2-D segment sum. Dispatches the 2-D case to the Pallas kernel."""
    if values.ndim == 2 and use_pallas():
        return edge_segment_sum_pallas(
            values, segment_ids, num_segments, interpret=_interpret()
        )
    return jax.ops.segment_sum(values, segment_ids, num_segments=num_segments)


def segment_min(values: jax.Array, segment_ids: jax.Array, num_segments: int) -> jax.Array:
    return jax.ops.segment_min(values, segment_ids, num_segments=num_segments)


def segment_max(values: jax.Array, segment_ids: jax.Array, num_segments: int) -> jax.Array:
    return jax.ops.segment_max(values, segment_ids, num_segments=num_segments)


def segment_mean(values: jax.Array, segment_ids: jax.Array, num_segments: int) -> jax.Array:
    ones = jnp.ones(values.shape[:1], dtype=values.dtype)
    counts = jax.ops.segment_sum(ones, segment_ids, num_segments=num_segments)
    total = segment_sum(values, segment_ids, num_segments)
    denom = jnp.maximum(counts, 1)
    return total / (denom[:, None] if values.ndim == 2 else denom)


def edge_segment_sum(values: jax.Array, dst: jax.Array, num_segments: int) -> jax.Array:
    """(E, D) edge values scattered-added to (N, D). The EdgeScan hot path."""
    if use_pallas():
        return edge_segment_sum_pallas(values, dst, num_segments, interpret=_interpret())
    return _ref.edge_segment_sum(values, dst, num_segments)


def masked_edge_segment_sum(values, src, dst, frontier, num_segments: int) -> jax.Array:
    mask = frontier[src].astype(values.dtype)
    return edge_segment_sum(values * mask[:, None], dst, num_segments)


def csr_segment_sum(values: jax.Array, indptr: jax.Array, num_segments: int) -> jax.Array:
    """Segment sum over CSR offset ranges: values pre-sorted by owning
    segment, indptr (N+1,).  The topology plane's vertex-centric hot path —
    accepts (E,) or (E, D) values; 1-D input returns a 1-D result.

    Like ``segment_sum``, only the 2-D case dispatches to the Pallas
    one-hot-matmul kernel — a single value column would waste the MXU.
    """
    if values.ndim == 1:
        return _ref.csr_segment_sum(values, indptr, num_segments)
    if use_pallas():
        return csr_segment_sum_pallas(
            values, indptr, num_segments, interpret=_interpret()
        )
    return _ref.csr_segment_sum(values, indptr, num_segments)


def stacked_segment_sum(values: jax.Array, segment_ids: jax.Array,
                        num_segments: int) -> jax.Array:
    """Segment sum for a *stack* of riders sharing one edge stream.

    ``values`` is (R, E) — R riders' per-edge contributions over the same
    (E,) ``segment_ids`` (the shared-scan batch layout: dead rider/edge
    pairs pre-zeroed by the caller's ``alive`` mask).  Returns (R, N).

    One transpose turns this into the (E, D) layout ``segment_sum`` already
    dispatches to the Pallas edge kernel, with riders riding the feature
    axis — the batch reuses the solo kernel instead of growing a new one.
    """
    return segment_sum(values.T, segment_ids, num_segments).T


# ---------------------------------------------------------------------------
# pytree stacking (batched rider state)
# ---------------------------------------------------------------------------

def tree_stack(trees: list):
    """Stack a list of identically-structured pytrees leaf-wise: R trees of
    (leaf_shape) -> one tree of (R, *leaf_shape).  The shared-scan batch
    path uses this to run R riders' frontier/accumulator state through one
    traced program."""
    return jax.tree.map(lambda *leaves: jnp.stack(leaves), *trees)


def tree_unstack(tree) -> list:
    """Inverse of :func:`tree_stack`: one tree of (R, *leaf_shape) back to
    a list of R per-rider trees."""
    leaves, treedef = jax.tree.flatten(tree)
    n = leaves[0].shape[0]
    return [treedef.unflatten([leaf[i] for leaf in leaves]) for i in range(n)]


# ---------------------------------------------------------------------------
# embedding bag
# ---------------------------------------------------------------------------

def embedding_bag(
    table: jax.Array,
    indices: jax.Array,
    weights: jax.Array | None = None,
    mode: str = "sum",
) -> jax.Array:
    """EmbeddingBag: (V, D) table, (B, L) indices -> (B, D)."""
    if weights is None:
        weights = jnp.ones(indices.shape, dtype=table.dtype)
    if use_pallas():
        out = embedding_bag_pallas(
            table, indices, weights, interpret=_interpret()
        )
        if mode == "mean":
            denom = jnp.maximum(weights.sum(axis=1, keepdims=True), 1e-9)
            out = out / denom.astype(out.dtype)
        return out
    return _ref.embedding_bag(table, indices, weights, mode=mode)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def flash_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, causal: bool = True,
    block_q: int = 512, block_kv: int = 512, kv_len_mask=None,
) -> jax.Array:
    """Memory-safe attention. q,k,v: (B, H, S, Dh), H pre-expanded for GQA.
    ``kv_len_mask``: optional traced scalar masking keys >= it."""
    q_len, kv_len = q.shape[2], k.shape[2]
    if use_pallas() and q_len % min(block_q, q_len) == 0 and kv_len % min(block_kv, kv_len) == 0:
        return flash_attention_pallas(
            q, k, v, causal=causal,
            block_q=min(block_q, q_len), block_kv=min(block_kv, kv_len),
            interpret=_interpret(), kv_len_mask=kv_len_mask,
        )
    from repro.perf_flags import enabled
    if (enabled("tri") and causal and kv_len_mask is None
            and q_len == kv_len and q_len % min(block_kv, kv_len) == 0
            and q_len // min(block_kv, kv_len) >= 2):
        return _ref.attention_triangular(q, k, v, causal=True,
                                         block=min(block_kv, kv_len),
                                         unroll=_ATTN_UNROLL)
    return _ref.attention_blockwise(q, k, v, causal=causal,
                                    block_kv=min(block_kv, kv_len),
                                    kv_len_mask=kv_len_mask,
                                    unroll=_ATTN_UNROLL)
