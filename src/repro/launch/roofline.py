"""Roofline-term derivation from compiled dry-run artifacts (deliverable g).

Per (arch x shape x mesh):

    compute    = HLO_FLOPs / (chips * PEAK_FLOPS)
    memory     = HLO_bytes / (chips * HBM_BW)
    collective = collective_bytes / (chips * ICI_BW)

``cost_analysis()`` provides FLOPs / bytes (whole-program totals across
devices on recent JAX; detected and normalized).  Collective bytes are NOT in
cost_analysis — we parse the compiled SPMD HLO and sum the result-shape bytes
of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute.  HLO shapes are per-device; multiplying by chip count
gives the global volume the formulas above divide back down.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link per chip

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVE_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> dict[str, int]:
    """Per-device bytes by collective kind, from compiled SPMD HLO text."""
    out: dict[str, int] = {}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        result_type, kind = m.group(1), m.group(2)
        out[kind] = out.get(kind, 0) + _shape_bytes(result_type)
    return out


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    cell: str
    mesh: str
    chips: int
    hlo_flops: float            # global FLOPs
    hlo_bytes: float            # global HBM bytes accessed
    collective_bytes: float     # global collective bytes
    model_flops: float          # analytic (6ND etc.)
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0

    def __post_init__(self):
        self.compute_s = self.hlo_flops / (self.chips * PEAK_FLOPS)
        self.memory_s = self.hlo_bytes / (self.chips * HBM_BW)
        self.collective_s = self.collective_bytes / (self.chips * ICI_BW)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flop_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — catches remat/redundancy waste."""
        return self.model_flops / max(self.hlo_flops, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline the dominant term allows:
        (model-flops time at peak) / (time the dominant term costs)."""
        ideal = self.model_flops / (self.chips * PEAK_FLOPS)
        return ideal / max(self.bound_s, 1e-30)

    def to_json(self) -> dict:
        return {
            "arch": self.arch, "cell": self.cell, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "collective_bytes": self.collective_bytes,
            "model_flops": self.model_flops,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "useful_flop_fraction": self.useful_flop_fraction,
            "roofline_fraction": self.roofline_fraction,
        }


def derive_terms(
    arch_id: str,
    cell_name: str,
    mesh_name: str,
    chips: int,
    cost: dict,
    hlo_text: str,
    model_flops: float,
    flops_are_global: bool = True,
) -> RooflineTerms:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    if not flops_are_global:
        flops *= chips
        byts *= chips
    coll = collective_bytes_from_hlo(hlo_text)
    coll_global = float(sum(coll.values())) * chips
    return RooflineTerms(
        arch=arch_id, cell=cell_name, mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=byts, collective_bytes=coll_global,
        model_flops=model_flops,
    )
