"""End-to-end training driver.

Picks an architecture from the registry, builds the (elastic) mesh, the
stateless data pipeline, and runs the fault-tolerant training loop with
checkpointing.  On this CPU container use ``--reduced`` (the full configs
are dry-run-only).

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --reduced \
        --steps 200 --ckpt-dir /tmp/ck
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_arch
from repro.data.pipeline import StatelessPipeline, lm_batch_maker, recsys_batch_maker
from repro.distributed.fault import PreemptionGuard
from repro.distributed.meshctx import use_mesh
from repro.launch.mesh import make_elastic_mesh
from repro.train.loop import TrainLoopConfig, run_training


def _make_pipeline(arch, cell, reduced: bool):
    cfg = arch.config(reduced)
    if arch.family == "lm":
        dims = arch._dims(cell, reduced)
        return StatelessPipeline(
            lm_batch_maker(cfg.vocab, dims["batch"], dims["seq"]))
    if arch.family == "recsys":
        b = arch._batch_size(cell, reduced)
        return StatelessPipeline(recsys_batch_maker(cfg, b))

    # GNN: synthetic graphs via the arch's own example_batch, re-seeded per step
    def make(seed, step, shard, n_shards):
        batch = arch.example_batch(cell, seed=seed * 10007 + step,
                                   reduced=reduced)
        batch.pop("n_graphs", None)
        return batch

    return StatelessPipeline(make)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--cell", default=None)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log", default=None)
    ap.add_argument("--use-mesh", action="store_true",
                    help="build an elastic mesh over available devices")
    args = ap.parse_args()

    arch = get_arch(args.arch)
    cells = [c for c in arch.shapes() if c.kind == "train" and not c.skip]
    cell = next((c for c in cells if c.name == args.cell), cells[0])
    print(f"training {args.arch} on cell {cell.name} "
          f"(reduced={args.reduced}, devices={len(jax.devices())})")

    mesh = make_elastic_mesh() if args.use_mesh else None
    try:
        step_fn = arch.make_step(cell, reduced=args.reduced, mesh=mesh)
    except TypeError:
        step_fn = arch.make_step(cell, reduced=args.reduced)

    def init():
        try:
            return arch.init_state(jax.random.PRNGKey(0), cell,
                                   reduced=args.reduced, mesh=mesh)
        except TypeError:
            return arch.init_state(jax.random.PRNGKey(0), cell,
                                   reduced=args.reduced)

    pipeline = _make_pipeline(arch, cell, args.reduced)
    guard = PreemptionGuard(install=True)
    cfg = TrainLoopConfig(
        total_steps=args.steps,
        checkpoint_every=args.ckpt_every,
        checkpoint_dir=args.ckpt_dir,
        log_path=args.log,
    )
    with use_mesh(mesh):
        result = run_training(init, step_fn, pipeline, cfg, preemption=guard)
    pipeline.close()
    print(f"steps run: {result.steps_run}  resumed_from: {result.resumed_from}")
    print(f"loss: {np.mean(result.losses[:5]):.4f} -> "
          f"{np.mean(result.losses[-5:]):.4f}")
    if result.straggler_steps:
        print(f"straggler steps: {result.straggler_steps}")


if __name__ == "__main__":
    main()
