"""Serving driver: GraphLake engine + batched BI query serving.

Generates (or reuses) an LDBC-style lakehouse, starts the engine (first or
second connection), and drives randomized batched queries through the
QueryServer, reporting startup time and latency percentiles — the in-process
equivalent of the paper's wrk2 evaluation (§7.5).

    PYTHONPATH=src python -m repro.launch.serve --sf 0.01 --requests 50
"""

from __future__ import annotations

import argparse
import json
import os
import random
import time

from repro.core.bi_queries import BI_QUERIES
from repro.core.engine import GraphLakeEngine
from repro.data.ldbc import generate_ldbc, ldbc_graph_schema
from repro.lakehouse.objectstore import ObjectStore, StoreConfig
from repro.serving.server import QueryServer, ServerConfig, latency_stats


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--root", default="/tmp/graphlake_serve")
    ap.add_argument("--sf", type=float, default=0.01)
    ap.add_argument("--requests", type=int, default=40)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--latency-scale", type=float, default=0.0,
                    help="1.0 simulates S3 latency on lake reads")
    ap.add_argument("--fresh", action="store_true", help="regenerate the lake")
    args = ap.parse_args()

    if args.fresh and os.path.exists(args.root):
        import shutil
        shutil.rmtree(args.root)
    store = ObjectStore(StoreConfig(root=args.root,
                                    latency_scale=args.latency_scale))
    if not os.path.exists(os.path.join(args.root, "tables")):
        print(f"generating LDBC SF={args.sf} ...")
        ds = generate_ldbc(store, scale_factor=args.sf)
        print(f"  {ds.n_persons} persons, {ds.n_comments} comments, "
              f"{ds.n_edges} edges")

    engine = GraphLakeEngine(store, ldbc_graph_schema())
    t0 = time.perf_counter()
    timings = engine.startup()
    print(f"startup ({engine.startup_mode}): {time.perf_counter()-t0:.3f}s  "
          f"breakdown={json.dumps({k: round(v, 3) for k, v in timings.items()})}")

    server = QueryServer(engine, BI_QUERIES,
                         ServerConfig(n_workers=args.workers))
    rng = random.Random(0)
    reqs = []
    for _ in range(args.requests):
        name = rng.choice(list(BI_QUERIES))
        params = {}
        if name == "bi1":
            params = {"date": rng.choice([20090101, 20120101, 20150101])}
        elif name == "bi4":
            params = {"city": f"city_{rng.randrange(50)}"}
        reqs.append((name, params))

    t1 = time.perf_counter()
    results = server.run_batch(reqs)
    wall = time.perf_counter() - t1
    server.close()
    engine.close()

    ok = [r for r in results if r.ok]
    stats = latency_stats(results)
    print(f"{len(ok)}/{len(results)} ok, throughput "
          f"{len(ok)/wall:.2f} q/s over {wall:.2f}s")
    print("latency:", json.dumps({k: round(v, 4) for k, v in stats.items()}))
    print("cache:", engine.cache.stats)


if __name__ == "__main__":
    main()
