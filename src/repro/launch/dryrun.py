import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (architecture x input shape x mesh) combination:
``jax.jit(step, in_shardings, out_shardings).lower(specs).compile()`` must
succeed on the production meshes (16x16 single-pod and 2x16x16 multi-pod,
512 placeholder CPU devices).  The compiled artifact yields
``memory_analysis()`` (proves per-device fit) and ``cost_analysis()`` +
SPMD HLO (feeds the roofline, deliverable g).

Results are written incrementally to ``benchmarks/results/dryrun/*.json``
(idempotent: existing results are skipped unless --force), so the sweep can
be resumed after interruption.

Usage:
    python -m repro.launch.dryrun                        # full sweep
    python -m repro.launch.dryrun --arch qwen2-1.5b      # one arch
    python -m repro.launch.dryrun --arch qwen2-1.5b --cell train_4k --mesh pod
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCH_IDS, get_arch
from repro.distributed.meshctx import use_mesh
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import derive_terms

RESULTS_DIR = os.path.join(
    os.path.dirname(os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))), "benchmarks", "results", "dryrun"
)


def _result_path(arch_id: str, cell: str, mesh_name: str) -> str:
    safe = arch_id.replace("/", "_").replace(".", "_")
    return os.path.join(RESULTS_DIR, f"{safe}__{cell}__{mesh_name}.json")


def _mem_to_json(mem) -> dict:
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes", "peak_memory_in_bytes"):
        try:
            out[attr] = int(getattr(mem, attr))
        except Exception:
            pass
    return out


def _per_device_bytes(mem_json: dict) -> int:
    """Per-device footprint: XLA's liveness-aware peak when available
    (arguments are donated/persistent, so add them), else args+temps."""
    args = mem_json.get("argument_size_in_bytes", 0)
    if "peak_memory_in_bytes" in mem_json:
        return mem_json["peak_memory_in_bytes"] + args
    return (args + mem_json.get("temp_size_in_bytes", 0)
            - mem_json.get("alias_size_in_bytes", 0))


def run_cell(arch_id: str, cell_name: str, mesh_name: str,
             force: bool = False, variant: str = "") -> dict:
    """Lower + compile one (arch, cell, mesh); returns the result record."""
    path = _result_path(arch_id, cell_name, mesh_name + variant)
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    arch = get_arch(arch_id)
    cell = {c.name: c for c in arch.shapes()}[cell_name]
    record = {
        "arch": arch_id, "cell": cell_name, "mesh": mesh_name,
        "kind": cell.kind, "status": "pending",
    }
    if cell.skip:
        record.update(status="skipped", reason=cell.skip)
        _write(path, record)
        return record

    multi_pod = mesh_name == "pod2"
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.perf_counter()
    try:
        state_sh, batch_sh = arch.shardings(mesh, cell)
        try:
            state_specs = arch.state_specs(cell, reduced=False, mesh=mesh)
        except TypeError:
            state_specs = arch.state_specs(cell, reduced=False)
        batch_specs = arch.batch_specs(cell, reduced=False)
        try:
            step = arch.make_step(cell, reduced=False, mesh=mesh)
        except TypeError:
            step = arch.make_step(cell, reduced=False)

        # donate the state: decode steps alias caches in place, train steps
        # alias params/optimizer — matches production and halves peak memory.
        # out_shardings must mirror the input state shardings or XLA cannot
        # alias the donated buffers.
        if cell.kind == "train":
            out_sh = (state_sh, None)
        elif cell.kind == "decode":
            out_sh = (None, state_sh)
        else:
            out_sh = None
        jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                         out_shardings=out_sh, donate_argnums=(0,))
        with use_mesh(mesh):
            lowered = jitted.lower(state_specs, batch_specs)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        hlo = compiled.as_text()

        # exact per-device cost accounting. cost_analysis counts loop bodies
        # once, so scanned layer stacks (LMs) are audited via two fully
        # unrolled variants (L=1, L=2) and extrapolated: exact for
        # layer-homogeneous stacks. GNN/recsys archs are python-unrolled
        # already; the dimenet ring gather gets an analytic correction.
        from repro.launch.roofline import collective_bytes_from_hlo
        from repro.perf_flags import enabled as _opt

        def _coll_bytes(hlo_text: str) -> float:
            # bf16-wire correction: the StableHLO ships bf16 all-gathers when
            # the bf16gather/gnnbf16 flags are on, but the CPU backend
            # legalizes sub-f32 collectives to f32 (verified; TPU ships bf16
            # natively) — halve the all-gather bytes to reflect the target.
            kinds = collective_bytes_from_hlo(hlo_text)
            if _opt("bf16gather") or _opt("gnnbf16"):
                kinds = dict(kinds)
                kinds["all-gather"] = kinds.get("all-gather", 0) * 0.5
            return float(sum(kinds.values()))
        if getattr(arch, "family", "") == "lm" and hasattr(arch, "cost_variant"):
            from repro.kernels import ops as kops
            samples = []
            for n_l in (1, 2):
                va = arch.cost_variant(n_l)
                v_state_sh, v_batch_sh = va.shardings(mesh, cell)
                with kops.attention_unroll(), use_mesh(mesh):
                    v_comp = jax.jit(
                        va.make_step(cell), in_shardings=(v_state_sh, v_batch_sh)
                    ).lower(va.state_specs(cell), va.batch_specs(cell)).compile()
                v_cost = v_comp.cost_analysis() or {}
                samples.append({
                    "flops": float(v_cost.get("flops", 0.0)),
                    "bytes": float(v_cost.get("bytes accessed", 0.0)),
                    "coll": _coll_bytes(v_comp.as_text()),
                })
            l_full = arch.config(False).n_layers
            def _extrap(key):
                return samples[0][key] + (l_full - 1) * (
                    samples[1][key] - samples[0][key])
            flops_dev = _extrap("flops")
            bytes_dev = _extrap("bytes")
            coll_dev = _extrap("coll")
            cost_audit = {"method": "unrolled L1/L2 extrapolation",
                          "samples": samples}
        else:
            flops_dev = float(cost.get("flops", 0.0))
            bytes_dev = float(cost.get("bytes accessed", 0.0))
            coll_dev = _coll_bytes(hlo)
            cost_audit = {"method": "direct (python-unrolled layers)"}
            if arch_id == "dimenet" and cell_name == "ogb_products":
                # ring-gather fori_loop bodies count once; add the analytic
                # per-device ring traffic: each gather streams the full table
                # past every device (E rows x width x 4B), x (2 geo + n_blocks
                # m_kj gathers) for fwd and again for the ring-reduce bwd.
                e = cell.dims["n_edges"]
                n_blocks = arch.config(False).n_blocks
                ring = 2.0 * (2 * e * 4 * 4 + n_blocks * e * 128 * 4)
                coll_dev += ring
                cost_audit["ring_correction_bytes"] = ring

        terms = derive_terms(
            arch_id, cell_name, mesh_name, chips, cost, hlo,
            model_flops=arch.model_flops(cell),
        )
        # overwrite with audited per-device numbers (x chips = global)
        terms.hlo_flops = flops_dev * chips
        terms.hlo_bytes = bytes_dev * chips
        terms.collective_bytes = coll_dev * chips
        terms.__post_init__()
        mem_json = _mem_to_json(mem)
        per_dev = _per_device_bytes(mem_json)
        record.update(
            status="ok",
            chips=chips,
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            memory=mem_json,
            per_device_bytes=per_dev,
            fits_hbm=bool(per_dev < 16e9),   # v5e: 16 GB HBM
            cost={k: cost[k] for k in ("flops", "bytes accessed")
                  if k in cost},
            cost_audit=cost_audit,
            roofline=terms.to_json(),
            hlo_collective_ops=_collective_op_counts(hlo),
        )
    except Exception as e:  # record failures — they are bugs to fix
        record.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-4000:])
    _write(path, record)
    return record


def _collective_op_counts(hlo: str) -> dict:
    import re
    counts: dict[str, int] = {}
    for kind in ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                 "collective-permute"):
        counts[kind] = len(re.findall(rf"\b{kind}(?:-start)?\(", hlo))
    return counts


def _write(path: str, record: dict) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(record, f, indent=1)
    os.replace(tmp, path)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--cell", default=None, help="one cell name (default: all)")
    ap.add_argument("--mesh", default=None, choices=[None, "pod", "pod2"],
                    help="pod=16x16, pod2=2x16x16 (default: both)")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ARCH_IDS
    meshes = [args.mesh] if args.mesh else ["pod", "pod2"]

    n_ok = n_skip = n_err = 0
    for arch_id in archs:
        arch = get_arch(arch_id)
        for cell in arch.shapes():
            if args.cell and cell.name != args.cell:
                continue
            for mesh_name in meshes:
                t0 = time.perf_counter()
                rec = run_cell(arch_id, cell.name, mesh_name, force=args.force)
                dt = time.perf_counter() - t0
                status = rec["status"]
                n_ok += status == "ok"
                n_skip += status == "skipped"
                n_err += status == "error"
                line = f"[{status:7s}] {arch_id:24s} {cell.name:16s} {mesh_name:5s} ({dt:6.1f}s)"
                if status == "ok":
                    r = rec["roofline"]
                    line += (f" dom={r['dominant']:10s}"
                             f" comp={r['compute_s']:.2e}s"
                             f" mem={r['memory_s']:.2e}s"
                             f" coll={r['collective_s']:.2e}s"
                             f" perdev={rec['per_device_bytes']/1e9:.2f}GB")
                elif status == "error":
                    line += " " + rec["error"][:120]
                print(line, flush=True)
    print(f"\nDRY-RUN SUMMARY: ok={n_ok} skipped={n_skip} errors={n_err}")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
