"""Production mesh construction (DESIGN.md §5).

A FUNCTION, not a module-level constant — importing this module never touches
jax device state, so library imports stay side-effect-free (the dry-run sets
its placeholder-device XLA flag before any jax import).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (one v5e pod's worth of chips) or 2x16x16 (two pods).

    ``pod`` is an outer pure-DP axis: gradient all-reduce crosses pods once
    per step; every other collective stays intra-pod.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_elastic_mesh(n_devices: int | None = None, model_parallel: int = 16):
    """Build the largest (data, model) mesh the available devices support —
    the elastic-scaling path: checkpoints restore onto any such mesh."""
    devices = jax.devices()
    n = n_devices or len(devices)
    model = min(model_parallel, n)
    while n % model:
        model //= 2
    data = n // model
    return jax.make_mesh(
        (data, model), ("data", "model"),
        devices=devices[:n],
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )
