"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Scale knobs are environment-tuned
for the CPU container; see each module for the paper figure it reproduces.

    PYTHONPATH=src python -m benchmarks.run [--only startup,queries,...]
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

SUITES = [
    ("startup", "benchmarks.bench_startup"),           # Fig 8 + 9
    ("queries", "benchmarks.bench_queries"),           # Fig 10 + 11
    ("algorithms", "benchmarks.bench_algorithms"),     # Table 2
    ("scalability", "benchmarks.bench_scalability"),   # Fig 12-14
    ("edgelist_vs_csr", "benchmarks.bench_edgelist_vs_csr"),  # Fig 15
    ("cache_units", "benchmarks.bench_cache_units"),   # Fig 16
    ("kernels", "benchmarks.bench_kernels"),
    ("roofline", "benchmarks.bench_roofline"),         # deliverable (g)
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    failures = []
    for name, module in SUITES:
        if only and name not in only:
            continue
        t0 = time.perf_counter()
        try:
            mod = __import__(module, fromlist=["run"])
            mod.run()
            print(f"# suite {name} done in {time.perf_counter()-t0:.1f}s",
                  file=sys.stderr)
        except Exception:
            failures.append(name)
            print(f"# suite {name} FAILED:\n{traceback.format_exc()}",
                  file=sys.stderr)
    if failures:
        print(f"# FAILED suites: {failures}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
