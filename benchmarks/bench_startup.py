"""Paper Fig. 8 + Fig. 9: startup time, first vs second connection, vs the
full-load (TigerGraph-style) baseline, with phase breakdown.

Simulated S3 latency is ON for this benchmark (the paper measures against
us-east-2); ratios are the comparable quantity (DESIGN.md §9).
"""

from __future__ import annotations

import shutil

from benchmarks.common import emit, fresh_store, make_engine, timed
from repro.core.baselines import FullLoadEngine
from repro.data.ldbc import generate_ldbc, ldbc_graph_schema


def run(sf: float = 0.02) -> None:
    store = fresh_store("startup", latency_scale=1.0)
    generate_ldbc(store, scale_factor=sf, n_files=4)
    schema = ldbc_graph_schema()

    # --- GraphLake first connection (topology-only build + materialize) -----
    eng1 = make_engine(store, schema)
    _, t_first = timed(eng1.startup)
    breakdown = dict(eng1.topology.timings)
    n_edges = eng1.topology.n_edges()
    topo_mb = eng1.topology.topology_bytes() / 1e6
    eng1.close()
    emit("fig8_graphlake_first_connection_s", t_first * 1e6,
         f"sf={sf};edges={n_edges};topology_mb={topo_mb:.1f}")

    # --- GraphLake second connection (materialized topology) -----------------
    eng2 = make_engine(store, schema)
    _, t_second = timed(eng2.startup)
    assert eng2.startup_mode == "second_connection"
    second_breakdown = dict(eng2.topology.timings)
    eng2.close()
    emit("fig8_graphlake_second_connection_s", t_second * 1e6,
         f"speedup_vs_first={t_first / t_second:.1f}x")

    # --- full-load baseline (loads every property column upfront) ------------
    full = FullLoadEngine(store, schema)
    _, t_full = timed(full.startup)
    emit("fig8_fullload_baseline_s", t_full * 1e6,
         f"graphlake_first_speedup={t_full / t_first:.1f}x;"
         f"graphlake_second_speedup={t_full / t_second:.1f}x")

    # --- Fig 9: phase breakdown ----------------------------------------------
    total = max(sum(breakdown.values()), 1e-9)
    for phase, secs in breakdown.items():
        emit(f"fig9_first_{phase}", secs * 1e6,
             f"fraction={secs / total:.2f}")
    for phase, secs in second_breakdown.items():
        emit(f"fig9_second_{phase}", secs * 1e6, "")

    # --- incremental update (edge-list advantage over CSR rebuild) -----------
    eng3 = make_engine(store, schema)
    eng3.startup()
    from repro.lakehouse.table import LakeCatalog
    import numpy as np
    if eng3.topology.idm is None or eng3.topology.idm.n_mapped("Person") == 0:
        eng3.topology._rebuild_idm(store)  # second connection deallocates it
    t = LakeCatalog(store).table("Person_Knows_Person")
    raw = eng3.topology.idm.raw_ids("Person")
    t.append_files([{
        "src": raw[:50], "dst": raw[50:100],
        "creationDate": np.full(50, 20230101, dtype=np.int64),
    }])
    _, t_incr = timed(lambda: eng3.topology.refresh_edges(
        store, LakeCatalog(store), "Knows"))
    eng3.close()
    emit("fig8_incremental_edge_file_add_s", t_incr * 1e6,
         f"vs_full_rebuild={t_first / max(t_incr, 1e-9):.0f}x")
