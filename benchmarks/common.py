"""Shared benchmark utilities: lake setup, timing, CSV emission."""

from __future__ import annotations

import os
import shutil
import time

from repro.core.engine import GraphLakeEngine
from repro.core.cache.manager import CacheConfig
from repro.data.graph500 import generate_graph500, graph500_schema
from repro.data.ldbc import generate_ldbc, ldbc_graph_schema
from repro.lakehouse.objectstore import ObjectStore, StoreConfig

BENCH_ROOT = os.environ.get("REPRO_BENCH_ROOT", "/tmp/repro_bench")
ROWS = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


def timed(fn, *args, repeats: int = 1, **kwargs):
    best = float("inf")
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        best = min(best, time.perf_counter() - t0)
    return out, best


def fresh_store(name: str, latency_scale: float = 0.0) -> ObjectStore:
    root = os.path.join(BENCH_ROOT, name)
    shutil.rmtree(root, ignore_errors=True)
    return ObjectStore(StoreConfig(root=root, latency_scale=latency_scale))


def reuse_store(name: str, latency_scale: float = 0.0) -> ObjectStore:
    root = os.path.join(BENCH_ROOT, name)
    return ObjectStore(StoreConfig(root=root, latency_scale=latency_scale))


def ldbc_lake(name: str, sf: float, latency_scale: float = 0.0,
              n_files: int = 4, shuffle_edges: bool = False):
    """Create (once) an LDBC lake; returns (store, schema)."""
    store = reuse_store(name, latency_scale)
    if not store.exists(f"tables/Person/metadata/VERSION"):
        generate_ldbc(store, scale_factor=sf, n_files=n_files,
                      shuffle_edges=shuffle_edges)
    return store, ldbc_graph_schema()


def graph500_lake(name: str, scale: int, latency_scale: float = 0.0):
    store = reuse_store(name, latency_scale)
    if not store.exists("tables/Node/metadata/VERSION"):
        generate_graph500(store, scale=scale)
    return store, graph500_schema()


def make_engine(store, schema, naive: bool = False, prefetch: bool = True,
                materialize: bool = True, memory_mb: int = 256,
                n_io_threads: int = 8) -> GraphLakeEngine:
    return GraphLakeEngine(
        store, schema,
        cache_config=CacheConfig(
            memory_budget_bytes=memory_mb * 1024 * 1024, naive_mode=naive),
        n_io_threads=n_io_threads,
        enable_prefetch=prefetch,
        materialize_topology=materialize,
    )
