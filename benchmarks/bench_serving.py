"""Shared-scan serving benchmark (DESIGN.md §9): one chunk pass serves
every concurrent rider.

Three sweeps snapshotted into ``BENCH_serving.json`` (override with
``REPRO_BENCH_SERVING_SNAPSHOT``):

- the **shared-scan sweep**: ``session.query_batch`` over varied-parameter
  riders verified bit-identical to solo ``session.query`` on the same epoch
  (vset, frames, every column, accumulators), plus the chunk-counter
  contract — same-parameter riders share exactly one fetch/decode pass, so
  the batch's ``chunks_read`` equals a single solo run's, not R times it;
- the **throughput sweep**: closed-loop concurrent clients replaying one
  installed template against a batching server vs an unbatched server
  (same worker count), asserting the ISSUE 6 acceptance floor — batched
  throughput >= ``min_speedup`` x unbatched at 16 clients;
- the **fixed-QPS sweep**: an open-loop arrival process over a *mixed*
  installed-template workload at a fixed request rate, reporting sustained
  throughput and p50/p99 latency for both server arms (report-only: tail
  latency under open-loop load is jitter-prone, so no floor is asserted).

``run(quick=True)`` is the CI gate mode — small scale, fewer requests.
"""

from __future__ import annotations

import json
import os
import threading
import time

import numpy as np

from benchmarks.common import emit, fresh_store, make_engine
from repro.data.ldbc import generate_ldbc, ldbc_graph_schema
from repro.gsql.session import GraphSession
from repro.serving.server import QueryServer, ServerConfig, latency_stats

SNAPSHOT_PATH = os.environ.get("REPRO_BENCH_SERVING_SNAPSHOT", "BENCH_serving.json")

HOT_TEMPLATE = """
    SELECT p FROM Comment:c -(HasCreator:e)- Person:p
    WHERE e.creationDate > $thr
    ACCUM p.@cnt += 1
"""
TAG_TEMPLATE = """
    SELECT p FROM Tag:t -(HasTag:e1)- Comment:c -(HasCreator:e2)- Person:p
    WHERE t.name == $tag AND e2.creationDate > $date
    ACCUM p.@deg += 1
"""


def _setup(sf: float, row_group_rows: int = 512):
    store = fresh_store(f"serving_{sf}")
    generate_ldbc(store, scale_factor=sf, n_files=3,
                  row_group_rows=row_group_rows)
    eng = make_engine(store, ldbc_graph_schema())
    eng.startup()
    session = GraphSession.for_engine(eng)
    session.install("hot", HOT_TEMPLATE)
    session.install("tag", TAG_TEMPLATE)
    return eng, session


def _date_quantiles(eng, fracs):
    comments = eng.all_vertices("Comment")
    dates = eng.read_vertex_column("Comment", comments.ids(), "creationDate")
    return [float(np.quantile(dates, f)) for f in fracs]


def _assert_result_parity(b, s) -> None:
    assert np.array_equal(b.vset.ids(), s.vset.ids())
    assert b.n_edges_scanned == s.n_edges_scanned
    for fb, fs in zip(b.frames, s.frames):
        assert np.array_equal(fb.u, fs.u) and np.array_equal(fb.v, fs.v)
        assert set(fb.columns) == set(fs.columns)
        for k in fb.columns:
            assert np.array_equal(fb.columns[k], fs.columns[k]), k
    assert set(b.accumulators) == set(s.accumulators)
    for k in b.accumulators:
        assert np.array_equal(b.accumulators[k], s.accumulators[k]), k


def shared_scan_sweep(sf: float = 0.004, n_riders: int = 8) -> dict:
    """Bit-parity + shared-pass chunk counters for ``query_batch``."""
    eng, session = _setup(sf)
    t0 = time.perf_counter()
    thrs = _date_quantiles(eng, np.linspace(0.2, 0.9, n_riders))

    # --- varied-parameter riders: every rider bit-identical to its solo run
    eng.cache.drop_all()
    batched = session.query_batch("hot", [{"thr": t} for t in thrs])
    for t, res in zip(thrs, batched):
        solo = session.query("hot", epoch=None, thr=t)
        _assert_result_parity(res, solo)

    # --- same-parameter riders: the union chunk set *is* the solo chunk
    # set, so the shared pass reads exactly one run's worth of chunks while
    # serving all riders
    eng.cache.drop_all()
    solo = session.query("hot", thr=thrs[0])
    solo_chunks = solo.pruning["chunks_read"]
    eng.cache.drop_all()
    same = session.query_batch("hot", [{"thr": thrs[0]}] * n_riders)
    batch_chunks = same[0].pruning["chunks_read"]
    assert batch_chunks == solo_chunks, (
        f"shared pass read {batch_chunks} chunks for {n_riders} riders; a "
        f"single solo run reads {solo_chunks} — the pass is not shared")
    for res in same:
        _assert_result_parity(res, solo)

    row = {
        "n_riders": n_riders,
        "solo_chunks_read": solo_chunks,
        "batch_chunks_read": batch_chunks,
        "chunks_per_rider": batch_chunks / n_riders,
        "batch_rows_decoded": same[0].pruning["rows_decoded"],
    }
    emit("shared_scan_chunks_read", float(batch_chunks),
         f"riders={n_riders};solo={solo_chunks};"
         f"per_rider={row['chunks_per_rider']:.2f}")
    eng.close()
    return {
        "bench": "serving_shared_scan_sweep",
        "sf": sf,
        "wall_s": time.perf_counter() - t0,
        "rows": [row],
    }


def _closed_loop(session, window_ms: float, n_clients: int,
                 reqs_per_client: int, n_workers: int, thrs) -> dict:
    srv = QueryServer(session, config=ServerConfig(
        n_workers=n_workers, max_queue=4096, batch_window_ms=window_ms))
    results: list[list] = [[] for _ in range(n_clients)]

    def client(i: int) -> None:
        for _ in range(reqs_per_client):
            rid = srv.submit("hot", thr=thrs[i % len(thrs)])
            results[i].append(srv.result(rid))

    threads = [threading.Thread(target=client, args=(i,))
               for i in range(n_clients)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    flat = [r for per in results for r in per]
    assert all(r.ok for r in flat), [r.error for r in flat if not r.ok][:3]
    stats = dict(srv.stats)
    lat = latency_stats(flat)
    srv.close()
    return {
        "window_ms": window_ms,
        "n_requests": len(flat),
        "wall_s": wall,
        "throughput_qps": len(flat) / wall,
        "p50_s": lat["p50_s"],
        "p99_s": lat["p99_s"],
        "mean_queued_s": lat["mean_queued_s"],
        "batches": stats["batches"],
        "batched_requests": stats["batched_requests"],
        "solo_requests": stats["solo_requests"],
        "max_batch_riders": stats["max_batch_riders"],
    }


def throughput_sweep(sf: float = 0.004, n_clients: int = 16,
                     reqs_per_client: int = 8, n_workers: int = 2,
                     window_ms: float = 2.0,
                     min_speedup: float = 2.0) -> dict:
    """Closed-loop clients replaying one installed template: the ISSUE 6
    acceptance floor — batching must at least double sustained throughput
    at 16 concurrent clients over the same worker pool."""
    eng, session = _setup(sf)
    t0 = time.perf_counter()
    # selective thresholds (top 1-20% of edges): the serving-shaped regime —
    # each rider keeps a small survivor set, so the shared gather dominates
    # and the per-rider mask/frame work stays cheap.  Low-selectivity riders
    # shift cost into per-rider result materialization, which batching
    # cannot share (it is each rider's own output).
    thrs = _date_quantiles(eng, np.linspace(0.8, 0.99, n_clients))
    # warm the decoded cache so both arms measure execution, not first-touch
    # I/O; then best-of-2 per arm to damp scheduler wake-up jitter
    for t in thrs:
        session.query("hot", thr=t)

    def arm(window: float) -> dict:
        a = _closed_loop(session, window, n_clients, reqs_per_client,
                         n_workers, thrs)
        b = _closed_loop(session, window, n_clients, reqs_per_client,
                         n_workers, thrs)
        return a if a["throughput_qps"] >= b["throughput_qps"] else b

    unbatched = arm(0.0)
    batched = arm(window_ms)
    speedup = batched["throughput_qps"] / unbatched["throughput_qps"]
    emit("serving_batched_qps", batched["throughput_qps"],
         f"unbatched={unbatched['throughput_qps']:.0f}qps;"
         f"speedup={speedup:.1f}x;batches={batched['batches']};"
         f"max_riders={batched['max_batch_riders']}")
    assert batched["batches"] >= 1 and batched["batched_requests"] > 0, batched
    assert unbatched["batches"] == 0, unbatched
    assert speedup >= min_speedup, (
        f"batched serving only {speedup:.2f}x over unbatched "
        f"(floor {min_speedup}x): batched={batched} unbatched={unbatched}")
    eng.close()
    return {
        "bench": "serving_throughput_sweep",
        "sf": sf,
        "n_clients": n_clients,
        "n_workers": n_workers,
        "reqs_per_client": reqs_per_client,
        "speedup": speedup,
        "min_speedup": min_speedup,
        "wall_s": time.perf_counter() - t0,
        "rows": [unbatched, batched],
    }


def qps_sweep(sf: float = 0.004, qps: float = 300.0,
              duration_s: float = 1.5, n_workers: int = 2,
              window_ms: float = 4.0) -> dict:
    """Open-loop fixed-QPS arrivals over a mixed installed-template
    workload; reports sustained throughput and p50/p99 per arm."""
    eng, session = _setup(sf)
    t0 = time.perf_counter()
    thrs = _date_quantiles(eng, [0.5, 0.8])
    workload = [("hot", {"thr": thrs[0]}), ("hot", {"thr": thrs[1]}),
                ("tag", {"tag": "Music", "date": 20100101})]
    for name, params in workload:
        session.query(name, **params)  # warm

    def arm(window: float) -> dict:
        srv = QueryServer(session, config=ServerConfig(
            n_workers=n_workers, max_queue=4096, batch_window_ms=window))
        rids = []
        interval = 1.0 / qps
        t_start = time.perf_counter()
        i = 0
        while True:
            now = time.perf_counter() - t_start
            if now >= duration_s:
                break
            target = i * interval
            if now < target:
                time.sleep(target - now)
            name, params = workload[i % len(workload)]
            rids.append(srv.submit(name, **params))
            i += 1
        results = [srv.result(rid) for rid in rids]
        wall = time.perf_counter() - t_start
        assert all(r.ok for r in results), \
            [r.error for r in results if not r.ok][:3]
        lat = latency_stats(results)
        stats = dict(srv.stats)
        srv.close()
        return {
            "window_ms": window,
            "offered_qps": qps,
            "n_requests": len(results),
            "sustained_qps": len(results) / wall,
            "p50_s": lat["p50_s"],
            "p99_s": lat["p99_s"],
            "mean_queued_s": lat["mean_queued_s"],
            "batches": stats["batches"],
            "batched_requests": stats["batched_requests"],
        }

    unbatched = arm(0.0)
    batched = arm(window_ms)
    emit("serving_qps_p99_ms", batched["p99_s"] * 1e3,
         f"unbatched_p99={unbatched['p99_s']*1e3:.1f}ms;"
         f"offered={qps:.0f}qps;"
         f"sustained={batched['sustained_qps']:.0f}qps")
    eng.close()
    return {
        "bench": "serving_qps_sweep",
        "sf": sf,
        "wall_s": time.perf_counter() - t0,
        "rows": [unbatched, batched],
    }


def _write_snapshot(snap: dict) -> None:
    with open(SNAPSHOT_PATH, "w") as f:
        json.dump(snap, f, indent=2)
    emit("serving_snapshot", 0.0, SNAPSHOT_PATH)


def run(sf: float = 0.01, quick: bool = False) -> None:
    snap = {}
    if quick:
        snap["shared_scan_sweep"] = shared_scan_sweep(sf=0.004)
        snap["throughput_sweep"] = throughput_sweep(sf=0.004,
                                                    reqs_per_client=6)
        snap["qps_sweep"] = qps_sweep(sf=0.004, qps=200.0, duration_s=1.0)
    else:
        snap["shared_scan_sweep"] = shared_scan_sweep(sf=sf, n_riders=16)
        snap["throughput_sweep"] = throughput_sweep(sf=sf,
                                                    reqs_per_client=12)
        snap["qps_sweep"] = qps_sweep(sf=sf)
    _write_snapshot(snap)


if __name__ == "__main__":
    run()
