"""Kernel micro-benchmarks: jnp reference path wall-time on CPU (the Pallas
TPU kernels are validated in interpret mode by tests; wall-clock here
measures the dispatchable reference path the CPU backend runs)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.kernels import ops, ref


def _bench_jit(fn, *args, repeats=5):
    jitted = jax.jit(fn)
    jitted(*args)[0].block_until_ready() if isinstance(jitted(*args), tuple) \
        else jitted(*args).block_until_ready()
    _, t = timed(lambda: jax.block_until_ready(jitted(*args)), repeats=repeats)
    return t


def run() -> None:
    rng = np.random.default_rng(0)

    e, n, d = 200_000, 20_000, 64
    values = jnp.asarray(rng.standard_normal((e, d)), jnp.float32)
    dstv = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    t = _bench_jit(lambda v, s: ops.edge_segment_sum(v, s, n), values, dstv)
    emit("kernel_edge_segment_sum_us", t * 1e6,
         f"E={e};D={d};GB/s={(e*d*4*2)/t/1e9:.1f}")

    v, b, l, dd = 100_000, 4096, 8, 32
    table = jnp.asarray(rng.standard_normal((v, dd)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, v, (b, l)), jnp.int32)
    w = jnp.asarray(rng.random((b, l)), jnp.float32)
    t = _bench_jit(lambda tb, i, ww: ops.embedding_bag(tb, i, ww), table, idx, w)
    emit("kernel_embedding_bag_us", t * 1e6, f"B={b};L={l};D={dd}")

    bq, h, s, dh = 2, 8, 1024, 64
    q = jnp.asarray(rng.standard_normal((bq, h, s, dh)), jnp.bfloat16)
    t = _bench_jit(lambda a, b2, c: ref.attention_blockwise(a, b2, c), q, q, q)
    flops = 4 * bq * h * s * s * dh
    emit("kernel_flash_attention_us", t * 1e6,
         f"S={s};GFLOP/s={flops/t/1e9:.1f}")
