"""Incremental epoch advance vs full topology rebuild (DESIGN.md §7).

The epoch subsystem's reason to exist at serving scale: picking up a small
append-only lake commit must not cost a topology rebuild.  This benchmark
stages a ≤5% append (new Comment vertex file + the matching HasCreator edge
file) against an LDBC lake, then measures — under the modeled object-store
latency — the two ways to become fresh:

- **incremental** ``engine.advance()``: pooled per-table snapshot diff,
  delta edge-list build for the new files only, IDM dense-offset extension,
  CSR merge-extension, atomic epoch publish;
- **full rebuild**: what the pre-epoch engine did on *any* vertex-table
  change — re-read every PK/FK column of every table from the lake, rebuild
  the IDM, all edge lists and the CSR indexes.

Asserts the incremental path clears the ISSUE 4 acceptance floor
(``advance`` ≥ 5x faster than rebuild for the ≤5% append) and that the
advanced engine's query results are **bit-identical** to a cold-started
engine on the new snapshot.  Snapshot written to ``BENCH_refresh.json``
(override with ``REPRO_BENCH_REFRESH_SNAPSHOT``); ``run(quick=True)`` is
the CI-gate mode.
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import emit, fresh_store, make_engine, timed
from repro.core.query import Query, gt
from repro.core.topology import GraphTopology
from repro.data.ldbc import generate_ldbc, ldbc_graph_schema
from repro.lakehouse.table import LakeCatalog

SNAPSHOT_PATH = os.environ.get("REPRO_BENCH_REFRESH_SNAPSHOT", "BENCH_refresh.json")

_EDGE_TYPES = ("Knows", "HasCreator", "HasTag")


def _assert_parity(a, b) -> None:
    assert a.n_edges_scanned == b.n_edges_scanned
    assert np.array_equal(a.vset.ids(), b.vset.ids())
    for fa, fb in zip(a.frames, b.frames):
        assert np.array_equal(fa.u, fb.u) and np.array_equal(fa.v, fb.v)
        for k in fa.columns:
            assert np.array_equal(fa.columns[k], fb.columns[k]), k


def _stage_append(store, eng, ds, append_frac: float, seed: int = 11):
    """Commit ~append_frac new comments + their HasCreator edges."""
    rng = np.random.default_rng(seed)
    n_new = max(8, int(ds.n_comments * append_frac))
    # continue the generator's raw-id scheme past the existing comments
    new_cids = (np.arange(ds.n_comments + 1, ds.n_comments + n_new + 1,
                          dtype=np.int64)) * 10 + 3
    lake = LakeCatalog(store)
    lake.table("Comment").append_files([{
        "id": new_cids,
        "creationDate": rng.integers(20230101, 20231231, n_new).astype(np.int64),
        "length": rng.integers(1, 2000, n_new).astype(np.int64),
        "browserUsed": np.array(["Chrome"] * n_new, dtype=object),
    }])
    person_raw = eng.topology.idm.raw_ids("Person")
    lake.table("Comment_HasCreator_Person").append_files([{
        "src": new_cids,
        "dst": person_raw[rng.integers(0, len(person_raw), n_new)],
        "creationDate": rng.integers(20230101, 20231231, n_new).astype(np.int64),
    }])
    return n_new


def refresh_sweep(
    sf: float = 0.02,
    append_frac: float = 0.05,
    latency_scale: float = 1.0,
    min_speedup: float = 5.0,
    row_group_rows: int = 512,
) -> dict:
    store = fresh_store(f"refresh_{sf}")
    ds = generate_ldbc(store, scale_factor=sf, n_files=2,
                       row_group_rows=row_group_rows)
    # materialize=False on every engine here: this benchmark compares lake
    # (re)read costs, and a cold start must see the *new* snapshot, not a
    # stale materialized topology blob
    eng = make_engine(store, ldbc_graph_schema(), materialize=False)
    eng.startup()
    t0 = time.perf_counter()

    # the advance must exercise the CSR merge-extension, so the current
    # epoch's CSR indexes exist before the commit lands
    for ename in _EDGE_TYPES:
        eng.current_epoch().plane.csr(ename)

    comments = eng.all_vertices("Comment")
    dates = eng.read_vertex_column("Comment", comments.ids(), "creationDate")
    thr = float(np.quantile(dates, 0.5))

    def make_query(e):
        return (Query(e)
                .vertices("Comment")
                .hop("HasCreator", direction="out",
                     edge_where=gt("creationDate", thr)))

    res_before = make_query(eng).run()
    n_new = _stage_append(store, eng, ds, append_frac)

    # -- arm 1: incremental advance, modeled store latency on ------------------
    store.config.latency_scale = latency_scale
    store.reset_counters()
    report, t_advance = timed(eng.advance)
    adv_requests = store.counters["get_requests"]
    assert report.changed and report.mode == "incremental", report
    assert "HasCreator" in report.csr_extended, report

    # -- arm 2: full topology rebuild (the pre-epoch vertex-change path) -------
    def full_rebuild():
        topo = GraphTopology(ldbc_graph_schema())
        topo.build(store, LakeCatalog(store))
        for ename in _EDGE_TYPES:   # rebuild the same derived state advance kept
            topo.plane.csr(ename)
        return topo

    store.reset_counters()
    _, t_rebuild = timed(full_rebuild)
    rebuild_requests = store.counters["get_requests"]
    store.config.latency_scale = 0.0

    speedup = t_rebuild / t_advance

    # -- parity: advanced engine vs a cold start on the new snapshot -----------
    res_after = make_query(eng).run()
    assert res_after.epoch_id > res_before.epoch_id
    cold = make_engine(store, ldbc_graph_schema(), materialize=False)
    cold.startup()
    res_cold = make_query(cold).run()
    _assert_parity(res_after, res_cold)
    cold.close()
    eng.close()

    row = {
        "sf": sf,
        "append_frac": append_frac,
        "appended_rows": n_new,
        "latency_scale": latency_scale,
        "advance_s": t_advance,
        "rebuild_s": t_rebuild,
        "speedup": speedup,
        "advance_get_requests": adv_requests,
        "rebuild_get_requests": rebuild_requests,
        "edges_added": report.edges_added,
        "vertices_added": report.vertices_added,
        "csr_extended": list(report.csr_extended),
        "mode": report.mode,
    }
    emit("refresh_advance_ms", t_advance * 1e3,
         f"rebuild={t_rebuild*1e3:.0f}ms;speedup={speedup:.1f}x;"
         f"gets={adv_requests}/{rebuild_requests};rows+={n_new}")
    assert speedup >= min_speedup, (
        f"incremental advance only {speedup:.2f}x over full rebuild "
        f"(floor {min_speedup}x): {row}")
    return {
        "bench": "refresh_incremental_vs_rebuild",
        "wall_s": time.perf_counter() - t0,
        "rows": [row],
    }


def _write_snapshot(snap: dict) -> None:
    with open(SNAPSHOT_PATH, "w") as f:
        json.dump(snap, f, indent=2)
    emit("refresh_snapshot", 0.0, SNAPSHOT_PATH)


def run(quick: bool = False) -> None:
    snap = {"refresh_sweep": refresh_sweep(sf=0.02 if quick else 0.05)}
    _write_snapshot(snap)


if __name__ == "__main__":
    run()
