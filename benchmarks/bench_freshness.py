"""Streaming-ingestion freshness SLO: CDC commit -> queryable epoch
(DESIGN.md §12).

Three arms, one snapshot (``BENCH_freshness.json``):

- **freshness under load** — a producer thread feeds an insert-heavy CDC
  stream (new comments + their HasCreator edges, a slice of updates)
  through the micro-batch pipeline while query threads hammer the same
  engine; reports p50/p99 *commit->queryable* (lake commit landed -> epoch
  published) and *ingest->queryable* (event admitted -> epoch published)
  from the epoch driver's samples, and asserts the p99 stays bounded.
- **oracle parity** — the identical event history replayed as one batch
  ``upsert_rows`` commit per table into a fresh copy of the seed lake;
  asserts the pipeline's micro-batched lake is row-for-row identical
  (zero dropped, zero duplicated events) and that the ingest counters
  surface through ``QueryServer.health()``.
- **backpressure under stall** — fault injection fails every table write,
  so flushes fail, the bounded queue fills, and ``submit()`` must shed a
  typed ``IngestBackpressureError``; healing the store drains the retained
  batch with exactly-once commits.

``run(quick=True)`` is the CI-gate mode (override the snapshot path with
``REPRO_BENCH_FRESHNESS_SNAPSHOT``).
"""

from __future__ import annotations

import json
import os
import threading
import time

import numpy as np

from benchmarks.common import emit, fresh_store, make_engine, timed
from repro.data.ldbc import generate_ldbc, ldbc_graph_schema
from repro.errors import IngestBackpressureError
from repro.ingest import ChangeEvent, ChangeLog, IngestConfig, IngestPipeline
from repro.lakehouse.columnfile import read_columns, read_footer
from repro.lakehouse.faults import FaultInjector, FaultRule
from repro.lakehouse.objectstore import ObjectStore, StoreConfig
from repro.lakehouse.table import LakeCatalog

SNAPSHOT_PATH = os.environ.get("REPRO_BENCH_FRESHNESS_SNAPSHOT",
                               "BENCH_freshness.json")

_QUERY = ("SELECT p FROM Comment:c -(HasCreator:e)- Person:p "
          "WHERE c.creationDate > 20120101 ACCUM p.@cnt += 1")


def _comment_row(cid: int, length: int, date: int = 20130101) -> dict:
    return {"id": int(cid), "creationDate": int(date), "length": int(length),
            "browserUsed": "Chrome"}


def _build_events(ds, n_events: int, seed: int = 23) -> list[ChangeEvent]:
    """Insert-heavy CDC stream: ~80% new comments (+ edge), ~20% updates of
    already-streamed comments.  Deterministic, so the identical history can
    be replayed into the batch-committed oracle."""
    rng = np.random.default_rng(seed)
    events: list[ChangeEvent] = []
    base = ds.n_comments
    streamed: list[int] = []
    t = 1000.0
    i = 0
    while len(events) < n_events:
        t += 0.001
        if streamed and rng.random() < 0.2:
            cid = int(streamed[rng.integers(0, len(streamed))])
            events.append(ChangeEvent(
                table="Comment", op="upsert",
                row=_comment_row(cid, length=9_000_000 + i), event_time=t))
        else:
            cid = (base + 1 + i) * 10 + 3
            events.append(ChangeEvent(
                table="Comment", op="upsert",
                row=_comment_row(cid, length=i + 1), event_time=t))
            t += 0.001
            events.append(ChangeEvent(
                table="Comment_HasCreator_Person", op="upsert",
                row={"src": cid, "dst": 11, "creationDate": 20130101},
                event_time=t))
            streamed.append(cid)
        i += 1
    return events


def _table_rows(store, table: str) -> dict:
    t = LakeCatalog(store).table(table)
    cols = [c.name for c in t.schema().columns]
    out = {}
    for fk in t.data_files():
        meta = read_footer(store, fk)
        data = read_columns(store, meta, cols)
        for i in range(meta.n_rows):
            row = tuple(data[c][i] for c in cols)
            key = row[0] if table == "Comment" else (row[0], row[1])
            assert key not in out, f"duplicate key {key} in {table}"
            out[key] = row
    return out


def freshness_sweep(sf: float = 0.004, n_events: int = 400,
                    n_query_threads: int = 2, cadence_ms: float = 20.0,
                    max_p99_s: float = 30.0) -> dict:
    store = fresh_store(f"freshness_{sf}")
    ds = generate_ldbc(store, scale_factor=sf, n_files=2, row_group_rows=512)
    eng = make_engine(store, ldbc_graph_schema(), materialize=False)
    eng.startup()
    t0 = time.perf_counter()
    session = eng.session()
    session.install("creators", _QUERY)
    events = _build_events(ds, n_events)
    log = ChangeLog()

    pipe = IngestPipeline(eng, IngestConfig(
        flush_interval_s=cadence_ms / 1000.0)).start()

    # paced producer: append the pre-built history to the live change log
    # in real time so the pipeline sees a stream, not one giant poll
    def produce() -> None:
        for e in events:
            log.append(e)
            time.sleep(0.002)

    producer = threading.Thread(target=produce)

    # concurrent query load on the same engine while the stream lands
    stop = threading.Event()
    query_counts = [0] * n_query_threads
    query_errors: list = []

    def query_loop(slot: int) -> None:
        while not stop.is_set():
            try:
                session.query("creators")
                query_counts[slot] += 1
            except Exception as ex:     # noqa: BLE001 — benchmark guardrail
                query_errors.append(repr(ex))
                return

    workers = [threading.Thread(target=query_loop, args=(i,))
               for i in range(n_query_threads)]
    for w in workers:
        w.start()

    pipe.attach_source(log)
    producer.start()
    producer.join()
    drained = pipe.drain(timeout=120.0)
    stop.set()
    for w in workers:
        w.join()

    stats = pipe.stats()
    pipe.close()
    assert drained, f"pipeline failed to drain: {stats}"
    assert not query_errors, query_errors
    assert stats["flush_errors"] == 0 and stats["rejected"] == 0, stats
    f = stats["freshness"]
    assert f["samples"] >= 5, f
    assert 0.0 < f["commit_to_queryable_p99_s"] <= max_p99_s, f
    assert f["ingest_to_queryable_p99_s"] >= f["commit_to_queryable_p99_s"], f
    # every admitted event became visible through an epoch
    assert (stats["driver"]["events_visible"]
            == stats["committer"]["events_committed"]), stats

    row = {
        "sf": sf,
        "n_events": n_events,
        "cadence_ms": cadence_ms,
        "n_query_threads": n_query_threads,
        "queries_served": int(sum(query_counts)),
        "events_submitted": stats["submitted"],
        "events_coalesced": stats["committer"]["events_coalesced"],
        "rows_inserted": stats["committer"]["rows_inserted"],
        "rows_updated": stats["committer"]["rows_updated"],
        "flushes": stats["flushes"],
        "advances": stats["driver"]["advances"],
        "commit_to_queryable_p50_s": f["commit_to_queryable_p50_s"],
        "commit_to_queryable_p99_s": f["commit_to_queryable_p99_s"],
        "ingest_to_queryable_p50_s": f["ingest_to_queryable_p50_s"],
        "ingest_to_queryable_p99_s": f["ingest_to_queryable_p99_s"],
        "final_epoch": eng.current_epoch().epoch_id,
    }
    emit("freshness_commit_to_queryable_p99_ms",
         f["commit_to_queryable_p99_s"] * 1e3,
         f"p50={f['commit_to_queryable_p50_s']*1e3:.1f}ms;"
         f"e2e_p99={f['ingest_to_queryable_p99_s']*1e3:.1f}ms;"
         f"events={stats['submitted']};advances={row['advances']};"
         f"queries={row['queries_served']}")
    return {"store": store, "ds": ds, "eng": eng, "log": log, "row": row,
            "wall_s": time.perf_counter() - t0}


def oracle_parity(sweep: dict) -> dict:
    """Replay the sweep's identical history into a batch-committed oracle
    lake; the pipeline's lake must match row-for-row, and the ingest
    counters must surface in QueryServer.health()."""
    from repro.serving.server import QueryServer, ServerConfig

    t0 = time.perf_counter()
    store, ds, eng, log = (sweep["store"], sweep["ds"], sweep["eng"],
                           sweep["log"])
    oroot = os.path.join(os.path.dirname(store.config.root),
                         "freshness_oracle")
    import shutil
    shutil.rmtree(oroot, ignore_errors=True)
    ostore = ObjectStore(StoreConfig(root=oroot))
    generate_ldbc(ostore, scale_factor=sweep["row"]["sf"], n_files=2,
                  row_group_rows=512)

    # one LWW-coalesced batch per table (history is event_time ordered)
    by_table: dict = {}
    for e in log.history():
        key = ((e.row["id"],) if e.table == "Comment"
               else (e.row["src"], e.row["dst"]))
        by_table.setdefault(e.table, {})[key] = e
    for table, slot in by_table.items():
        lt = LakeCatalog(ostore).table(table)
        cols = [c.name for c in lt.schema().columns]
        ups = list(slot.values())
        lt.upsert_rows(
            {c: np.array([e.row[c] for e in ups],
                         dtype=(object if c == "browserUsed" else np.int64))
             for c in cols},
            key_columns=(["id"] if lt.schema().primary_key
                         else ["src", "dst"]))

    mismatches = 0
    for table in ("Comment", "Comment_HasCreator_Person"):
        got = _table_rows(store, table)
        want = _table_rows(ostore, table)
        if got != want:
            mismatches += 1
    assert mismatches == 0, "pipeline lake diverged from batch oracle"

    # ingest counters ride the serving health surface while a pipeline runs
    pipe = IngestPipeline(eng, IngestConfig(flush_interval_s=0.05)).start()
    server = QueryServer(eng, {}, ServerConfig(n_workers=1))
    health = server.health()
    server.close()
    pipe.close()
    assert "ingest" in health and "freshness" in health["ingest"], health
    eng.close()

    row = {"tables_checked": 2, "mismatches": mismatches,
           "events_replayed": len(log.history()),
           "health_has_ingest": True}
    emit("freshness_oracle_mismatches", float(mismatches),
         f"events={row['events_replayed']};tables=2")
    return {"row": row, "wall_s": time.perf_counter() - t0}


def backpressure_under_stall(sf: float = 0.004, max_queue: int = 16) -> dict:
    """A stalled lake must surface as typed backpressure at the producer
    edge, and a healed lake must drain the retained batch exactly once."""
    t0 = time.perf_counter()
    store = fresh_store(f"freshness_stall_{sf}")
    ds = generate_ldbc(store, scale_factor=sf, n_files=2, row_group_rows=512)
    eng = make_engine(store, ldbc_graph_schema(), materialize=False)
    eng.startup()
    store.faults = FaultInjector(
        [FaultRule(prefix="tables/", ops=("put", "put_if"),
                   transient_rate=1.0)], seed=5)
    pipe = IngestPipeline(eng, IngestConfig(
        flush_interval_s=0.01, max_queue=max_queue)).start()

    base = ds.n_comments
    shed = 0
    admitted = 0
    t_start = time.monotonic()
    t_shed = None
    deadline = t_start + 60.0
    while shed == 0 and time.monotonic() < deadline:
        try:
            pipe.submit(ChangeEvent(
                table="Comment", op="upsert",
                row=_comment_row((base + 1 + admitted) * 10 + 3,
                                 length=admitted + 1)))
            admitted += 1
        except IngestBackpressureError:
            shed += 1
            t_shed = time.monotonic() - t_start
        time.sleep(0.002)
    stats_stalled = pipe.stats()
    assert shed == 1, f"no typed shed within 60s: {stats_stalled}"
    assert stats_stalled["flush_errors"] >= 1, stats_stalled
    assert stats_stalled["stalled"], stats_stalled

    store.faults = None                 # heal
    drained = pipe.drain(timeout=60.0)
    stats_healed = pipe.stats()
    pipe.close()
    assert drained, stats_healed
    rows = _table_rows(store, "Comment")        # asserts no duplicate keys
    landed = sum(1 for k in rows if k > base * 10 + 3)
    assert landed == admitted, (landed, admitted)
    eng.close()

    row = {
        "max_queue": max_queue,
        "events_admitted": admitted,
        "typed_sheds": shed,
        "time_to_shed_s": t_shed,
        "flush_errors_while_stalled": stats_stalled["flush_errors"],
        "backpressure_trips": stats_healed["backpressure_trips"],
        "rows_landed_after_heal": landed,
    }
    emit("freshness_backpressure_shed_s", (t_shed or 0.0) * 1e3,
         f"admitted={admitted};flush_errors={row['flush_errors_while_stalled']};"
         f"landed={landed}")
    return {"row": row, "wall_s": time.perf_counter() - t0}


def _write_snapshot(snap: dict) -> None:
    with open(SNAPSHOT_PATH, "w") as f:
        json.dump(snap, f, indent=2)
    emit("freshness_snapshot", 0.0, SNAPSHOT_PATH)


def run(quick: bool = False) -> None:
    sweep = freshness_sweep(
        sf=0.004 if quick else 0.01,
        n_events=300 if quick else 1500,
        n_query_threads=2 if quick else 4,
    )
    parity = oracle_parity(sweep)
    stall = backpressure_under_stall()
    _write_snapshot({
        "freshness_sweep": {"rows": [sweep["row"]], "wall_s": sweep["wall_s"]},
        "oracle_parity": {"rows": [parity["row"]], "wall_s": parity["wall_s"]},
        "backpressure_under_stall": {"rows": [stall["row"]],
                                     "wall_s": stall["wall_s"]},
    })


if __name__ == "__main__":
    run()
