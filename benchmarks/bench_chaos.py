"""Chaos benchmark: success rate and p99 inflation vs injected fault rate
(DESIGN.md §11).

Runs the same cold-cache scan query against one LDBC lake through store
handles with increasing seeded transient-fault schedules (``transient_chaos``:
transient errors at the rate, torn reads at rate/2, 10x latency spikes at
2x rate, all on ``tables/`` reads), under a small modeled store latency so
spikes and backoff register in wall time.  The cache is dropped between
requests so every request re-reads the lake — faults keep firing for the
whole run instead of only during warmup.

Floors asserted (the ISSUE 8 acceptance bar):

- **100% success** at every swept rate (5-10% transient): retries + typed
  classification absorb every injected fault, zero user-visible failures;
- **bit-parity**: every request's result ids match the fault-free run;
- **bounded p99 inflation**: p99 at the highest rate stays under
  ``max_p99_inflation`` x the fault-free p99 (plus a small absolute grace
  for timer noise) — backoff is bounded, not a meltdown;
- the injector actually fired (a dead injector cannot silently pass).

Snapshot written to ``BENCH_chaos.json`` (override with
``REPRO_BENCH_CHAOS_SNAPSHOT``); ``run(quick=True)`` is the CI-gate mode.
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import BENCH_ROOT, emit, fresh_store, make_engine
from repro.data.ldbc import generate_ldbc, ldbc_graph_schema
from repro.gsql.session import GraphSession
from repro.lakehouse.faults import transient_chaos
from repro.lakehouse.objectstore import ObjectStore, StoreConfig
from repro.lakehouse.retry import default_policy

SNAPSHOT_PATH = os.environ.get("REPRO_BENCH_CHAOS_SNAPSHOT", "BENCH_chaos.json")

QUERY = ("SELECT c FROM Tag:t -(HasTag:e)- Comment:c "
         "WHERE t.name == $tag")
# real LDBC tag names (data/ldbc.py _TAG_NAMES) so every request's result
# set is non-empty and parity-under-faults is asserted on real ids
TAGS = ("Music", "Sports", "Politics", "Movies",
        "Science", "Travel", "Food", "Art")


def _chaos_handle(root: str, rate: float, seed: int) -> ObjectStore:
    """A store handle over the shared lake bytes: seeded faults on tables/
    plus a small modeled latency so spikes/backoff show up in wall time."""
    return ObjectStore(StoreConfig(
        root=root,
        request_latency_s=0.0003,
        latency_scale=1.0,
        faults=transient_chaos(rate, seed=seed) if rate > 0 else None,
    ))


def _pct(lats: list, q: float) -> float:
    s = sorted(lats)
    return s[min(len(s) - 1, int(q * len(s)))]


def chaos_sweep(
    sf: float = 0.004,
    rates: tuple = (0.0, 0.05, 0.10),
    n_requests: int = 30,
    seed: int = 11,
    max_p99_inflation: float = 25.0,
) -> dict:
    root = os.path.join(BENCH_ROOT, "chaos")
    gen_store = fresh_store("chaos")
    generate_ldbc(gen_store, scale_factor=sf, n_files=2, row_group_rows=256)
    t0 = time.perf_counter()

    rows = []
    baseline_ids = None
    baseline_p99 = None
    for rate in rates:
        store = _chaos_handle(root, rate, seed)
        retry_before = default_policy().snapshot()
        eng = make_engine(store, ldbc_graph_schema(), materialize=False,
                          prefetch=False)
        eng.startup()
        session = GraphSession(eng)
        session.install("scan", QUERY)
        lats, failures, ids = [], 0, None
        try:
            for i in range(n_requests):
                eng.cache.drop_all()   # cold lake read every request
                t1 = time.perf_counter()
                try:
                    res = session.query("scan", tag=TAGS[i % len(TAGS)])
                    got = res.vset.ids()
                except Exception as e:   # a user-visible failure
                    failures += 1
                    emit("chaos_request_failed", 0.0,
                         f"rate={rate};{type(e).__name__}: {e}")
                    continue
                finally:
                    lats.append(time.perf_counter() - t1)
                if i == 0:
                    ids = np.array(got)
        finally:
            eng.close()
        retry_after = default_policy().snapshot()
        retries = retry_after["retries"] - retry_before["retries"]
        fault_snap = store.faults.snapshot() if store.faults else {}
        success_rate = (n_requests - failures) / n_requests
        p50, p99 = _pct(lats, 0.50), _pct(lats, 0.99)
        row = {
            "rate": rate,
            "n_requests": n_requests,
            "success_rate": success_rate,
            "p50_s": p50,
            "p99_s": p99,
            "retries": retries,
            "giveups": retry_after["giveups"] - retry_before["giveups"],
            "faults": fault_snap,
        }
        rows.append(row)
        emit(f"chaos_rate_{rate:g}_p99_ms", p99 * 1e3,
             f"success={success_rate:.3f};retries={retries};"
             f"fired={sum(fault_snap.get(c, 0) for c in ('transient', 'torn', 'spike', 'missing'))}")

        # -- floors ----------------------------------------------------------
        assert success_rate == 1.0, (
            f"user-visible failures at rate {rate}: {row}")
        if rate == 0.0:
            assert ids is not None and ids.size > 0, (
                "fault-free scan returned no ids — parity would be vacuous")
            baseline_ids = ids
            baseline_p99 = p99
        else:
            assert np.array_equal(ids, baseline_ids), (
                f"result drift under faults at rate {rate}")
            assert store.faults.fired("transient") > 0, (
                "injector never fired — the sweep tested nothing")
            assert retries > 0, "faults fired but no retry ever ran"
            assert p99 <= max_p99_inflation * baseline_p99 + 0.25, (
                f"p99 inflation unbounded at rate {rate}: "
                f"{p99:.3f}s vs fault-free {baseline_p99:.3f}s")

    return {
        "bench": "chaos_success_and_p99_vs_fault_rate",
        "wall_s": time.perf_counter() - t0,
        "seed": seed,
        "rows": rows,
    }


def _write_snapshot(snap: dict) -> None:
    with open(SNAPSHOT_PATH, "w") as f:
        json.dump(snap, f, indent=2)
    emit("chaos_snapshot", 0.0, SNAPSHOT_PATH)


def run(quick: bool = False) -> None:
    snap = {"chaos_sweep": chaos_sweep(
        sf=0.004 if quick else 0.01,
        n_requests=20 if quick else 60,
    )}
    _write_snapshot(snap)


if __name__ == "__main__":
    run()
