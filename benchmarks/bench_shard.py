"""Shard fabric throughput: scatter-gather BI suite across 1/2/4 shards
(DESIGN.md §13).

Each arm connects to the *same* LDBC lake — the 1-shard arm is the plain
single engine, the 2/4-shard arms attach a :class:`ShardFabric` — and runs
the whole BI suite with **cold caches per pass** under the modeled
object-store latency.  What scales is per-worker I/O capacity: every shard
worker owns its vertex-slice cache and I/O pool (block-hash ownership
matches the lake's row-group granularity, so frontier-side reads are
chunk-disjoint across workers), edge chunks and far-side boundary columns
dedup through the coordinator's shared single-flight cache, and worker
legs overlap their chunk fetches where the single engine is bounded by one
pool.

Asserts, per the ISSUE 10 acceptance bar:

- every sharded result is **bit-identical** to the 1-shard arm (vset,
  accumulators, frame rows in global edge order);
- 4-shard suite throughput >= ``min_speedup`` (1.5x) over the single
  engine.

Snapshot written to ``BENCH_shard.json`` (override with
``REPRO_BENCH_SHARD_SNAPSHOT``); ``run(quick=True)`` is the CI-gate mode.
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import emit, fresh_store
from repro.core.bi_queries import BI_GSQL, install_bi_queries
from repro.core.cache.manager import CacheManager
from repro.data.ldbc import generate_ldbc, ldbc_graph_schema
from repro.gsql.session import connect
from repro.lakehouse.objectstore import ObjectStore, StoreConfig

SNAPSHOT_PATH = os.environ.get("REPRO_BENCH_SHARD_SNAPSHOT", "BENCH_shard.json")

BI_PARAMS = {
    "bi1": {"tag": "Music", "date": 20100101},
    "bi2": {"lo": 20120101, "hi": 20151231},
    "bi3": {"min_len": 50},
    "bi4": {"city": "city_1"},
    "bi5": {"min_degree": 3, "date": 20100101},
}

# the lake below commits 512-row groups; 2**9-row ownership blocks keep a
# shard's vertex reads chunk-local (one block == one row group)
ROW_GROUP_ROWS = 512
BLOCK_BITS = 9


def _assert_parity(a, b, label) -> None:
    assert a.n_edges_scanned == b.n_edges_scanned, label
    assert np.array_equal(a.vset.ids(), b.vset.ids()), label
    for k in a.accumulators:
        assert np.array_equal(a.accumulators[k], b.accumulators[k]), (label, k)
    for fa, fb in zip(a.frames, b.frames):
        assert np.array_equal(fa.u, fb.u) and np.array_equal(fa.v, fb.v), label
        if fa.eid is not None and fb.eid is not None:
            assert np.array_equal(fa.eid, fb.eid), label
        for k in fa.columns:
            assert np.array_equal(fa.columns[k], fb.columns[k]), (label, k)


def _chill(session) -> None:
    """Cold caches for the next pass: the coordinator's manager and every
    shard worker's (each worker owns its own, DESIGN.md §13)."""
    eng = session.engine
    eng.cache = CacheManager(eng.store, None)
    fabric = eng._shard_fabric
    if fabric is not None:
        for worker in fabric.workers.values():
            worker.reset_cache()


def _suite(session) -> dict:
    return {name: session.query(name, **BI_PARAMS[name]) for name in BI_GSQL}


def shard_sweep(
    sf: float = 0.02,
    latency_scale: float = 1.0,
    passes: int = 3,
    min_speedup: float = 1.5,
    arms: tuple = (1, 2, 4),
) -> dict:
    # generate with the latency model off; only measured passes pay it
    store = fresh_store("shard", latency_scale=0.0)
    generate_ldbc(store, scale_factor=sf, n_files=4,
                  row_group_rows=ROW_GROUP_ROWS)
    root = store.config.root

    results = {}
    out = {"sf": sf, "latency_scale": latency_scale, "passes": passes,
           "n_queries": len(BI_GSQL), "arms": {}}
    for n in arms:
        handle = ObjectStore(StoreConfig(root=root))
        session = connect(handle, ldbc_graph_schema(),
                          shards=n if n >= 2 else None,
                          shard_block_bits=BLOCK_BITS,
                          enable_prefetch=False)
        install_bi_queries(session)
        try:
            results[n] = _suite(session)      # warm correctness pass
            handle.config.latency_scale = latency_scale
            walls = []
            for _ in range(passes):
                _chill(session)
                t0 = time.perf_counter()
                _suite(session)
                walls.append(time.perf_counter() - t0)
            handle.config.latency_scale = 0.0
            fabric = session.engine._shard_fabric
            arm = {
                "wall_s": min(walls),
                "queries_per_s": len(BI_GSQL) / min(walls),
                "get_requests": handle.counters["get_requests"],
            }
            if fabric is not None:
                snap = fabric.stats_snapshot()
                arm["scatter_gathers"] = snap["scatter_gathers"]
                arm["worker_scans"] = snap["worker_scans"]
                arm["shard_csr_blobs"] = snap["shard_csr_blobs"]
            out["arms"][str(n)] = arm
        finally:
            session.close()

    # bit-parity: every sharded arm reproduces the single engine exactly
    for n in arms:
        if n == 1:
            continue
        for name in BI_GSQL:
            _assert_parity(results[1][name], results[n][name],
                           (n, name))
    out["parity"] = "bit-identical"

    base = out["arms"]["1"]["queries_per_s"]
    for n in arms:
        out["arms"][str(n)]["speedup"] = out["arms"][str(n)][
            "queries_per_s"] / base
    top = max(n for n in arms if n >= 2)
    speedup = out["arms"][str(top)]["speedup"]
    emit(f"shard_suite_x{top}",
         out["arms"][str(top)]["wall_s"] * 1e6 / len(BI_GSQL),
         {"speedup_vs_single": round(speedup, 3),
          "single_qps": round(base, 3),
          "sharded_qps": round(out["arms"][str(top)]["queries_per_s"], 3)})
    assert speedup >= min_speedup, (
        f"{top}-shard fabric {speedup:.2f}x < required {min_speedup}x "
        f"suite throughput over the single engine")
    out["min_speedup"] = min_speedup
    return out


def run(quick: bool = False) -> None:
    if quick:
        snap = shard_sweep(sf=0.012, latency_scale=1.0, passes=2)
    else:
        snap = shard_sweep()
    with open(SNAPSHOT_PATH, "w") as f:
        json.dump(snap, f, indent=2, sort_keys=True)
    print(f"wrote {SNAPSHOT_PATH}")


if __name__ == "__main__":
    run()
