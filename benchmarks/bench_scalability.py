"""Paper Fig. 12/13/14: scalability — throughput vs scale factor (single
node), startup vs node count, and query throughput vs node count (the
partitioned DistributedGraphLake with its two-pass EdgeScan)."""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, fresh_store, ldbc_lake, make_engine, timed
from repro.core.bi_queries import BI_QUERIES
from repro.core.distributed import DistributedGraphLake
from repro.data.ldbc import generate_ldbc, ldbc_graph_schema
from repro.serving.server import QueryServer, ServerConfig


def run() -> None:
    # --- Fig 12: single-node throughput vs scale factor -----------------------
    for sf in (0.002, 0.008, 0.03):
        store, schema = ldbc_lake(f"scal_sf{sf}", sf)
        eng = make_engine(store, schema)
        eng.startup()
        BI_QUERIES["bi1"](eng)  # warm
        t0 = time.perf_counter()
        n = 6
        for i in range(n):
            BI_QUERIES["bi1"](eng, date=20090101 + i)
        thr = n / (time.perf_counter() - t0)
        emit(f"fig12_bi1_sf{sf}_qps", 1e6 / max(thr, 1e-9),
             f"throughput={thr:.2f}q/s;edges={eng.topology.n_edges()}")
        eng.close()

    # --- Fig 13: startup scaling with partitions (distributed build) ----------
    store, schema = ldbc_lake("scal_dist", 0.02, n_files=8)
    single = make_engine(store, schema, materialize=False)
    _, t1 = timed(single.startup)
    single.close()
    emit("fig13_startup_1node_s", t1 * 1e6, "")
    for p in (2, 4):
        dist = DistributedGraphLake(store, ldbc_graph_schema(), n_partitions=p)
        _, tp = timed(dist.startup)
        dist.close()
        emit(f"fig13_startup_{p}node_s", tp * 1e6,
             f"scaling={t1 / tp:.2f}x")

    # --- Fig 14: distributed query throughput ---------------------------------
    for p in (1, 2, 4):
        dist = DistributedGraphLake(store, ldbc_graph_schema(), n_partitions=p)
        dist.startup()
        frontier = dist.engines[0].all_vertices("Comment")

        def q():
            return dist.edge_scan_accumulate(
                frontier, "HasCreator", "out",
                edge_columns=["creationDate"],
                v_columns=["gender"],
                edge_filter=lambda fr: fr["e.creationDate"] > 20150101,
                v_filter=lambda fr: np.asarray(
                    [g == "Female" for g in fr["v.gender"]]),
            )

        q()  # warm
        _, tq = timed(q, repeats=2)
        emit(f"fig14_twopass_query_{p}node_us", tq * 1e6,
             f"net_requests={dist.net.requests};"
             f"rows_shipped={dist.net.vertex_rows_shipped}")
        dist.close()
