"""Paper Fig. 16: graph-aware cache units (decoded value arrays) vs naive
column chunks (re-decode per access) across vertex-access selectivities."""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, ldbc_lake, make_engine, timed
from repro.core.types import VSet


def run(sf: float = 0.02) -> None:
    store, schema = ldbc_lake("fig16", sf)

    for mode, naive in (("graph_aware", False), ("naive", True)):
        eng = make_engine(store, schema, naive=naive)
        eng.startup()
        n = eng.topology.n_vertices("Comment")
        rng = np.random.default_rng(1)
        for sel in (0.001, 0.01, 0.1):
            ids = rng.choice(eng.topology.n_real_vertices("Comment"),
                             size=max(1, int(n * sel)), replace=False)
            vset = VSet.from_dense_ids("Comment", n, ids)

            def q():
                out, _ = eng.vertex_map(
                    vset, columns=["length"],
                    filter_fn=lambda fr: fr["length"] > 1000,
                )
                return out

            q()  # admit cache units
            _, t = timed(q, repeats=3)
            decode_ops = sum(
                getattr(u, "decode_ops", 0)
                for u in eng.cache._units.values())
            emit(f"fig16_{mode}_sel{sel}_us", t * 1e6,
                 f"decode_ops={decode_ops}")
        eng.close()
