"""Deliverable (g): emit the roofline table from the dry-run artifacts."""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")
OPT_DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun_opt")


def load_records(directory: str = DRYRUN_DIR) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(directory, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
            rec["_file"] = os.path.basename(path)
            recs.append(rec)
    return recs


def run() -> None:
    recs = load_records()
    if not recs:
        emit("roofline_no_dryrun_results", 0.0, "run repro.launch.dryrun first")
        return
    n_ok = n_skip = n_err = 0
    for rec in recs:
        if rec["status"] == "skipped":
            n_skip += 1
            continue
        if rec["status"] != "ok":
            n_err += 1
            emit(f"roofline_ERROR_{rec['arch']}_{rec['cell']}_{rec['mesh']}",
                 0.0, rec.get("error", "")[:80])
            continue
        n_ok += 1
        r = rec["roofline"]
        emit(
            f"roofline_{rec['arch']}_{rec['cell']}_{rec['mesh']}",
            r["compute_s"] * 1e6,
            f"dom={r['dominant']};mem_s={r['memory_s']:.3e};"
            f"coll_s={r['collective_s']:.3e};"
            f"useful={r['useful_flop_fraction']:.3f};"
            f"roofline_frac={r['roofline_fraction']:.4f};"
            f"perdev_gb={rec['per_device_bytes']/1e9:.2f};"
            f"fits={rec['fits_hbm']}",
        )
    emit("roofline_summary", 0.0, f"ok={n_ok};skipped={n_skip};errors={n_err}")
    # perf-variant records (EXPERIMENTS.md §Perf before/after)
    for rec in load_records(OPT_DIR):
        if rec["status"] != "ok":
            continue
        r = rec["roofline"]
        variant = rec["_file"].rsplit("__", 1)[-1].replace(".json", "")
        emit(
            f"perf_{rec['arch']}_{rec['cell']}_{variant}",
            r["compute_s"] * 1e6,
            f"dom={r['dominant']};mem_s={r['memory_s']:.3e};"
            f"coll_s={r['collective_s']:.3e};"
            f"roofline_frac={r['roofline_fraction']:.4f}",
        )
