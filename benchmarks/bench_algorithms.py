"""Paper Table 2: graph algorithms (PR, WCC, CDLP, LCC, BFS) on a
Graph500-style RMAT graph."""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, graph500_lake, make_engine, timed
from repro.core.algorithms import bfs, cdlp, lcc, pagerank, wcc


def run(scale: int = 12) -> None:
    store, schema = graph500_lake("graph500", scale)
    eng = make_engine(store, schema)
    eng.startup()
    n = eng.topology.n_vertices("Node")
    n_edges = eng.topology.n_edges("Edge")

    _, t = timed(pagerank, eng, "Edge", max_iters=20, repeats=2)
    emit("table2_pagerank_us", t * 1e6, f"n={n};m={n_edges};iters=20")

    _, t = timed(wcc, eng, "Edge", repeats=2)
    emit("table2_wcc_us", t * 1e6, "")

    _, t = timed(cdlp, eng, "Edge", iterations=10)
    emit("table2_cdlp_us", t * 1e6, "iters=10")

    _, t = timed(lcc, eng, "Edge")
    emit("table2_lcc_us", t * 1e6, "")

    src, _ = eng.concat_edges("Edge")
    _, t = timed(bfs, eng, "Edge", int(src[0]), repeats=2)
    emit("table2_bfs_us", t * 1e6, "")
    eng.close()
