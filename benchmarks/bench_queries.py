"""Paper Fig. 10/11: BI query time — hot vs disk-cold vs S3-cold, GraphLake
vs the in-situ naive baseline (PuppyGraph-style: no decoded cache, no
prefetch, no materialized topology).

Plus two sweeps snapshotted into ``BENCH_queries.json`` (override the path
with ``REPRO_BENCH_SNAPSHOT``) so the perf trajectory is tracked PR over PR:

- the predicate-pushdown selectivity sweep (DESIGN.md §4): one selective hop
  run at several edge-predicate selectivities, pushdown on vs off, with
  bit-identical-result verification and the zone-map pruning counters
  (chunks skipped, rows/bytes decoded);
- the chunk-pipeline sweep (DESIGN.md §5): the same hop under the *enabled*
  object-store latency model (``latency_scale>0``), sequential vs pipelined
  read path, reporting wall times, speedup and overlap efficiency (fraction
  of the I/O pool's worker-seconds spent inside modeled store waits) — with
  bit-identical-result verification and a floor assertion on the speedup;
- the GSQL parity sweep (DESIGN.md §8): representative queries run through
  both front ends — fluent builder chains and GSQL text via the session —
  asserting bit-identical results (vset, frames, accumulators) and that
  parse+compile costs at most 5% of a cold execution.

``run(quick=True)`` is the CI gate mode — sweeps only, small scale.
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import emit, fresh_store, ldbc_lake, make_engine, timed
from repro.core.bi_queries import BI_QUERIES
from repro.core.query import ExecOptions, Query, accum_sum, eq, gt
from repro.data.ldbc import generate_ldbc, ldbc_graph_schema

SNAPSHOT_PATH = os.environ.get("REPRO_BENCH_SNAPSHOT", "BENCH_queries.json")


def _fig10(sf: float) -> None:
    store, schema = ldbc_lake("queries", sf)

    # --- GraphLake engine ------------------------------------------------------
    eng = make_engine(store, schema)
    eng.startup()
    for name, fn in BI_QUERIES.items():
        # cold: empty cache tiers
        eng.cache.drop_all()
        _, t_cold = timed(fn, eng)
        # disk-cold: encoded chunks on local disk, decoded state gone
        eng.cache.drop_memory()
        _, t_disk = timed(fn, eng)
        # hot: everything warmed
        _, t_hot = timed(fn, eng, repeats=3)
        emit(f"fig10_{name}_hot_us", t_hot * 1e6,
             f"cold={t_cold*1e6:.0f}us;disk={t_disk*1e6:.0f}us")
    gl_stats = dict(eng.cache.stats)
    eng.close()

    # --- naive in-situ baseline --------------------------------------------------
    naive = make_engine(store, schema, naive=True, prefetch=False,
                        materialize=False)
    naive.startup()
    for name, fn in BI_QUERIES.items():
        naive.cache.drop_memory()
        _, t_naive = timed(fn, naive)
        emit(f"fig10_{name}_naive_us", t_naive * 1e6, "")
    naive.close()
    emit("fig10_cache_stats", 0.0,
         f"hits={gl_stats['hits']};misses={gl_stats['misses']};"
         f"lake_fetches={gl_stats['lake_fetches']}")


def _assert_parity(a, b) -> None:
    assert a.n_edges_scanned == b.n_edges_scanned
    assert np.array_equal(a.vset.ids(), b.vset.ids())
    for fa, fb in zip(a.frames, b.frames):
        assert np.array_equal(fa.u, fb.u) and np.array_equal(fa.v, fb.v)
        assert set(fa.columns) == set(fb.columns)
        for k in fa.columns:
            assert np.array_equal(fa.columns[k], fb.columns[k]), k


def selectivity_sweep(sf: float = 0.02, row_group_rows: int = 512) -> dict:
    """Pushdown-vs-baseline sweep over edge-predicate selectivity.

    A one-hop Comment -[HasCreator]-> Person scan with a ``creationDate``
    range predicate; thresholds are data quantiles so each point keeps a
    known row fraction.  Every point verifies bit-identical results and
    reports the pruning counters; the selective points are where zone maps
    must shine (chunks_skipped > 0, fewer rows decoded).
    """
    store = fresh_store(f"queries_sweep_{sf}")
    generate_ldbc(store, scale_factor=sf, n_files=2, row_group_rows=row_group_rows)
    eng = make_engine(store, ldbc_graph_schema())
    eng.startup()

    # data quantiles of the predicate column -> exact target selectivities
    comments = eng.all_vertices("Comment")
    dates = eng.read_vertex_column("Comment", comments.ids(), "creationDate")
    rows = []
    t0 = time.perf_counter()
    for keep_frac in (0.5, 0.1, 0.01):
        thr = float(np.quantile(dates, 1.0 - keep_frac))
        q = (Query(eng)
             .vertices("Comment")
             .hop("HasCreator", direction="out",
                  edge_where=gt("creationDate", thr)))
        eng.cache.drop_all()
        res_off, t_off = timed(q.run, ExecOptions(pushdown=False))
        eng.cache.drop_all()
        res_on, t_on = timed(q.run, ExecOptions(pushdown=True))
        _assert_parity(res_off, res_on)
        row = {
            "keep_frac": keep_frac,
            "n_survivors": int(res_on.n_edges_scanned),
            "pushdown_us": t_on * 1e6,
            "baseline_us": t_off * 1e6,
            "chunks_skipped": res_on.pruning["chunks_skipped"],
            "chunks_read": res_on.pruning["chunks_read"],
            "rows_decoded": res_on.pruning["rows_decoded"],
            "rows_decoded_baseline": res_off.pruning["rows_decoded"],
            "bytes_read": res_on.pruning["bytes_read"],
            "bytes_read_baseline": res_off.pruning["bytes_read"],
            "bytes_skipped": res_on.pruning["bytes_skipped"],
        }
        rows.append(row)
        emit(f"sweep_keep{keep_frac}_pushdown_us", row["pushdown_us"],
             f"baseline={row['baseline_us']:.0f}us;"
             f"chunks_skipped={row['chunks_skipped']};"
             f"rows_decoded={row['rows_decoded']}/{row['rows_decoded_baseline']};"
             f"bytes_read={row['bytes_read']}/{row['bytes_read_baseline']}")

    # acceptance invariant: a selective hop (<=10% kept) must actually prune
    selective = [r for r in rows if r["keep_frac"] <= 0.1]
    assert all(r["chunks_skipped"] > 0 for r in selective), rows
    assert all(r["rows_decoded"] < r["rows_decoded_baseline"] for r in selective), rows
    eng.close()

    return {
        "bench": "queries_selectivity_sweep",
        "sf": sf,
        "row_group_rows": row_group_rows,
        "wall_s": time.perf_counter() - t0,
        "rows": rows,
    }


def pipeline_sweep(
    sf: float = 0.02,
    row_group_rows: int = 512,
    latency_scale: float = 1.0,
    keep_frac: float = 0.1,
    min_speedup: float = 3.0,
) -> dict:
    """Sequential-vs-pipelined read path under the modeled store latency.

    One 10%-selectivity Comment -[HasCreator]-> Person hop, run cold twice:
    ``pipeline=False`` fetches+decodes each surviving chunk serially on the
    caller thread (every chunk pays the full modeled first-byte latency);
    ``pipeline=True`` batches the gather's fetch plan through the engine's
    shared IOPool (DESIGN.md §5).  Prefetch is disabled in both arms so the
    measurement isolates the read-path pipelining itself.  Results must be
    bit-identical; the pipelined arm must beat the sequential arm by
    ``min_speedup`` (the ISSUE 3 acceptance floor).
    """
    store = fresh_store(f"queries_pipe_{sf}_{row_group_rows}")
    generate_ldbc(store, scale_factor=sf, n_files=2,
                  row_group_rows=row_group_rows)
    # 16 I/O threads: the modeled store charges first-byte latency per
    # request (it overlaps, like real S3) and divides bandwidth statically,
    # so more streams legitimately hide more latency
    eng = make_engine(store, ldbc_graph_schema(), prefetch=False,
                      n_io_threads=16)
    eng.startup()
    n_io_threads = eng.pool.n_threads
    t0 = time.perf_counter()

    comments = eng.all_vertices("Comment")
    dates = eng.read_vertex_column("Comment", comments.ids(), "creationDate")
    thr = float(np.quantile(dates, 1.0 - keep_frac))
    q = (Query(eng)
         .vertices("Comment")
         .hop("HasCreator", direction="out",
              edge_where=gt("creationDate", thr)))

    # startup/generation ran latency-free; queries now pay the modeled store
    store.config.latency_scale = latency_scale

    def arm(pipelined: bool, repeats: int = 3):
        # best-of-N *cold* runs: the pipelined arm's wall time is sensitive
        # to thread wake-up jitter (its whole point is concurrent sleeps in
        # the latency model), and min() is the jitter-robust estimator
        best = float("inf")
        res = io_s = None
        for _ in range(repeats):
            eng.cache.drop_all()
            store.reset_counters()
            r, wall = timed(q.run, ExecOptions(pipeline=pipelined))
            if wall < best:
                best, res, io_s = wall, r, store.counters["simulated_wait_s"]
        return res, best, io_s

    res_seq, t_seq, io_seq = arm(False)
    res_pipe, t_pipe, io_pipe = arm(True)
    store.config.latency_scale = 0.0
    _assert_parity(res_seq, res_pipe)

    speedup = t_seq / t_pipe
    # fraction of the pool's worker-seconds spent inside modeled store waits
    # during the pipelined run: 1.0 would mean every I/O thread was waiting
    # on the store for the whole query — perfect fetch/decode/compute overlap
    overlap_efficiency = io_pipe / (n_io_threads * t_pipe)
    row = {
        "keep_frac": keep_frac,
        "latency_scale": latency_scale,
        "n_io_threads": n_io_threads,
        "chunks_read": res_pipe.pruning["chunks_read"],
        "sequential_s": t_seq,
        "pipelined_s": t_pipe,
        "speedup": speedup,
        "io_wait_sequential_s": io_seq,
        "io_wait_pipelined_s": io_pipe,
        "overlap_efficiency": overlap_efficiency,
    }
    emit("pipe_sequential_ms", t_seq * 1e3,
         f"pipelined={t_pipe*1e3:.0f}ms;speedup={speedup:.1f}x;"
         f"overlap_eff={overlap_efficiency:.2f};"
         f"chunks={row['chunks_read']}")
    assert speedup >= min_speedup, (
        f"pipelined read path only {speedup:.2f}x over sequential "
        f"(floor {min_speedup}x): {row}")
    eng.close()

    return {
        "bench": "queries_pipeline_sweep",
        "sf": sf,
        "row_group_rows": row_group_rows,
        "wall_s": time.perf_counter() - t0,
        "rows": [row],
    }


def gsql_parity_sweep(sf: float = 0.004, row_group_rows: int = 512,
                      max_compile_frac: float = 0.05) -> dict:
    """Builder-vs-GSQL parity: the ISSUE 5 acceptance sweep.

    Each case pairs a fluent-builder chain with the equivalent GSQL text and
    asserts the two front ends produce **bit-identical** results — vset,
    every frame column, accumulator arrays — plus a compile-overhead bound:
    parse+compile (median) must cost at most ``max_compile_frac`` of one
    cold execution, i.e. the textual front end is free at serving
    granularity.
    """
    from repro.gsql.compiler import compile_query
    from repro.gsql.parser import parse
    from repro.gsql.session import GraphSession

    store = fresh_store(f"queries_gsql_{sf}")
    generate_ldbc(store, scale_factor=sf, n_files=2,
                  row_group_rows=row_group_rows)
    eng = make_engine(store, ldbc_graph_schema())
    eng.startup()
    session = GraphSession.for_engine(eng)
    t0 = time.perf_counter()

    comments = eng.all_vertices("Comment")
    dates = eng.read_vertex_column("Comment", comments.ids(), "creationDate")
    thr = float(np.quantile(dates, 0.9))

    cases = [
        ("hop_edge_pred",
         lambda: (Query(eng).vertices("Comment")
                  .hop("HasCreator", direction="out",
                       edge_where=gt("creationDate", thr))),
         "SELECT p FROM Comment:c -(HasCreator:e)- Person:p "
         "WHERE e.creationDate > $thr",
         {"thr": thr}),
        ("seed_2hop_accum",
         lambda: (Query(eng).vertices("Tag", where=eq("name", "Music"))
                  .hop("HasTag", direction="in")
                  .hop("HasCreator", direction="out",
                       edge_where=gt("creationDate", 20100101),
                       target_where=eq("gender", "Female"),
                       accum=accum_sum("cnt", 1.0))),
         "SELECT p FROM Tag:t -(HasTag:e1)- Comment:c -(HasCreator:e2)- Person:p "
         "WHERE t.name == $tag AND e2.creationDate > $date "
         "AND p.gender == 'Female' ACCUM p.@cnt += 1",
         {"tag": "Music", "date": 20100101}),
    ]

    rows = []
    for name, build, text, params in cases:
        # builder arm (cold), accumulators snapshotted before the GSQL arm
        # re-runs (both arms share the engine's accumulator store)
        for key in list(eng.accums._arrays):
            eng.accums.reset(*key)
        eng.cache.drop_all()
        res_b, t_builder = timed(build().run)
        accums_b = {k: np.array(v) for k, v in res_b.accumulators.items()}

        eng.cache.drop_all()
        res_g, t_gsql = timed(session.query, text, **params)
        _assert_parity(res_b, res_g)
        assert set(accums_b) == set(res_g.accumulators)
        for k, arr in accums_b.items():
            assert np.array_equal(arr, res_g.accumulators[k]), k

        compiles = []
        for _ in range(25):
            c0 = time.perf_counter()
            compile_query(parse(text), session.catalog(), params)
            compiles.append(time.perf_counter() - c0)
        t_compile = float(np.median(compiles))
        frac = t_compile / t_gsql
        row = {
            "case": name,
            "n_survivors": int(res_g.n_edges_scanned),
            "builder_us": t_builder * 1e6,
            "gsql_us": t_gsql * 1e6,
            "compile_us": t_compile * 1e6,
            "compile_frac_of_cold_exec": frac,
        }
        rows.append(row)
        emit(f"gsql_{name}_compile_us", row["compile_us"],
             f"gsql={row['gsql_us']:.0f}us;builder={row['builder_us']:.0f}us;"
             f"compile_frac={frac:.4f}")
        assert frac <= max_compile_frac, (
            f"GSQL compile overhead {frac:.1%} exceeds "
            f"{max_compile_frac:.0%} of a cold execution: {row}")
    eng.close()

    return {
        "bench": "queries_gsql_parity_sweep",
        "sf": sf,
        "row_group_rows": row_group_rows,
        "max_compile_frac": max_compile_frac,
        "wall_s": time.perf_counter() - t0,
        "rows": rows,
    }


def _write_snapshot(snap: dict) -> None:
    with open(SNAPSHOT_PATH, "w") as f:
        json.dump(snap, f, indent=2)
    emit("sweep_snapshot", 0.0, SNAPSHOT_PATH)


def run(sf: float = 0.02, quick: bool = False) -> None:
    snap = {}
    if quick:
        snap["selectivity_sweep"] = selectivity_sweep(sf=0.004)
        snap["pipeline_sweep"] = pipeline_sweep()
        # compile cost is a ~constant ~120us while a cold exec shrinks with
        # the lake: at the quick-mode sf=0.004 scale the 5% bound sits right
        # on the measured ratio and flakes with machine load, so quick mode
        # relaxes it; the full run keeps the tight bound at real scale
        snap["gsql_parity_sweep"] = gsql_parity_sweep(max_compile_frac=0.10)
    else:
        _fig10(sf)
        snap["selectivity_sweep"] = selectivity_sweep(sf=sf)
        # the pipeline sweep runs at its tuned operating point regardless of
        # ``sf``: larger lakes grow the CPU share (gather + predicate eval)
        # faster than the I/O share, which measures overlap less cleanly
        snap["pipeline_sweep"] = pipeline_sweep()
        snap["gsql_parity_sweep"] = gsql_parity_sweep(sf=sf)
    _write_snapshot(snap)
