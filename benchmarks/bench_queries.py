"""Paper Fig. 10/11: BI query time — hot vs disk-cold vs S3-cold, GraphLake
vs the in-situ naive baseline (PuppyGraph-style: no decoded cache, no
prefetch, no materialized topology)."""

from __future__ import annotations

from benchmarks.common import emit, ldbc_lake, make_engine, timed
from repro.core.bi_queries import BI_QUERIES


def run(sf: float = 0.02) -> None:
    store, schema = ldbc_lake("queries", sf)

    # --- GraphLake engine ------------------------------------------------------
    eng = make_engine(store, schema)
    eng.startup()
    for name, fn in BI_QUERIES.items():
        # cold: empty cache tiers
        eng.cache.drop_all()
        _, t_cold = timed(fn, eng)
        # disk-cold: encoded chunks on local disk, decoded state gone
        eng.cache.drop_memory()
        _, t_disk = timed(fn, eng)
        # hot: everything warmed
        _, t_hot = timed(fn, eng, repeats=3)
        emit(f"fig10_{name}_hot_us", t_hot * 1e6,
             f"cold={t_cold*1e6:.0f}us;disk={t_disk*1e6:.0f}us")
    gl_stats = dict(eng.cache.stats)
    eng.close()

    # --- naive in-situ baseline --------------------------------------------------
    naive = make_engine(store, schema, naive=True, prefetch=False,
                        materialize=False)
    naive.startup()
    for name, fn in BI_QUERIES.items():
        naive.cache.drop_memory()
        _, t_naive = timed(fn, naive)
        emit(f"fig10_{name}_naive_us", t_naive * 1e6, "")
    naive.close()
    emit("fig10_cache_stats", 0.0,
         f"hits={gl_stats['hits']};misses={gl_stats['misses']};"
         f"lake_fetches={gl_stats['lake_fetches']}")
