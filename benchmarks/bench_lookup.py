"""Point-lookup tier benchmark (DESIGN.md §10): plan-cached fast path vs
the full engine on the same installed templates.

Closed-loop p50/p99 over a warm cache for three representative templates —
a green point lookup, a green single-hop neighbor read, and a yellow
single-hop with an edge predicate + accumulator (pays the single-chunk
column path) — each measured through ``session.lookup()`` (IDM probe + CSR
slice, no compile, no staged scan) and through ``session.query()`` (the
full lex -> parse -> compile -> staged-scan engine).

Every measured pair is asserted **bit-identical** first (vset, alias sets,
``n_edges_scanned``, accumulator arrays), and the green templates assert
the ISSUE 7 acceptance floor: fast-path p50 >= ``MIN_SPEEDUP`` x the full
engine's p50 on a warm cache.  Results snapshot into ``BENCH_lookup.json``
(override with ``REPRO_BENCH_LOOKUP_SNAPSHOT``).

``run(quick=True)`` is the CI gate mode — small scale, fewer calls.
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import emit, fresh_store, make_engine
from repro.data.ldbc import generate_ldbc, ldbc_graph_schema
from repro.gsql.session import GraphSession

SNAPSHOT_PATH = os.environ.get("REPRO_BENCH_LOOKUP_SNAPSHOT",
                               "BENCH_lookup.json")

# the acceptance floor: warm-cache p50 of a green lookup vs the full engine
MIN_SPEEDUP = 10.0

TEMPLATES = [
    ("point", "SELECT p FROM Person:p WHERE p.id == $pid", "green"),
    ("neighbors",
     "SELECT c FROM Person:p <-(HasCreator:e)- Comment:c WHERE p.id == $pid",
     "green"),
    ("filtered_count",
     "SELECT p FROM Person:p <-(HasCreator:e)- Comment:c "
     "WHERE p.id == $pid AND e.creationDate > $d ACCUM p.@n += 1",
     "yellow"),
]


def _setup(sf: float):
    store = fresh_store(f"lookup_{sf}")
    generate_ldbc(store, scale_factor=sf, n_files=3, row_group_rows=512)
    eng = make_engine(store, ldbc_graph_schema())
    eng.startup()
    session = GraphSession.for_engine(eng)
    for name, text, tier in TEMPLATES:
        iq = session.install(name, text)
        assert iq.route.tier == tier, (name, iq.route)
    return store, eng, session


def _params(session, name: str, pid: int) -> dict:
    return {"pid": pid, "d": 20100101} if name == "filtered_count" \
        else {"pid": pid}


def _assert_parity(fast, full, name: str) -> None:
    assert fast.route == "lookup" and full.route == "full", name
    np.testing.assert_array_equal(fast.vset.mask, full.vset.mask)
    assert fast.n_edges_scanned == full.n_edges_scanned, name
    assert set(fast.accumulators) == set(full.accumulators), name
    for k in fast.accumulators:
        np.testing.assert_array_equal(fast.accumulators[k],
                                      full.accumulators[k])
    assert set(fast.alias_sets) == set(full.alias_sets), name
    for k in fast.alias_sets:
        np.testing.assert_array_equal(fast.alias_sets[k].mask,
                                      full.alias_sets[k].mask)


def _percentiles(lats: list) -> tuple[float, float]:
    lats = sorted(lats)
    pick = lambda q: lats[min(len(lats) - 1, int(q * len(lats)))]
    return pick(0.50), pick(0.99)


def lookup_sweep(sf: float = 0.01, n_calls: int = 400,
                 n_parity: int = 8) -> dict:
    store, eng, session = _setup(sf)
    t0 = time.perf_counter()
    person_ids = eng.topology.idm.raw_ids("Person")
    pids = person_ids[np.linspace(0, len(person_ids) - 1,
                                  num=min(32, len(person_ids)),
                                  dtype=np.int64)]
    rows = []
    try:
        for name, _text, tier in TEMPLATES:
            # bit-parity first — a fast wrong answer is not a result
            for pid in pids[:n_parity]:
                p = _params(session, name, int(pid))
                _assert_parity(session.lookup(name, **p),
                               session.query(name, **p), name)
            # warm everything both paths touch (plan caches, CSR, columns)
            for pid in pids:
                p = _params(session, name, int(pid))
                session.lookup(name, **p)
                session.query(name, **p)
            lk, fl = [], []
            for i in range(n_calls):
                p = _params(session, name, int(pids[i % len(pids)]))
                t = time.perf_counter()
                session.lookup(name, **p)
                lk.append(time.perf_counter() - t)
                t = time.perf_counter()
                session.query(name, **p)
                fl.append(time.perf_counter() - t)
            lk50, lk99 = _percentiles(lk)
            fl50, fl99 = _percentiles(fl)
            speedup = fl50 / lk50
            rows.append({
                "template": name,
                "tier": tier,
                "lookup_p50_us": lk50 * 1e6,
                "lookup_p99_us": lk99 * 1e6,
                "full_p50_us": fl50 * 1e6,
                "full_p99_us": fl99 * 1e6,
                "speedup_p50": speedup,
                "n_calls": n_calls,
            })
            emit(f"lookup_{name}_{tier}", lk50 * 1e6,
                 f"full_p50={fl50 * 1e6:.1f}us speedup={speedup:.1f}x")
            if tier == "green":
                assert speedup >= MIN_SPEEDUP, (
                    f"{name}: warm-cache fast-path p50 speedup "
                    f"{speedup:.1f}x below the {MIN_SPEEDUP:.0f}x floor "
                    f"(lookup {lk50 * 1e6:.1f}us vs full {fl50 * 1e6:.1f}us)")
    finally:
        eng.close()
    return {"sf": sf, "min_speedup": MIN_SPEEDUP,
            "wall_s": time.perf_counter() - t0, "rows": rows}


def _write_snapshot(snap: dict) -> None:
    with open(SNAPSHOT_PATH, "w") as f:
        json.dump(snap, f, indent=2)
    emit("lookup_snapshot", 0.0, SNAPSHOT_PATH)


def run(sf: float = 0.01, quick: bool = False) -> None:
    snap = {}
    if quick:
        snap["lookup_sweep"] = lookup_sweep(sf=0.004, n_calls=150,
                                            n_parity=4)
    else:
        snap["lookup_sweep"] = lookup_sweep(sf=sf)
    _write_snapshot(snap)


if __name__ == "__main__":
    run()
